#!/usr/bin/env bash
# Host runtime preset for launching repro workers (source me, or use as a
# command prefix: `scripts/run_env.sh python my_worker.py ...`).
#
# Shell twin of repro.launch.runtime_env.runtime_env() -- the launcher
# applies the same preset programmatically via rank_env(); this script is
# for hand-launched real multi-host runs (one invocation per host):
#
#   REPRO_COORDINATOR=host0:1234 REPRO_NUM_PROCESSES=4 REPRO_PROCESS_ID=$I \
#     scripts/run_env.sh python my_worker.py
#
# Idiom per SNIPPETS §1-3 (HomebrewNLP/olmax run.sh, MaxText):
#   * tcmalloc LD_PRELOAD when the host ships it (glibc malloc fragments
#     the finalize stage's large transient buffers);
#   * silence its large-alloc reports (~60 GB threshold = never);
#   * quiet TF/XLA C++ worker logging.

for _lib in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
            /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
            /usr/lib/libtcmalloc.so.4 \
            /usr/lib/libtcmalloc_minimal.so.4; do
  if [ -e "$_lib" ]; then
    export LD_PRELOAD="${LD_PRELOAD:+$LD_PRELOAD:}$_lib"
    export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
    break
  fi
done
unset _lib

export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

# CPU emulation: REPRO_HOST_DEVICES=K adds the forced host device count
# (must be in XLA_FLAGS before the worker imports jax).
if [ -n "${REPRO_HOST_DEVICES:-}" ]; then
  export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=${REPRO_HOST_DEVICES}"
fi

# Prefix mode: exec the wrapped command under the preset.
if [ "$#" -gt 0 ]; then
  exec "$@"
fi
