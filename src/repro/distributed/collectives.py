"""MPI -> jax.lax collective analogues (paper Sec. IV phase mapping).

| paper                          | here                                   |
|--------------------------------|----------------------------------------|
| MPI_Allreduce(MIN/MAX) ratios  | lax.pmin / lax.pmax                    |
| MPI_Allreduce(SUM) histogram   | lax.psum                               |
| MPI_Scan block boundaries      | exclusive_scan (all_gather + masked    |
|                                | cumsum; static shortcut when shards    |
|                                | are even)                              |
| MPI_Send/Recv index alignment  | lax.ppermute fixed-width edge slices   |
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def allreduce_minmax(lo, hi, axis: str):
    return lax.pmin(lo, axis), lax.pmax(hi, axis)


def allreduce_sum(x, axis: str):
    return lax.psum(x, axis)


def exclusive_scan_sum(x, axis: str):
    """MPI_Exscan analogue: sum of `x` over lower-ranked shards.

    Implemented as all_gather + masked sum -- O(P) payload like a gather-
    based scan; P is the mesh axis size so this is tiny metadata traffic.
    """
    idx = lax.axis_index(axis)
    gathered = lax.all_gather(x, axis)          # (P, ...)
    ranks = jnp.arange(gathered.shape[0])
    mask = (ranks < idx).astype(gathered.dtype)
    return jnp.tensordot(mask, gathered, axes=1)


def axis_size(axis: str) -> int:
    """Mesh-axis size inside shard_map.

    `lax.axis_size` only exists in newer JAX; `psum(1, axis)` is the
    portable spelling and returns a static int under shard_map.
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def right_edge_exchange(x_head, axis: str, fill):
    """Every shard receives the *head* slice of its right neighbour.

    The paper's "index alignment": a block straddling a shard boundary is
    completed from the right neighbour's first elements.  The last shard
    receives `fill`.
    """
    n = axis_size(axis)
    perm = [(s, s - 1) for s in range(1, n)]
    recv = lax.ppermute(x_head, axis, perm)
    is_last = lax.axis_index(axis) == n - 1
    return jnp.where(is_last, fill, recv)


__all__ = ["allreduce_minmax", "allreduce_sum", "axis_size",
           "exclusive_scan_sum", "right_edge_exchange"]
