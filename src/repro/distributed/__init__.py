"""Distributed runtime: shard_map compression pipeline + collectives."""
