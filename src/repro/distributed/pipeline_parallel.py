"""GPipe-style pipeline parallelism over a mesh axis (library feature).

Stages live on consecutive devices of the `pipe` axis; microbatches flow
through a `lax.ppermute` ring.  Forward runs the classic GPipe schedule in
M + P - 1 ticks inside one shard_map; the backward schedule falls out of
reverse-mode AD through the same program (grad-of-ppermute is the opposite
permutation), so `jax.grad` of a pipelined loss is itself pipelined.

This is the PP building block (DESIGN.md Sec. 6); the assigned-arch
configs default to DP+TP+FSDP which covers every dry-run cell, so PP is
exercised by unit tests (tests/test_pipeline_parallel.py) rather than the
40-cell table.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, axis: str, stage_fn: Callable,
                   stage_params, microbatches):
    """Run `stage_fn` as a P-stage pipeline.

    stage_params: pytree with leading dim P (one slice per stage), sharded
                  over `axis`.
    microbatches: (M, mb, ...) array; every stage maps mb-sized activations
                  to same-shaped activations (homogeneous pipeline).
    Returns (M, mb, ...) outputs (as produced by the last stage).
    """
    n_stages = mesh.shape[axis]
    M = microbatches.shape[0]

    def shard_body(params_l, mb_l):
        # params_l: (1, ...) this stage's params; mb_l: (M, mb, ...) full
        # microbatch stream is replicated; only stage 0 consumes it.
        params_me = jax.tree.map(lambda a: a[0], params_l)
        stage = jax.lax.axis_index(axis)
        right = [(i, i + 1) for i in range(n_stages - 1)]

        mb_shape = mb_l.shape[1:]
        outputs = jnp.zeros((M,) + mb_shape, mb_l.dtype)
        carry = jnp.zeros(mb_shape, mb_l.dtype)

        def tick(t, state):
            outputs, carry = state
            # receive activations from the left neighbour
            recv = jax.lax.ppermute(carry, axis, right)
            x_in = jnp.where(stage == 0,
                             mb_l[jnp.clip(t, 0, M - 1)], recv)
            y = stage_fn(params_me, x_in)
            # my microbatch index at tick t is t - stage
            mb_idx = t - stage
            active = (mb_idx >= 0) & (mb_idx < M)
            carry = jnp.where(active, y, carry)
            is_last = stage == n_stages - 1
            outputs = jax.lax.cond(
                active & is_last,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.clip(mb_idx, 0, M - 1),)
                    + (0,) * len(mb_shape)),
                lambda o: o, outputs)
            return outputs, carry

        outputs, _ = jax.lax.fori_loop(0, M + n_stages - 1, tick,
                                       (outputs, carry))
        # every shard returns the same outputs tensor; only the last
        # stage's is non-zero -- sum-reduce to broadcast it.
        return jax.lax.psum(outputs, axis)[None]

    # Library entry point: callers jit pipeline_apply as a whole, so the
    # shard_map below traces inside the caller's cache entry.
    # repro-lint: disable=jit-cache-hygiene
    out = shard_map(
        shard_body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis), check_rep=False)(stage_params, microbatches)
    return out[0]


def stack_stages(layer_params_list):
    """[per-stage pytrees] -> stacked pytree with leading stage dim."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layer_params_list)


__all__ = ["pipeline_apply", "stack_stages"]
