"""Sharded NUMARCK compression pipeline (paper Sec. IV, shard_map version).

Phases and their parallelization, 1:1 with the paper:

  1. change-ratio calculation  -- local Pallas kernel; pmin/pmax for the
     global range (MPI_Allreduce analogue).
  2. bin construction (top-k)  -- local Pallas histogram; lax.psum merges
     (MPI_Allreduce); every shard runs the same top-k sort + Eq. (6) B scan
     (replicated "serial part", Table 3).
  3. indexing                  -- local rank-LUT lookup.
  4. index alignment           -- block boundaries are *static* under the
     even distribution both we and the paper assume; the straddling block is
     completed by a fixed-width lax.ppermute edge exchange (MPI_Send/Recv
     analogue, <= 1 block like the paper's <= 2 MB).
  5. bits packing              -- local Pallas kernel over owned blocks.
  6. entropy coding + write    -- host stage (not a TPU workload; the paper
     also runs it on the CPU cores).  Shared with the single-device driver:
     `core.pipeline.finalize_step` dispatches the pluggable codec
     (`core.entropy`) over a thread pool.

B must be static for bit-packing, so the pipeline is two jitted stages:
`analyze` (histogram -> auto-B) and `encode` (indices -> packed blocks).
Both stages are jit-cached per (shape, B) signature so a temporal series
traces once and replays, and with ``overlap=True`` the host finalize
(exceptions + entropy + assembly) of step i runs on a background thread
while the caller drives the device encode of step i+1 -- the sharded
version of the paper's Sec. IV-C compute/IO overlap (at 12800 ranks the
entropy+write stage is exactly where NUMARCK's wall-clock hides).

The temporal reference chain (REF_RECONSTRUCTED) is mesh-resident by
default: a third jit-cached shard_map stage reuses the `_decode_shard`
dequantize kernel plus an on-device exception patch from the current
step, so between-step state stays sharded on the devices instead of
round-tripping through host `reconstruct_from_indices` every step.
Byte-identical to the host chain (``chain="host"``) by construction --
reconstruction arithmetic runs in the source precision on both paths.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import Future
from functools import partial
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import binning, entropy, packing, ratios, select_b
from repro.core import chain as chainmod
from repro.core import pipeline as pipe
from repro.core.container import ShardNCKWriter, StepFragment
from repro.core.compress import decompress_step, device_entropy_route
from repro.core.overlap import FinalizeQueue
from repro.core.pipeline import DeviceEncoded
from repro.core.types import (CompressedStep, NumarckParams,
                              REF_RECONSTRUCTED)
from repro.distributed import collectives as coll
from repro.faults import inject
from repro.kernels import dequant
from repro.kernels import ops as kops
from repro.kernels import rans
from repro.obs import telemetry


def _pad_to(x: np.ndarray, total: int, value) -> np.ndarray:
    return np.pad(x, (0, total - x.size), constant_values=value)


def _put_sharded(arr: np.ndarray, sharding):
    """Host -> device upload honoring `sharding`, multi-process safe:
    under a multi-process mesh only this process's addressable shards
    materialize (make_array_from_callback); every process holds the same
    host array (SPMD input), so the global array is consistent without
    any cross-process transfer."""
    if jax.process_count() == 1:
        return jax.device_put(arr, sharding)
    arr = np.asarray(arr)
    return jax.make_array_from_callback(arr.shape, sharding,
                                        lambda idx: arr[idx])


def _analyze_shard(prev_l, curr_l, error_bound, *, max_bins, b_max,
                   elem_bytes, n_total, axis, use_pallas,
                   fixed_domain=False):
    """Per-shard phase 1+2: ratios, local histogram, global reduce, auto-B."""
    if fixed_domain:
        # SS Perf: skip the range pass entirely -- one fewer full read of
        # prev/curr and no phase-1 Allreduce (NumarckParams.fixed_domain)
        width = jnp.float32(2.0 * error_bound)
        domain_lo = -0.5 * width * max_bins
        lo = domain_lo
        hi = -domain_lo
    else:
        r, valid = ratios.change_ratios(prev_l, curr_l)
        lo_l = jnp.min(jnp.where(valid, r, jnp.inf))
        hi_l = jnp.max(jnp.where(valid, r, -jnp.inf))
        lo, hi = coll.allreduce_minmax(lo_l, hi_l, axis)  # MPI_Allreduce
        any_valid = coll.allreduce_sum(valid.sum(), axis) > 0
        lo = jnp.where(any_valid & jnp.isfinite(lo), lo, 0.0)
        hi = jnp.where(any_valid & jnp.isfinite(hi), hi, 0.0)
        domain_lo, width = ratios.histogram_domain(lo, hi, error_bound,
                                                   max_bins)
    _, bin_ids = kops.change_ratio_bins(prev_l, curr_l, domain_lo, width,
                                        max_bins=max_bins,
                                        use_pallas=use_pallas)
    hist_l = kops.histogram(bin_ids, max_bins=max_bins,
                            use_pallas=use_pallas)
    hist = coll.allreduce_sum(hist_l, axis)          # MPI_Allreduce(SUM)
    counts_desc, ids_desc = binning.sort_histogram(hist)
    b_auto, est_sizes = select_b.choose_b(counts_desc, n_total, elem_bytes,
                                          b_max)
    # Post-allreduce metadata is identical on every shard; replicated
    # (P()) out_specs make it host-fetchable on EVERY process of a
    # multi-process mesh (a P(axis) output's np.asarray would need a
    # cross-process gather, which jax rightly refuses).
    return (b_auto, ids_desc, counts_desc, domain_lo, width, est_sizes)


def _encode_shard(prev_l, curr_l, ids_desc, domain_lo, width, *, b_bits,
                  k_eff, max_bins, block_elems, ln, n_total, axis,
                  use_pallas):
    """Per-shard phase 3-5: index, align (ppermute), pack (Pallas)."""
    marker = (1 << b_bits) - 1
    _, bin_ids = kops.change_ratio_bins(prev_l, curr_l, domain_lo,
                                        width, max_bins=max_bins,
                                        use_pallas=use_pallas)
    lut = binning.rank_lut(ids_desc[:k_eff], k_eff, max_bins)
    ranks = lut[jnp.clip(bin_ids, 0, max_bins - 1)]
    ranks = jnp.where(ranks >= k_eff, marker, ranks)
    idx = jnp.where(bin_ids >= 0, ranks, marker).astype(jnp.int32)

    # --- index alignment (paper Sec. IV-C) -------------------------------
    be = block_elems
    edge = coll.right_edge_exchange(idx[:be], axis,
                                    jnp.full((be,), marker, jnp.int32))
    ext = jnp.concatenate([idx, edge])               # (ln + be,)

    # int32 element offsets: fine for n < 2^31 (8.6 GB f32 per variable);
    # production runs on real multi-host fleets enable jax_enable_x64.
    s = jax.lax.axis_index(axis).astype(jnp.int32)
    my_lo = s * jnp.int32(ln)
    first_blk = (my_lo + be - 1) // be               # ceil
    nbmax = -(-ln // be)                             # blocks I may own

    packed_rows = []
    valids = []
    for j in range(nbmax):                            # static unroll
        gstart = (first_blk + j) * be
        lstart = (gstart - my_lo).astype(jnp.int32)
        in_range = (gstart < my_lo + ln) & (gstart < n_total)
        lstart = jnp.clip(lstart, 0, ln - 1)
        blk = jax.lax.dynamic_slice(ext, (lstart,), (be,))
        words = kops.pack_bits(blk, b_bits=b_bits, use_pallas=use_pallas)
        packed_rows.append(words)
        valids.append(in_range)
    packed = jnp.stack(packed_rows)                  # (nbmax, wpb)
    valid = jnp.stack(valids)                        # (nbmax,)
    return idx[None], packed[None], valid[None]


class ShardedCompressor:
    """Distributed NUMARCK over one mesh axis (or a flattened mesh).

    ``overlap=True`` double-buffers the device/host split across temporal
    steps: the host finalize (exceptions + entropy + blob assembly) of
    step i runs on a background thread while the caller's next
    ``compress_async``/``add_async`` drives the device analyze/encode of
    step i+1.  At most two finalizes are in flight (one executing + one
    queued), inputs are snapshotted before handing them to the background
    thread, and the blobs are byte-identical to ``overlap=False`` -- both
    modes run the exact same shared finalize.

    ``chain`` picks the temporal reference chain residency: "auto"
    (default) keeps between-step state sharded and device-resident on the
    mesh whenever the dtype allows (f32, or f64 under jax_enable_x64),
    advancing it with the `_advance_shard` stage; "host" restores the
    original host `reconstruct_from_indices` round-trip.  Blobs are
    byte-identical across residencies and overlap modes.
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 params: NumarckParams = NumarckParams(),
                 use_pallas: bool = True, overlap: bool = False,
                 chain: str = chainmod.CHAIN_AUTO):
        if chain not in chainmod.RESIDENCIES:
            raise ValueError(f"unknown chain residency {chain!r}")
        self.mesh = mesh
        self.axis = axis
        self.params = params
        self.use_pallas = use_pallas
        self.overlap = overlap
        self.chain = chain
        self.n_shards = mesh.shape[axis]
        self._q = FinalizeQueue(overlap, name="shard-finalize")
        self._chain: Optional[chainmod.ReferenceChain] = None
        self._step = 0
        # jit caches: a temporal series traces each stage once per
        # (shape, B) signature instead of once per step -- without this the
        # per-step shard_map retrace dominates the sharded hot path.
        self._analyze_fns: Dict[Tuple, object] = {}
        self._encode_fns: Dict[Tuple, object] = {}
        self._advance_fns: Dict[Tuple, object] = {}
        self._entropy_fns: Dict[Tuple, object] = {}

    def _shardings(self):
        return (NamedSharding(self.mesh, P(self.axis)),
                NamedSharding(self.mesh, P()))

    def _analyze_fn(self, ebytes: int, n: int):
        key = (ebytes, n)
        if key not in self._analyze_fns:
            p = self.params
            fn = shard_map(
                partial(_analyze_shard, max_bins=p.max_bins, b_max=p.b_max,
                        elem_bytes=ebytes, n_total=n, axis=self.axis,
                        use_pallas=self.use_pallas,
                        fixed_domain=p.fixed_domain),
                mesh=self.mesh,
                in_specs=(P(self.axis), P(self.axis), P()),
                out_specs=(P(),) * 6, check_rep=False)
            self._analyze_fns[key] = jax.jit(fn)
        return self._analyze_fns[key]

    def _encode_fn(self, bb: int, k_eff: int, be: int, ln: int, n: int):
        key = (bb, k_eff, be, ln, n)
        if key not in self._encode_fns:
            p = self.params
            fn = shard_map(
                partial(_encode_shard, b_bits=bb, k_eff=k_eff,
                        max_bins=p.max_bins, block_elems=be, ln=ln,
                        n_total=n, axis=self.axis,
                        use_pallas=self.use_pallas),
                mesh=self.mesh,
                in_specs=(P(self.axis), P(self.axis), P(), P(), P()),
                out_specs=(P(self.axis),) * 3, check_rep=False)
            self._encode_fns[key] = jax.jit(fn)
        return self._encode_fns[key]

    def _entropy_fn(self, nbmax: int, wpb: int, L: int):
        """Device entropy stage (jit-cached shard_map): every shard rANS-
        codes its own packed blocks, so index blocks never leave the mesh
        before they are entropy-coded -- only the dense emission buffers
        and 4-byte lane states cross to host for blob assembly."""
        key = (nbmax, wpb, L)
        if key not in self._entropy_fns:
            fn = shard_map(
                partial(_entropy_shard, L=L),
                mesh=self.mesh,
                in_specs=(P(self.axis), P(self.axis)),
                out_specs=(P(self.axis),) * 3, check_rep=False)
            self._entropy_fns[key] = jax.jit(fn)
        return self._entropy_fns[key]

    def _entropy_stage(self, packed, valid: np.ndarray, nblocks: int,
                       nbytes: int) -> List[bytes]:
        """Run the device entropy stage over the mesh-resident packed
        blocks and assemble one self-describing blob per (valid) block in
        global order.  Byte-identical to the single-device device stage
        and to the host ``rans.compress`` of the same packed bytes."""
        P_, nbmax, wpb = packed.shape
        rows_dev = packed.reshape(P_ * nbmax, wpb)
        stride = rans.sample_stride(nbytes)
        samples = np.asarray(rans.sample_words(rows_dev, stride))
        rows_idx = np.flatnonzero(valid)
        assert rows_idx.size == nblocks, (rows_idx.size, nblocks)
        freqs, fcs = rans.tables_from_samples(samples[rows_idx])
        L = rans.lanes_for(nbytes)
        # Invalid (out-of-range) rows get a placeholder table; their
        # lanes are encoded and discarded.
        fc_full = np.tile(rans.pack_fc(
            rans.freq_from_counts(np.zeros(256, np.uint64))),
            (P_ * nbmax, 1))
        fc_full[rows_idx] = fcs
        sharded, _ = self._shardings()
        fc_dev = jax.device_put(fc_full.reshape(P_, nbmax, 256), sharded)
        states, vals, masks = self._entropy_fn(nbmax, wpb, L)(packed,
                                                              fc_dev)
        states = np.asarray(states).reshape(P_ * nbmax, L)
        vals = np.asarray(vals).reshape(P_ * nbmax, -1)
        masks = np.asarray(masks).reshape(P_ * nbmax, -1)
        blobs = []
        for g, r in enumerate(rows_idx):
            def raw_bytes(r=r):
                return (np.asarray(rows_dev[r]).astype("<u4")
                        .tobytes()[:nbytes])

            blobs.append(rans.assemble_blob(nbytes, freqs[g], states[r],
                                            vals[r][masks[r]],
                                            raw_bytes=raw_bytes))
        return blobs

    def _advance_fn(self, bb: int):
        """Chain-advance stage: `_decode_shard` dequantize + on-device
        exception patch from `curr` (jit-cached per B; input shapes key
        the jit cache underneath)."""
        key = (bb,)
        if key not in self._advance_fns:
            fn = shard_map(
                partial(_advance_shard, b_bits=bb,
                        use_pallas=self.use_pallas),
                mesh=self.mesh,
                in_specs=(P(self.axis), P(self.axis), P(self.axis), P()),
                out_specs=P(self.axis), check_rep=False)
            self._advance_fns[key] = jax.jit(fn)
        return self._advance_fns[key]

    # -------------------------------------------------------- device stage
    def _device_encode(self, prev, curr: np.ndarray,
                       b_bits: Optional[int] = None) -> DeviceEncoded:
        """Phases 1-5 on device; returns the pre-entropy encode result
        (host numpy) that both the finalize stage and the reconstructed-
        reference chain consume.

        `prev` is either a host array (padded + device_put here) or the
        mesh-resident chain state: an already padded, sharded f32
        jax.Array of shape (n_shards * ln,), fed straight back in."""
        p = self.params
        curr_f = np.asarray(curr, np.float32).reshape(-1)
        n = curr_f.size
        if n >= (1 << 31):
            raise ValueError("per-variable n >= 2^31 needs jax_enable_x64 "
                             "(see pipeline offset note)")
        P_ = self.n_shards
        ln = -(-n // P_)
        sharded, _ = self._shardings()
        # Pad so every shard holds ln elements; pads are invalid (prev=0).
        if isinstance(prev, jax.Array):
            if prev.shape != (P_ * ln,):
                raise ValueError(
                    f"device-resident chain state {prev.shape} does not "
                    f"match this step's padded layout ({P_ * ln},); "
                    "reset() the compressor before changing shapes")
            prev_dev = prev
        else:
            prev_f = np.asarray(prev, np.float32).reshape(-1)
            prev_dev = _put_sharded(_pad_to(prev_f, P_ * ln, 0.0), sharded)
        curr_dev = _put_sharded(_pad_to(curr_f, P_ * ln, 0.0), sharded)
        ebytes = np.dtype(np.asarray(curr).dtype).itemsize

        analyze = self._analyze_fn(ebytes, n)
        # The b_auto fetch is a device sync point: the analyze span covers
        # dispatch + the wait, so it reads as real stage time.
        with telemetry.span("encode.analyze", annotate=True,
                            n=n) as sp_an:
            (b_auto, ids_desc, counts_desc, domain_lo, width,
             est_sizes) = analyze(prev_dev, curr_dev,
                                  jnp.float32(p.error_bound))
            # Replicated out specs: every process holds the full value.
            b_auto = int(np.asarray(b_auto))
        bb = int(b_bits if b_bits is not None
                 else (p.b_bits if p.b_bits is not None else b_auto))
        k_eff = min((1 << bb) - 1, p.max_bins)
        be = p.block_elems(bb)
        if be > ln:
            be = max(32, ln // 32 * 32) if ln >= 32 else 32
            if be > ln:
                raise ValueError(
                    f"shard length {ln} smaller than minimum block (32); "
                    "use fewer shards or larger inputs")

        encode = self._encode_fn(bb, k_eff, be, ln, n)
        with telemetry.span("encode.index", annotate=True,
                            b_bits=bb) as sp_idx:
            idx_dev, packed, valid = encode(prev_dev, curr_dev,
                                            ids_desc, domain_lo, width)
            if telemetry.enabled():
                jax.block_until_ready((idx_dev, packed, valid))

        marker = (1 << bb) - 1
        with telemetry.span("encode.exceptions") as sp_exc:
            exc_counts, exc_pos = kops.exception_compact(
                idx_dev.reshape(-1), n, marker, be)
            valid_np = np.asarray(valid).reshape(-1)
        nblocks = -(-n // be)
        nbytes_block = be * bb // 8
        raws = coded = coded_name = None
        sp_pack_s = 0.0
        with telemetry.span("encode.device_entropy", annotate=True) as sp_de:
            if device_entropy_route(p, n, bb):
                # Entropy-code on the mesh; only emission buffers cross to
                # host.  The packed words never leave the devices un-coded.
                coded = self._entropy_stage(packed, valid_np, nblocks,
                                            nbytes_block)
                coded_name = p.codec
        if coded is None:
            with telemetry.span("encode.pack_fetch") as sp_pack:
                packed_h = np.asarray(packed)
                # Valid blocks in global order (shards own contiguous
                # ranges).
                packed_h = packed_h.reshape(-1, packed_h.shape[-1])
                rows = packed_h[valid_np]    # (nblocks, words_per_block)
                assert rows.shape[0] == nblocks, (rows.shape, nblocks)
                raws = [r.astype("<u4").tobytes()[:nbytes_block]
                        for r in rows]
            sp_pack_s = sp_pack.duration

        # Host copy of the index table (blocks until the device work of
        # THIS step is done; the previous step's finalize may still be
        # running behind us).  With device entropy + device exceptions the
        # finalize never reads it, so only a host-resident reference chain
        # still needs the fetch; idx_dev stays on the mesh for the
        # chain-advance stage either way.
        need_host_idx = coded is None or (
            self._chain is not None
            and self._chain.residency == chainmod.CHAIN_HOST)
        with telemetry.span("encode.idx_fetch") as sp_fetch:
            idx = (np.asarray(idx_dev).reshape(-1)[:n] if need_host_idx
                   else None)

        enc = pipe.EncodedIndices(idx=idx, b_bits=bb, block_elems=be,
                                  n=n, packed=raws, entropy_coded=coded,
                                  entropy_codec=coded_name,
                                  exc_positions=exc_pos,
                                  exc_block_counts=exc_counts)
        domain_lo = float(np.asarray(domain_lo))
        width = float(np.asarray(width))
        centers = pipe.topk_centers(np.asarray(ids_desc), k_eff,
                                    domain_lo, width)
        centers = pipe.round_centers(centers, np.asarray(curr).dtype)
        meta = {"b_auto": b_auto,
                "est_sizes": np.asarray(est_sizes).tolist(),
                "n_shards": self.n_shards, "pipeline": "sharded"}
        if telemetry.enabled():
            # Same driver-timing keys as the single-device encode_device;
            # finalize_step folds them into the canonical per-step record.
            meta["telemetry"] = {
                "analyze_s": sp_an.duration,
                "encode_s": (sp_idx.duration + sp_exc.duration + sp_pack_s
                             + sp_fetch.duration),
                "device_entropy_s": sp_de.duration,
            }
        return DeviceEncoded(enc=enc, centers=centers, domain_lo=domain_lo,
                             width=width, meta=meta,
                             idx_dev=idx_dev, curr_dev=curr_dev)

    # --------------------------------------------------------- host stage
    def compress_async(self, prev: np.ndarray, curr: np.ndarray,
                       b_bits: Optional[int] = None
                       ) -> "Future[CompressedStep]":
        """Device-encode now; return a future of the finalized step
        (finalize runs on the background thread when overlap=True, with at
        most two in flight).

        `curr` is snapshotted before the background finalize reads it
        (exception values), so callers may reuse their buffers.
        """
        dev = self._device_encode(prev, curr, b_bits)
        step_i, self._step = self._step, self._step + 1
        curr_s = (np.array(curr, copy=True) if self.overlap
                  else np.asarray(curr))
        return self._q.submit(pipe.finalize_step, curr_s, dev.enc,
                              dev.centers, dev.domain_lo, dev.width,
                              self.params, dev.meta,
                              label=f"finalize step {step_i}")

    def compress(self, prev: np.ndarray, curr: np.ndarray,
                 b_bits: Optional[int] = None) -> CompressedStep:
        return self.compress_async(prev, curr, b_bits).result()

    def _make_chain(self, dtype) -> chainmod.ReferenceChain:
        if (chainmod.resolve_residency(self.chain, dtype)
                == chainmod.CHAIN_DEVICE):
            return _ShardedDeviceChain(self)
        return chainmod.HostReferenceChain()

    # ------------------------------------------------- temporal streaming
    def add_async(self, arr: np.ndarray) -> "Future[CompressedStep]":
        """Streaming interface over a temporal series (first call stores a
        lossless anchor).  The reference chain advances from the
        pre-entropy encode result before returning, so the next step's
        device work never waits on this step's entropy stage; with the
        default device-resident chain the state also never leaves the
        mesh."""
        arr = np.asarray(arr)
        step_i, self._step = self._step, self._step + 1
        if self._chain is None or self._chain.empty:
            self._chain = self._make_chain(arr.dtype)
            self._chain.seed(arr)
            return self._q.submit(pipe.finalize_anchor, arr.copy(),
                                  self.params,
                                  label=f"anchor step {step_i}")
        dev = self._device_encode(self._chain.peek(), arr)
        if self.params.reference == REF_RECONSTRUCTED:
            self._chain.advance(dev, arr)
        else:
            self._chain.replace(arr)
        curr_s = np.array(arr, copy=True) if self.overlap else arr
        return self._q.submit(pipe.finalize_step, curr_s, dev.enc,
                              dev.centers, dev.domain_lo, dev.width,
                              self.params, dev.meta,
                              label=f"finalize step {step_i}")

    def add(self, arr: np.ndarray) -> CompressedStep:
        return self.add_async(arr).result()

    def compress_series(self, arrays) -> List[CompressedStep]:
        """Compress a temporal series; double-buffered when overlap=True."""
        self.reset()
        out: List[CompressedStep] = []
        futs: Deque[Future] = deque()
        for a in arrays:
            futs.append(self.add_async(a))
            while len(futs) > 2:
                out.append(futs.popleft().result())
        out.extend(f.result() for f in futs)
        return out

    def flush(self):
        """Block until every in-flight finalize has completed (re-raises
        the first background exception, if any)."""
        self._q.flush()

    def close(self):
        self._q.close()

    def reference_state(self) -> Optional[np.ndarray]:
        """Host copy of the current chain state (None before the anchor);
        the one explicit boundary where the mesh-resident chain crosses
        to host."""
        if self._chain is None or self._chain.empty:
            return None
        return self._chain.to_host()

    def reset(self):
        """Drop the temporal chain state (next add() writes an anchor)."""
        self._chain = None
        self._step = 0


def _entropy_shard(words_l, fc_l, *, L):
    """Per-shard device entropy: rANS-scan the shard's packed blocks
    (kernels.rans.encode_bytes_body) with their per-block fused tables.
    Returns (states, per-block emission buffers, masks); the host only
    compacts each block's contiguous buffer into its blob."""
    st, vals, masks = rans.encode_bytes_body(
        rans.words_to_bytes(words_l[0]), fc_l[0], L)
    return st[None], vals[None], masks[None]


def _decode_shard(idx_l, prev_l, centers, *, b_bits, use_pallas):
    """Per-shard fused dequantize (Pallas one-hot-MXU gather kernel)."""
    out = kops.dequantize(idx_l, prev_l, centers[0], b_bits=b_bits,
                          use_pallas=use_pallas)
    return out[None]


def _rans_decode_shard_packed(dec_l, states_l, stream_l, *, m, L, b_bits,
                              be):
    """Per-shard device entropy decode of v1 (byte-rANS) blocks: the
    forward L-lane scan (kernels.rans.decode_scan_body) fused with the
    word unpack, symmetric to `_entropy_shard`.  Dummy (padding) rows
    decode to garbage that the caller drops; stream-integrity validation
    happens on host over the real rows only."""
    syms, xf, ptrf = rans.decode_scan_body(dec_l[0], None, states_l[0],
                                           stream_l[0], m, L)
    nbytes = be * b_bits // 8
    idx = rans.unpack_words(rans.bytes_to_words(syms[:, :nbytes]),
                            b_bits, be)
    return idx[None], xf[None], ptrf[None]


def _rans_decode_shard_syms(dec_l, states_l, stream_l, *, m, L, n_sym,
                            b_bits, be):
    """Per-shard device entropy decode of v2 (symbol-rANS) blocks with a
    dense alphabet <= 256 (symbol fused into the decode table)."""
    syms, xf, ptrf = rans.decode_scan_body(dec_l[0], None, states_l[0],
                                           stream_l[0], m, L)
    syms = syms[:, :be].astype(jnp.int32)
    marker = jnp.int32((1 << b_bits) - 1)
    idx = jnp.where(syms >= jnp.int32(n_sym - 1), marker, syms)
    return idx[None], xf[None], ptrf[None]


def _rans_decode_shard_syms_wide(dec_l, sym_l, states_l, stream_l, *, m, L,
                                 n_sym, b_bits, be):
    """Wide-alphabet (> 256 symbols) flavor of `_rans_decode_shard_syms`:
    symbols come from a second slot->symbol table gather."""
    syms, xf, ptrf = rans.decode_scan_body(dec_l[0], sym_l[0], states_l[0],
                                           stream_l[0], m, L)
    syms = syms[:, :be].astype(jnp.int32)
    marker = jnp.int32((1 << b_bits) - 1)
    idx = jnp.where(syms >= jnp.int32(n_sym - 1), marker, syms)
    return idx[None], xf[None], ptrf[None]


def _advance_shard(idx_l, prev_l, curr_l, centers, *, b_bits, use_pallas):
    """Temporal chain advance on the mesh: the same dequantize kernel as
    `_decode_shard` composed with the on-device exception patch from the
    current step (one shared body, ``kops.chain_advance_core``), so
    between-step chain state never leaves the devices."""
    return kops.chain_advance_core(idx_l, prev_l, curr_l, centers[0],
                                   b_bits=b_bits, use_pallas=use_pallas)


class _ShardedDeviceChain(chainmod.ReferenceChain):
    """Mesh-resident reference chain: state is the padded, sharded f32
    (or f64 under x64) array the encode stages consume directly, advanced
    by the driver's jit-cached `_advance_shard` stage."""

    residency = chainmod.CHAIN_DEVICE

    def __init__(self, driver: "ShardedCompressor"):
        super().__init__()
        self._d = driver
        self._n = 0
        self._shape: Optional[tuple] = None
        self._dtype = None

    def _pad_put(self, arr: np.ndarray):
        d = self._d
        flat = np.asarray(arr, pipe.reconstruction_dtype(arr.dtype)
                          ).reshape(-1)
        ln = -(-flat.size // d.n_shards)
        sharded, _ = d._shardings()
        return _put_sharded(_pad_to(flat, d.n_shards * ln, 0.0), sharded)

    def seed(self, arr) -> None:
        arr = np.asarray(arr)
        if not chainmod.device_supports(arr.dtype):
            raise ValueError(
                f"mesh-resident chain cannot hold {arr.dtype} bit-exactly "
                "(float64 needs jax_enable_x64)")
        self._n, self._shape, self._dtype = arr.size, arr.shape, arr.dtype
        self._state = self._pad_put(arr)

    def replace(self, arr) -> None:
        self.seed(arr)

    def advance(self, dev: DeviceEncoded, curr) -> None:
        bb = dev.enc.b_bits
        # Exact cast: centers are a f64 view of dtype-rounded values.
        # Host numpy (not a committed local jax.Array): jit replicates it
        # per the P() in_spec, which stays valid under multi-process
        # meshes where a single-device-committed array would not.
        centers = np.asarray(dev.centers).astype(self._state.dtype)[None]
        # dev.curr_dev is the encode stages' f32 copy; a float64 chain
        # (x64) must patch exceptions from the source-precision values.
        curr_dev = (dev.curr_dev if self._state.dtype == jnp.float32
                    else self._pad_put(np.asarray(curr)))
        fn = self._d._advance_fn(bb)
        self._state = fn(dev.idx_dev.reshape(-1), self._state,
                         curr_dev, centers)

    def to_host(self) -> np.ndarray:
        return (np.asarray(self._state)[: self._n]
                .astype(self._dtype).reshape(self._shape))


class ShardedDecompressor:
    """Distributed reconstruction, mirror image of the sharded encode.

    Steps that qualify for the device decode route
    (``core.compress.device_decode_route`` with uniform-format rans
    blocks) entropy-decode **on the mesh**: a jit-cached shard_map stage
    symmetric to `_entropy_shard` runs the forward rANS scan over each
    shard's blocks, feeding the (also jit-cached) fused dequantize stage
    and the on-device exception patch -- blob to reconstruction with one
    final host fetch.  Everything else inflates on host (block-parallel
    over the shared entropy pool) and uploads; both routes and the
    single-device driver are bit-identical.

    Reconstruction preserves the source dtype: float32 runs the f32
    kernel, float64 runs the dtype-preserving gather path under
    jax_enable_x64 and falls back to the (bit-identical) host
    `decompress_step` when x64 is off -- it never silently truncates f64
    data through an f32 kernel."""

    def __init__(self, mesh: Mesh, axis: str = "data",
                 use_pallas: bool = True):
        self.mesh = mesh
        self.axis = axis
        self.use_pallas = use_pallas
        self.n_shards = mesh.shape[axis]
        # jit caches (same discipline as ShardedCompressor): one traced
        # executable per static signature across a temporal series.
        self._dequant_fns: Dict[Tuple, object] = {}
        self._rans_fns: Dict[Tuple, object] = {}

    def _shardings(self):
        return (NamedSharding(self.mesh, P(self.axis)),
                NamedSharding(self.mesh, P()))

    def _dequant_fn(self, bb: int):
        key = (bb,)
        if key not in self._dequant_fns:
            fn = shard_map(
                partial(_decode_shard, b_bits=bb,
                        use_pallas=self.use_pallas),
                mesh=self.mesh,
                in_specs=(P(self.axis), P(self.axis), P()),
                out_specs=P(self.axis), check_rep=False)
            self._dequant_fns[key] = jax.jit(fn)
        return self._dequant_fns[key]

    def _rans_fn(self, kind: str, **static):
        key = (kind, tuple(sorted(static.items())))
        if key not in self._rans_fns:
            body = {"v1": _rans_decode_shard_packed,
                    "v2": _rans_decode_shard_syms,
                    "v2w": _rans_decode_shard_syms_wide}[kind]
            n_in = 4 if kind == "v2w" else 3
            fn = shard_map(partial(body, **static), mesh=self.mesh,
                           in_specs=(P(self.axis),) * n_in,
                           out_specs=(P(self.axis),) * 3, check_rep=False)
            self._rans_fns[key] = jax.jit(fn)
        return self._rans_fns[key]

    def _parse_uniform(self, step: CompressedStep):
        """Parse a device-codec step's rans blobs for the mesh decode
        stage.  Returns (signature, records) when every block shares one
        blob version / lane count / alphabet (uniform rows are what the
        shard_map stage needs); None sends the step down the
        single-device device route instead (still bit-identical)."""
        sig = None
        recs = []
        nbytes = step.block_elems * step.b_bits // 8
        for blob in step.index_blocks:
            v = rans.blob_version(blob)
            if v == 1:
                nb_, L, freq, states, stream = rans._parse_v1(blob)
                if nb_ != nbytes:
                    return None
                k = (1, L, 256)
            elif v == 2:
                ne, bb, L, freq, states, stream = rans._parse_v2(blob)
                if bb != step.b_bits or ne != step.block_elems:
                    return None
                k = (2, L, freq.size)
            else:
                return None
            if sig is None:
                sig = k
            elif k != sig:
                return None
            recs.append({"freq": freq, "states": states, "stream": stream})
        return sig, recs

    def _rans_decode_stage(self, step: CompressedStep, parsed):
        """Mesh-resident entropy decode: blobs -> sharded (P, nbmax, be)
        int32 indices.  Blocks pad to P * nbmax rows with dummy rows
        (reused tables, lane states at STATE_LO, empty streams) whose
        output is garbage past position n and is never read; validation
        covers the real rows, matching ``decode_np`` semantics."""
        (version, L, n_sym), recs = parsed
        P_ = self.n_shards
        be = step.block_elems
        nblocks = len(recs)
        nbmax = -(-nblocks // P_)
        rows = P_ * nbmax
        m = -(-(be * step.b_bits // 8 if version == 1 else be) // L)
        smax = max(1, max(r["stream"].size for r in recs))
        states = np.full((rows, L), rans.STATE_LO, np.uint32)
        stream = np.zeros((rows, smax), np.uint16)
        dec = np.empty((rows, rans.M), np.uint32)
        sym = None
        cache: Dict[bytes, tuple] = {}
        for i, r in enumerate(recs):
            key = r["freq"].tobytes()
            if key not in cache:
                cache[key] = rans._decode_tables(r["freq"])
            d, s2 = cache[key]
            dec[i] = d
            states[i] = r["states"]
            stream[i, :r["stream"].size] = r["stream"]
            if s2 is not None:
                if sym is None:
                    sym = np.empty((rows, rans.M), np.int32)
                sym[i] = s2
        if rows > nblocks:                    # dummy rows: any valid table
            dec[nblocks:] = dec[0]
            if sym is not None:
                sym[nblocks:] = sym[0]
        sharded, _ = self._shardings()
        dec_dev = jax.device_put(dec.reshape(P_, nbmax, rans.M), sharded)
        st_dev = jax.device_put(states.reshape(P_, nbmax, L), sharded)
        sm_dev = jax.device_put(stream.reshape(P_, nbmax, smax), sharded)
        if version == 1:
            fn = self._rans_fn("v1", m=m, L=L, b_bits=step.b_bits, be=be)
            idx, xf, ptrf = fn(dec_dev, st_dev, sm_dev)
        elif sym is None:
            fn = self._rans_fn("v2", m=m, L=L, n_sym=n_sym,
                               b_bits=step.b_bits, be=be)
            idx, xf, ptrf = fn(dec_dev, st_dev, sm_dev)
        else:
            sym_dev = jax.device_put(sym.reshape(P_, nbmax, rans.M),
                                     sharded)
            fn = self._rans_fn("v2w", m=m, L=L, n_sym=n_sym,
                               b_bits=step.b_bits, be=be)
            idx, xf, ptrf = fn(dec_dev, sym_dev, st_dev, sm_dev)
        n_emit = np.array([r["stream"].size for r in recs], np.int64)
        rans._check_decoded(np.asarray(xf).reshape(rows, L)[:nblocks],
                            np.asarray(ptrf).reshape(rows)[:nblocks],
                            n_emit)
        return idx

    def decompress(self, step: CompressedStep,
                   prev: np.ndarray) -> np.ndarray:
        from repro.core import compress as comp
        cdt = pipe.reconstruction_dtype(step.dtype)
        if cdt == np.float64 and not jax.config.jax_enable_x64:
            return decompress_step(step, prev)
        tele = telemetry.enabled()
        n = step.n
        marker = (1 << step.b_bits) - 1
        P_ = self.n_shards
        parsed = None
        if comp.device_decode_route(step):
            parsed = self._parse_uniform(step)
            if parsed is None:
                # Mixed blob formats (e.g. a marker-heavy ragged tail
                # that stored raw): the single-device device route
                # handles heterogeneous groups -- still device-resident
                # and bit-identical, just not mesh-sharded.
                return decompress_step(step, prev)
        with telemetry.span("decode.entropy", annotate=True) as sp_e:
            if parsed is not None:
                # Mesh-resident entropy decode: blocks distribute
                # contiguously over shards, so the flattened output IS
                # the global element order (dummy-row garbage past n).
                idx_dev = self._rans_decode_stage(step, parsed)
                ln = idx_dev.shape[1] * step.block_elems
                idx_dev = idx_dev.reshape(-1)
            else:
                # host: inflate + unpack (block-parallel over the shared
                # entropy pool), one upload.
                idx = comp._decode_index_host(step)
                ln = -(-n // P_)
                sharded, _ = self._shardings()
                idx_dev = jax.device_put(
                    _pad_to(idx.astype(np.int32), P_ * ln, marker),
                    sharded)
            if tele:
                jax.block_until_ready(idx_dev)
        with telemetry.span("decode.dequant", annotate=True) as sp_d:
            sharded, rep = self._shardings()
            prev_p = _pad_to(np.asarray(prev, cdt).reshape(-1), P_ * ln,
                             0.0)
            centers = step.centers.astype(cdt)[None]
            out = self._dequant_fn(step.b_bits)(
                idx_dev, jax.device_put(prev_p, sharded),
                jax.device_put(centers, rep)).reshape(-1)
            if tele:
                jax.block_until_ready(out)
        with telemetry.span("decode.patch", annotate=True) as sp_p:
            # device: scatter the exception table over the marker lanes
            # (the padded tail may also read as marker, but real markers
            # all precede it in stream order, so the table lands exactly
            # on the first n lanes).
            if step.n_incompressible:
                out = dequant.patch_exceptions(
                    out, idx_dev,
                    jnp.asarray(step.incomp_values.astype(cdt)),
                    b_bits=step.b_bits)
            if tele:
                jax.block_until_ready(out)
        with telemetry.span("decode.fetch", annotate=True) as sp_f:
            res = np.asarray(out)[:n].astype(step.dtype
                                             ).reshape(step.shape)
        if tele:
            comp._record_read(step, entropy_s=sp_e.duration,
                              dequant_s=sp_d.duration,
                              patch_s=sp_p.duration, fetch_s=sp_f.duration,
                              device=parsed is not None)
        return res


def _addressable_rows(arr) -> Tuple[int, np.ndarray]:
    """This process's contiguous rows of an axis-0-sharded array: (global
    row start, stacked host copy).  Only addressable shards are fetched,
    so no payload bytes ever cross processes -- a non-addressable fetch
    is structurally impossible here (jax raises on it)."""
    shards = sorted(arr.addressable_shards,
                    key=lambda s: s.index[0].start or 0)
    datas = [np.asarray(s.data) for s in shards]
    starts = [s.index[0].start or 0 for s in shards]
    for i in range(len(starts) - 1):
        if starts[i] + datas[i].shape[0] != starts[i + 1]:
            raise ValueError("addressable shards of one process must be "
                             "contiguous on the mesh axis")
    return int(starts[0]), np.concatenate(datas, axis=0)


class MultiProcessCompressor(ShardedCompressor):
    """Multi-process NUMARCK: the shard_map stages run unchanged over the
    global (cross-process) mesh; each process then writes ONLY its own
    blocks (paper Sec. IV-D collective write analogue).

    Differences from the single-process `ShardedCompressor` path:

      * the packed index blocks are fetched per-process from the
        *addressable* shards only -- payload bytes never cross hosts;
      * exceptions are recovered per-rank by unpacking the rank's own
        packed blocks (the device exception compaction would be a global
        fetch) and gathering values from the host-resident input;
      * the entropy stage runs on each host over its own blocks;
      * output is a `StepFragment` per step per rank, published as a
        ``<path>.g<gen>.rank<k>`` NCK shard file plus a rank-0 NCKM
        manifest (`save_series`).

    Blobs are byte-identical to the single-process driver for every
    concrete codec; ``codec="auto"`` may legitimately pick different
    per-block codecs (its lzma budget is a *global* payload bound the
    ranks cannot see) and is therefore only split-identical, not
    byte-identical.  The temporal reference chain must be mesh-resident
    (the host chain would need a global index fetch).
    """

    def __init__(self, mesh: Mesh, axis: str = "data",
                 params: NumarckParams = NumarckParams(),
                 use_pallas: bool = True, overlap: bool = False,
                 chain: str = chainmod.CHAIN_AUTO):
        super().__init__(mesh, axis, params, use_pallas=use_pallas,
                         overlap=overlap, chain=chain)
        if params.symbol_rans:
            raise ValueError("symbol-level rANS blobs come from the device "
                             "entropy stage; the multi-process driver "
                             "entropy-codes per host (set symbol_rans="
                             "False)")
        if chain == chainmod.CHAIN_HOST:
            raise ValueError("multi-process compression needs the mesh-"
                             "resident reference chain (chain='host' "
                             "would gather the index table)")
        import jax as _jax
        self.rank = _jax.process_index()
        self.num_ranks = _jax.process_count()
        pidx = [d.process_index for d in self.mesh.devices.flat]
        mine = [i for i, pi in enumerate(pidx) if pi == self.rank]
        if mine != list(range(mine[0], mine[0] + len(mine))):
            raise ValueError("one process's devices must be contiguous on "
                             "the mesh axis (use launch.global_mesh)")

    def _make_chain(self, dtype) -> chainmod.ReferenceChain:
        if (chainmod.resolve_residency(self.chain, dtype)
                != chainmod.CHAIN_DEVICE):
            raise ValueError(
                f"multi-process compression of {np.dtype(dtype)} needs "
                "the device-resident chain (float64 requires "
                "jax_enable_x64)")
        return _ShardedDeviceChain(self)

    # ------------------------------------------------- local device stage
    def _device_encode_local(self, prev, curr: np.ndarray,
                             b_bits: Optional[int] = None):
        """Phases 1-5 on the global mesh; fetches only this process's
        packed blocks.  Returns (DeviceEncoded for the chain, local
        payload dict for the fragment finalize)."""
        p = self.params
        curr_np = np.asarray(curr)
        curr_f = np.asarray(curr_np, np.float32).reshape(-1)
        n = curr_f.size
        if n >= (1 << 31):
            raise ValueError("per-variable n >= 2^31 needs jax_enable_x64 "
                             "(see pipeline offset note)")
        P_ = self.n_shards
        ln = -(-n // P_)
        sharded, _ = self._shardings()
        if isinstance(prev, jax.Array):
            if prev.shape != (P_ * ln,):
                raise ValueError(
                    f"device-resident chain state {prev.shape} does not "
                    f"match this step's padded layout ({P_ * ln},); "
                    "reset() the compressor before changing shapes")
            prev_dev = prev
        else:
            prev_f = np.asarray(prev, np.float32).reshape(-1)
            prev_dev = _put_sharded(_pad_to(prev_f, P_ * ln, 0.0), sharded)
        curr_dev = _put_sharded(_pad_to(curr_f, P_ * ln, 0.0), sharded)
        ebytes = np.dtype(curr_np.dtype).itemsize

        analyze = self._analyze_fn(ebytes, n)
        with telemetry.span("encode.analyze", annotate=True, n=n) as sp_an:
            (b_auto, ids_desc, counts_desc, domain_lo, width,
             est_sizes) = analyze(prev_dev, curr_dev,
                                  jnp.float32(p.error_bound))
            b_auto = int(np.asarray(b_auto))
        bb = int(b_bits if b_bits is not None
                 else (p.b_bits if p.b_bits is not None else b_auto))
        k_eff = min((1 << bb) - 1, p.max_bins)
        be = p.block_elems(bb)
        if be > ln:
            be = max(32, ln // 32 * 32) if ln >= 32 else 32
            if be > ln:
                raise ValueError(
                    f"shard length {ln} smaller than minimum block (32); "
                    "use fewer shards or larger inputs")

        encode = self._encode_fn(bb, k_eff, be, ln, n)
        with telemetry.span("encode.index", annotate=True,
                            b_bits=bb) as sp_idx:
            idx_dev, packed, valid = encode(prev_dev, curr_dev,
                                            ids_desc, domain_lo, width)
            if telemetry.enabled():
                jax.block_until_ready((idx_dev, packed, valid))

        nblocks = -(-n // be)
        with telemetry.span("encode.pack_fetch") as sp_pack:
            r0, words = _addressable_rows(packed)
            _, valid_rows = _addressable_rows(valid)
            nrows, nbmax = words.shape[0], words.shape[1]
            words = words.reshape(nrows * nbmax, -1)
            local_words = words[np.asarray(valid_rows).reshape(-1)]
        first_blk = lambda s: -(-(s * ln) // be)          # noqa: E731
        block_start = min(first_blk(r0), nblocks)
        block_stop = min(first_blk(r0 + nrows), nblocks)
        if local_words.shape[0] != block_stop - block_start:
            raise AssertionError(
                f"rank {self.rank}: fetched {local_words.shape[0]} valid "
                f"blocks, layout says [{block_start}, {block_stop})")

        domain_lo = float(np.asarray(domain_lo))
        width = float(np.asarray(width))
        centers = pipe.topk_centers(np.asarray(ids_desc), k_eff,
                                    domain_lo, width)
        centers = pipe.round_centers(centers, curr_np.dtype)
        meta = {"b_auto": b_auto,
                "est_sizes": np.asarray(est_sizes).tolist(),
                "n_shards": self.n_shards, "rank": self.rank,
                "num_ranks": self.num_ranks, "pipeline": "multiprocess"}
        if telemetry.enabled():
            meta["telemetry"] = {
                "analyze_s": sp_an.duration,
                "encode_s": sp_idx.duration + sp_pack.duration,
            }
        enc = pipe.EncodedIndices(idx=None, b_bits=bb, block_elems=be, n=n)
        dev = DeviceEncoded(enc=enc, centers=centers, domain_lo=domain_lo,
                            width=width, meta=meta, idx_dev=idx_dev,
                            curr_dev=curr_dev)
        local = {"words": local_words, "block_start": block_start,
                 "nblocks": nblocks}
        return dev, local

    # ------------------------------------------------------ host finalize
    def _fragment_finalize(self, curr: np.ndarray, dev: DeviceEncoded,
                           local: dict) -> StepFragment:
        """Per-rank finalize: exceptions recovered by unpacking this
        rank's own packed blocks, host entropy over the same blocks.
        Block-for-block byte-identical to `core.pipeline.finalize_step`
        on the concatenated fragments (concrete codecs)."""
        p = self.params
        curr = np.asarray(curr)
        bb, be, n = dev.enc.b_bits, dev.enc.block_elems, int(dev.enc.n)
        marker = (1 << bb) - 1
        nbytes_block = be * bb // 8
        words = local["words"]
        g0 = int(local["block_start"])
        meta = dict(dev.meta)
        drv_tele = meta.pop("telemetry", None) or {}
        with telemetry.span("finalize", n=n, b_bits=bb) as sp_fin:
            with telemetry.span("finalize.exceptions") as sp_exc:
                curr_flat = curr.reshape(-1)
                counts = np.zeros(words.shape[0], np.int64)
                vals = []
                for j in range(words.shape[0]):
                    idx_blk = packing.unpack_indices_np(
                        words[j].astype("<u4").view(np.uint8), be, bb)
                    pos = np.flatnonzero(idx_blk == marker) + (g0 + j) * be
                    pos = pos[pos < n]       # final-block marker padding
                    counts[j] = pos.size
                    vals.append(curr_flat[pos])
                values = (np.concatenate(vals) if vals
                          else np.zeros(0, curr.dtype)
                          ).astype(curr.dtype, copy=False)
            block_codecs: Optional[List[str]] = None
            with telemetry.span("finalize.entropy") as sp_ent:
                raws = [w.astype("<u4").tobytes()[:nbytes_block]
                        for w in words]
                if p.codec == entropy.AUTO_CODEC and len(raws) > 1:
                    per = entropy.choose_block_codecs(raws, p.zlib_level)
                    if len(set(per)) > 1:
                        codec = pipe._primary_codec(per)
                        block_codecs = per
                        blks = entropy.compress_blocks_per_codec(
                            raws, per, level=p.zlib_level,
                            parallel=p.parallel_entropy)
                    else:
                        codec = per[0]
                        blks = entropy.compress_blocks(
                            raws, codec=codec, level=p.zlib_level,
                            parallel=p.parallel_entropy)
                else:
                    codec = entropy.resolve_codec(p.codec, raws,
                                                  p.zlib_level)
                    blks = entropy.compress_blocks(
                        raws, codec=codec, level=p.zlib_level,
                        parallel=p.parallel_entropy)
                sp_ent.set(codec=codec, blocks=len(blks))
            centers = dev.centers
            if centers.size > marker:
                centers = centers[:marker]
            bytes_in = len(raws) * nbytes_block
            bytes_out = sum(len(b) for b in blks)
            sp_fin.set(codec=codec, bytes_in=bytes_in, bytes_out=bytes_out)
        info = dict(
            total_data_num=n, shape=list(curr.shape), dtype=str(curr.dtype),
            bin_centers_number=int(centers.size), elements_per_block=be,
            B=bb, error_bound=p.error_bound, strategy=p.strategy,
            reference=p.reference, domain_lo=dev.domain_lo,
            bin_width=dev.width, is_anchor=False,
            n_blocks=int(local["nblocks"]), codec=codec)
        frag = StepFragment(
            is_anchor=False, block_start=g0, info=info, index_blocks=blks,
            centers=centers if self.rank == 0 else None,
            incomp_values=values, incomp_block_counts=counts,
            block_codecs=block_codecs)
        if telemetry.enabled():
            meta["telemetry"] = {
                "analyze_s": float(drv_tele.get("analyze_s", 0.0)),
                "encode_s": float(drv_tele.get("encode_s", 0.0)),
                "exceptions_s": sp_exc.duration,
                "entropy_s": sp_ent.duration,
                "finalize_s": sp_fin.duration,
                "bytes_in": bytes_in, "bytes_out": bytes_out,
                "entropy_ratio": bytes_in / max(bytes_out, 1),
                "codec": codec, "device_entropy": False,
            }
        frag.meta = meta
        return frag

    def _anchor_fragment(self, arr: np.ndarray) -> StepFragment:
        """Lossless anchor, split by block index: rank k owns the global
        anchor blocks [k*nb/R, (k+1)*nb/R) of the same block grid the
        single-process `finalize_anchor` uses, so per-block bytes match
        it exactly (blocks compress independently)."""
        p = self.params
        arr = np.asarray(arr)
        flat = arr.reshape(-1)
        be_a = max(1, p.block_bytes // flat.dtype.itemsize)
        slices = pipe.block_slices(flat.size, be_a)
        nb = len(slices)
        g_lo = self.rank * nb // self.num_ranks
        g_hi = (self.rank + 1) * nb // self.num_ranks
        with telemetry.span("finalize.anchor", n=arr.size) as sp:
            raws = [flat[s:e].tobytes() for s, e in slices[g_lo:g_hi]]
            codec = entropy.resolve_codec(p.codec, raws, p.zlib_level)
            blks = entropy.compress_blocks(raws, codec=codec,
                                           level=p.zlib_level,
                                           parallel=p.parallel_entropy)
            sp.set(codec=codec)
        info = dict(
            total_data_num=arr.size, shape=list(arr.shape),
            dtype=str(arr.dtype), bin_centers_number=0,
            elements_per_block=be_a, B=0, error_bound=p.error_bound,
            strategy=p.strategy, reference=p.reference, domain_lo=0.0,
            bin_width=0.0, is_anchor=True, n_blocks=nb, codec=codec)
        frag = StepFragment(is_anchor=True, block_start=g_lo, info=info,
                            index_blocks=blks)
        if telemetry.enabled():
            bytes_in = sum(len(r) for r in raws)
            bytes_out = sum(len(b) for b in blks)
            frag.meta["telemetry"] = {
                "analyze_s": 0.0, "encode_s": 0.0, "exceptions_s": 0.0,
                "entropy_s": sp.duration, "finalize_s": sp.duration,
                "bytes_in": bytes_in, "bytes_out": bytes_out,
                "entropy_ratio": bytes_in / max(bytes_out, 1),
                "codec": codec, "device_entropy": False,
            }
        return frag

    # ------------------------------------------------- temporal streaming
    def add_fragment_async(self, arr: np.ndarray) -> "Future[StepFragment]":
        """Streaming multi-process interface: like `add_async`, but the
        future resolves to this rank's StepFragment (first call seeds the
        chain and fragments a lossless anchor)."""
        arr = np.asarray(arr)
        step_i, self._step = self._step, self._step + 1
        # Fleet fault-injection sites (no-ops without REPRO_FAULTS): a
        # rank dying mid-encode, or stalling as a straggler, exercises
        # rank 0's quarantine/rollback commit path.
        inject.fire("rank_crash", step=step_i, rank=self.rank)
        inject.fire("straggler", step=step_i, rank=self.rank)
        if self._chain is None or self._chain.empty:
            self._chain = self._make_chain(arr.dtype)
            self._chain.seed(arr)
            return self._q.submit(self._anchor_fragment, arr.copy(),
                                  label=f"anchor fragment {step_i}")
        dev, local = self._device_encode_local(self._chain.peek(), arr)
        if self.params.reference == REF_RECONSTRUCTED:
            self._chain.advance(dev, arr)
        else:
            self._chain.replace(arr)
        curr_s = np.array(arr, copy=True) if self.overlap else arr
        return self._q.submit(self._fragment_finalize, curr_s, dev, local,
                              label=f"fragment step {step_i}")

    def add_fragment(self, arr: np.ndarray) -> StepFragment:
        return self.add_fragment_async(arr).result()

    def compress_series_fragments(self, arrays) -> List[StepFragment]:
        """This rank's fragments of a temporal series (double-buffered
        when overlap=True), device work in lockstep across ranks."""
        self.reset()
        out: List[StepFragment] = []
        futs: Deque[Future] = deque()
        for a in arrays:
            futs.append(self.add_fragment_async(a))
            while len(futs) > 2:
                out.append(futs.popleft().result())
        out.extend(f.result() for f in futs)
        return out

    def save_series(self, path: str, arrays, names=None, *,
                    generation: Optional[int] = None,
                    manifest_timeout: float = 60.0) -> str:
        """Compress a series and publish it multi-process: every rank
        writes its own ``<path>.g<gen>.rank<k>`` shard file (atomic),
        rank 0 waits for the full file set and commits the NCKM
        manifest.  Returns the manifest path on rank 0, this rank's
        shard path elsewhere.  `NCKReader(path)` then reads the logical
        file; a crashed rank leaves the previous manifest loadable."""
        frags = self.compress_series_fragments(arrays)
        names = (list(names) if names is not None
                 else [f"step{i:04d}" for i in range(len(frags))])
        if len(names) != len(frags):
            raise ValueError(f"{len(names)} names for {len(frags)} steps")
        w = ShardNCKWriter(path, self.rank, self.num_ranks,
                           generation=generation)
        for name, frag in zip(names, frags):
            w.add_fragment(name, frag)
        w.write()
        if self.rank == 0:
            return w.commit_manifest(timeout=manifest_timeout)
        return w.rank_path


__all__ = ["ShardedCompressor", "ShardedDecompressor",
           "MultiProcessCompressor"]
