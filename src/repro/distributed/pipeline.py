"""Sharded NUMARCK compression pipeline (paper Sec. IV, shard_map version).

Phases and their parallelization, 1:1 with the paper:

  1. change-ratio calculation  -- local Pallas kernel; pmin/pmax for the
     global range (MPI_Allreduce analogue).
  2. bin construction (top-k)  -- local Pallas histogram; lax.psum merges
     (MPI_Allreduce); every shard runs the same top-k sort + Eq. (6) B scan
     (replicated "serial part", Table 3).
  3. indexing                  -- local rank-LUT lookup.
  4. index alignment           -- block boundaries are *static* under the
     even distribution both we and the paper assume; the straddling block is
     completed by a fixed-width lax.ppermute edge exchange (MPI_Send/Recv
     analogue, <= 1 block like the paper's <= 2 MB).
  5. bits packing              -- local Pallas kernel over owned blocks.
  6. entropy coding + write    -- host stage (not a TPU workload; the paper
     also runs it on the CPU cores).  Shared with the single-device driver:
     `core.pipeline.finalize_step` dispatches the pluggable codec
     (`core.entropy`) over a thread pool.

B must be static for bit-packing, so the pipeline is two jitted stages:
`analyze` (histogram -> auto-B) and `encode` (indices -> packed blocks).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import binning, ratios, select_b
from repro.core import pipeline as pipe
from repro.core.types import CompressedStep, NumarckParams
from repro.distributed import collectives as coll
from repro.kernels import ops as kops


def _pad_to(x: np.ndarray, total: int, value) -> np.ndarray:
    return np.pad(x, (0, total - x.size), constant_values=value)


def _analyze_shard(prev_l, curr_l, error_bound, *, max_bins, b_max,
                   elem_bytes, n_total, axis, use_pallas,
                   fixed_domain=False):
    """Per-shard phase 1+2: ratios, local histogram, global reduce, auto-B."""
    if fixed_domain:
        # SS Perf: skip the range pass entirely -- one fewer full read of
        # prev/curr and no phase-1 Allreduce (NumarckParams.fixed_domain)
        width = jnp.float32(2.0 * error_bound)
        domain_lo = -0.5 * width * max_bins
        lo = domain_lo
        hi = -domain_lo
    else:
        r, valid = ratios.change_ratios(prev_l, curr_l)
        lo_l = jnp.min(jnp.where(valid, r, jnp.inf))
        hi_l = jnp.max(jnp.where(valid, r, -jnp.inf))
        lo, hi = coll.allreduce_minmax(lo_l, hi_l, axis)  # MPI_Allreduce
        any_valid = coll.allreduce_sum(valid.sum(), axis) > 0
        lo = jnp.where(any_valid & jnp.isfinite(lo), lo, 0.0)
        hi = jnp.where(any_valid & jnp.isfinite(hi), hi, 0.0)
        domain_lo, width = ratios.histogram_domain(lo, hi, error_bound,
                                                   max_bins)
    _, bin_ids = kops.change_ratio_bins(prev_l, curr_l, domain_lo, width,
                                        max_bins=max_bins,
                                        use_pallas=use_pallas)
    hist_l = kops.histogram(bin_ids, max_bins=max_bins,
                            use_pallas=use_pallas)
    hist = coll.allreduce_sum(hist_l, axis)          # MPI_Allreduce(SUM)
    counts_desc, ids_desc = binning.sort_histogram(hist)
    b_auto, est_sizes = select_b.choose_b(counts_desc, n_total, elem_bytes,
                                          b_max)
    return (b_auto[None], ids_desc[None], counts_desc[None],
            domain_lo[None], width[None], est_sizes[None])


def _encode_shard(prev_l, curr_l, ids_desc, domain_lo, width, *, b_bits,
                  k_eff, max_bins, block_elems, ln, n_total, axis,
                  use_pallas):
    """Per-shard phase 3-5: index, align (ppermute), pack (Pallas)."""
    marker = (1 << b_bits) - 1
    ids_desc = ids_desc[0]
    _, bin_ids = kops.change_ratio_bins(prev_l, curr_l, domain_lo[0],
                                        width[0], max_bins=max_bins,
                                        use_pallas=use_pallas)
    lut = binning.rank_lut(ids_desc[:k_eff], k_eff, max_bins)
    ranks = lut[jnp.clip(bin_ids, 0, max_bins - 1)]
    ranks = jnp.where(ranks >= k_eff, marker, ranks)
    idx = jnp.where(bin_ids >= 0, ranks, marker).astype(jnp.int32)

    # --- index alignment (paper Sec. IV-C) -------------------------------
    be = block_elems
    edge = coll.right_edge_exchange(idx[:be], axis,
                                    jnp.full((be,), marker, jnp.int32))
    ext = jnp.concatenate([idx, edge])               # (ln + be,)

    # int32 element offsets: fine for n < 2^31 (8.6 GB f32 per variable);
    # production runs on real multi-host fleets enable jax_enable_x64.
    s = jax.lax.axis_index(axis).astype(jnp.int32)
    my_lo = s * jnp.int32(ln)
    first_blk = (my_lo + be - 1) // be               # ceil
    nbmax = -(-ln // be)                             # blocks I may own

    packed_rows = []
    valids = []
    for j in range(nbmax):                            # static unroll
        gstart = (first_blk + j) * be
        lstart = (gstart - my_lo).astype(jnp.int32)
        in_range = (gstart < my_lo + ln) & (gstart < n_total)
        lstart = jnp.clip(lstart, 0, ln - 1)
        blk = jax.lax.dynamic_slice(ext, (lstart,), (be,))
        words = kops.pack_bits(blk, b_bits=b_bits, use_pallas=use_pallas)
        packed_rows.append(words)
        valids.append(in_range)
    packed = jnp.stack(packed_rows)                  # (nbmax, wpb)
    valid = jnp.stack(valids)                        # (nbmax,)
    return idx[None], packed[None], valid[None]


class ShardedCompressor:
    """Distributed NUMARCK over one mesh axis (or a flattened mesh)."""

    def __init__(self, mesh: Mesh, axis: str = "data",
                 params: NumarckParams = NumarckParams(),
                 use_pallas: bool = True):
        self.mesh = mesh
        self.axis = axis
        self.params = params
        self.use_pallas = use_pallas
        self.n_shards = mesh.shape[axis]

    def _shardings(self):
        return (NamedSharding(self.mesh, P(self.axis)),
                NamedSharding(self.mesh, P()))

    def compress(self, prev: np.ndarray, curr: np.ndarray,
                 b_bits: Optional[int] = None) -> CompressedStep:
        p = self.params
        prev_f = np.asarray(prev, np.float32).reshape(-1)
        curr_f = np.asarray(curr, np.float32).reshape(-1)
        n = curr_f.size
        if n >= (1 << 31):
            raise ValueError("per-variable n >= 2^31 needs jax_enable_x64 "
                             "(see pipeline offset note)")
        P_ = self.n_shards
        ln = -(-n // P_)
        # Pad so every shard holds ln elements; pads are invalid (prev=0).
        prev_p = _pad_to(prev_f, P_ * ln, 0.0)
        curr_p = _pad_to(curr_f, P_ * ln, 0.0)
        ebytes = np.dtype(np.asarray(curr).dtype).itemsize

        sharded, rep = self._shardings()
        spec_s, spec_r = P(self.axis), P()

        analyze = shard_map(
            partial(_analyze_shard, max_bins=p.max_bins, b_max=p.b_max,
                    elem_bytes=ebytes, n_total=n, axis=self.axis,
                    use_pallas=self.use_pallas,
                    fixed_domain=p.fixed_domain),
            mesh=self.mesh,
            in_specs=(spec_s, spec_s, spec_r),
            out_specs=(spec_s,) * 6, check_rep=False)
        analyze = jax.jit(analyze)

        (b_auto, ids_desc, counts_desc, domain_lo, width,
         est_sizes) = analyze(
            jax.device_put(prev_p, sharded), jax.device_put(curr_p, sharded),
            jnp.float32(p.error_bound))
        # Out specs are sharded over P copies of identical values; take row 0.
        b_auto = int(np.asarray(b_auto)[0])
        bb = int(b_bits if b_bits is not None
                 else (p.b_bits if p.b_bits is not None else b_auto))
        k_eff = min((1 << bb) - 1, p.max_bins)
        be = p.block_elems(bb)
        if be > ln:
            be = max(32, ln // 32 * 32) if ln >= 32 else 32
            if be > ln:
                raise ValueError(
                    f"shard length {ln} smaller than minimum block (32); "
                    f"use fewer shards or larger inputs")

        encode = shard_map(
            partial(_encode_shard, b_bits=bb, k_eff=k_eff,
                    max_bins=p.max_bins, block_elems=be, ln=ln, n_total=n,
                    axis=self.axis, use_pallas=self.use_pallas),
            mesh=self.mesh,
            in_specs=(spec_s, spec_s, spec_s, spec_s, spec_s),
            out_specs=(spec_s, spec_s, spec_s), check_rep=False)
        encode = jax.jit(encode)

        idx, packed, valid = encode(
            jax.device_put(prev_p, sharded), jax.device_put(curr_p, sharded),
            ids_desc, domain_lo, width)

        return self._finalize(np.asarray(curr), np.asarray(idx),
                              np.asarray(packed), np.asarray(valid),
                              bb, k_eff, be, n,
                              float(np.asarray(domain_lo)[0]),
                              float(np.asarray(width)[0]),
                              np.asarray(ids_desc)[0],
                              int(b_auto),
                              np.asarray(est_sizes)[0])

    def _finalize(self, curr, idx, packed, valid, bb, k_eff, be, n,
                  domain_lo, width, ids_desc, b_auto, est_sizes
                  ) -> CompressedStep:
        """Host stage: hand the device-packed blocks to the shared
        finalize (`core.pipeline.finalize_step`) -- exceptions, parallel
        entropy coding, blob assembly.  Byte-identical to the
        single-device driver by construction."""
        idx = idx.reshape(-1)[:n]

        # Valid blocks in global order (shards own contiguous block ranges).
        packed = packed.reshape(-1, packed.shape[-1])
        rows = packed[valid.reshape(-1)]     # (nblocks, words_per_block)
        nblocks = -(-n // be)
        assert rows.shape[0] == nblocks, (rows.shape, nblocks)
        nbytes_block = be * bb // 8
        raws = [r.astype("<u4").tobytes()[:nbytes_block] for r in rows]

        enc = pipe.EncodedIndices(idx=idx, b_bits=bb, block_elems=be,
                                  packed=raws)
        centers = pipe.topk_centers(ids_desc, k_eff, domain_lo, width)
        return pipe.finalize_step(
            np.asarray(curr), enc, centers, domain_lo, width, self.params,
            meta={"b_auto": b_auto, "est_sizes": est_sizes.tolist(),
                  "n_shards": self.n_shards, "pipeline": "sharded"})


def _decode_shard(idx_l, prev_l, centers, *, b_bits, use_pallas):
    """Per-shard fused dequantize (Pallas one-hot-MXU gather kernel)."""
    out = kops.dequantize(idx_l, prev_l, centers[0], b_bits=b_bits,
                          use_pallas=use_pallas)
    return out[None]


class ShardedDecompressor:
    """Distributed reconstruction: hosts inflate+unpack blocks (entropy
    stage stays on CPU, like the paper), devices run the fused dequantize
    kernel, hosts patch exceptions."""

    def __init__(self, mesh: Mesh, axis: str = "data",
                 use_pallas: bool = True):
        self.mesh = mesh
        self.axis = axis
        self.use_pallas = use_pallas
        self.n_shards = mesh.shape[axis]

    def decompress(self, step: CompressedStep,
                   prev: np.ndarray) -> np.ndarray:
        from repro.core import blocks as blk
        n = step.n
        marker = (1 << step.b_bits) - 1
        # host: inflate + unpack (per-block; each block independently)
        idx = np.concatenate([
            blk.inflate_block(b, min(step.block_elems,
                                     n - i * step.block_elems),
                              step.b_bits, codec=step.codec)
            for i, b in enumerate(step.index_blocks)])
        P_ = self.n_shards
        ln = -(-n // P_)
        idx_p = _pad_to(idx.astype(np.int32), P_ * ln, marker)
        prev_p = _pad_to(np.asarray(prev, np.float32).reshape(-1),
                         P_ * ln, 0.0)
        k = max(1, step.centers.size)
        centers = step.centers.astype(np.float32)[None]

        sharded = NamedSharding(self.mesh, P(self.axis))
        rep = NamedSharding(self.mesh, P())
        fn = shard_map(
            partial(_decode_shard, b_bits=step.b_bits,
                    use_pallas=self.use_pallas),
            mesh=self.mesh,
            in_specs=(P(self.axis), P(self.axis), P()),
            out_specs=P(self.axis), check_rep=False)
        out = np.asarray(jax.jit(fn)(
            jax.device_put(idx_p, sharded), jax.device_put(prev_p, sharded),
            jax.device_put(centers, rep))).reshape(-1)[:n]
        # host: patch exceptions in stream order
        mask = idx == marker
        out = out.astype(np.float64)
        out[mask] = step.incomp_values.astype(np.float64)
        return out.astype(step.dtype).reshape(step.shape)


__all__ = ["ShardedCompressor", "ShardedDecompressor"]
