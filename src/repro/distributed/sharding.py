"""GSPMD sharding rules: 2-D (FSDP x TP) weight sharding + activation
constraints (DESIGN.md Sec. 6).

Weights carry PartitionSpecs over ("data", "model"): FSDP shards a large
non-TP dim over "data" (GSPMD inserts the gather/reduce-scatter), Megatron
TP shards heads / ffn-hidden / vocab / experts over "model".  Dims that
don't divide the axis fall back to replication (e.g. minicpm3's 40 heads on
a 16-way axis shard the LoRA rank instead).

Activation constraints are applied through a process-global active-mesh
context so model code stays mesh-agnostic (identity when no mesh is
active -- CPU unit tests).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE = {"mesh": None, "dp": ("data",), "tp": "model",
           "shard_seq": False}


def activate(mesh: Optional[Mesh], dp_axes=("data",), tp_axis="model",
             shard_seq: bool = False):
    _ACTIVE.update(mesh=mesh, dp=tuple(dp_axes), tp=tp_axis,
                   shard_seq=shard_seq)


def deactivate():
    _ACTIVE.update(mesh=None)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


def logical_to_spec(logical: Tuple, mesh: Mesh, dp, tp,
                    shape=None) -> P:
    """('dp'|'tp'|'tp!'|None, ...) -> PartitionSpec.

    'tp' falls back to replication when the dim doesn't divide; 'tp!'
    forces the sharding (GSPMD pads uneven shards -- used for padded
    expert parallelism, E=8 on a 16-way axis).
    """
    elems = []
    for i, ax in enumerate(logical):
        if ax == "dp":
            elems.append(dp if len(dp) > 1 else dp[0])
        elif ax == "tp!":
            elems.append(tp)
        elif ax == "tp":
            if shape is not None and shape[i] % axis_size(mesh, tp) != 0:
                elems.append(None)
            else:
                elems.append(tp)
        else:
            elems.append(None)
    return P(*elems)


def constrain(x, *logical):
    """with_sharding_constraint against the active mesh (identity if none).

    logical elems: 'dp', 'tp', 'seq' (tp iff shard_seq is on), or None.
    """
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    dp, tp = _ACTIVE["dp"], _ACTIVE["tp"]
    resolved = tuple(
        ("tp" if _ACTIVE["shard_seq"] else None) if ax == "seq" else ax
        for ax in logical)
    spec = logical_to_spec(resolved, mesh, dp, tp, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter rules
# ---------------------------------------------------------------------------

def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def param_logical(path_s: str, ndim: int, cfg) -> Tuple:
    """Map a parameter path to logical axes ('fsdp'->dp, 'tp', None)."""
    name = path_s.split("/")[-1]
    # stacked layer params may sit under a wrapper key ("params/layers/...")
    stacked = "layers/" in path_s or path_s.startswith("layers")
    lead = ("layer",) if stacked else ()
    body_ndim = ndim - len(lead)

    table = {
        "embed": ("tp", "dp"),
        "unembed": ("dp", "tp"),
        "wq": ("dp", "tp", None),
        "wk": ("dp", "tp", None),
        "wv": ("dp", "tp", None),
        "wo": ("tp", None, "dp"),
        "bq": ("tp", None),
        "bk": ("tp", None),
        "bv": ("tp", None),
        "w_gate": ("dp", "tp"),
        "w_up": ("dp", "tp"),
        "w_down": ("tp", "dp"),
        "router": ("dp", None),
        # MoE experts: EP over 'model' when the slot count divides the axis
        # (moe_ep_split fans experts out; SS Perf mixtral iteration), else
        # TP inside the expert
        "we_gate": ("tp", "dp", None) if _ep_ok(cfg) else (None, "dp", "tp"),
        "we_up": ("tp", "dp", None) if _ep_ok(cfg) else (None, "dp", "tp"),
        "we_down": ("tp", None, "dp") if _ep_ok(cfg)
        else (None, "tp", "dp"),
        # MLA
        "wq_a": ("dp", "tp"),
        "wq_b": ("tp", None, None),     # shard q_lora rank (heads may not
        "wk_b": ("tp", None, None),     # divide the axis: 40 on 16)
        "wv_b": ("tp", None, None),
        "wkv_a": ("dp", None),
        # SSD
        "in_proj": ("dp", "tp"),
        "out_proj": ("tp", "dp"),
        "conv_w": (None, "tp"),
        "conv_b": ("tp",),
        "A_log": ("tp",),
        "D": ("tp",),
        "dt_bias": ("tp",),
        "scale": (None,),
    }
    logical = table.get(name, (None,) * body_ndim)
    if len(logical) != body_ndim:
        logical = (None,) * body_ndim
    return (None,) * len(lead) + tuple(logical)


def _ep_ok(cfg) -> bool:
    # SS Perf iteration (EXPERIMENTS.md, mixtral train_4k): FSDP-gathering
    # expert weights every step costs ~90 GB/device/step of all-gather;
    # expert parallelism keeps experts resident.  moe_ep_split fans each
    # expert into FFN slices so slots = n_experts * split matches the
    # 16-way model axis (mixtral: 8 x 2).
    slots = (getattr(cfg, "n_experts", 0)
             * getattr(cfg, "moe_ep_split", 1))
    return slots >= 16


def param_specs(params_tree, cfg, mesh: Mesh, dp=("data",), tp="model"):
    """Pytree of PartitionSpecs matching `params_tree` (shapes or arrays)."""
    def one(path, leaf):
        shape = leaf.shape
        logical = param_logical(_path_str(path), len(shape), cfg)
        resolved = tuple("dp" if ax == "dp" else ax for ax in logical)
        # fsdp ('dp') dims must also divide; else replicate.  'tp!' forces
        # the sharding (GSPMD pads; padded expert parallelism).
        elems = []
        for i, ax in enumerate(resolved):
            if ax == "dp":
                if shape[i] % axis_size(mesh, dp if len(dp) > 1 else dp[0]) \
                        != 0:
                    elems.append(None)
                else:
                    elems.append(dp if len(dp) > 1 else dp[0])
            elif ax == "tp!":
                elems.append(tp)
            elif ax == "tp":
                if shape[i] % axis_size(mesh, tp) != 0:
                    elems.append(None)
                else:
                    elems.append(tp)
            else:
                elems.append(None)
        return P(*elems)

    return jax.tree_util.tree_map_with_path(one, params_tree)


def named_shardings(params_tree, cfg, mesh: Mesh, dp=("data",), tp="model"):
    specs = param_specs(params_tree, cfg, mesh, dp, tp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# serve-cache rules
# ---------------------------------------------------------------------------

_CACHE_TABLE = {
    # name: logical spec for the *unstacked* leaf.  "tp>alt" = shard this
    # dim over tp, falling back to the dim marked "alt" when it doesn't
    # divide (e.g. 8 or 24 kv heads on a 16-way axis -> shard head_dim;
    # keeps 100+ GB KV caches inside 16 GB/chip, see EXPERIMENTS.md).
    "k": ("batch", None, "tp>", "alt"),
    "v": ("batch", None, "tp>", "alt"),
    "ckv": ("batch", None, "alt"),
    "krope": ("batch", None, None),
    "pos_map": (None,),
    "conv": ("batch", None, "tp"),
    "h": ("batch", "tp>", "alt", None),
}


def cache_specs(cache_tree, mesh: Mesh, dp=("data",), tp="model",
                stacked: bool = True):
    """PartitionSpecs for a decode cache pytree (KV over batch+TP heads).

    Falls back to replication per-dim when sizes don't divide (e.g.
    long_500k's global_batch=1, or 8 kv heads on a 16-way axis).
    """
    dp_name = dp if len(dp) > 1 else dp[0]
    dp_size = axis_size(mesh, dp_name)
    tp_size = axis_size(mesh, tp)

    def one(path, leaf):
        name = _path_str(path).split("/")[-1]
        logical = _CACHE_TABLE.get(name)
        shape = leaf.shape
        if logical is None:
            return P(*([None] * len(shape)))
        lead = len(shape) - len(logical)
        elems = [None] * lead
        primary_failed = False
        used_tp = False
        for i, ax in enumerate(logical):
            dim = shape[lead + i]
            if ax == "batch" and dim % dp_size == 0:
                elems.append(dp_name)
            elif ax == "tp" and dim % tp_size == 0 and dim > 1:
                elems.append(tp)
            elif ax == "tp>":
                if dim % tp_size == 0 and dim > 1:
                    elems.append(tp)
                    used_tp = True
                else:
                    elems.append(None)
                    primary_failed = True
            elif ax == "alt":
                if ((primary_failed or not used_tp)
                        and dim % tp_size == 0 and dim > 1):
                    elems.append(tp)
                    used_tp = True
                else:
                    elems.append(None)
            else:
                elems.append(None)
        return P(*elems)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def batch_specs(batch_tree, mesh: Mesh, dp=("data",)):
    """Input batches: shard the leading (global batch) dim over dp."""
    dp_name = dp if len(dp) > 1 else dp[0]
    dp_size = axis_size(mesh, dp_name)

    def one(leaf):
        if not leaf.shape:
            return P()
        elems = [None] * len(leaf.shape)
        if leaf.shape[0] % dp_size == 0:
            elems[0] = dp_name
        return P(*elems)

    return jax.tree.map(one, batch_tree)


__all__ = ["activate", "deactivate", "constrain", "param_specs",
           "named_shardings", "logical_to_spec", "axis_size"]
