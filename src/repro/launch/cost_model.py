"""Analytical FLOPs / bytes / collective model for the roofline table.

WHY THIS EXISTS: XLA's `compiled.cost_analysis()` counts a while-loop body
ONCE -- scan-over-layers models therefore under-report FLOPs/bytes by ~L
(verified empirically: llama train_4k flops at L=2 vs L=4 differ by <1%).
The dry-run records BOTH the raw HLO numbers (the prompt's convention) and
the analytical totals below; dominant-term decisions in EXPERIMENTS.md use
the analytical ones.  The model is validated against *fully unrolled*
small-config HLO in tests/test_cost_model.py (flops within a few %).

Conventions: dot(M,K)x(K,N) = 2MNK flops (XLA's convention); backward =
2x forward; block-remat adds one extra forward recompute.  Bytes are a
traffic model of this implementation (params + major activation tensors +
cache reads), documented per term; they are estimates, not HLO ground
truth.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.models.config import SHAPES, ModelConfig

BF16 = 2
F32 = 4


def hlo_cost(compiled) -> Dict[str, float]:
    """Normalize `compiled.cost_analysis()` across jax versions.

    jax <= 0.4.30 returns a per-platform *list* of dicts; newer versions
    return the dict directly (and some builds return None for trivial
    programs).  Every consumer of HLO cost numbers in this repo goes
    through here so the analytic-vs-HLO validation keeps working across
    the toolchain.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def hlo_flops(compiled) -> float:
    return float(hlo_cost(compiled).get("flops", 0.0))


@dataclass
class CellCost:
    flops_total: float           # whole step, all chips
    bytes_total: float           # whole step, all chips (traffic model)
    collective_total: float      # per-device collective bytes (corrected)

    def per_device(self, chips: int):
        return (self.flops_total / chips, self.bytes_total / chips)


def _attn_flops(cfg: ModelConfig, D: float, ctx: float) -> float:
    """One layer of attention for D query tokens against avg context ctx."""
    d = cfg.d_model
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        f = 2 * D * d * cfg.q_lora_rank
        f += 2 * D * cfg.q_lora_rank * cfg.n_heads * qk
        f += 2 * D * d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
        f += 2 * D * cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_dim
                                                       + cfg.v_head_dim)
        f += 2 * D * ctx * cfg.n_heads * (qk + cfg.v_head_dim)
        f += 2 * D * cfg.n_heads * cfg.v_head_dim * d
        return f
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f = 2 * D * d * (H + 2 * K) * hd            # qkv projections
    f += 2 * D * ctx * H * hd * 2               # scores + pv
    f += 2 * D * H * hd * d                     # output projection
    return f


def _mla_absorbed_decode_flops(cfg: ModelConfig, B: float, T: float):
    d = cfg.d_model
    r, rp = cfg.kv_lora_rank, cfg.qk_rope_dim
    f = 2 * B * d * cfg.q_lora_rank
    f += 2 * B * cfg.q_lora_rank * cfg.n_heads * (cfg.qk_nope_dim + rp)
    f += 2 * B * d * (r + rp)
    f += 2 * B * cfg.n_heads * cfg.qk_nope_dim * r        # q absorb
    f += 2 * B * cfg.n_heads * T * (r + rp)               # scores
    f += 2 * B * cfg.n_heads * T * r                      # o_lat
    f += 2 * B * cfg.n_heads * r * cfg.v_head_dim         # expand out
    f += 2 * B * cfg.n_heads * cfg.v_head_dim * d
    return f


def _ffn_flops(cfg: ModelConfig, D: float) -> float:
    if not cfg.d_ff:
        return 0.0
    if cfg.n_experts:
        # capacity-padded grouped matmuls do top_k * capacity_factor worth
        # of work per token + the router
        eff = cfg.moe_top_k * cfg.capacity_factor
        return (6 * D * eff * cfg.d_model * cfg.d_ff
                + 2 * D * cfg.d_model * cfg.n_experts)
    return 6 * D * cfg.d_model * cfg.d_ff


def _ssd_flops(cfg: ModelConfig, D: float, decode: bool) -> float:
    if not cfg.ssm_state:
        return 0.0
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    f = 2 * D * d * (2 * di + 2 * N + nh)       # in_proj
    f += 2 * D * cfg.conv_width * (di + 2 * N)  # conv
    f += 2 * D * di * d                          # out_proj
    if decode:
        f += 2 * D * nh * hd * N * 2             # h update + y readout
        return f
    Q = cfg.ssm_chunk
    # intra-chunk: CB^T (Q x Q x N, head-shared) + two (Q,Q)x(Q,hd)-ish
    # contractions per head; inter-chunk state ops are O(D*nh*hd*N)
    f += 2 * D * Q * N                           # scores (shared)
    f += 2 * D * Q * nh * hd                     # y_diag
    f += 2 * D * N * nh * hd * 2                 # states + y_off
    return f


def flops_cell(cfg: ModelConfig, shape_name: str) -> float:
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    # ctx models the IMPLEMENTATION.  With block skipping (SS Perf
    # iteration 4) causal attention visits ~(S + qb)/2 kv positions per
    # query and SWA visits ~window + block slack; hymba's mixed-window
    # train scan traces windows and cannot skip (full S), and prefix-LM
    # (paligemma) keeps full tiles.  Decode context is bounded by the ring
    # cache for SWA archs.
    QB, KB = 512.0, 1024.0
    skip = (cfg.family != "hybrid") and not cfg.n_prefix
    if kind in ("train", "prefill"):
        D = B * S
        mult = (4.0 if cfg.remat == "block" else 3.0) \
            if kind == "train" else 1.0
        nqb = S / QB
        if not skip:
            ctx = float(S)
        elif cfg.sliding_window and cfg.sliding_window < S:
            # SWA band scan skips at any T
            ctx = float(min(S, cfg.sliding_window + QB + KB))
        elif nqb <= 8:
            # causal python-unrolled skip (train_4k); clamp for S < QB
            ctx = min((S + QB) / 2, float(S))
        else:
            # dense long prefill: rolled path, no causal skip
            ctx = float(S)
        if kind == "prefill" and cfg.family == "hybrid":
            ctx = float(min(S, cfg.sliding_window + QB + KB)) \
                if cfg.sliding_window else float(S)   # loop path skips
    else:
        D, ctx, mult = B, float(S), 1.0
        if cfg.sliding_window:
            ctx = float(min(S, cfg.sliding_window))

    per_layer = 0.0
    if cfg.family == "hybrid":
        per_layer += _attn_flops(cfg, D, ctx)
        per_layer += _ssd_flops(cfg, D, decode=(kind == "decode"))
    elif cfg.n_heads:
        if cfg.attn_kind == "mla" and kind == "decode":
            per_layer += _mla_absorbed_decode_flops(cfg, D, ctx)
        else:
            per_layer += _attn_flops(cfg, D, ctx)
    elif cfg.ssm_state:
        per_layer += _ssd_flops(cfg, D, decode=(kind == "decode"))
    per_layer += _ffn_flops(cfg, D)

    logits = 2 * D * cfg.d_model * cfg.vocab_size
    return (cfg.n_layers * per_layer + logits) * mult


def bytes_cell(cfg: ModelConfig, shape_name: str) -> float:
    """Traffic model: parameters + residual/attention/cache streams."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    D = B * S if kind != "decode" else B
    P = cfg.param_count()
    d = cfg.d_model

    if kind == "train":
        # params: fwd read + bwd read + grad write (bf16) + adam m/v r+w and
        # master read/write (f32)
        pbytes = P * (3 * BF16 + 6 * F32)
        act_mult = 3.0 if cfg.remat != "block" else 2.0
    else:
        pbytes = P * BF16
        act_mult = 1.0

    # residual stream + a handful of layer-internal tensors
    act = cfg.n_layers * D * d * BF16 * 8 * act_mult
    # attention K/V stream: decode reads the whole cache; prefill/train
    # re-reads K/V once per q-block (nqb ~ S/512)
    cache = 0.0
    if cfg.n_heads:
        K = (cfg.n_kv_heads * cfg.head_dim if cfg.attn_kind != "mla"
             else cfg.kv_lora_rank + cfg.qk_rope_dim)
        ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
        if kind == "decode":
            cache = cfg.n_layers * B * ctx * K * BF16 * 2
        else:
            nqb = max(1, S // 512)
            reread = min(nqb, 8)           # XLA keeps blocks resident-ish
            cache = cfg.n_layers * B * ctx * K * BF16 * 2 * reread
    if cfg.ssm_state and kind == "decode":
        cache += cfg.n_layers * B * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * F32 * 2
    logits = D * cfg.vocab_size * F32 * (2 if kind == "train" else 1)
    return pbytes + act + cache + logits


def collective_cell(cfg: ModelConfig, shape_name: str, chips: int,
                    dp: int, tp: int) -> float:
    """Per-device collective bytes (FSDP gathers + grad reduce + TP)."""
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]
    kind = sh["kind"]
    D = B * S if kind != "decode" else B
    P = cfg.param_count()
    if kind == "train":
        # FSDP: all-gather params fwd + bwd (bf16), reduce-scatter grads
        fsdp = P * BF16 * 2 / tp + P * BF16 / tp
        # TP: activation all-reduces, ~2 per layer of the residual stream
        tpc = 2 * cfg.n_layers * (D / dp) * cfg.d_model * BF16
        return fsdp + tpc
    # inference: params stay resident; TP all-reduces only
    return 2 * cfg.n_layers * (max(D // dp, 1)) * cfg.d_model * BF16


def cell_cost(cfg: ModelConfig, shape_name: str, chips: int = 256,
              dp: int = 16, tp: int = 16) -> CellCost:
    return CellCost(
        flops_total=flops_cell(cfg, shape_name),
        bytes_total=bytes_cell(cfg, shape_name),
        collective_total=collective_cell(cfg, shape_name, chips, dp, tp))


__all__ = ["cell_cost", "flops_cell", "bytes_cell", "collective_cell",
           "CellCost"]
