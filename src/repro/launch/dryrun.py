import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes
((16,16) single pod, (2,16,16) = 2 pods), `jax.jit(step).lower(**specs)`
+ `.compile()` must succeed for every cell, and the compiled artifact
yields the roofline terms (cost_analysis + HLO collective parse).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""

import argparse
import json
import re
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed import sharding as shd
from repro.launch.mesh import dp_axes, make_production_mesh, mesh_chips, \
    tp_axis
from repro.models.config import SHAPES, runnable_shapes
from repro.models.model import Model
from repro.train import optim

# TPU v5e targets (per chip / per link)
HW = dict(peak_flops_bf16=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "u64": 8, "u32": 4, "s16": 2, "u16": 2,
                "s8": 1, "u8": 1, "pred": 1}
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, loop_trip: int = 1):
    """Per-device bytes moved by collectives, from the optimized HLO.

    Convention: the RESULT shape of each collective op (ring traffic for
    all-gather ~ result; all-reduce ~ 2x operand in a ring, we report 1x and
    note the factor in EXPERIMENTS.md).

    `loop_trip`: HLO cost/text counts a while-loop body ONCE; collectives
    found inside non-ENTRY computations (the scan-over-layers body) are
    multiplied by the layer count.  `total_raw` keeps the uncorrected sum.
    """
    out = {}
    raw_total = 0
    entry = True
    for line in hlo_text.splitlines():
        # computation definitions start at column 0: "ENTRY %main (...) {"
        # or "%region_3.88 (...) -> ... {"; body lines are indented
        if line.startswith("ENTRY"):
            entry = True
        elif line.startswith("%") and line.rstrip().endswith("{"):
            entry = False
        m = _COLL_RE.search(line)
        if m:
            ty, op = m.group(1), m.group(2)
            b = shape_bytes(ty)
            raw_total += b
            mult = 1 if entry else loop_trip
            out[op] = out.get(op, 0) + b * mult
    out["total"] = sum(out.values())
    out["total_raw"] = raw_total
    return out


def _train_step_fn(model: Model):
    ocfg = optim.AdamWConfig()

    def step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        params, opt_state, _ = optim.apply_updates(grads=grads,
                                                   params=params,
                                                   state=opt_state, cfg=ocfg)
        return params, opt_state, loss

    return step


def build_cell(model: Model, shape_name: str, mesh):
    """-> (fn, args_specs, in_shardings, out_shardings)."""
    cfg = model.cfg
    dp = dp_axes(mesh)
    tp = tp_axis(mesh)
    kind = SHAPES[shape_name]["kind"]
    S = SHAPES[shape_name]["seq_len"]

    params_s = model.shape_params()
    param_ns = shd.named_shardings(params_s, cfg, mesh, dp, tp)

    if kind == "train":
        batch_s = model.input_specs(shape_name)
        batch_ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.batch_specs(batch_s, mesh, dp),
                                is_leaf=lambda x: isinstance(x, P))
        opt_s = jax.eval_shape(optim.init_state, params_s)
        opt_ns = optim.AdamState(
            step=NamedSharding(mesh, P()),
            m=jax.tree.map(lambda s: s, param_ns),
            v=jax.tree.map(lambda s: s, param_ns))
        fn = _train_step_fn(model)
        return (fn, (params_s, opt_s, batch_s),
                (param_ns, opt_ns, batch_ns),
                (param_ns, opt_ns, NamedSharding(mesh, P())))

    if kind == "prefill":
        batch_s = model.input_specs(shape_name)
        batch_ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                shd.batch_specs(batch_s, mesh, dp),
                                is_leaf=lambda x: isinstance(x, P))

        def fn(params, batch):
            return model.prefill(params, batch, s_max=S)

        cache_s = jax.eval_shape(fn, params_s, batch_s)[1]
        cache_ns = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            shd.cache_specs(cache_s, mesh, dp, tp),
            is_leaf=lambda x: isinstance(x, P))
        out_ns = (NamedSharding(mesh, P()), cache_ns,
                  NamedSharding(mesh, P()))
        return fn, (params_s, batch_s), (param_ns, batch_ns), out_ns

    # decode: one new token against a seq_len-deep cache
    specs = model.input_specs(shape_name)
    cache_s = specs["cache"]
    cache_ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            shd.cache_specs(cache_s, mesh, dp, tp),
                            is_leaf=lambda x: isinstance(x, P))
    tok_s = {k: v for k, v in specs.items() if k != "cache"}
    tok_ns = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          shd.batch_specs(tok_s, mesh, dp),
                          is_leaf=lambda x: isinstance(x, P))

    def fn(params, cache, toks):
        return model.decode(params, cache, token=toks.get("token"),
                            pos=toks["pos"], embed=toks.get("embed"))

    out_ns = (NamedSharding(mesh, P()), cache_ns)
    return (fn, (params_s, cache_s, tok_s),
            (param_ns, cache_ns, tok_ns), out_ns)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir=None,
             donate: bool = True):
    cfg = get_config(arch)
    model = Model(cfg)
    if shape_name not in runnable_shapes(cfg):
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                   status="SKIP", reason="full attention at 500k "
                   "(DESIGN.md Sec. 5)")
        _emit(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    dp = dp_axes(mesh)
    shd.activate(mesh, dp, tp_axis(mesh),
                 shard_seq=(cfg.name == "qwen1.5-110b"))
    t0 = time.time()
    try:
        fn, args, in_ns, out_ns = build_cell(model, shape_name, mesh)
        kind = SHAPES[shape_name]["kind"]
        if not donate:
            dn = ()
        elif kind == "train":
            dn = (0, 1)          # params + optimizer state update in place
        elif kind == "decode":
            dn = (1,)            # KV/SSM cache updates in place
        else:
            dn = ()
        # One-shot lower/compile for cost analysis -- never re-invoked.
        # repro-lint: disable=jit-cache-hygiene
        jitted = jax.jit(fn, in_shardings=in_ns, out_shardings=out_ns,
                         donate_argnums=dn)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        from repro.launch.cost_model import hlo_cost
        cost = hlo_cost(compiled)
        mem = compiled.memory_analysis()
        colls = collective_bytes(compiled.as_text(),
                                 loop_trip=cfg.n_layers)
        chips = mesh_chips(mesh)

        # raw HLO numbers (NB: XLA counts while-loop bodies ONCE, so raw
        # flops/bytes under-report scanned layers ~L-fold; see cost_model)
        flops_dev = float(cost.get("flops", 0.0))
        bytes_dev = float(cost.get("bytes accessed", 0.0))
        coll_dev = float(colls.get("total", 0))
        terms = dict(
            compute_s=flops_dev / HW["peak_flops_bf16"],
            memory_s=bytes_dev / HW["hbm_bw"],
            collective_s=coll_dev / HW["ici_bw"],
        )

        # analytical totals (validated vs unrolled HLO in
        # tests/test_cost_model.py) -- the numbers SS Roofline reasons from
        from repro.launch import cost_model
        from repro.launch.mesh import dp_axes as _dpa
        dp_size = 1
        for a in _dpa(mesh):
            dp_size *= mesh.shape[a]
        ana = cost_model.cell_cost(cfg, shape_name, chips=chips,
                                   dp=dp_size, tp=mesh.shape["model"])
        ana_flops_dev = ana.flops_total / chips
        ana_bytes_dev = ana.bytes_total / chips
        ana_terms = dict(
            compute_s=ana_flops_dev / HW["peak_flops_bf16"],
            memory_s=ana_bytes_dev / HW["hbm_bw"],
            collective_s=coll_dev / HW["ici_bw"],
        )
        dominant = max(ana_terms, key=ana_terms.get)
        n_params = cfg.param_count()
        n_active = cfg.active_param_count()
        tokens = (SHAPES[shape_name]["global_batch"]
                  * (SHAPES[shape_name]["seq_len"]
                     if SHAPES[shape_name]["kind"] != "decode" else 1))
        mf = (6 * n_active * tokens
              * (1 if SHAPES[shape_name]["kind"] == "train" else 1 / 3))
        rec = dict(
            arch=arch, shape=shape_name, mesh=mesh_kind, status="OK",
            chips=chips,
            flops_per_device=flops_dev, bytes_per_device=bytes_dev,
            collective_bytes_per_device=coll_dev,
            collectives=colls, roofline_hlo_raw=terms,
            analytic_flops_per_device=ana_flops_dev,
            analytic_bytes_per_device=ana_bytes_dev,
            roofline=ana_terms, dominant=dominant,
            model_flops=mf,
            useful_ratio=(mf / ana.flops_total
                          if ana.flops_total else None),
            memory=dict(
                argument=mem.argument_size_in_bytes,
                output=mem.output_size_in_bytes,
                temp=mem.temp_size_in_bytes,
                peak=getattr(mem, "peak_memory_in_bytes", None),
            ) if mem else None,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            n_params=n_params, n_active_params=n_active,
        )
    except Exception as e:  # noqa: BLE001 -- dry-run failures are findings
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                   status="FAIL", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    finally:
        shd.deactivate()
    _emit(rec, out_dir)
    return rec


def _emit(rec, out_dir):
    tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
    if rec["status"] == "OK":
        t = rec["roofline"]
        print(f"[{rec['status']}] {tag}: dominant={rec['dominant']} "
              f"compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s "
              f"collective={t['collective_s']:.3e}s "
              f"peak/dev={_fmt_b(rec['memory']['peak'] if rec['memory'] else None)} "
              f"(lower {rec.get('lower_s', '-')}s "
              f"compile {rec.get('compile_s', '-')}s)")
    else:
        print(f"[{rec['status']}] {tag}: "
              f"{rec.get('reason', rec.get('error', ''))[:300]}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        safe = tag.replace("/", "_").replace(".", "_")
        with open(os.path.join(out_dir, safe + ".json"), "w") as f:
            json.dump(rec, f, indent=1, default=str)


def _fmt_b(n):
    if n is None:
        return "?"
    for u in ("B", "KB", "MB", "GB", "TB"):
        if n < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}PB"


def run_compression_dryrun(mesh_kind: str, out_dir=None,
                           n_elems: int = 2_000_000_000):
    """Paper-representative cell: NUMARCK encode stage over the full mesh.

    n defaults to 2e9 elements (8 GB f32 variable, the int32-offset
    envelope; Stir-2/3 scale linearly in per-shard work).
    """
    from repro.core.types import NumarckParams
    from repro.distributed import pipeline as pl
    from jax.experimental.shard_map import shard_map

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    axis_names = mesh.axis_names
    P_ = mesh_chips(mesh)
    params = NumarckParams(error_bound=1e-3, max_bins=1 << 16)
    t0 = time.time()
    try:
        # analyze stage: one-shot lower/compile for cost analysis.
        # repro-lint: disable=jit-cache-hygiene
        analyze = shard_map(
            partial(pl._analyze_shard, max_bins=params.max_bins,
                    b_max=params.b_max, elem_bytes=4, n_total=n_elems,
                    axis=axis_names[0], use_pallas=False),
            mesh=mesh, in_specs=(P(axis_names[0]), P(axis_names[0]), P()),
            out_specs=(P(axis_names[0]),) * 6, check_rep=False)
        # NB: shard over the first axis only for the collective pattern the
        # paper has (one flat allreduce); remaining axes replicate.
        n_shards = mesh.shape[axis_names[0]]
        ln_a = n_elems // n_shards
        sds = jax.ShapeDtypeStruct((n_shards * ln_a,), jnp.float32)
        # repro-lint: disable=jit-cache-hygiene
        low = jax.jit(analyze).lower(sds, sds, jnp.float32(1e-3))
        comp = low.compile()
        from repro.launch.cost_model import hlo_cost
        cost = hlo_cost(comp)
        colls = collective_bytes(comp.as_text())
        mem = comp.memory_analysis()
        rec = dict(arch="numarck-pipeline", shape=f"n{n_elems:.0e}",
                   mesh=mesh_kind, status="OK", chips=P_,
                   flops_per_device=float(cost.get("flops", 0)),
                   bytes_per_device=float(cost.get("bytes accessed", 0)),
                   collective_bytes_per_device=colls.get("total", 0),
                   collectives=colls,
                   roofline=dict(
                       compute_s=float(cost.get("flops", 0))
                       / HW["peak_flops_bf16"],
                       memory_s=float(cost.get("bytes accessed", 0))
                       / HW["hbm_bw"],
                       collective_s=colls.get("total", 0) / HW["ici_bw"]),
                   memory=dict(
                       argument=mem.argument_size_in_bytes,
                       output=mem.output_size_in_bytes,
                       temp=mem.temp_size_in_bytes,
                       peak=getattr(mem, "peak_memory_in_bytes", None),
                   ) if mem else None,
                   compile_s=round(time.time() - t0, 2))
        rec["dominant"] = max(rec["roofline"], key=rec["roofline"].get)
    except Exception as e:  # noqa: BLE001
        rec = dict(arch="numarck-pipeline", shape=f"n{n_elems:.0e}",
                   mesh=mesh_kind, status="FAIL",
                   error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    _emit(rec, out_dir)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compression", action="store_true",
                    help="also dry-run the NUMARCK pipeline cell")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")

    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                results.append(run_cell(arch, shape, mesh_kind, args.out))
        if args.compression:
            results.append(run_compression_dryrun(mesh_kind, args.out))

    n_ok = sum(r["status"] == "OK" for r in results)
    n_skip = sum(r["status"] == "SKIP" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n== dry-run: {n_ok} OK, {n_skip} skipped (documented), "
          f"{n_fail} FAILED ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
