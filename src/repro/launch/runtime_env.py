"""Host runtime environment preset (ROADMAP "host runtime hardening").

The multi-process launcher spawns worker interpreters; each one pays the
host-side costs the big JAX training launchers all patch over the same
way (HomebrewNLP/olmax run.sh, MaxText MultiHostJob -- SNIPPETS §1-3):

  * glibc malloc fragments the large transient host buffers the finalize
    stage churns through -- preload tcmalloc when the host has it;
  * tcmalloc then logs every "large alloc" over ~1 GB to stderr, which
    garbles benchmark CSV output -- raise the report threshold;
  * TF/XLA C++ logging defaults to chatty INFO on workers -- silence it;
  * the CPU emulation path needs ``--xla_force_host_platform_device_count``
    set *before* jax imports, so it must travel via the child environment.

Everything here is a pure dict-in/dict-out helper: nothing touches
``os.environ`` of the calling process, and importing this module never
imports jax (launchers build child environments long before jax exists
in the child).
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional

# Common soname locations across distro families; first hit wins.  The
# plain .so names cover toolchain images that ship only the -dev links.
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so",
)

# ~60 GB, the olmax value: effectively "never report" without disabling
# the accounting entirely.
TCMALLOC_REPORT_THRESHOLD = "60000000000"


def find_tcmalloc(candidates=TCMALLOC_CANDIDATES) -> Optional[str]:
    """First present tcmalloc soname, or None (glibc malloc stays)."""
    for path in candidates:
        if os.path.exists(path):
            return path
    return None


def merge_xla_flags(existing: Optional[str], flags: List[str]) -> str:
    """Append XLA flags to an existing XLA_FLAGS value, dropping any
    duplicate ``--flag=...`` the caller is overriding (last write wins,
    matching XLA's own parse order would keep the first -- so we remove
    the stale copy instead of relying on it)."""
    keep = []
    new_keys = {f.split("=", 1)[0] for f in flags}
    for tok in (existing or "").split():
        if tok.split("=", 1)[0] not in new_keys:
            keep.append(tok)
    return " ".join(keep + list(flags)).strip()


def runtime_env(base: Optional[Dict[str, str]] = None, *,
                host_device_count: Optional[int] = None,
                tcmalloc: bool = True,
                quiet_logs: bool = True) -> Dict[str, str]:
    """Build a child-process environment with the runtime preset applied.

    ``base`` defaults to a copy of ``os.environ``; the result is always a
    new dict.  ``host_device_count`` adds the CPU-emulation XLA flag
    (``--xla_force_host_platform_device_count=K``), which only has an
    effect when set before the child imports jax -- which is exactly why
    it lives in the environment and not in code.
    """
    env = dict(os.environ if base is None else base)
    if tcmalloc:
        lib = find_tcmalloc()
        if lib is not None:
            pre = env.get("LD_PRELOAD", "")
            if lib not in pre.split(":"):
                env["LD_PRELOAD"] = f"{pre}:{lib}".strip(":")
            env.setdefault("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                           TCMALLOC_REPORT_THRESHOLD)
    if quiet_logs:
        env.setdefault("TF_CPP_MIN_LOG_LEVEL", "4")
    if host_device_count is not None:
        env["XLA_FLAGS"] = merge_xla_flags(
            env.get("XLA_FLAGS"),
            [f"--xla_force_host_platform_device_count={host_device_count}"])
    return env


__all__ = ["find_tcmalloc", "merge_xla_flags", "runtime_env",
           "TCMALLOC_CANDIDATES", "TCMALLOC_REPORT_THRESHOLD"]
