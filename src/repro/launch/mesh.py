"""Production mesh construction (TPU v5e pods; 256 chips/pod).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests and benches must keep seeing the
plain CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """Batch/FSDP axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n


__all__ = ["make_production_mesh", "dp_axes", "tp_axis", "mesh_chips"]
