"""Multi-process launch: jax.distributed init + localhost CI emulation.

Two ways into the same code path:

  * **Real multi-host**: every host runs the same program;
    ``initialize()`` reads the coordinator address / process id / process
    count from the ``REPRO_COORDINATOR`` / ``REPRO_PROCESS_ID`` /
    ``REPRO_NUM_PROCESSES`` environment (or explicit arguments) and calls
    ``jax.distributed.initialize``.  After that, ``jax.devices()`` is
    global and ``global_mesh()`` spans every process.

  * **CI emulation**: ``spawn_emulated(n, argv)`` launches n localhost
    subprocesses of the *same* worker program with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` (the
    HomebrewNLP/olmax run.sh idiom) and a free-port coordinator, so the
    2-process integration tests and the speedup-vs-ranks bench exercise
    the identical initialize/mesh/shard_map path a real fleet uses.

CPU processes talk through the gloo collectives backend; that config
must land before the first collective compiles, so ``initialize()`` sets
it right before ``jax.distributed.initialize``.  Like launch.mesh,
everything here is functions -- importing this module never touches jax
device state.
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.faults.retry import Backoff
from repro.launch.runtime_env import runtime_env

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

# A fleet that died because the coordinator could not bind its probed
# port (the free_port() bind-then-release race: another process grabbed
# it first) is retried with a fresh port; any other failure is real and
# returned to the caller untouched.
_BIND_FAILURE_MARKERS = ("address already in use", "eaddrinuse",
                         "errno: 98", "failed to bind")


@dataclass(frozen=True)
class DistributedConfig:
    """Where this process sits in the fleet (1-process == no fleet)."""

    coordinator: str = "localhost:0"
    num_processes: int = 1
    process_id: int = 0


def env_config(environ: Optional[Dict[str, str]] = None
               ) -> Optional[DistributedConfig]:
    """Fleet coordinates from the environment; None when not launched as
    part of one (plain single-process runs stay untouched)."""
    env = os.environ if environ is None else environ
    if ENV_NUM_PROCESSES not in env:
        return None
    return DistributedConfig(
        coordinator=env.get(ENV_COORDINATOR, "localhost:0"),
        num_processes=int(env[ENV_NUM_PROCESSES]),
        process_id=int(env.get(ENV_PROCESS_ID, "0")))


def initialize(cfg: Optional[DistributedConfig] = None, *,
               collectives: str = "gloo") -> DistributedConfig:
    """Join the fleet (idempotent for 1-process configs).

    Must run before any other jax device use.  Returns the resolved
    config so workers can log their coordinates.
    """
    if cfg is None:
        cfg = env_config() or DistributedConfig()
    if cfg.num_processes > 1:
        import jax
        # CPU processes need a cross-process collectives backend; the
        # config has to land before distributed init spins up the client.
        jax.config.update("jax_cpu_collectives_implementation", collectives)
        jax.distributed.initialize(coordinator_address=cfg.coordinator,
                                   num_processes=cfg.num_processes,
                                   process_id=cfg.process_id)
    return cfg


def global_mesh(axis: str = "data"):
    """1-D mesh over every device in the fleet.  With
    ``jax.distributed.initialize`` done, ``jax.devices()`` enumerates all
    processes' devices (process 0's first, each process contiguous), so
    shard i of an evenly split axis is addressable exactly on the process
    that owns device i -- the contiguous-ownership layout the per-host
    writer tier relies on."""
    import jax
    import numpy as np
    return jax.sharding.Mesh(np.asarray(jax.devices()), (axis,))


def process_rank() -> int:
    import jax
    return jax.process_index()


def process_count() -> int:
    import jax
    return jax.process_count()


def free_port() -> int:
    """A currently free TCP port for the emulated coordinator (the usual
    bind-to-0 trick; the tiny race against other processes is fine for
    CI-scope launches)."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def rank_env(rank: int, num_processes: int, coordinator: str, *,
             devices_per_process: int = 1,
             base: Optional[Dict[str, str]] = None,
             preset: bool = True) -> Dict[str, str]:
    """Child environment for emulated rank `rank`: fleet coordinates plus
    the runtime preset (tcmalloc / log level / XLA host-device flag)."""
    env = (runtime_env(base, host_device_count=devices_per_process)
           if preset else dict(os.environ if base is None else base))
    if not preset and devices_per_process != 1:
        from repro.launch.runtime_env import merge_xla_flags
        env["XLA_FLAGS"] = merge_xla_flags(
            env.get("XLA_FLAGS"),
            [f"--xla_force_host_platform_device_count="
             f"{devices_per_process}"])
    env[ENV_COORDINATOR] = coordinator
    env[ENV_NUM_PROCESSES] = str(num_processes)
    env[ENV_PROCESS_ID] = str(rank)
    return env


def _coordinator_bind_failed(results: List[subprocess.CompletedProcess]
                             ) -> bool:
    """Did this fleet die on the coordinator-port bind race?  Only a
    failing rank whose stderr carries a bind-failure marker counts --
    worker crashes, injected faults and timeouts are NOT retried."""
    for r in results:
        if r.returncode == 0:
            continue
        text = (r.stderr or "").lower()
        if any(m in text for m in _BIND_FAILURE_MARKERS):
            return True
    return False


def _spawn_once(num_processes: int, argv: Sequence[str], coordinator: str,
                devices_per_process: int,
                base_env: Optional[Dict[str, str]], preset: bool,
                timeout: float) -> List[subprocess.CompletedProcess]:
    procs = []
    for rank in range(num_processes):
        env = rank_env(rank, num_processes, coordinator,
                       devices_per_process=devices_per_process,
                       base=base_env, preset=preset)
        procs.append(subprocess.Popen(
            [sys.executable, *argv], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    deadline = time.monotonic() + timeout
    results: List[subprocess.CompletedProcess] = []
    for rank, proc in enumerate(procs):
        left = max(deadline - time.monotonic(), 0.1)
        try:
            out, err = proc.communicate(timeout=left)
        except subprocess.TimeoutExpired:
            for p in procs:
                if p.poll() is None:
                    p.kill()
            out, err = proc.communicate()
        results.append(subprocess.CompletedProcess(
            proc.args, proc.returncode, out, err))
    return results


def spawn_emulated(num_processes: int, argv: Sequence[str], *,
                   devices_per_process: int = 1,
                   base_env: Optional[Dict[str, str]] = None,
                   preset: bool = True,
                   timeout: float = 600.0,
                   bind_attempts: int = 3
                   ) -> List[subprocess.CompletedProcess]:
    """Launch ``python <argv...>`` num_processes times on localhost with a
    shared free-port coordinator; wait for all; return per-rank results
    (rank order).  Does not raise on nonzero exits -- crash-tolerance
    tests inspect returncodes; use ``check_spawned`` for the common
    all-must-succeed case.

    The coordinator port comes from ``free_port()``'s bind-then-release
    probe, which races other processes on the host: by the time the fleet
    binds it, someone else may own it.  When a failing rank's stderr
    shows a bind failure, the *whole fleet* is relaunched with a fresh
    port -- up to ``bind_attempts`` total attempts with jittered backoff
    (``repro.faults.retry.Backoff``) -- since a half-initialized fleet
    can never recover in place.
    """
    results: List[subprocess.CompletedProcess] = []
    delays = Backoff(attempts=max(1, bind_attempts) - 1, base=0.1).delays()
    for attempt in range(max(1, bind_attempts)):
        coordinator = f"localhost:{free_port()}"
        results = _spawn_once(num_processes, argv, coordinator,
                              devices_per_process, base_env, preset, timeout)
        if not _coordinator_bind_failed(results):
            break
        try:
            time.sleep(next(delays))
        except StopIteration:  # attempts exhausted: return the last fleet
            break
    return results


def check_spawned(results: List[subprocess.CompletedProcess]) -> None:
    """Raise with the first failing rank's output attached."""
    for rank, r in enumerate(results):
        if r.returncode != 0:
            raise RuntimeError(
                f"emulated rank {rank} exited {r.returncode}\n"
                f"--- stdout ---\n{r.stdout}\n--- stderr ---\n{r.stderr}")


__all__ = ["DistributedConfig", "env_config", "initialize", "global_mesh",
           "process_rank", "process_count", "free_port", "rank_env",
           "spawn_emulated", "check_spawned",
           "ENV_COORDINATOR", "ENV_NUM_PROCESSES", "ENV_PROCESS_ID"]
