"""Training driver: --arch <id> end-to-end (data -> train loop -> NUMARCK
checkpoints -> restart).

On this CPU container use --smoke (reduced config); the full configs are
exercised through launch/dryrun.py.  On a real fleet the same driver runs
under jax.distributed with the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.core import NumarckParams
from repro.data.tokens import TokenPipeline
from repro.models.model import build
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-error-bound", type=float, default=1e-4)
    ap.add_argument("--grad-compression-bits", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    model = build(args.arch, smoke=args.smoke)
    if model.cfg.frontend:
        raise SystemExit(f"{args.arch}: frontend archs train via "
                         "examples/train_restart.py sample batches")
    print(f"arch={model.cfg.name} params~{model.cfg.param_count():,}")

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(
            args.ckpt_dir,
            params=NumarckParams(error_bound=args.ckpt_error_bound),
            anchor_every=4, keep=3)
    tcfg = TrainerConfig(
        opt=optim.AdamWConfig(lr=args.lr, warmup_steps=10,
                              decay_steps=args.steps),
        checkpoint_every=args.ckpt_every if mgr else 0,
        grad_compression_bits=args.grad_compression_bits)
    trainer = Trainer(model, tcfg, checkpoint_manager=mgr)

    state, start = trainer.restore_or_init(jax.random.PRNGKey(args.seed))
    if start:
        print(f"restored checkpoint at step {start}")
    pipe = TokenPipeline(model.cfg.vocab_size, args.seq + 1, args.batch,
                         seed=args.seed)
    state, step, hist = trainer.fit(state, pipe.from_step(start),
                                    start_step=start, n_steps=args.steps)
    print(f"done at step {step}; loss {hist[0]:.4f} -> {hist[-1]:.4f}; "
          f"straggler events: {trainer.straggler_events}")
    if mgr:
        mgr.save(step, state.tree())
        print("final checkpoint saved")


if __name__ == "__main__":
    main()
