"""Data layer: synthetic temporal fields + LM token pipeline."""
