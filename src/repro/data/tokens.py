"""Deterministic sharded LM token pipeline.

Production shape: an infinite iterator of global batches, deterministic in
(seed, step) so every restart resumes bit-identically at any step (the
fault-tolerance contract), and sharded placement-ready (each host would
slice its rows; in this container there is one host).

A tiny synthetic "language" (order-2 Markov chain over the vocab) gives the
loss a learnable structure for convergence tests.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, order: int = 2, n_states: int = 64):
        self.V = vocab_size
        self.S = seq_len
        self.B = global_batch
        self.seed = seed
        rng = np.random.default_rng(seed)
        # sparse-ish transition structure: each state strongly prefers a few
        # successors -> learnable
        self.n_states = min(n_states, vocab_size)
        probs = rng.dirichlet(np.full(self.n_states, 0.1),
                              size=self.n_states)
        self.cum = np.cumsum(probs, axis=1)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for `step` (restart-safe)."""
        rng = np.random.default_rng((self.seed, step))
        u = rng.random((self.B, self.S))
        toks = np.zeros((self.B, self.S), np.int64)
        toks[:, 0] = rng.integers(0, self.n_states, self.B)
        for t in range(1, self.S):
            state = toks[:, t - 1] % self.n_states
            toks[:, t] = (self.cum[state] < u[:, t, None]).sum(axis=1)
        toks = toks % self.V
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1

    def from_step(self, start: int) -> Iterator[Dict[str, np.ndarray]]:
        step = start
        while True:
            yield self.batch(step)
            step += 1


__all__ = ["TokenPipeline"]
