"""Synthetic temporal scientific datasets mimicking the paper's corpora.

The paper evaluates on FLASH Sedov/Stir (hydrodynamic turbulence), ASR
(Arctic reanalysis) and CMIP3 (coupled climate).  Real corpora are not
available offline, so we synthesize fields with the statistical properties
the paper leans on:

  * spatial correlation -- power-law spectrum (turbulence-like; `slope`)
  * temporal coherence  -- element-wise multiplicative evolution with
    volatility `vol` (small change ratios, the property NUMARCK exploits)
  * intermittency      -- a fraction of elements jumps (incompressible)
  * entropy control    -- `vol` scales the change-ratio spread; stir-like
    fields use high vol (hard to compress), sedov-like fields mostly-static
    cells (ratios under |E| -> the paper's ZLIB 'Sedov effect', Fig. 17)

Each generator yields float32/float64 arrays of the paper's per-variable
shapes (scaled down by `scale` to fit CPU memory).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


def _correlated_field(rng, shape, slope=-1.7):
    """Random field with power-law spectrum via FFT filtering."""
    white = rng.standard_normal(shape)
    f = np.fft.rfftn(white)
    freqs = np.meshgrid(*[np.fft.fftfreq(n) for n in shape[:-1]]
                        + [np.fft.rfftfreq(shape[-1])], indexing="ij")
    k = np.sqrt(sum(g ** 2 for g in freqs))
    k[tuple([0] * len(shape))] = 1.0
    f *= k ** slope
    out = np.fft.irfftn(f, shape, axes=tuple(range(len(shape))))
    out = (out - out.mean()) / (out.std() + 1e-9)
    return out


@dataclass
class TemporalFieldSpec:
    name: str
    shape: Tuple[int, ...]
    dtype: str
    vol: float            # change-ratio volatility per step
    jump_frac: float      # fraction of intermittent jumps per step
    static_frac: float    # fraction of cells with ~zero change (sedov-like)
    offset: float = 2.0   # keeps values away from 0
    slope: float = -1.7


# paper Table 1 analogues (scaled: `scale` divides each dim)
SPECS = {
    # Sedov: double precision, 80% of points change less than |E|
    "sedov": TemporalFieldSpec("sedov", (165, 32, 32), "float64",
                               vol=5e-3, jump_frac=0.002, static_frac=0.8),
    # Stir: fully developed turbulence, high entropy, hard to compress
    "stir": TemporalFieldSpec("stir", (64, 157, 157), "float32",
                              vol=2e-2, jump_frac=0.01, static_frac=0.0),
    # ASR: atmospheric reanalysis (wind speed UU-like)
    "asr": TemporalFieldSpec("asr", (29, 320, 320), "float32",
                             vol=8e-3, jump_frac=0.005, static_frac=0.1),
    # CMIP: ocean current velocity (UVEL-like), smooth + repetitive
    "cmip": TemporalFieldSpec("cmip", (42, 360, 240), "float32",
                              vol=4e-3, jump_frac=0.002, static_frac=0.3),
}


def generate_series(spec_name: str, n_iterations: int = 5, seed: int = 0,
                    scale: int = 1) -> Iterator[np.ndarray]:
    """Yield `n_iterations` temporally-coherent snapshots."""
    spec = SPECS[spec_name]
    shape = tuple(max(4, s // scale) for s in spec.shape)
    rng = np.random.default_rng(seed)
    base = _correlated_field(rng, shape, spec.slope) + spec.offset
    field = base.astype(spec.dtype)
    static_mask = rng.random(shape) < spec.static_frac
    yield field.copy()
    for _ in range(n_iterations - 1):
        # spatially-correlated multiplicative change
        change = 1.0 + spec.vol * _correlated_field(rng, shape, spec.slope)
        change = np.where(static_mask,
                          1.0 + rng.standard_normal(shape) * 1e-6, change)
        jumps = rng.random(shape) < spec.jump_frac
        change = np.where(jumps, 1.0 + rng.standard_normal(shape), change)
        field = (field * change).astype(spec.dtype)
        yield field.copy()


def dataset_bytes(spec_name: str, scale: int = 1) -> int:
    spec = SPECS[spec_name]
    shape = tuple(max(4, s // scale) for s in spec.shape)
    return int(np.prod(shape)) * np.dtype(spec.dtype).itemsize


__all__ = ["SPECS", "TemporalFieldSpec", "generate_series", "dataset_bytes"]
