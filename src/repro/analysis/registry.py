"""Pass-plugin registry: passes self-register at import time.

Mirrors the codec registry in ``core.entropy`` -- one dict keyed by rule
id, a ``register_pass`` decorator, and name-based lookup so the CLI's
``--select``/``--list-rules`` and the tests can address passes
individually.  Importing :mod:`repro.analysis.passes` populates it.
"""
from __future__ import annotations

from typing import Dict, List, Type

from repro.analysis.core import LintPass

_REGISTRY: Dict[str, Type[LintPass]] = {}


def register_pass(cls: Type[LintPass]) -> Type[LintPass]:
    if cls.rule in _REGISTRY and _REGISTRY[cls.rule] is not cls:
        raise ValueError(f"duplicate lint rule {cls.rule!r}")
    _REGISTRY[cls.rule] = cls
    return cls


def get_pass(rule: str) -> Type[LintPass]:
    _ensure_loaded()
    try:
        return _REGISTRY[rule]
    except KeyError:
        raise ValueError(
            f"unknown lint rule {rule!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def all_passes() -> List[Type[LintPass]]:
    _ensure_loaded()
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def _ensure_loaded():
    # Import-for-effect: the passes package registers every shipped pass.
    from repro.analysis import passes  # noqa: F401


__all__ = ["register_pass", "get_pass", "all_passes"]
