"""Committed-baseline handling for repro-lint.

The baseline is the reviewed list of violations the repo has accepted
(intentional boundary syncs, constructor-time jit stores with a
documented lifetime).  A fingerprint deliberately excludes line numbers
-- ``(rule, path, scope, message)`` -- so unrelated edits above a
baselined site don't churn the file; moving the code to a different
function or changing the message retires the entry.

``diff`` returns both directions: *new* violations (fail CI) and *stale*
baseline entries (the accepted violation no longer exists -- reported so
the baseline can be re-tightened, but not a failure: a lint run must
never go red because someone fixed a bug).
"""
from __future__ import annotations

import json
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Violation

DEFAULT_BASELINE = "repro-lint.baseline.json"

Fingerprint = Tuple[str, str, str, str]


def save(path: str, violations: Sequence[Violation]) -> None:
    entries = sorted({v.fingerprint() for v in violations})
    payload = {
        "comment": "accepted repro-lint violations; regenerate with "
                   "`python -m repro.analysis --write-baseline`",
        "entries": [
            {"rule": r, "path": p, "scope": s, "message": m}
            for (r, p, s, m) in entries
        ],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")


def load(path: str) -> List[Fingerprint]:
    try:
        with open(path, "r", encoding="utf-8") as fh:
            payload = json.load(fh)
    except FileNotFoundError:
        return []
    out: List[Fingerprint] = []
    for e in payload.get("entries", []):
        out.append((e["rule"], e["path"], e["scope"], e["message"]))
    return out


def diff(violations: Sequence[Violation],
         baseline: Sequence[Fingerprint],
         ) -> Tuple[List[Violation], List[Fingerprint]]:
    """(new_violations, stale_baseline_entries).

    Fingerprints are counted, not set-matched: two *new* unlabeled
    submits in the same scope with the same message are two findings,
    and a baseline entry absorbs exactly as many occurrences as were
    accepted when it was written (one per entry -- ``save`` dedups, so
    an entry absorbs all same-fingerprint occurrences; the distinction
    matters only for hand-edited baselines, where dropping an entry
    surfaces every occurrence again).
    """
    accepted: Dict[Fingerprint, bool] = {fp: False for fp in baseline}
    new: List[Violation] = []
    for v in violations:
        fp = v.fingerprint()
        if fp in accepted:
            accepted[fp] = True
        else:
            new.append(v)
    stale = [fp for fp, seen in accepted.items() if not seen]
    return new, stale
