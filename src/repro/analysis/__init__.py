"""repro-lint: project-specific static analysis for the pipeline's
cross-cutting contracts.

The pipeline's correctness and performance rest on invariants that span
modules and that generic linters cannot see: device-resident paths must
not host-sync (PR 4/5/7), ``jax.jit``/``shard_map`` callables must come
from keyed caches so temporal series trace once (a retrace storm is a
silent 10x regression), the overlap/entropy concurrency machinery has a
lock and labelling discipline (PR 3/6), the NCK container / rANS blob
format matrix must stay closed (PR 5/7), and float64 must never reach a
device path without an x64 guard (PR 4).  ``repro.analysis`` encodes each
of those contracts as an AST pass over ``src/repro``:

  * :mod:`repro.analysis.core` -- shared source model: parsed AST,
    qualified function scopes, ``# repro-lint: disable=<rule>`` inline
    suppressions.
  * :mod:`repro.analysis.registry` -- the pass-plugin registry; passes
    self-register at import.
  * :mod:`repro.analysis.baseline` -- committed-baseline handling: CI
    fails only on *new* violations (line-number-free fingerprints).
  * :mod:`repro.analysis.passes` -- the five shipped passes (see
    ``docs/static_analysis.md`` for the rule catalogue).
  * :mod:`repro.analysis.cli` -- ``python -m repro.analysis`` /
    ``repro-lint`` entry point (``make lint``).
"""
from repro.analysis.core import (LintPass, Project, SourceFile, Violation,
                                 device_resident, load_project)
from repro.analysis.registry import all_passes, get_pass, register_pass

__all__ = ["LintPass", "Project", "SourceFile", "Violation",
           "device_resident", "load_project", "all_passes", "get_pass",
           "register_pass"]
