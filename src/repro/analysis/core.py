"""Shared AST walker and source model for repro-lint passes.

One :class:`SourceFile` per scanned file carries the parsed tree, the
source lines, a map of inline suppressions, and the function scope table
(qualified names, so passes report ``ShardedCompressor._device_encode``
instead of a bare line number).  :class:`Project` bundles the scanned
files with the repo root so cross-file passes (format closure needs the
container writer, the blob header definitions and the test fixtures at
once) can see the whole surface.

Suppressions: a trailing or immediately preceding comment of the form ::

    # repro-lint: disable=<rule>[,<rule>...]

suppresses those rules for the annotated line.  Placed on a ``def`` line
it suppresses the rules for the whole function body -- that is the escape
hatch for documented, intentional contract exceptions (use sparingly; the
committed baseline is for legacy findings, suppressions are for
load-bearing ones that should never resurface as "new").
"""
from __future__ import annotations

import ast
import io
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=([\w\-,\s]+)")

# Marker attribute set by the @device_resident decorator; the host-sync
# and dtype passes treat decorated functions exactly like registry hits.
_DEVICE_ATTR = "__repro_device_resident__"


def device_resident(fn):
    """Mark a function as device-resident for repro-lint (no runtime
    effect).  The host-sync and dtype-hazard passes scan decorated
    functions in addition to the built-in name registry."""
    setattr(fn, _DEVICE_ATTR, True)
    return fn


@dataclass(frozen=True)
class Violation:
    """One finding.  ``scope`` is the qualified function name (or
    ``<module>``); the baseline fingerprint deliberately excludes the
    line number so unrelated edits above a finding don't churn it."""

    rule: str
    path: str                    # repo-relative, "/"-separated
    line: int
    scope: str
    message: str

    def fingerprint(self) -> Tuple[str, str, str, str]:
        return (self.rule, self.path, self.scope, self.message)

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.scope}: "
                f"{self.message}")


@dataclass
class FunctionInfo:
    """One function scope: qualified name, its AST node, decorator names
    (dotted strings) and the line range it covers."""

    qualname: str
    node: ast.AST                # FunctionDef | AsyncFunctionDef | Lambda
    decorators: List[str] = field(default_factory=list)

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def line_range(self) -> Tuple[int, int]:
        return (self.node.lineno, max(self.node.lineno,
                                      getattr(self.node, "end_lineno", 0)
                                      or self.node.lineno))


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None (the one name
    resolver every pass shares)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee (``np.asarray``, ``self._q.submit``)."""
    return dotted_name(call.func)


def names_in(node: ast.AST) -> Set[str]:
    """Every dotted name (and bare name) mentioned anywhere under node."""
    out: Set[str] = set()
    for sub in ast.walk(node):
        d = dotted_name(sub)
        if d is not None:
            out.add(d)
    return out


class _ScopeCollector(ast.NodeVisitor):
    """Builds the qualified-name function table of one module."""

    def __init__(self):
        self.functions: List[FunctionInfo] = []
        self._stack: List[str] = []

    def _qual(self, name: str) -> str:
        return ".".join(self._stack + [name])

    def _visit_func(self, node):
        decs = [d for d in (dotted_name(dec.func)
                            if isinstance(dec, ast.Call) else dotted_name(dec)
                            for dec in node.decorator_list) if d]
        # partial(jax.jit, ...) decorators: record the inner callable too.
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call):
                for a in dec.args:
                    d = dotted_name(a)
                    if d:
                        decs.append(d)
        info = FunctionInfo(self._qual(node.name), node, decs)
        self.functions.append(info)
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_ClassDef(self, node):
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()


def _parse_suppressions(source: str) -> Dict[int, Set[str]]:
    """line -> set(rule) from ``# repro-lint: disable=...`` comments."""
    out: Dict[int, Set[str]] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = _SUPPRESS_RE.search(tok.string)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return out


class SourceFile:
    """One parsed module plus its scope table and suppression map."""

    def __init__(self, path: str, rel: str, source: str):
        self.path = path
        self.rel = rel.replace(os.sep, "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        col = _ScopeCollector()
        col.visit(self.tree)
        self.functions = col.functions
        self._suppress = _parse_suppressions(source)
        # def-line suppressions widen to the whole function body.
        self._func_suppress: List[Tuple[int, int, Set[str]]] = []
        for fi in self.functions:
            lo, hi = fi.line_range
            rules: Set[str] = set()
            dec_lo = min([d.lineno for d in fi.node.decorator_list] + [lo])
            # dec_lo - 1: a comment line directly above the def (or its
            # first decorator) suppresses the whole body, matching the
            # prev-line semantics statements already get.
            for ln in range(dec_lo - 1, getattr(fi.node, "body",
                                                [fi.node])[0].lineno + 1):
                rules |= self._suppress.get(ln, set())
            if rules:
                self._func_suppress.append((lo, hi, rules))

    def scope_at(self, line: int) -> str:
        """Qualified name of the *innermost* function covering `line`."""
        best: Optional[FunctionInfo] = None
        for fi in self.functions:
            lo, hi = fi.line_range
            if lo <= line <= hi:
                if best is None or lo >= best.line_range[0]:
                    best = fi
        return best.qualname if best else "<module>"

    def suppressed(self, line: int, rule: str) -> bool:
        for probe in (line, line - 1):
            if rule in self._suppress.get(probe, set()):
                return True
        for lo, hi, rules in self._func_suppress:
            if lo <= line <= hi and rule in rules:
                return True
        return False

    def function_named(self, name: str) -> List[FunctionInfo]:
        return [f for f in self.functions if f.name == name]


class Project:
    """The scanned file set plus the repo root (for cross-tree passes)."""

    def __init__(self, files: Sequence[SourceFile], root: str):
        self.files = list(files)
        self.root = root

    def by_rel(self, rel: str) -> Optional[SourceFile]:
        for f in self.files:
            if f.rel == rel or f.rel.endswith("/" + rel):
                return f
        return None

    def iter_tree_files(self, subdir: str,
                        suffix: str = ".py") -> Iterator[str]:
        """Paths under ``root/subdir`` (e.g. the test fixtures the format
        pass cross-checks); yields nothing when the dir is absent."""
        base = os.path.join(self.root, subdir)
        for dirpath, _, names in os.walk(base):
            for n in sorted(names):
                if n.endswith(suffix):
                    yield os.path.join(dirpath, n)


class LintPass:
    """Base class for repro-lint passes.

    Subclasses set ``rule`` (the id used by suppressions and the
    baseline) and implement either :meth:`check_file` (per-module passes)
    or :meth:`check_project` (cross-file passes); the driver calls both.
    Use :meth:`emit` so suppression filtering is applied uniformly.
    """

    rule: str = "abstract"
    description: str = ""

    def __init__(self):
        self._out: List[Violation] = []

    def emit(self, sf: Optional[SourceFile], line: int, message: str,
             rel: Optional[str] = None, scope: Optional[str] = None):
        if sf is not None and sf.suppressed(line, self.rule):
            return
        self._out.append(Violation(
            rule=self.rule,
            path=rel if rel is not None else (sf.rel if sf else "<project>"),
            line=line,
            scope=scope if scope is not None
            else (sf.scope_at(line) if sf else "<project>"),
            message=message))

    def check_file(self, sf: SourceFile) -> None:   # per-module hook
        pass

    def check_project(self, project: Project) -> None:  # cross-file hook
        pass

    def run(self, project: Project) -> List[Violation]:
        self._out = []
        for sf in project.files:
            self.check_file(sf)
        self.check_project(project)
        return list(self._out)


def load_project(paths: Sequence[str], root: str) -> Project:
    """Parse every ``.py`` under `paths` into a Project (skips files that
    fail to parse -- reported by the CLI as hard errors instead)."""
    files: List[SourceFile] = []
    seen: Set[str] = set()
    for p in paths:
        if os.path.isfile(p):
            cands = [p]
        else:
            cands = [os.path.join(dp, n)
                     for dp, _, names in os.walk(p)
                     for n in sorted(names) if n.endswith(".py")]
        for c in sorted(cands):
            c = os.path.abspath(c)
            if c in seen:
                continue
            seen.add(c)
            rel = os.path.relpath(c, root)
            with open(c, "r", encoding="utf-8") as fh:
                files.append(SourceFile(c, rel, fh.read()))
    return Project(files, root)


__all__ = ["Violation", "FunctionInfo", "SourceFile", "Project", "LintPass",
           "device_resident", "dotted_name", "call_name", "names_in",
           "load_project"]
