"""Shipped repro-lint passes.

Importing this package registers every pass with
:mod:`repro.analysis.registry` (import-for-effect, like the entropy
codec registry).  Third-party/project-local passes can register the same
way: subclass :class:`repro.analysis.LintPass`, decorate with
``@register_pass``, and import the module before running.
"""
from repro.analysis.passes import concurrency        # noqa: F401
from repro.analysis.passes import dtype_hazards      # noqa: F401
from repro.analysis.passes import format_closure     # noqa: F401
from repro.analysis.passes import host_sync          # noqa: F401
from repro.analysis.passes import jit_cache          # noqa: F401
from repro.analysis.passes import retry_discipline   # noqa: F401
