"""Pass: jit-cache-hygiene.

A ``jax.jit``/``shard_map`` callable constructed per call retraces every
step -- a silent 10x regression on exactly the temporal-series hot path
the paper's parallel design exists to speed up (the sharded driver's
per-step shard_map retrace used to dominate before the
``self._analyze_fns[key]`` caches landed, PR 3).  This pass enforces the
sanctioned shapes:

  1. **module scope** -- ``@jax.jit`` / ``@partial(jax.jit, ...)``
     decorators on top-level functions, or module-level
     ``fn = jax.jit(...)`` assignments: traced once per process per
     static signature.
  2. **keyed cache stores** -- inside a function, the ``jax.jit(...)`` /
     ``shard_map(...)`` result must be assigned into a subscript
     (``self._analyze_fns[key] = jax.jit(fn)``), the memoized-executable
     pattern of ``distributed/pipeline.py``.

Everything else inside a function body is flagged, with
``jax.jit(lambda ...)`` called out explicitly -- that one is *always* a
per-call trace.  Constructor-time ``self._fn = jax.jit(...)`` stores are
*not* auto-sanctioned: they trace per instance, which is fine for
long-lived engines but wrong for per-step objects -- legitimate ones
carry an inline suppression so the reviewer sees the claim.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional

from repro.analysis.core import LintPass, SourceFile, call_name, dotted_name
from repro.analysis.registry import register_pass

_JIT_NAMES = {"jax.jit", "jit", "shard_map", "pjit", "jax.pjit"}


def _is_jit_call(node: ast.Call) -> bool:
    name = call_name(node)
    if name in _JIT_NAMES:
        return True
    # partial(jax.jit, ...) / functools.partial(shard_map, ...)
    if name in {"partial", "functools.partial"} and node.args:
        return dotted_name(node.args[0]) in _JIT_NAMES
    return False


@register_pass
class JitCachePass(LintPass):
    rule = "jit-cache-hygiene"
    description = ("jax.jit/shard_map call sites must be module-level or "
                   "stored into a keyed cache dict")

    def check_file(self, sf: SourceFile) -> None:
        parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(sf.tree):
            for child in ast.iter_child_nodes(node):
                parent[child] = node
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call) or not _is_jit_call(node):
                continue
            enc_func = self._enclosing_function(parent, node)
            if enc_func is None:
                continue            # module scope: traced once, fine
            if self._is_decorator_of(enc_func, node, parent):
                # @jax.jit on a def: fine when the def itself is at
                # module/class scope (the FunctionDef's own enclosing
                # function decides).
                if self._enclosing_function(parent, enc_func) is None:
                    continue
                self.emit(sf, node.lineno,
                          f"`@{call_name(node) or 'jit'}` on the nested "
                          f"function `{enc_func.name}` traces per call of "
                          "the enclosing function")
                continue
            stmt = self._enclosing_statement(parent, node)
            if stmt is not None and self._keyed_store(stmt, node, enc_func):
                continue
            lam = any(isinstance(a, ast.Lambda) for a in node.args)
            what = call_name(node) or "jit"
            fname = enc_func.name
            msg = (f"per-call `{what}(lambda ...)` inside `{fname}` "
                   "retraces on every invocation" if lam else
                   f"`{what}` inside `{fname}` is neither module-level "
                   "nor stored into a keyed cache "
                   "(`self._fns[key] = ...` pattern)")
            self.emit(sf, node.lineno, msg)

    @staticmethod
    def _enclosing_function(parent, node) -> Optional[ast.AST]:
        cur = parent.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = parent.get(cur)
        return None

    @staticmethod
    def _is_decorator_of(func: ast.AST, node: ast.AST, parent) -> bool:
        decs = getattr(func, "decorator_list", [])
        cur = node
        while cur is not None and cur is not func:
            if any(cur is d for d in decs):
                return True
            cur = parent.get(cur)
        return False

    @staticmethod
    def _enclosing_statement(parent, node) -> Optional[ast.stmt]:
        cur = parent.get(node)
        while cur is not None:
            if isinstance(cur, ast.stmt):
                return cur
            cur = parent.get(cur)
        return None

    @staticmethod
    def _keyed_store(stmt: ast.stmt, call: ast.Call,
                     enc_func: ast.AST) -> bool:
        """``cache[key] = jax.jit(...)`` (the call feeds the value), or a
        two-step version of the same: ``fn = shard_map(...)`` whose name
        is stored into a subscript elsewhere in the function
        (``self._fns[key] = jax.jit(fn)``)."""
        if not isinstance(stmt, ast.Assign):
            return False
        if not any(n is call for n in ast.walk(stmt.value)):
            return False
        if any(isinstance(t, ast.Subscript) for t in stmt.targets):
            return True
        tnames = {t.id for t in stmt.targets if isinstance(t, ast.Name)}
        if not tnames:
            return False
        for other in ast.walk(enc_func):
            if other is stmt or not isinstance(other, ast.Assign):
                continue
            if not any(isinstance(t, ast.Subscript) for t in other.targets):
                continue
            used = {n.id for n in ast.walk(other.value)
                    if isinstance(n, ast.Name)}
            if tnames & used:
                return True
        return False
