"""Pass: concurrency-discipline.

Three contracts of the host runtime (PR 3/5/6), one rule id:

  1. **No blocking while holding a registry/pool lock.**  The telemetry
     ``Registry._lock`` and the entropy ``_pool_lock`` serialize *every*
     hot-path writer (pool workers, overlap workers, the main thread); a
     ``Future.result()``, pool dispatch, or jax sync inside a
     ``with <lock>:`` body turns a bounded critical section into a
     pipeline-wide stall (and ``_pool_lock`` + process-pool dispatch can
     deadlock outright).  Flags blocking calls inside ``with`` blocks
     whose context expression ends in ``_lock``.

  2. **Process-pool dispatch only behind a ``holds_gil`` check.**  The
     forked ``ProcessPoolExecutor`` exists solely because GIL-holding
     codecs get nothing from threads; dispatching GIL-releasing codecs
     there pays pickle freight for negative win, and any *new*
     process-pool call site multiplies the fork-after-jax exposure that
     ``RansCodec`` deliberately opted out of.  Any function that touches
     ``_shared_proc_pool`` must test ``holds_gil`` somewhere.

  3. **Every FinalizeQueue.submit names its task.**  Background-failure
     attribution ("finalize step 12") only works when every submit
     passes ``label=``; an unlabeled submit re-raises bare Future errors
     (the PR 6 contract).  Receivers are recognized by the
     ``FinalizeQueue(...)`` construction in the same module or the
     ``_q`` naming convention.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis.core import (LintPass, SourceFile, call_name,
                                 dotted_name, names_in)
from repro.analysis.registry import register_pass

# Calls that block (or dispatch work that must complete) -- forbidden
# while holding a `*_lock`.
_BLOCKING_METHODS = {"result", "submit", "map", "shutdown",
                     "block_until_ready", "join", "acquire"}
_BLOCKING_CALLS = {"jax.block_until_ready", "jax.device_get", "time.sleep"}
# jax dispatch inside a lock is a stall too: any jax.* / jnp.* call.
_JAX_PREFIXES = ("jax.", "jnp.")


def _queue_receivers(sf: SourceFile) -> Set[str]:
    """Names holding a FinalizeQueue in this module: anything assigned
    from ``FinalizeQueue(...)`` plus the ``_q`` convention."""
    out: Set[str] = {"_q", "self._q"}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            cn = call_name(node.value)
            if cn and cn.rsplit(".", 1)[-1] == "FinalizeQueue":
                for t in node.targets:
                    d = dotted_name(t)
                    if d:
                        out.add(d)
                        if d.startswith("self."):
                            out.add(d[len("self."):])
    return out


@register_pass
class ConcurrencyPass(LintPass):
    rule = "concurrency-discipline"
    description = ("no blocking under *_lock, holds_gil-gated process "
                   "pools, labelled FinalizeQueue submits")

    def check_file(self, sf: SourceFile) -> None:
        self._check_lock_blocks(sf)
        self._check_proc_pool_gating(sf)
        self._check_submit_labels(sf)

    # ---------------------------------------------- 1. with-lock bodies
    def _check_lock_blocks(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.With):
                continue
            lock_names = [dotted_name(item.context_expr)
                          for item in node.items]
            if not any(n and n.rsplit(".", 1)[-1].endswith("_lock")
                       for n in lock_names):
                continue
            for stmt in node.body:
                for sub in ast.walk(stmt):
                    if not isinstance(sub, ast.Call):
                        continue
                    cn = call_name(sub)
                    blocking = (
                        cn in _BLOCKING_CALLS
                        or (cn and cn.startswith(_JAX_PREFIXES))
                        or (isinstance(sub.func, ast.Attribute)
                            and sub.func.attr in _BLOCKING_METHODS))
                    if blocking:
                        self.emit(sf, sub.lineno,
                                  f"blocking call `{cn or sub.func.attr}` "
                                  "while holding a lock "
                                  f"(`with {lock_names[0]}:`)")

    # ------------------------------------- 2. process-pool holds_gil gate
    def _check_proc_pool_gating(self, sf: SourceFile) -> None:
        for fi in sf.functions:
            # The accessor itself (and the retire path) may touch the
            # pool unconditionally; dispatchers must gate on holds_gil.
            if fi.name.startswith(("_shared_proc_pool", "_retire_proc_pool")):
                continue
            touches = [n for n in ast.walk(fi.node)
                       if isinstance(n, (ast.Name, ast.Attribute))
                       and (dotted_name(n) or "").rsplit(".", 1)[-1]
                       == "_shared_proc_pool"]
            if not touches:
                continue
            gated = any("holds_gil" in {nm.rsplit(".", 1)[-1]
                                        for nm in names_in(t.test)}
                        for t in ast.walk(fi.node)
                        if isinstance(t, (ast.If, ast.IfExp)))
            if not gated:
                self.emit(sf, touches[0].lineno,
                          f"`{fi.name}` dispatches to the process pool "
                          "without a `holds_gil` check (thread-safe "
                          "codecs must stay on the thread pool)")

    # ------------------------------------------- 3. labelled queue submits
    def _check_submit_labels(self, sf: SourceFile) -> None:
        queues = _queue_receivers(sf)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            if not (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "submit"):
                continue
            recv = dotted_name(node.func.value)
            if recv is None or recv not in queues:
                continue
            if not any(kw.arg == "label" for kw in node.keywords):
                self.emit(sf, node.lineno,
                          f"`{recv}.submit(...)` without `label=`: "
                          "background failures lose their stage/step "
                          "attribution")
