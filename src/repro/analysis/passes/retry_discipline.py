"""Pass: retry-discipline.

Every retry/poll loop in ``src/repro`` must be *bounded*: a fleet that
waits on a crashed rank, a wedged worker, or a file that will never
appear must surface a structured timeout, not spin forever.  The
sanctioned shape is ``repro.faults.retry.Backoff`` -- a bounded attempt
count (or a deadline via ``sleep_until``) with growing, jittered delays
-- and every loop that sleeps must be able to *stop*.

The check: a ``while`` loop whose body calls ``time.sleep`` must contain
at least one exit edge -- ``break``, ``return`` or ``raise`` -- inside
the loop body (exits nested in inner function definitions do not count).
A sleep-loop with no exit edge can only terminate via its test
expression, and when that test is the constant ``True`` (or the loop
otherwise never re-checks a deadline) the process hangs unboundedly on
any lost wakeup.  Conservatively, *any* sleeping ``while`` with no
break/return/raise is flagged: even a ``while not done():`` shape should
raise on a deadline rather than trust the condition to eventually flip.

Suppress intentionally-infinite daemons with
``# repro-lint: disable=retry-discipline`` and a justification.
"""
from __future__ import annotations

import ast

from repro.analysis.core import LintPass, SourceFile, call_name
from repro.analysis.registry import register_pass


def _body_nodes(loop: ast.While):
    """Loop-body nodes, not descending into nested function defs (an
    inner callback's `return` does not exit the loop)."""
    stack = list(loop.body) + list(loop.orelse)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


@register_pass
class RetryDisciplinePass(LintPass):
    rule = "retry-discipline"
    description = ("retry/poll loops are bounded: a while-loop that "
                   "time.sleep()s must break, return or raise")

    def check_file(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.While):
                continue
            sleeps = False
            has_exit = False
            for sub in _body_nodes(node):
                if isinstance(sub, ast.Call) \
                        and (call_name(sub) or "") == "time.sleep":
                    sleeps = True
                elif isinstance(sub, (ast.Break, ast.Return, ast.Raise)):
                    has_exit = True
            if sleeps and not has_exit:
                self.emit(
                    sf, node.lineno,
                    "unbounded retry loop: `while` body sleeps but has no "
                    "break/return/raise -- bound it with "
                    "faults.retry.Backoff (attempt count or deadline) and "
                    "raise a structured timeout")
