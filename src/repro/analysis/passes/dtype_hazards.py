"""Pass: dtype-hazard.

The pipeline runs with jax's default x64-disabled config: a ``float64``
/ ``int64`` literal dtype reaching a jitted device path is silently
downcast (changing quantization bin edges and therefore *bytes*, a
byte-identity break that only shows up when someone flips
``jax_enable_x64``), or worse, forces an f64 constant onto an
accelerator that emulates it.  This pass flags 64-bit dtype requests
inside device-reachable functions -- device-resident registry names plus
any function carrying a ``jax.jit``/``partial(jax.jit, ...)`` decorator:

  * ``jnp.float64`` / ``jnp.int64`` / ``np.float64`` attribute uses
  * ``dtype="float64"`` / ``.astype("int64")`` string dtypes
  * ``jnp.asarray(x, dtype=np.float64)``-style keyword requests

unless the function (or the statement) is guarded by an x64-awareness
check (a test mentioning ``jax_enable_x64`` / ``x64_enabled``).  Host-side
float64 staging (e.g. ``np.float64`` accumulators in pure-numpy paths)
is untouched -- only device-reachable scopes are scanned.
"""
from __future__ import annotations

import ast
from typing import Set

from repro.analysis.core import LintPass, SourceFile, dotted_name, names_in
from repro.analysis.registry import register_pass
from repro.analysis.passes.host_sync import is_device_resident

_WIDE_ATTRS: Set[str] = {
    "jnp.float64", "jnp.int64", "jnp.uint64", "jnp.complex128",
    "np.float64", "np.int64", "numpy.float64", "numpy.int64",
    "jax.numpy.float64", "jax.numpy.int64",
}
_WIDE_STRINGS: Set[str] = {"float64", "int64", "uint64", "complex128"}
_X64_GUARDS = {"jax_enable_x64", "x64_enabled", "enable_x64"}

_JIT_DECOS = {"jax.jit", "jit", "pjit", "jax.pjit", "shard_map"}


def _is_jitted(decorators) -> bool:
    return any(d.rsplit(".", 1)[-1] in {n.rsplit(".", 1)[-1]
                                        for n in _JIT_DECOS}
               or d in _JIT_DECOS for d in decorators)


def _x64_guarded_lines(fn_node: ast.AST) -> Set[int]:
    out: Set[int] = set()
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.If):
            continue
        tails = {n.rsplit(".", 1)[-1] for n in names_in(node.test)}
        consts = {c.value for c in ast.walk(node.test)
                  if isinstance(c, ast.Constant) and isinstance(c.value, str)}
        if tails & _X64_GUARDS or consts & _X64_GUARDS:
            for stmt in node.body + node.orelse:
                lo = stmt.lineno
                hi = getattr(stmt, "end_lineno", lo) or lo
                out.update(range(lo, hi + 1))
    return out


@register_pass
class DtypeHazardPass(LintPass):
    rule = "dtype-hazard"
    description = ("no unguarded 64-bit dtypes in device-reachable "
                   "functions (x64 is off; silent downcasts change bytes)")

    def check_file(self, sf: SourceFile) -> None:
        for fi in sf.functions:
            if not (is_device_resident(fi.name, fi.decorators)
                    or _is_jitted(fi.decorators)):
                continue
            guarded = _x64_guarded_lines(fi.node)
            for node in ast.walk(fi.node):
                line = getattr(node, "lineno", None)
                if line is None or line in guarded:
                    continue
                if sf.scope_at(line).rsplit(".", 1)[-1] != fi.name:
                    continue
                if isinstance(node, ast.Attribute):
                    dn = dotted_name(node)
                    if dn in _WIDE_ATTRS:
                        self.emit(sf, line,
                                  f"64-bit dtype `{dn}` in device-reachable "
                                  f"function `{fi.name}` without an x64 "
                                  "guard")
                elif isinstance(node, ast.Constant) \
                        and isinstance(node.value, str) \
                        and node.value in _WIDE_STRINGS:
                    self.emit(sf, line,
                              f'64-bit dtype string "{node.value}" in '
                              f"device-reachable function `{fi.name}` "
                              "without an x64 guard")
