"""Pass: host-sync-in-device-path.

The device-resident stages (PR 4/5/7) exist so that between-step state
never round-trips through the host; one stray ``np.asarray`` inside them
silently serializes the whole overlapped pipeline.  This pass flags host
synchronization primitives inside functions *registered* as
device-resident:

  * explicit sync calls: ``jax.device_get``, ``jax.block_until_ready``,
    ``.block_until_ready()``, ``.item()``, ``np.asarray``/``np.array``
  * scalar fetches of device dict results: ``float(x[...])`` /
    ``int(x[...])`` (the ``int(a["b_auto"])`` pattern -- a subscripted
    argument is how analyze-stage results cross to host; plain
    ``int(params.b_bits)`` is not flagged).

Registered means: listed in :data:`DEVICE_RESIDENT_NAMES` (exact names or
``fnmatch`` patterns -- the ``_*_shard`` bodies), or decorated with
``repro.analysis.device_resident``.

Allowance: sync points gated on telemetry are *by design* (span durations
must mean stage time, not dispatch time -- see ``docs/observability.md``),
so anything under ``if telemetry.enabled():`` / ``if tele:`` is exempt.
Intentional boundary syncs (the analyze-stage b_auto fetch, the final
``idx_fetch``) carry inline suppressions or live in the committed
baseline -- the point of the pass is that *new* ones cannot land quietly.
"""
from __future__ import annotations

import ast
import fnmatch
from typing import List, Set, Tuple

from repro.analysis.core import LintPass, SourceFile, call_name, names_in
from repro.analysis.registry import register_pass

# Functions whose bodies are device paths.  Names (not paths) so seeded
# fixtures and future modules are covered the moment they reuse a name;
# patterns cover the shard_map stage bodies.
DEVICE_RESIDENT_NAMES: Tuple[str, ...] = (
    "encode_device",
    "decompress_step_device",
    "decode_anchor_device",
    "chain_advance",
    "chain_advance_core",
    "decode_blocks_device",
    "decode_bytes_blocks_device",
    "compress_blocks_device",
    "compress_blocks_device_symbols",
    "_*_shard",
)

# Callee names that force a device->host sync.
_SYNC_CALLS: Set[str] = {
    "jax.device_get", "jax.block_until_ready", "np.asarray", "np.array",
    "numpy.asarray", "numpy.array",
}
# Attribute-method syncs: flagged whatever the receiver (a device path
# has no business calling these on anything).
_SYNC_METHODS: Set[str] = {"item", "block_until_ready"}
# Builtins that sync when fed a device subscript (dict-of-arrays fetch).
_SCALAR_BUILTINS: Set[str] = {"float", "int", "bool"}

_TELE_GATES = {"tele", "telemetry.enabled"}


def is_device_resident(name: str, decorators: List[str]) -> bool:
    if any(d.endswith("device_resident") for d in decorators):
        return True
    return any(fnmatch.fnmatchcase(name, pat)
               for pat in DEVICE_RESIDENT_NAMES)


def _telemetry_gated_lines(fn_node: ast.AST) -> Set[int]:
    """Lines inside ``if tele:`` / ``if telemetry.enabled():`` branches."""
    out: Set[int] = set()
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.If):
            continue
        if names_in(node.test) & _TELE_GATES:
            for stmt in node.body:
                lo = stmt.lineno
                hi = getattr(stmt, "end_lineno", lo) or lo
                out.update(range(lo, hi + 1))
    return out


@register_pass
class HostSyncPass(LintPass):
    rule = "host-sync-in-device-path"
    description = ("no host synchronization inside device-resident "
                   "functions (telemetry-gated syncs exempt)")

    def check_file(self, sf: SourceFile) -> None:
        for fi in sf.functions:
            if not is_device_resident(fi.name, fi.decorators):
                continue
            gated = _telemetry_gated_lines(fi.node)
            lo, hi = fi.line_range
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                if node.lineno in gated:
                    continue
                # Nested defs inside a device function are separate
                # scopes (closures run later, host-side); only flag
                # calls whose innermost scope is this function.
                if sf.scope_at(node.lineno).rsplit(".", 1)[-1] != fi.name:
                    continue
                name = call_name(node)
                if name in _SYNC_CALLS:
                    self.emit(sf, node.lineno,
                              f"host sync `{name}` in device-resident "
                              f"function `{fi.name}`")
                elif (isinstance(node.func, ast.Attribute)
                        and node.func.attr in _SYNC_METHODS
                        and name not in _SYNC_CALLS):
                    self.emit(sf, node.lineno,
                              f"host sync `.{node.func.attr}()` in "
                              f"device-resident function `{fi.name}`")
                elif (isinstance(node.func, ast.Name)
                        and node.func.id in _SCALAR_BUILTINS
                        and node.args
                        and isinstance(node.args[0], ast.Subscript)):
                    self.emit(sf, node.lineno,
                              f"scalar fetch `{node.func.id}(...[...])` in "
                              f"device-resident function `{fi.name}` forces "
                              "a device sync")
