"""Pass: format-closure.

The on-disk format is a closed matrix (PR 5/7): every NCK container
magic has a reader branch and old readers reject newer files cleanly;
every rANS blob version is written and parsed from one ``_V_*``
definition; and the per-step / per-read telemetry records carry exactly
the canonical key sets so trajectory tooling can diff rollups
structurally.  A new magic, blob version, or telemetry key that lands in
only one of its places is a corrupt-file or broken-dashboard bug waiting
for the next reader.  Sub-checks:

  1. **Magic matrix** (``core/container.py``): the ``_MAGIC_V*``
     constants, the ``_MAGICS`` reader-accept dict and the writer's
     version->magic map must cover exactly the same set, and every magic
     byte-string must appear in at least one test (the NCK1/NCK2/NCK3
     compat matrix is a tested contract, not an implementation detail).

  2. **Blob versions** (``kernels/rans.py``): every ``_V_*`` constant
     must appear in both a writer context (``*.pack(...)`` argument) and
     a reader comparison (``version == _V_X``); header pack calls must
     pass the named constant, never an integer literal.

  3. **Telemetry key canon**: dict literals stored into
     ``...["telemetry"]`` / ``...["telemetry_read"]`` must use exactly
     the canonical keys (``obs.report.STEP_TELEMETRY_KEYS`` /
     ``READ_TELEMETRY_KEYS``, parsed from their one definition) --
     finalize-stage writes match exactly; driver-stage partial records
     (folded by finalize) may use the canonical subset plus
     ``device_entropy_s``; single-key stores must name a canonical key.

  4. **Manifest magic** (``core/container.py``): when the multi-process
     ``_MANIFEST_MAGIC`` exists it must have a reader branch (appear in
     a comparison) and a test fixture, like the data magics -- a
     manifest the reader cannot distinguish from a data file corrupts
     every multi-process open.

  5. **Atomic publish discipline**: every durable publish goes through
     ``core.container.atomic_commit`` (write tmp, flush, fsync, rename).
     Any other ``os.replace``/``os.rename`` call in ``src/`` is flagged:
     a rename without the fsync can publish a file whose bytes are not
     on disk yet, and a crashed save would then corrupt the previous
     generation instead of leaving it loadable.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import LintPass, Project, SourceFile, call_name
from repro.analysis.registry import register_pass

# Driver-stage partial record keys that finalize_step folds into the
# canonical record (see core/pipeline.py).
_DRIVER_EXTRA_KEYS = {"device_entropy_s"}


def _const_str_keys(d: ast.Dict) -> Optional[List[str]]:
    keys = []
    for k in d.keys:
        if isinstance(k, ast.Constant) and isinstance(k.value, str):
            keys.append(k.value)
        else:
            return None
    return keys


def _tuple_of_strs(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        vals = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                vals.append(e.value)
            else:
                return None
        return tuple(vals)
    return None


def _module_str_assigns(sf: SourceFile) -> Dict[str, bytes]:
    """Module-level ``NAME = b"..."`` / ``NAME = "..."`` assignments."""
    out: Dict[str, bytes] = {}
    for node in sf.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, (bytes, str)):
            name = node.targets[0].id
            v = node.value.value
            out[name] = v if isinstance(v, bytes) else v.encode()
    return out


@register_pass
class FormatClosurePass(LintPass):
    rule = "format-closure"
    description = ("container magics, blob versions and telemetry key "
                   "sets stay closed across writer/reader/tests")

    def check_project(self, project: Project) -> None:
        canon = self._load_canon(project)
        for sf in project.files:
            self._check_telemetry_writes(sf, canon)
            self._check_atomic_publish(sf)
        csf = project.by_rel("src/repro/core/container.py")
        if csf is not None:
            self._check_magics(csf, project)
            self._check_manifest_magic(csf, project)
            self._check_checksum_frame(csf, project)
        rsf = project.by_rel("src/repro/kernels/rans.py")
        if rsf is not None:
            self._check_blob_versions(rsf)

    # ----------------------------------------------------- canon loading
    @staticmethod
    def _load_canon(project: Project) -> Dict[str, Tuple[str, ...]]:
        canon: Dict[str, Tuple[str, ...]] = {}
        rsf = project.by_rel("src/repro/obs/report.py")
        if rsf is None:
            return canon
        for node in rsf.tree.body:
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                name = node.targets[0].id
                if name in ("STEP_TELEMETRY_KEYS", "READ_TELEMETRY_KEYS"):
                    vals = _tuple_of_strs(node.value)
                    if vals:
                        canon[name] = vals
        return canon

    # ----------------------------------------------- telemetry key canon
    def _check_telemetry_writes(self, sf: SourceFile,
                                canon: Dict[str, Tuple[str, ...]]) -> None:
        step_keys = set(canon.get("STEP_TELEMETRY_KEYS", ()))
        read_keys = set(canon.get("READ_TELEMETRY_KEYS", ()))
        if not step_keys or not read_keys:
            return
        # Dict literals assigned to local names, for one-hop resolution
        # (the `rec = {...}; meta["telemetry_read"] = rec` pattern).
        local_dicts: Dict[Tuple[str, str], ast.Dict] = {}
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name) \
                    and isinstance(node.value, ast.Dict):
                local_dicts[(sf.scope_at(node.lineno),
                             node.targets[0].id)] = node.value
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Assign):
                continue
            for tgt in node.targets:
                which = self._telemetry_slot(tgt)
                if which is None:
                    continue
                slot, sub_key = which
                exact = slot == "telemetry_read" or sf.scope_at(
                    node.lineno).rsplit(".", 1)[-1].startswith("finalize")
                allowed = (read_keys if slot == "telemetry_read"
                           else step_keys)
                if sub_key is not None:
                    # x["telemetry_read"]["fetch_s"] = ... single-key store
                    if sub_key not in allowed:
                        self.emit(sf, node.lineno,
                                  f'key "{sub_key}" written to '
                                  f'meta["{slot}"] is not in the canonical '
                                  'key set')
                    continue
                d = node.value
                if isinstance(d, ast.Name):
                    d = local_dicts.get((sf.scope_at(node.lineno), d.id), d)
                if not isinstance(d, ast.Dict):
                    continue
                keys = _const_str_keys(d)
                if keys is None:
                    self.emit(sf, node.lineno,
                              f'meta["{slot}"] written with non-literal '
                              'keys; the canonical key set cannot be '
                              'checked')
                    continue
                extra = ([k for k in keys if k not in allowed]
                         if slot == "telemetry_read" or exact else
                         [k for k in keys
                          if k not in allowed | _DRIVER_EXTRA_KEYS])
                missing = ([k for k in sorted(allowed)
                            if k not in keys] if exact else [])
                for k in extra:
                    self.emit(sf, node.lineno,
                              f'key "{k}" written to meta["{slot}"] is '
                              'not in the canonical key set')
                if missing:
                    self.emit(sf, node.lineno,
                              f'meta["{slot}"] record is missing canonical '
                              f'keys: {", ".join(missing)}')

    @staticmethod
    def _telemetry_slot(tgt: ast.AST) -> Optional[Tuple[str, Optional[str]]]:
        """(slot, sub_key) when `tgt` stores into a telemetry record."""
        if not isinstance(tgt, ast.Subscript):
            return None
        key = tgt.slice
        if not (isinstance(key, ast.Constant) and isinstance(key.value, str)):
            return None
        if key.value in ("telemetry", "telemetry_read"):
            return key.value, None
        # one level deeper: x["telemetry_read"]["fetch_s"] = ...
        inner = tgt.value
        if isinstance(inner, ast.Subscript) \
                and isinstance(inner.slice, ast.Constant) \
                and inner.slice.value in ("telemetry", "telemetry_read"):
            return inner.slice.value, key.value
        return None

    # -------------------------------------------------- container magics
    def _check_magics(self, sf: SourceFile, project: Project) -> None:
        consts = {k: v for k, v in _module_str_assigns(sf).items()
                  if re.fullmatch(r"_MAGIC_V\d+", k)}
        magics_keys: Set[str] = set()
        writer_magics: Set[str] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == "_MAGICS"
                            for t in node.targets) \
                    and isinstance(node.value, ast.Dict):
                for k in node.value.keys:
                    if isinstance(k, ast.Name):
                        magics_keys.add(k.id)
            # the writer's version -> magic literal map ({1: _MAGIC_V1,..})
            elif isinstance(node, ast.Dict) and node.keys and all(
                    isinstance(k, ast.Constant) and isinstance(k.value, int)
                    for k in node.keys):
                for v in node.values:
                    if isinstance(v, ast.Name) and v.id in consts:
                        writer_magics.add(v.id)
        for name in sorted(consts):
            if name not in magics_keys:
                self.emit(sf, 1, f"container magic `{name}` is not accepted "
                          "by the `_MAGICS` reader matrix", scope="<module>")
            if writer_magics and name not in writer_magics:
                self.emit(sf, 1, f"container magic `{name}` has no writer "
                          "branch (version -> magic map)",
                          scope="<module>")
        # every magic byte-string must appear in a test file
        tests_text = ""
        for path in project.iter_tree_files("tests"):
            with open(path, "r", encoding="utf-8") as fh:
                tests_text += fh.read()
        for name, magic in sorted(consts.items()):
            token = magic.decode("ascii", "replace")
            if tests_text and token not in tests_text:
                self.emit(sf, 1, f"container magic `{name}` ({token}) has "
                          "no test fixture exercising it",
                          scope="<module>")

    # -------------------------------------------------- manifest closure
    def _check_manifest_magic(self, sf: SourceFile,
                              project: Project) -> None:
        consts = _module_str_assigns(sf)
        magic = consts.get("_MANIFEST_MAGIC")
        if magic is None:
            return
        compared = False
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Name) \
                            and sub.id == "_MANIFEST_MAGIC":
                        compared = True
        if not compared:
            self.emit(sf, 1, "`_MANIFEST_MAGIC` has no reader branch "
                      "(never compared against file bytes)",
                      scope="<module>")
        token = magic.decode("ascii", "replace")
        tests_text = ""
        for path in project.iter_tree_files("tests"):
            with open(path, "r", encoding="utf-8") as fh:
                tests_text += fh.read()
        if tests_text and token not in tests_text:
            self.emit(sf, 1, f"manifest magic `_MANIFEST_MAGIC` ({token}) "
                      "has no test fixture exercising it",
                      scope="<module>")

    # -------------------------------------------- NCK4 checksum closure
    def _check_checksum_frame(self, sf: SourceFile,
                              project: Project) -> None:
        """The NCK4 checksum frame joins the writer/reader/test closure:
        when `_MAGIC_V4` exists, the `_CRC_KEY` / `_BLOCK_CRC_KEY`
        record keys must each have a writer site (subscript store or
        dict-literal key), a reader site (load / `.get` / membership
        test), and a test exercising the literal key string -- a digest
        that is stamped but never verified (or vice versa) is an open
        frame."""
        consts = _module_str_assigns(sf)
        if "_MAGIC_V4" not in consts:
            return
        keys = [k for k in ("_CRC_KEY", "_BLOCK_CRC_KEY") if k in consts]
        for want in ("_CRC_KEY", "_BLOCK_CRC_KEY"):
            if want not in consts:
                self.emit(sf, 1, f"NCK4 exists but checksum key constant "
                          f"`{want}` is not defined", scope="<module>")
        written: Set[str] = set()
        read: Set[str] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.slice, ast.Name) \
                    and node.slice.id in keys:
                if isinstance(node.ctx, ast.Store):
                    written.add(node.slice.id)
                else:
                    read.add(node.slice.id)
            elif isinstance(node, ast.Dict):
                for k in node.keys:
                    if isinstance(k, ast.Name) and k.id in keys:
                        written.add(k.id)
            elif isinstance(node, ast.Call):
                cn = call_name(node) or ""
                if cn.endswith(".get"):
                    for a in node.args:
                        if isinstance(a, ast.Name) and a.id in keys:
                            read.add(a.id)
            elif isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Name) and sub.id in keys:
                        read.add(sub.id)
        for name in keys:
            if name not in written:
                self.emit(sf, 1, f"checksum key `{name}` is never stamped "
                          "by a writer (no store site)", scope="<module>")
            if name not in read:
                self.emit(sf, 1, f"checksum key `{name}` is never verified "
                          "by a reader (no load site)", scope="<module>")
        tests_text = ""
        for path in project.iter_tree_files("tests"):
            with open(path, "r", encoding="utf-8") as fh:
                tests_text += fh.read()
        for name in keys:
            token = consts[name].decode("ascii", "replace")
            if tests_text and f'"{token}"' not in tests_text:
                self.emit(sf, 1, f"checksum key `{name}` (\"{token}\") has "
                          "no test fixture exercising it",
                          scope="<module>")

    def _check_atomic_publish(self, sf: SourceFile) -> None:
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            cn = call_name(node) or ""
            if cn not in ("os.replace", "os.rename"):
                continue
            scope = sf.scope_at(node.lineno)
            if scope.rsplit(".", 1)[-1] == "atomic_commit":
                continue
            self.emit(sf, node.lineno,
                      f"`{cn}` outside core.container.atomic_commit: "
                      "durable publishes must go through the "
                      "fsync-before-rename helper")

    # ---------------------------------------------------- blob versions
    def _check_blob_versions(self, sf: SourceFile) -> None:
        vnames = {node.targets[0].id
                  for node in sf.tree.body
                  if isinstance(node, ast.Assign)
                  and len(node.targets) == 1
                  and isinstance(node.targets[0], ast.Name)
                  and re.fullmatch(r"_V_\w+", node.targets[0].id)}
        packed: Set[str] = set()
        compared: Set[str] = set()
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                cn = call_name(node) or ""
                if cn.endswith(".pack") or cn.endswith(".pack_into"):
                    for i, a in enumerate(node.args):
                        if isinstance(a, ast.Name) and a.id in vnames:
                            packed.add(a.id)
                        elif isinstance(a, ast.Constant) \
                                and isinstance(a.value, int) and i == 1 \
                                and cn.startswith(("_HDR", "_RAW_HDR")):
                            self.emit(sf, node.lineno,
                                      "blob header packed with literal "
                                      f"version {a.value}; use the `_V_*` "
                                      "constant")
            elif isinstance(node, ast.Compare):
                for sub in ast.walk(node):
                    if isinstance(sub, ast.Name) and sub.id in vnames:
                        compared.add(sub.id)
        for name in sorted(vnames):
            if name not in packed:
                self.emit(sf, 1, f"blob version `{name}` is never written "
                          "(no pack site uses it)", scope="<module>")
            if name not in compared:
                self.emit(sf, 1, f"blob version `{name}` has no reader "
                          "branch (never compared)", scope="<module>")
