"""repro-lint command line.

``python -m repro.analysis [paths...]`` (or the ``repro-lint`` console
script) runs every registered pass over the given paths (default:
``src/repro``), diffs against the committed baseline, and exits nonzero
iff *new* violations exist.  ``--write-baseline`` accepts the current
state; ``--select`` narrows to a comma-separated rule subset;
``--list-rules`` prints the catalogue.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import Violation, load_project
from repro.analysis.registry import all_passes, get_pass

_DEFAULT_PATHS = ("src/repro",)


def _find_root(start: str) -> str:
    """Nearest ancestor holding a baseline file or .git; else `start`."""
    cur = os.path.abspath(start)
    while True:
        if os.path.exists(os.path.join(cur, baseline_mod.DEFAULT_BASELINE)) \
                or os.path.isdir(os.path.join(cur, ".git")):
            return cur
        nxt = os.path.dirname(cur)
        if nxt == cur:
            return os.path.abspath(start)
        cur = nxt


def run_lint(paths: List[str], root: str,
             select: Optional[List[str]] = None) -> List[Violation]:
    """Run the (selected) passes over `paths`; returns raw violations
    (pre-baseline).  Paths may be files or directories."""
    project = load_project(paths, root=root)
    passes = ([get_pass(r) for r in select] if select
              else all_passes())
    out: List[Violation] = []
    for cls in passes:
        out.extend(cls().run(project))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="project-specific static analysis for the repro "
                    "pipeline (device residency, jit caching, "
                    "concurrency, format closure, dtype hazards)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src/repro)")
    ap.add_argument("--root", default=None,
                    help="repo root for relative paths and the baseline "
                         "(default: auto-detected)")
    ap.add_argument("--baseline", default=None,
                    help="baseline file (default: "
                         f"<root>/{baseline_mod.DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every violation, ignoring the baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current violations into the baseline")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule subset to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for cls in all_passes():
            print(f"{cls.rule:28s} {cls.description}")
        return 0

    root = os.path.abspath(args.root) if args.root else _find_root(os.getcwd())
    paths = args.paths or [os.path.join(root, p) for p in _DEFAULT_PATHS]
    select = ([s.strip() for s in args.select.split(",") if s.strip()]
              if args.select else None)
    violations = run_lint(paths, root=root, select=select)

    bl_path = args.baseline or os.path.join(
        root, baseline_mod.DEFAULT_BASELINE)
    if args.write_baseline:
        baseline_mod.save(bl_path, violations)
        print(f"repro-lint: wrote {len(violations)} accepted violation(s) "
              f"to {os.path.relpath(bl_path, root)}")
        return 0

    known = [] if args.no_baseline else baseline_mod.load(bl_path)
    new, stale = baseline_mod.diff(violations, known)

    for v in new:
        print(f"{v.path}:{v.line}: [{v.rule}] {v.message} "
              f"(in {v.scope})")
    for fp in stale:
        rule, path, scope, _msg = fp
        print(f"repro-lint: stale baseline entry [{rule}] {path} "
              f"({scope}) -- fixed? regenerate with --write-baseline")
    n_accepted = len(violations) - len(new)
    print(f"repro-lint: {len(new)} new violation(s), "
          f"{n_accepted} baselined, {len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
