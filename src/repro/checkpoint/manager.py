"""Checkpoint manager: NUMARCK anchor+delta compression, atomic publish,
manifest, retention, corruption fallback, async save.

This is the paper's motivating use-case wired into the trainer: checkpoints
form a temporal series per tensor, so every `anchor_every`-th save is a
lossless anchor and the rest are NUMARCK deltas against the previous
*reconstructed* state (drift-free; DESIGN.md Sec. 3).

Layout:
    <dir>/step_000123.nck      one NCK container per step (all tensors)
    <dir>/MANIFEST.json        {steps: [...], last_good: int, params: ...}

Fault tolerance:
  * atomic rename on both .nck and manifest, fsync'd before the rename --
    the manifest is only committed AFTER its step file is durable, so a
    crash at any point leaves a manifest that references complete files
    only (tested)
  * restore walks back past corrupted/incomplete files (tested)
  * retention keeps the last `keep` checkpoints plus their anchors
  * async saves ride the same double-buffered machinery as the overlapped
    compression stream: the caller thread snapshots the tree to host and
    returns; a single background worker runs compress+write, with at most
    two saves in flight (one executing + one queued) and a `wait()`
    barrier
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import (NumarckParams, decompress_step, make_anchor)
from repro.core import chain as chainmod
from repro.core import pipeline as pipe
from repro.core.compress import decode_anchor, encode_device
from repro.core import container
from repro.core.container import NCKReader, NCKWriter
from repro.core.overlap import FinalizeQueue
from repro.obs import telemetry


def _flatten(tree, snapshot: bool = False) -> Dict[str, np.ndarray]:
    """Host copy of a pytree.  `snapshot=True` forces a private copy even
    for numpy leaves (async saves read the arrays on another thread after
    the caller may have mutated them in place)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(leaf)
        flat[key] = np.array(arr, copy=True) if (
            snapshot and isinstance(leaf, np.ndarray)) else arr
    return flat


class CheckpointManager:
    def __init__(self, directory: str,
                 params: NumarckParams = NumarckParams(error_bound=1e-3),
                 anchor_every: int = 4, keep: int = 3,
                 compress: bool = True, async_save: bool = False,
                 exempt_substrings: Tuple[str, ...] = ("scale", "step",
                                                       "pos_map"),
                 chain: str = chainmod.CHAIN_HOST):
        """`exempt_substrings`: tensor paths stored losslessly regardless
        (norm scales and counters are tiny but precision-critical).

        `chain`: residency of the per-tensor reference chains the deltas
        encode against ("host" default -- checkpoint trees are snapshotted
        to host anyway; "auto"/"device" keeps the reconstructed state on
        the accelerator between saves at the cost of one state copy of
        device memory).  Applied per tensor: checkpoint trees mix float
        params with int counters/steps, so tensors the device cannot hold
        bit-exactly always get host chains instead of failing the save."""
        if chain not in chainmod.RESIDENCIES:
            raise ValueError(f"unknown chain residency {chain!r}")
        self.dir = directory
        self.params = params
        self.anchor_every = max(1, anchor_every)
        self.keep = keep
        self.compress = compress
        self.async_save = async_save
        self.exempt = exempt_substrings
        # Populated by restore_latest: steps it had to skip and why.
        self.last_restore_report: List[Dict] = []
        self.chain = chain
        os.makedirs(directory, exist_ok=True)
        # One ReferenceChain per tensor path: the prev->recon state every
        # delta encodes against.  Raw ndarrays never leak out of the
        # chains except through an explicit .to_host()/seed boundary.
        self._recon_state: Dict[str, chainmod.ReferenceChain] = {}
        self._save_count = 0
        # Single worker serializes compress+write (manifest ordering stays
        # trivially correct); the queue bounds in-flight saves at two.
        self._q = FinalizeQueue(overlap=True, name="ckpt-save")
        self._treedef = None

    # ------------------------------------------------------------------ io
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "MANIFEST.json")

    def _step_path(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}.nck")

    def _read_manifest(self) -> Dict:
        try:
            with open(self._manifest_path()) as f:
                return json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            return {"steps": [], "anchors": []}

    def _write_manifest(self, m: Dict):
        # Shared fsync-before-rename commit discipline (core.container).
        container.atomic_commit(self._manifest_path(),
                                json.dumps(m, indent=1).encode())

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: Optional[bool] = None):
        """Checkpoint a pytree (params/opt state/...).

        Blocking saves return the stats dict.  Async saves snapshot the
        tree to host on the caller thread and return a Future of the stats
        dict immediately; compress+write run on the background worker,
        double-buffered (at most two saves in flight -- the third `save`
        call blocks until the oldest completes, bounding host memory at
        ~two checkpoints).  `wait()` is the barrier.
        """
        blocking = (not self.async_save) if blocking is None else blocking
        flat = _flatten(tree, snapshot=not blocking)  # caller-thread copy
        if blocking:
            self.wait()                  # keep manifest commit order
            return self._save_inner(step, flat)
        return self._q.submit(self._save_inner, step, flat,
                              label=f"save step {step}")

    def wait(self):
        """Barrier: block until every in-flight async save is durable;
        re-raises the first background exception, if any."""
        self._q.flush()

    def _seeded_chain(self, arr: np.ndarray) -> chainmod.ReferenceChain:
        # Per-tensor residency: "device" degrades to host for dtypes the
        # device cannot hold bit-exactly (ints, f16, f64 without x64) --
        # those tensors are lossless-only anyway.
        residency = self.chain
        if not chainmod.device_supports(arr.dtype):
            residency = chainmod.CHAIN_HOST
        c = chainmod.make_reference_chain(residency, arr.dtype)
        c.seed(arr)
        return c

    def _save_inner(self, step: int, flat: Dict[str, np.ndarray]):
        with telemetry.span("ckpt.save", step=step,
                            tensors=len(flat)) as sp:
            stats = self._save_body(step, flat, sp)
        return stats

    def _save_body(self, step: int, flat: Dict[str, np.ndarray], sp):
        is_anchor = (self._save_count % self.anchor_every == 0
                     or not self._recon_state)
        w = NCKWriter()
        stats = {"step": step, "anchor": is_anchor, "orig_bytes": 0,
                 "comp_bytes": 0, "codec": self.params.codec}
        names = {}
        staged: Dict[str, chainmod.ReferenceChain] = {}
        with telemetry.span("ckpt.encode", step=step):
            for i, (key, arr) in enumerate(sorted(flat.items())):
                var = f"t{i:04d}"
                names[var] = key
                stats["orig_bytes"] += arr.nbytes
                lossless = (not self.compress or is_anchor
                            or any(s in key for s in self.exempt)
                            or not np.issubdtype(arr.dtype, np.floating)
                            or arr.size < 4096
                            or key not in self._recon_state)
                if lossless:
                    st = make_anchor(arr, self.params)
                    staged[key] = self._seeded_chain(arr)
                else:
                    # Encode against the chain state; advance a *fork*
                    # from the pre-entropy result (bit-identical to
                    # decompressing the blob, without inflating it back).
                    # Checkpoints always chain the reconstruction,
                    # whatever params.reference says -- restore only ever
                    # replays reconstructions.
                    prev_chain = self._recon_state[key]
                    dev = encode_device(
                        prev_chain.peek(), arr, self.params,
                        need_host_idx=(prev_chain.residency
                                       == chainmod.CHAIN_HOST))
                    st = pipe.finalize_step(arr, dev.enc, dev.centers,
                                            dev.domain_lo, dev.width,
                                            self.params, dev.meta)
                    c = prev_chain.fork()
                    c.advance(dev, arr)
                    staged[key] = c
                stats["comp_bytes"] += st.nbytes
                w.add_step(var, st)
        w.add_array("__names__",
                    np.frombuffer(json.dumps(names).encode(), np.uint8),
                    attrs={"step": step})
        # The container's own write span ("nck.write" + fsync/rename
        # children) nests under this one on the same lane.
        with telemetry.span("ckpt.write", step=step):
            w.write(self._step_path(step))
        # Commit the in-memory delta chains only after the step file is
        # durable: a save that dies mid-write must leave the next delta
        # encoding against the last *persisted* state, or every subsequent
        # delta would silently chain off a ghost step.  The forks above
        # make this a handle swap, never an in-place mutation.
        self._recon_state.update(staged)
        self._save_count += 1

        with telemetry.span("ckpt.manifest", step=step):
            m = self._read_manifest()
            m["steps"] = sorted(set(m["steps"] + [step]))
            if is_anchor:
                m["anchors"] = sorted(set(m.get("anchors", []) + [step]))
            self._write_manifest(m)
            self._retention(m)
        stats["ratio"] = stats["orig_bytes"] / max(stats["comp_bytes"], 1)
        sp.set(anchor=is_anchor, orig_bytes=stats["orig_bytes"],
               comp_bytes=stats["comp_bytes"])
        return stats

    def _retention(self, m: Dict):
        """Keep the last `keep` steps + the anchors their deltas chain to."""
        steps: List[int] = m["steps"]
        if len(steps) <= self.keep:
            return
        keep_set = set(steps[-self.keep:])
        anchors = [s for s in m.get("anchors", [])]
        for s in list(keep_set):
            past = [a for a in anchors if a <= s]
            if past:
                keep_set.add(max(past))
        # deltas chain step-to-step; keep everything from the oldest needed
        # anchor forward
        oldest = min(keep_set)
        keep_set = {s for s in steps if s >= oldest}
        for s in steps:
            if s not in keep_set:
                try:
                    os.remove(self._step_path(s))
                except FileNotFoundError:
                    pass
        m["steps"] = sorted(keep_set)
        m["anchors"] = sorted(set(m.get("anchors", [])) & keep_set)
        self._write_manifest(m)

    # ------------------------------------------------------------- restore
    def _load_flat(self, upto_step: int, m: Dict) -> Dict[str, np.ndarray]:
        """Replay anchors+deltas up to `upto_step` (inclusive)."""
        anchors = [a for a in m.get("anchors", []) if a <= upto_step]
        if not anchors:
            raise FileNotFoundError("no anchor at or before requested step")
        start = max(anchors)
        chain = [s for s in m["steps"] if start <= s <= upto_step]
        state: Dict[str, np.ndarray] = {}
        for s in chain:
            r = NCKReader(self._step_path(s))
            names = json.loads(bytes(r.read_array("__names__")).decode())
            for var, key in names.items():
                st = r.read_step(var)
                if st.is_anchor:
                    state[key] = decode_anchor(st)
                else:
                    state[key] = decompress_step(st, state[key])
        return state

    def restore_latest(self, template: Any = None
                       ) -> Optional[Tuple[int, Any]]:
        """(step, tree) from the newest valid checkpoint; walks back past
        corrupt files.  With `template`, leaves are reshaped/cast onto the
        template pytree (elastic restore does its resharding there).

        Every skipped (corrupt/missing) step is recorded in
        ``last_restore_report`` -- a list of ``{"step", "error"}`` dicts
        -- so a restore that silently walked past damage is still
        auditable after the fact."""
        self.wait()                      # drain in-flight async saves
        m = self._read_manifest()
        self.last_restore_report: List[Dict] = []
        for step in reversed(m["steps"]):
            try:
                flat = self._load_flat(step, m)
                self._recon_state = {k: self._seeded_chain(v)
                                     for k, v in flat.items()}
                self._save_count = len(
                    [s for s in m["steps"] if s <= step])
                return step, self._unflatten(flat, template)
            except Exception as e:  # noqa: BLE001 -- corrupt/missing: walk back
                self.last_restore_report.append(
                    {"step": int(step), "error": f"{type(e).__name__}: {e}"})
                continue
        return None

    def _unflatten(self, flat: Dict[str, np.ndarray], template: Any):
        if template is None:
            # nested-dict reconstruction from path keys
            root: Dict = {}
            for key, arr in flat.items():
                parts = key.split("/")
                d = root
                for p in parts[:-1]:
                    d = d.setdefault(p, {})
                d[parts[-1]] = arr
            return root
        # template may hold abstract leaves (ShapeDtypeStruct) -- only
        # shape/dtype/structure are consumed
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        out_leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            shape = getattr(leaf, "shape", np.shape(leaf))
            dtype = getattr(leaf, "dtype", None)
            arr = flat[key].reshape(shape)
            out_leaves.append(arr.astype(dtype) if dtype is not None
                              else arr)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)


__all__ = ["CheckpointManager"]
