"""Elastic restore: load a checkpoint onto a *different* mesh.

Checkpoints are stored mesh-agnostic (full logical tensors on host), so
elastic scaling reduces to re-device_put with the new mesh's NamedShardings
-- GSPMD reshards on the fly.  This is the restart path after growing or
shrinking the fleet (e.g. 512 -> 256 chips after losing a pod).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh

from repro.distributed import sharding as shd


def reshard_tree(tree: Any, cfg, mesh: Mesh, dp=("data",), tp="model"):
    """Host pytree -> device pytree sharded for `mesh` (params rules)."""
    ns = shd.named_shardings(tree, cfg, mesh, dp, tp)
    return jax.tree.map(jax.device_put, tree, ns)


def restore_elastic(manager, template, cfg, mesh: Mesh, dp=("data",),
                    tp="model"):
    """restore_latest + reshard onto `mesh`.  Returns (step, tree) or
    None."""
    out = manager.restore_latest(template=template)
    if out is None:
        return None
    step, tree = out
    return step, reshard_tree(tree, cfg, mesh, dp, tp)


__all__ = ["reshard_tree", "restore_elastic"]
