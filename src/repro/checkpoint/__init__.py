"""Fault-tolerant checkpointing with NUMARCK temporal compression."""
