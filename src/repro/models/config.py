"""Architecture configuration schema covering all assigned families.

One frozen dataclass spans dense / MoE / SSM / hybrid / VLM / audio; unused
fields stay at their zero defaults.  Exact full-size configs live in
src/repro/configs/<arch>.py; each also provides a reduced `smoke()` for CPU
tests.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                   # 0 => attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0

    # attention
    attn_kind: str = "gqa"         # gqa | mla | none
    rope_theta: float = 10000.0
    sliding_window: int = 0        # 0 => full causal
    global_attn_layers: Tuple[int, ...] = ()   # SWA exceptions (hymba)
    qkv_bias: bool = False         # qwen-style

    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    # SS Perf (EXPERIMENTS.md, mixtral): split each expert's FFN into
    # `moe_ep_split` independent column/row slices so n_experts*split
    # matches the model axis -> clean expert parallelism with no FSDP
    # weight gathers and no padding.  Mathematically exact for SwiGLU.
    moe_ep_split: int = 1

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # frontend stubs (assignment: modality frontend provides embeddings)
    frontend: str = ""             # "" | "patches" | "frames"
    n_prefix: int = 0              # e.g. 256 SigLIP patches

    # numerics / training
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: str = "none"            # none | block
    tie_embeddings: bool = False

    # which assigned input shapes are runnable (DESIGN.md Sec. 5)
    supports_long_context: bool = False

    def __post_init__(self):
        if self.n_heads and not self.head_dim and self.attn_kind == "gqa":
            object.__setattr__(self, "head_dim",
                               self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, L, ff, V = self.d_model, self.n_layers, self.d_ff, self.vocab_size
        total = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attn_kind == "gqa" and self.n_heads:
            hd = self.head_dim
            per_layer += d * self.n_heads * hd          # q
            per_layer += 2 * d * self.n_kv_heads * hd   # k, v
            per_layer += self.n_heads * hd * d          # o
        elif self.attn_kind == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            per_layer += d * self.q_lora_rank
            per_layer += self.q_lora_rank * self.n_heads * qk
            per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer += self.kv_lora_rank * self.n_heads * (
                self.qk_nope_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * d
        if self.n_experts:
            per_layer += d * self.n_experts              # router
            per_layer += self.n_experts * 3 * d * ff     # swiglu experts
        elif ff:
            per_layer += 3 * d * ff
        if self.ssm_state:
            din, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * din + 2 * ns + nh)     # in_proj
            per_layer += din * d                          # out_proj
            per_layer += self.conv_width * (din + 2 * ns) + 3 * nh
        per_layer += 2 * d                                # norms
        return total + L * per_layer

    def active_param_count(self) -> int:
        """N_active for MoE rooflines (6 * N_active * D)."""
        if not self.n_experts:
            return self.param_count()
        d, L, ff = self.d_model, self.n_layers, self.d_ff
        dense_experts = self.n_experts - self.moe_top_k
        return self.param_count() - L * dense_experts * 3 * d * ff


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the smoke-test config: same family/topology, tiny sizes."""
    base = dict(
        n_layers=2, d_model=64, d_ff=128,
        vocab_size=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 0,
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window
        else 0,
        global_attn_layers=(0,) if cfg.global_attn_layers else (),
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_dim=16 if cfg.qk_nope_dim else 0,
        qk_rope_dim=8 if cfg.qk_rope_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=16,
        n_prefix=8 if cfg.n_prefix else 0,
        dtype="float32", remat="none",
    )
    if base["n_experts"]:
        # Drop-free MoE capacity (cap == T exactly when cf = E/k): capacity
        # overflow assigns buffer slots through a cumsum over ALL tokens, so
        # a drop couples a token's output to arbitrarily distant tokens'
        # routing -- which breaks the locality properties the smoke tests
        # assert (e.g. SWA receptive-field isolation).  Production configs
        # keep their trained capacity_factor; drop behavior itself is
        # covered by test_moe.py with an explicit tiny factor.
        base["capacity_factor"] = max(
            cfg.capacity_factor, base["n_experts"] / base["moe_top_k"])
    base.update(overrides)
    return replace(cfg, **base)


# Assigned input shapes (seq_len, global_batch); decode_*/long_* lower
# serve_step with a KV cache of seq_len (one new token).
SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def runnable_shapes(cfg: ModelConfig):
    """long_500k only for sub-quadratic archs (DESIGN.md Sec. 5)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        names.append("long_500k")
    return names


__all__ = ["ModelConfig", "reduced", "SHAPES", "runnable_shapes"]
