"""Mamba-2 SSD (state-space duality) block -- arXiv:2405.21060.

Training/prefill uses the *chunked dual form*: block-diagonal (intra-chunk)
attention-like matmuls + a low-rank inter-chunk state recurrence.  This is
the TPU-native formulation -- every heavy op is an MXU matmul over
(chunk x chunk) or (chunk x state) tiles; the only sequential op is the
O(T/chunk) state scan.

Decode is the O(1) recurrence h <- a*h + dt*B (x) , y = C.h + D*x.

Layout: ngroups = 1 (B/C shared across heads), d_inner = expand*d_model,
heads = d_inner / head_dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll
from repro.models.layers import _dense_init, rms_norm, rms_norm_init


def ssd_init(key, cfg: ModelConfig):
    d = cfg.d_model
    din, N, nh, w = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    ks = jax.random.split(key, 4)
    conv_ch = din + 2 * N
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * din + 2 * N + nh)),
        "conv_w": _dense_init(ks[1], (w, conv_ch), scale=w ** -0.5),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, nh, dtype=jnp.float32))),
        "gate_norm": rms_norm_init(din),
        "out_proj": _dense_init(ks[2], (din, d)),
    }


def _split_proj(p, x, cfg: ModelConfig):
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj = jnp.einsum("btd,de->bte", x, p["in_proj"].astype(x.dtype))
    z = proj[..., :din]
    xBC = proj[..., din: 2 * din + 2 * N]
    dt_raw = proj[..., 2 * din + 2 * N:]
    return z, xBC, dt_raw


def _causal_conv(p, xBC, w):
    """Depthwise causal conv via w static shifts (w is 4: cheap + fusable)."""
    pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
    T = xBC.shape[1]
    out = sum(pad[:, i: i + T, :] * p["conv_w"][i].astype(xBC.dtype)
              for i in range(w))
    return jax.nn.silu(out + p["conv_b"].astype(xBC.dtype))


def _segsum_decay(a_cum):
    """L[q, s] = exp(a_cum[q] - a_cum[s]) masked to q >= s.

    a_cum: (..., Q, nh) inclusive cumulative log-decay.
    Returns (..., Q, Q, nh) in f32.
    """
    diff = a_cum[..., :, None, :] - a_cum[..., None, :, :]
    Q = a_cum.shape[-2]
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(tri[..., None], jnp.exp(diff), 0.0)


def ssd_apply(p, x, *, cfg: ModelConfig, valid_len=None, init_state=None):
    """x (B, T, d) -> (y (B, T, d), final ssm state h (B, nh, hd, N)).

    `valid_len`: positions >= valid_len get dt = 0 (identity update), so the
    returned state reflects exactly the first valid_len tokens (prefill with
    padding).
    """
    B_, T, _ = x.shape
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd, Q = cfg.ssm_head_dim, cfg.ssm_chunk
    dt_ = x.dtype

    z, xBC, dt_raw = _split_proj(p, x, cfg)
    xBC = _causal_conv(p, xBC, cfg.conv_width)
    xs = xBC[..., :din].reshape(B_, T, nh, hd)
    Bm = xBC[..., din: din + N]
    Cm = xBC[..., din + N:]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])               # (B,T,nh) f32
    if valid_len is not None:
        tpos = jnp.arange(T)
        dt = jnp.where(tpos[None, :, None] < valid_len, dt, 0.0)
    A = -jnp.exp(p["A_log"])                           # (nh,)
    a = dt * A                                         # log-decay, <= 0

    # pad T to a chunk multiple (causal: pads can't affect real outputs;
    # dt=0 there keeps the carried state exact)
    Tp = -(-T // Q) * Q
    if Tp != T:
        pad = Tp - T
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    nc = Tp // Q

    xdt = (xs.astype(jnp.float32) * dt[..., None]).astype(dt_)
    def ch(t, shape):
        return t.reshape((B_, nc, Q) + shape)
    xdt_c, B_c, C_c = ch(xdt, (nh, hd)), ch(Bm, (N,)), ch(Cm, (N,))
    a_c = a.reshape(B_, nc, Q, nh)
    a_cum = jnp.cumsum(a_c, axis=2)                    # (B,nc,Q,nh)

    # ---- intra-chunk (block-diagonal attention-dual) --------------------
    L = _segsum_decay(a_cum)                           # (B,nc,Q,Q,nh)
    scores = jnp.einsum("bcqn,bcsn->bcqs", C_c, B_c)   # shared across heads
    w_att = (scores[..., None] * L).astype(dt_)        # (B,nc,Q,Q,nh)
    y_diag = jnp.einsum("bcqsh,bcshd->bcqhd", w_att, xdt_c)

    # ---- chunk boundary states -----------------------------------------
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # (B,nc,Q,nh)
    S = jnp.einsum("bcqn,bcqhd->bchdn",
                   B_c.astype(jnp.float32),
                   xdt_c.astype(jnp.float32) * decay_to_end[..., None])

    # ---- inter-chunk recurrence (the only sequential op) ----------------
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])          # (B,nc,nh)
    h0 = (jnp.zeros((B_, nh, hd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(h, inp):
        dec, s = inp                                   # (B,nh), (B,nh,hd,N)
        h_next = h * dec[:, :, None, None] + s
        return h_next, h

    (h_final, h_prevs) = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(chunk_decay, 1, 0),
                      jnp.moveaxis(S, 1, 0)), unroll=scan_unroll())
    h_prev = jnp.moveaxis(h_prevs, 0, 1)               # (B,nc,nh,hd,N)

    # ---- inter-chunk contribution ---------------------------------------
    in_decay = jnp.exp(a_cum)                          # (B,nc,Q,nh)
    y_off = jnp.einsum("bcqn,bchdn->bcqhd", C_c.astype(jnp.float32),
                       h_prev) * in_decay[..., None]

    y = (y_diag.astype(jnp.float32) + y_off).reshape(B_, Tp, nh, hd)[:, :T]
    y = y + xs[:, :T].astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B_, T, din).astype(dt_)

    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt_))
    return out, h_final.astype(jnp.float32)


def ssd_decode(p, x, cache, *, cfg: ModelConfig):
    """One-token recurrent step.  x (B,1,d); cache {conv (B,w-1,ch),
    h (B,nh,hd,N)}."""
    B_, _, _ = x.shape
    din, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hd, w = cfg.ssm_head_dim, cfg.conv_width
    dt_ = x.dtype

    z, xBC_new, dt_raw = _split_proj(p, x, cfg)
    window = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # (B,w,ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)                                 # (B,ch)
    xs = xBC[:, :din].reshape(B_, nh, hd)
    Bm = xBC[:, din: din + N]
    Cm = xBC[:, din + N:]

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                         # (B,nh)

    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhd->bhdn", Bm, xs * dt[..., None])                 # (B,nh,hd,N)
    y = jnp.einsum("bn,bhdn->bhd", Cm, h) + xs * p["D"][None, :, None]
    y = y.reshape(B_, 1, din).astype(dt_)
    y = rms_norm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(dt_))
    return out, {"conv": window[:, 1:], "h": h}


def ssd_empty_cache(cfg: ModelConfig, batch, dtype):
    din, N = cfg.d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, din + 2 * N),
                          jnp.float32),
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, N),
                       jnp.float32),
    }


def ssd_prefill_cache(p, x, *, cfg: ModelConfig, valid_len=None):
    """Run ssd_apply and also return the decode cache (state + conv tail)."""
    out, h = ssd_apply(p, x, cfg=cfg, valid_len=valid_len)
    _, xBC, _ = _split_proj(p, x, cfg)
    w = cfg.conv_width
    conv_tail = xBC[:, -(w - 1):, :].astype(jnp.float32)
    return out, {"conv": conv_tail, "h": h}


__all__ = ["ssd_init", "ssd_apply", "ssd_decode", "ssd_empty_cache",
           "ssd_prefill_cache"]
