"""Public model API: --arch <id> -> Model(init/loss/prefill/decode/specs).

`input_specs(shape_name)` returns ShapeDtypeStruct stand-ins for every model
input of the assigned (arch x shape) cell -- weak-type-correct, shardable,
no device allocation -- which is what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import SHAPES, ModelConfig, runnable_shapes


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- parameters ------------------------------------------------------
    def init(self, key) -> Dict:
        return lm.init_params(key, self.cfg)

    def shape_params(self) -> Dict:
        """Abstract parameter tree (ShapeDtypeStructs) -- dry-run input."""
        return jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), self.cfg))

    def param_count(self) -> int:
        shapes = self.shape_params()
        return int(sum(int(jnp.prod(jnp.asarray(leaf.shape)))
                       for leaf in jax.tree.leaves(shapes)))

    # ---- steps -----------------------------------------------------------
    def loss(self, params, batch):
        return lm.lm_loss(params, self.cfg, batch)

    def forward(self, params, batch):
        return lm.forward(params, self.cfg, tokens=batch.get("tokens"),
                          extra_embeds=batch.get("embeds"))

    def prefill(self, params, batch, s_max: Optional[int] = None):
        return lm.prefill(params, self.cfg, tokens=batch.get("tokens"),
                          extra_embeds=batch.get("embeds"), s_max=s_max)

    def decode(self, params, cache, token=None, pos=None, embed=None):
        return lm.decode_step(params, self.cfg, cache, token=token, pos=pos,
                              embed=embed)

    def empty_cache(self, batch, s_max):
        return lm.empty_cache(self.cfg, batch, s_max,
                              stacked=not lm.uses_layer_loop(self.cfg))

    # ---- assigned input shapes --------------------------------------------
    def input_specs(self, shape_name: str):
        """ShapeDtypeStruct pytree for one assigned (arch x shape) cell.

        train  -> {tokens/embeds, labels}
        prefill-> {tokens/embeds}
        decode -> {token/embed, pos, cache}  (one new token, seq_len KV)
        """
        cfg = self.cfg
        if shape_name not in SHAPES:
            raise KeyError(shape_name)
        if shape_name not in runnable_shapes(cfg):
            raise ValueError(
                f"{cfg.name} skips {shape_name} (full attention; "
                "DESIGN.md Sec. 5)")
        sh = SHAPES[shape_name]
        B, S = sh["global_batch"], sh["seq_len"]
        dt = jnp.dtype(cfg.dtype)
        i32 = jnp.int32

        if sh["kind"] == "train":
            return self._train_specs(B, S, dt, i32)
        if sh["kind"] == "prefill":
            return self._prompt_specs(B, S, dt, i32)
        # decode: one new token with a seq_len-deep cache
        cache = jax.eval_shape(
            lambda: self.empty_cache(B, S))
        batch: Dict = {"cache": cache, "pos": _sds((), i32)}
        if cfg.frontend == "frames":
            batch["embed"] = _sds((B, 1, cfg.d_model), dt)
        else:
            batch["token"] = _sds((B, 1), i32)
        return batch

    def _train_specs(self, B, S, dt, i32):
        cfg = self.cfg
        specs = self._prompt_specs(B, S, dt, i32)
        n_text = S - (cfg.n_prefix if cfg.frontend == "patches" else 0)
        specs["labels"] = _sds((B, n_text), i32)
        return specs

    def _prompt_specs(self, B, S, dt, i32):
        cfg = self.cfg
        if cfg.frontend == "frames":       # musicgen: EnCodec frame embeds
            return {"embeds": _sds((B, S, cfg.d_model), dt)}
        if cfg.frontend == "patches":      # paligemma: SigLIP patch embeds
            return {"embeds": _sds((B, cfg.n_prefix, cfg.d_model), dt),
                    "tokens": _sds((B, S - cfg.n_prefix), i32)}
        return {"tokens": _sds((B, S), i32)}

    # ---- concrete sample batches (smoke tests / examples) -----------------
    def sample_batch(self, key, batch_size: int, seq_len: int):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k1, k2, k3 = jax.random.split(key, 3)
        n_text = seq_len - (cfg.n_prefix if cfg.frontend == "patches" else 0)
        batch = {}
        if cfg.frontend == "frames":
            batch["embeds"] = jax.random.normal(
                k1, (batch_size, seq_len, cfg.d_model), dt)
            batch["labels"] = jax.random.randint(
                k2, (batch_size, seq_len), 0, cfg.vocab_size)
        elif cfg.frontend == "patches":
            batch["embeds"] = jax.random.normal(
                k1, (batch_size, cfg.n_prefix, cfg.d_model), dt)
            batch["tokens"] = jax.random.randint(
                k2, (batch_size, n_text), 0, cfg.vocab_size)
            batch["labels"] = jax.random.randint(
                k3, (batch_size, n_text), 0, cfg.vocab_size)
        else:
            batch["tokens"] = jax.random.randint(
                k1, (batch_size, seq_len), 0, cfg.vocab_size)
            batch["labels"] = jax.random.randint(
                k2, (batch_size, seq_len), 0, cfg.vocab_size)
        return batch


def build(arch_id: str, smoke: bool = False) -> Model:
    from repro.configs import get_config, get_smoke_config
    return Model(get_smoke_config(arch_id) if smoke else get_config(arch_id))


__all__ = ["Model", "build"]
