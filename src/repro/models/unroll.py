"""Global scan-unroll switch (cost-model validation only).

XLA's cost analysis counts while-loop bodies once; with every lax.scan
fully unrolled the HLO FLOPs are exact, which is how the analytical cost
model (launch/cost_model.py) is validated on small configs.  Production
lowering always uses rolled scans (compact HLO).
"""
from __future__ import annotations

from contextlib import contextmanager

_FLAG = {"on": False}


def scan_unroll():
    """Pass as lax.scan's unroll= argument."""
    return True if _FLAG["on"] else 1


@contextmanager
def full_unroll():
    prev = _FLAG["on"]
    _FLAG["on"] = True
    try:
        yield
    finally:
        _FLAG["on"] = prev


__all__ = ["scan_unroll", "full_unroll"]
