"""Decoder-only LM assembly for all 10 assigned architectures.

One homogeneous `layer` definition per config covers dense / MoE / SSM /
hybrid; layers are *stacked* (leading L axis) and applied with
lax.scan-over-layers (compact HLO, the production pattern).  Hymba's decode
path unrolls a python loop instead because its per-layer caches are
heterogeneous (3 global-attention layers hold full-length KV; SWA layers
hold ring buffers).

Frontends ([vlm]/[audio]) are stubs per the assignment: the model consumes
precomputed patch/frame embeddings through `extra_embeds`.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {}
    if cfg.n_heads:
        p["ln_attn"] = L.rms_norm_init(d)
        p["attn"] = (L.mla_init(ks[0], cfg) if cfg.attn_kind == "mla"
                     else L.gqa_init(ks[0], cfg))
    if cfg.ssm_state:
        if not cfg.n_heads:
            p["ln_ssm"] = L.rms_norm_init(d)
        p["ssm"] = S.ssd_init(ks[1], cfg)
        if cfg.family == "hybrid":
            p["ln_attn_out"] = L.rms_norm_init(d)
            p["ln_ssm_out"] = L.rms_norm_init(d)
    if cfg.d_ff:
        p["ln_mlp"] = L.rms_norm_init(d)
        p["mlp"] = (L.moe_init(ks[2], cfg) if cfg.n_experts
                    else L.ffn_init(ks[2], cfg))
    return p


def init_params(key, cfg: ModelConfig):
    ke, kl, ko = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    params = {
        "embed": jax.random.normal(ke, (cfg.vocab_size, cfg.d_model),
                                   jnp.float32) * 0.02,
        "ln_f": L.rms_norm_init(cfg.d_model),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = jax.random.normal(
            ko, (cfg.d_model, cfg.vocab_size),
            jnp.float32) * cfg.d_model ** -0.5
    # SS Perf iteration (EXPERIMENTS.md): store weight matrices in the
    # compute dtype (bf16 on the full configs).  Adam moments stay f32
    # (optim.init_state), so this is the standard bf16-weights +
    # f32-optimizer-state recipe; it halves every FSDP all-gather and
    # gradient reduce-scatter on the wire.  Norm scales stay f32.
    dt = jnp.dtype(cfg.dtype)
    if dt != jnp.float32:
        def cast(path, x):
            name = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if name == "scale" or x.ndim == 0:
                return x
            return x.astype(dt)
        params = jax.tree_util.tree_map_with_path(cast, params)
    return params


def layer_flags(cfg: ModelConfig):
    """(L,) int32 per-layer attention window (0 = global).

    numpy (host-side) so values stay concrete under jit; scan converts to a
    device constant when used as xs.
    """
    import numpy as np
    if not cfg.sliding_window:
        return np.zeros((cfg.n_layers,), np.int32)
    w = np.full((cfg.n_layers,), cfg.sliding_window, np.int32)
    for g in cfg.global_attn_layers:
        w[g] = 0
    return w


# ---------------------------------------------------------------------------
# one layer, training/prefill form
# ---------------------------------------------------------------------------

def layer_apply(p, x, *, cfg: ModelConfig, positions, window, prefix,
                valid_len=None):
    """x (B,T,d) -> (x', aux_loss).  `window` may be traced (scan xs).

    Block outputs are tagged with checkpoint_name so the block-remat
    policy can SAVE them: the backward pass then re-runs the block-local
    math but never re-runs the TP all-reduces that produced a_out/m_out
    (SS Perf iteration: collective term of remat'd training steps).
    """
    from jax.ad_checkpoint import checkpoint_name
    aux = jnp.float32(0.0)
    has_window = bool(cfg.sliding_window)
    if cfg.family == "hybrid":
        h = L.rms_norm(p["ln_attn"], x, cfg.norm_eps)
        a_out, _ = L.gqa_apply(p["attn"], h, cfg=cfg, positions=positions,
                               window=window, prefix=prefix,
                               has_window=has_window)
        s_out, _ = S.ssd_apply(p["ssm"], h, cfg=cfg, valid_len=valid_len)
        a_out = L.rms_norm(p["ln_attn_out"], a_out, cfg.norm_eps)
        s_out = L.rms_norm(p["ln_ssm_out"], s_out, cfg.norm_eps)
        x = x + checkpoint_name(0.5 * (a_out + s_out), "block_out")
    elif cfg.n_heads:
        h = L.rms_norm(p["ln_attn"], x, cfg.norm_eps)
        if cfg.attn_kind == "mla":
            a_out, _ = L.mla_apply(p["attn"], h, cfg=cfg,
                                   positions=positions, prefix=prefix)
        else:
            a_out, _ = L.gqa_apply(p["attn"], h, cfg=cfg,
                                   positions=positions, window=window,
                                   prefix=prefix, has_window=has_window)
        x = x + checkpoint_name(a_out, "block_out")
    elif cfg.ssm_state:
        h = L.rms_norm(p["ln_ssm"], x, cfg.norm_eps)
        s_out, _ = S.ssd_apply(p["ssm"], h, cfg=cfg, valid_len=valid_len)
        x = x + checkpoint_name(s_out, "block_out")
    if cfg.d_ff:
        h = L.rms_norm(p["ln_mlp"], x, cfg.norm_eps)
        if cfg.n_experts:
            m_out, aux = L.moe_apply(p["mlp"], h, cfg=cfg)
        else:
            m_out = L.ffn_apply(p["mlp"], h)
        x = x + checkpoint_name(m_out, "block_out")
    return x, aux


# ---------------------------------------------------------------------------
# backbone forward (train / prefill logits)
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, tokens=None, extra_embeds=None):
    """Token embeddings, optionally prefixed with frontend embeddings."""
    dt = L.cdtype(cfg)
    parts = []
    if extra_embeds is not None:
        parts.append(extra_embeds.astype(dt))
    if tokens is not None:
        parts.append(params["embed"].astype(dt)[tokens])
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    return x * jnp.asarray(cfg.d_model ** 0.5, dt) if cfg.family == "vlm" \
        else x


def forward(params, cfg: ModelConfig, tokens=None, extra_embeds=None,
            valid_len=None):
    """-> (logits (B,T,V) f32, aux_loss)."""
    x = embed_inputs(params, cfg, tokens, extra_embeds)
    B, T, _ = x.shape
    positions = jnp.arange(T, dtype=jnp.int32)
    prefix = cfg.n_prefix
    windows = layer_flags(cfg)
    import numpy as _np
    # uniform windows stay STATIC so chunked_sdpa can block-skip
    # (SS Perf iteration); mixed SWA/global (hymba) must trace them
    uniform_w = (int(windows[0]) if _np.unique(windows).size == 1
                 else None)

    def body(carry, xs):
        x, aux = carry
        lp, w = xs
        if uniform_w is not None:
            w = uniform_w
        # residual stream: batch over dp; sequence over tp when shard_seq
        # (Megatron-style sequence parallelism -- bounds remat memory)
        x = shd.constrain(x, "dp", "seq", None)
        fn = functools.partial(layer_apply, cfg=cfg, positions=positions,
                               prefix=prefix, valid_len=valid_len)
        if cfg.remat == "block":
            # save the post-collective block outputs: backward recomputes
            # block-local math but not the TP all-reduces (SS Perf)
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.save_only_these_names(
                    "block_out"))
        x, a = fn(lp, x, window=w)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], windows),
                               unroll=scan_unroll())
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, aux


def unembed(params, cfg: ModelConfig, x):
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(x.dtype)
    return jnp.einsum("btd,dv->btv", x, w).astype(jnp.float32)


def lm_loss(params, cfg: ModelConfig, batch):
    """Cross-entropy over next-token labels; labels == -100 are masked."""
    logits, aux = forward(params, cfg,
                          tokens=batch.get("tokens"),
                          extra_embeds=batch.get("embeds"))
    logits = shd.constrain(logits, "dp", None, "tp")   # vocab-sharded CE
    labels = batch["labels"]
    # frontend prefix produces positions without labels
    T_lab = labels.shape[1]
    logits = logits[:, -T_lab:]
    mask = labels != -100
    labels_safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)
    return loss + 0.01 * aux / max(cfg.n_layers, 1), {
        "loss": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _attn_cache_spec(cfg: ModelConfig, window: int, batch, s_max, dtype):
    if cfg.attn_kind == "mla":
        return L.mla_empty_cache(cfg, batch, s_max, dtype)
    return L.gqa_empty_cache(cfg, batch, s_max, window, dtype)


def empty_cache(cfg: ModelConfig, batch, s_max, stacked: bool = True):
    """Decode cache pytree.  stacked=True -> leading L axis (scan archs)."""
    dt = L.cdtype(cfg)
    windows = [int(w) for w in layer_flags(cfg)]

    def one(layer_idx):
        c = {}
        if cfg.n_heads:
            c["attn"] = _attn_cache_spec(cfg, windows[layer_idx], batch,
                                         s_max, dt)
        if cfg.ssm_state:
            c["ssm"] = S.ssd_empty_cache(cfg, batch, dt)
        return c

    if stacked:
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[one(i) for i in range(cfg.n_layers)])
    return [one(i) for i in range(cfg.n_layers)]


def uses_layer_loop(cfg: ModelConfig) -> bool:
    """Heterogeneous caches (mixed SWA/global) -> python-loop decode."""
    return bool(cfg.global_attn_layers)


def layer_decode(p, x, cache, *, cfg: ModelConfig, pos, window: int,
                 prefix: int = 0):
    """One layer, one token.  cache: {attn?, ssm?} for this layer."""
    new_cache = dict(cache)
    if cfg.family == "hybrid":
        h = L.rms_norm(p["ln_attn"], x, cfg.norm_eps)
        a_out, new_cache["attn"] = L.gqa_decode(
            p["attn"], h, cache["attn"], cfg=cfg, pos=pos, window=window,
            prefix=prefix)
        s_out, new_cache["ssm"] = S.ssd_decode(p["ssm"], h, cache["ssm"],
                                               cfg=cfg)
        a_out = L.rms_norm(p["ln_attn_out"], a_out, cfg.norm_eps)
        s_out = L.rms_norm(p["ln_ssm_out"], s_out, cfg.norm_eps)
        x = x + 0.5 * (a_out + s_out)
    elif cfg.n_heads:
        h = L.rms_norm(p["ln_attn"], x, cfg.norm_eps)
        if cfg.attn_kind == "mla":
            a_out, new_cache["attn"] = L.mla_decode(
                p["attn"], h, cache["attn"], cfg=cfg, pos=pos)
        else:
            a_out, new_cache["attn"] = L.gqa_decode(
                p["attn"], h, cache["attn"], cfg=cfg, pos=pos,
                window=window, prefix=prefix)
        x = x + a_out
    elif cfg.ssm_state:
        h = L.rms_norm(p["ln_ssm"], x, cfg.norm_eps)
        s_out, new_cache["ssm"] = S.ssd_decode(p["ssm"], h, cache["ssm"],
                                               cfg=cfg)
        x = x + s_out
    if cfg.d_ff:
        h = L.rms_norm(p["ln_mlp"], x, cfg.norm_eps)
        if cfg.n_experts:
            m_out, _ = L.moe_apply(p["mlp"], h, cfg=cfg)
        else:
            m_out = L.ffn_apply(p["mlp"], h)
        x = x + m_out
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache, token=None, pos=None,
                embed=None):
    """One new token for the whole batch.

    token (B,1) int32 (or `embed` (B,1,d) for frontend archs); pos scalar
    int32 absolute position; cache as from `empty_cache`/prefill.
    Returns (logits (B,1,V) f32, new_cache).
    """
    dt = L.cdtype(cfg)
    if embed is not None:
        x = embed.astype(dt)
    else:
        x = params["embed"].astype(dt)[token]
    windows = layer_flags(cfg)
    prefix = cfg.n_prefix

    if uses_layer_loop(cfg):
        new_caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, nc = layer_decode(lp, x, cache[i], cfg=cfg, pos=pos,
                                 window=int(windows[i]), prefix=prefix)
            new_caches.append(nc)
        new_cache = new_caches
    else:
        w0 = int(windows[0])       # homogeneous stack

        def body(x, xs):
            lp, c = xs
            x, nc = layer_decode(lp, x, c, cfg=cfg, pos=pos, window=w0,
                                 prefix=prefix)
            return x, nc

        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache),
                                    unroll=scan_unroll())

    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return unembed(params, cfg, x), new_cache


def prefill(params, cfg: ModelConfig, tokens=None, extra_embeds=None,
            s_max: Optional[int] = None):
    """Full forward + build the decode cache.

    Returns (logits_last (B,1,V), cache, next_pos scalar).
    For scan archs the cache is the stacked pytree; for loop archs a list.
    """
    dt = L.cdtype(cfg)
    x = embed_inputs(params, cfg, tokens, extra_embeds)
    B, T, _ = x.shape
    s_max = s_max or T
    positions = jnp.arange(T, dtype=jnp.int32)
    prefix = cfg.n_prefix
    windows = layer_flags(cfg)
    has_window = bool(cfg.sliding_window)

    def run_layer(lp, x, w, window_static: int):
        """Returns (x', cache_entry) for one layer."""
        c = {}
        if cfg.family == "hybrid":
            h = L.rms_norm(lp["ln_attn"], x, cfg.norm_eps)
            a_out, (k, v) = L.gqa_apply(lp["attn"], h, cfg=cfg,
                                        positions=positions, window=w,
                                        prefix=prefix,
                                        has_window=has_window)
            s_out, sc = S.ssd_prefill_cache(lp["ssm"], h, cfg=cfg)
            c["attn"] = _kv_to_cache(cfg, k, v, T, s_max, window_static, dt)
            c["ssm"] = sc
            a_out = L.rms_norm(lp["ln_attn_out"], a_out, cfg.norm_eps)
            s_out = L.rms_norm(lp["ln_ssm_out"], s_out, cfg.norm_eps)
            x = x + 0.5 * (a_out + s_out)
        elif cfg.n_heads:
            h = L.rms_norm(lp["ln_attn"], x, cfg.norm_eps)
            if cfg.attn_kind == "mla":
                a_out, (ckv, krope) = L.mla_apply(
                    lp["attn"], h, cfg=cfg, positions=positions,
                    prefix=prefix)
                c["attn"] = _mla_to_cache(cfg, ckv, krope, T, s_max, dt)
            else:
                a_out, (k, v) = L.gqa_apply(
                    lp["attn"], h, cfg=cfg, positions=positions, window=w,
                    prefix=prefix, has_window=has_window)
                c["attn"] = _kv_to_cache(cfg, k, v, T, s_max,
                                         window_static, dt)
            x = x + a_out
        elif cfg.ssm_state:
            h = L.rms_norm(lp["ln_ssm"], x, cfg.norm_eps)
            s_out, sc = S.ssd_prefill_cache(lp["ssm"], h, cfg=cfg)
            c["ssm"] = sc
            x = x + s_out
        if cfg.d_ff:
            h = L.rms_norm(lp["ln_mlp"], x, cfg.norm_eps)
            m_out = (L.moe_apply(lp["mlp"], h, cfg=cfg)[0] if cfg.n_experts
                     else L.ffn_apply(lp["mlp"], h))
            x = x + m_out
        return x, c

    if uses_layer_loop(cfg):
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, c = run_layer(lp, x, int(windows[i]), int(windows[i]))
            caches.append(c)
        cache = caches
    else:
        # non-loop archs have uniform windows (uses_layer_loop is True for
        # mixed) -> pass the STATIC window so chunked_sdpa block-skips
        w0 = int(windows[0])

        def body(x, xs):
            (lp,) = xs
            fn = run_layer
            if cfg.remat == "block":
                fn = jax.checkpoint(run_layer, static_argnums=(2, 3))
            return fn(lp, x, w0, w0)

        x, cache = jax.lax.scan(body, x, (params["layers"],),
                                unroll=scan_unroll())

    x = L.rms_norm(params["ln_f"], x[:, -1:, :], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    return logits, cache, jnp.int32(T)


def _kv_to_cache(cfg, k, v, T, s_max, window: int, dt):
    """Prefill K/V (B,T,K,hd) -> decode cache layout (ring for SWA)."""
    ring = min(window, s_max) if window else s_max
    B = k.shape[0]
    pos = jnp.arange(T, dtype=jnp.int32)
    ck = jnp.zeros((B, ring, cfg.n_kv_heads, cfg.head_dim), dt)
    cv = jnp.zeros_like(ck)
    pm = jnp.full((ring,), -1, jnp.int32)
    if window and T > ring:
        # keep the trailing `ring` positions, placed at their ring slots
        keep = pos[-ring:]
        slots = keep % ring
        ck = ck.at[:, slots].set(k[:, -ring:].astype(dt))
        cv = cv.at[:, slots].set(v[:, -ring:].astype(dt))
        pm = pm.at[slots].set(keep)
    else:
        ck = ck.at[:, :T].set(k.astype(dt))
        cv = cv.at[:, :T].set(v.astype(dt))
        pm = pm.at[:T].set(pos)
    return {"k": ck, "v": cv, "pos_map": pm}


def _mla_to_cache(cfg, ckv, krope, T, s_max, dt):
    B = ckv.shape[0]
    c = {
        "ckv": jnp.zeros((B, s_max, cfg.kv_lora_rank), dt
                         ).at[:, :T].set(ckv.astype(dt)),
        "krope": jnp.zeros((B, s_max, cfg.qk_rope_dim), dt
                           ).at[:, :T].set(krope.astype(dt)),
        "pos_map": jnp.full((s_max,), -1, jnp.int32
                            ).at[:T].set(jnp.arange(T, dtype=jnp.int32)),
    }
    return c


__all__ = ["init_params", "init_layer", "forward", "lm_loss", "prefill",
           "decode_step", "empty_cache", "uses_layer_loop", "layer_flags",
           "unembed"]
