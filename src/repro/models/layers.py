"""Transformer building blocks: norms, RoPE, GQA/SWA/MLA attention, MoE.

Functional style: every block is (init(key, cfg) -> params-dict,
apply(params, x, ...) -> y).  Parameters are float32 masters; forward casts
to cfg.dtype (bf16 on TPU).  Softmax and norms accumulate in f32.

Decode caches:
  * full attention -- (B, S_max, K, hd) written at `pos`
  * sliding window -- ring buffer of W slots + `pos_map` of absolute
    positions (mask derives validity; RoPE is applied pre-cache at absolute
    positions, so ring rotation is transparent)
  * MLA -- compressed latent (B, S, kv_lora) + shared roped key (B, S, r)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from functools import partial as functools_partial

from repro.models.config import ModelConfig
from repro.models.unroll import scan_unroll


def cdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _dense_init(key, shape, scale=None):
    import math
    fan_in = shape[0] if len(shape) <= 2 else math.prod(shape[:-1])
    scale = scale if scale is not None else fan_in ** -0.5
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm_init(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (half-split / llama style)
# ---------------------------------------------------------------------------

def rope_tables(positions, dim, theta):
    """positions (T,) int32 -> cos/sin (T, dim/2) f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., T, H, dim); cos/sin (T, dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, :, None, :].astype(x.dtype)
    s = sin[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(q_pos, kv_pos, window=0, prefix: int = 0,
                has_window: bool = False):
    """(Tq, Tk) bool: True = attend.

    `window` may be a *traced* scalar (hymba mixes SWA and global layers in
    one scan); `has_window` statically marks whether banding can occur at
    all.  window == 0 means full causal.  prefix > 0 makes the first
    `prefix` kv positions visible to everyone (prefix-LM).
    """
    m = kv_pos[None, :] <= q_pos[:, None]
    if has_window:
        window = jnp.asarray(window)
        band = kv_pos[None, :] > (q_pos[:, None] - window)
        m &= (window == 0) | band
    if prefix:
        m |= (kv_pos[None, :] < prefix)
    return m


def chunked_sdpa(q, k, v, *, q_pos, kv_pos, window=0, prefix=0,
                 has_window=False, n_rep=1, q_block=512, kv_block=1024,
                 block_skip=False):
    """Blockwise online-softmax attention (flash-style, pure JAX).

    Never materializes the (T, S) score matrix: lax.scan over query blocks,
    inner lax.scan over kv blocks carrying (m, lse, acc) running statistics.
    This is what makes the 32k/500k shapes lowerable -- see DESIGN.md.

    block_skip (SS Perf iteration): when q/kv positions are the aligned
    0..T-1 training/prefill layout and `window` is static, the q loop
    unrolls in python and each query block only visits kv blocks inside
    its causal (and SWA) band -- cutting attention FLOPs ~2x for causal
    and ~S/window for long SWA prefill.  Skipped for traced windows
    (hymba's mixed-layer scan) and for prefix-LM.

    q (B,T,H,hd), k (B,S,K,hd), v (B,S,K,hdv); H = K * n_rep.
    Returns (B,T,H,hdv).  hdv may differ from hd (MLA).
    """
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    qb = min(q_block, T)
    kb = min(kv_block, S)
    Tp, Sp = -(-T // qb) * qb, -(-S // kb) * kb
    BIG = jnp.int32(1 << 30)

    q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0)))
    k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    q_pos = jnp.pad(q_pos, (0, Tp - T), constant_values=-2)   # masked rows
    kv_pos = jnp.pad(kv_pos, (0, Sp - S), constant_values=BIG)

    q = q.reshape(B, Tp // qb, qb, K, n_rep, hd)
    qs = jnp.moveaxis(q, 1, 0)                  # (nqb, B, qb, K, R, hd)
    ks = jnp.moveaxis(k.reshape(B, Sp // kb, kb, K, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, Sp // kb, kb, K, hdv), 1, 0)
    qps = q_pos.reshape(Tp // qb, qb)
    kps = kv_pos.reshape(Sp // kb, kb)
    scale = hd ** -0.5

    def kv_step(qblk, qp, carry, kv_in):
        m, lse, acc = carry
        kblk, vblk, kp = kv_in
        s = jnp.einsum("bqkrh,bskh->bkrqs", qblk, kblk) * scale
        s = s.astype(jnp.float32)
        msk = causal_mask(qp, kp, window, prefix, has_window)
        s = jnp.where(msk[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_new = jnp.maximum(m_new, -1e30)        # keep finite
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        lse = lse * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkrqs,bskh->bkrqh", p.astype(vblk.dtype), vblk)
        acc = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, lse, acc), None

    def init_carry():
        return (jnp.full((B, K, n_rep, qb), -1e30, jnp.float32),
                jnp.zeros((B, K, n_rep, qb), jnp.float32),
                jnp.zeros((B, K, n_rep, qb, hdv), jnp.float32))

    nqb = Tp // qb
    static_w = isinstance(window, (int,))
    skip_ok = block_skip and static_w and prefix == 0 and T == S
    if skip_ok and window and window < S:
        # SWA: rolled q scan; every q block reads a FIXED-size kv band via
        # dynamic_slice (band blocks = (window+qb)/kb + 1), so HLO stays
        # compact at any T (the unrolled variant exploded compile time on
        # hymba prefill_32k -- see EXPERIMENTS.md SS Perf)
        nb_band = min(Sp // kb, (window + qb) // kb + 1)

        def q_step_band(_, q_in):
            qblk, qp, qi = q_in
            lo_pos = jnp.maximum(qi * qb - window, 0)
            b0 = jnp.clip(lo_pos // kb, 0, Sp // kb - nb_band)
            ks_b = jax.lax.dynamic_slice_in_dim(ks, b0, nb_band, 0)
            vs_b = jax.lax.dynamic_slice_in_dim(vs, b0, nb_band, 0)
            kps_b = jax.lax.dynamic_slice_in_dim(kps, b0, nb_band, 0)
            (m, lse, acc), _ = jax.lax.scan(
                functools_partial(kv_step, qblk, qp), init_carry(),
                (ks_b, vs_b, kps_b), unroll=scan_unroll())
            out = acc / jnp.where(lse == 0, 1.0, lse)[..., None]
            return None, out.astype(qblk.dtype)

        _, outs = jax.lax.scan(
            q_step_band, None,
            (qs, qps, jnp.arange(nqb, dtype=jnp.int32)),
            unroll=scan_unroll())
    elif skip_ok and not window and nqb <= 8:
        # causal: python q loop, each block scans its causal kv prefix
        # (bounded unroll keeps HLO small; covers train_4k)
        outs = []
        for qi in range(nqb):
            q_hi = (qi + 1) * qb                 # causal end (exclusive)
            b1 = min(Sp // kb, -(-q_hi // kb))   # ceil
            (m, lse, acc), _ = jax.lax.scan(
                functools_partial(kv_step, qs[qi], qps[qi]), init_carry(),
                (ks[:b1], vs[:b1], kps[:b1]),
                unroll=scan_unroll())
            out_i = acc / jnp.where(lse == 0, 1.0, lse)[..., None]
            outs.append(out_i.astype(q.dtype))
        outs = jnp.stack(outs)                   # (nqb, B, K, R, qb, hdv)
    else:
        def q_step(_, q_in):
            qblk, qp = q_in                      # (B,qb,K,R,hd), (qb,)
            (m, lse, acc), _ = jax.lax.scan(
                functools_partial(kv_step, qblk, qp), init_carry(),
                (ks, vs, kps), unroll=scan_unroll())
            out = acc / jnp.where(lse == 0, 1.0, lse)[..., None]
            return None, out.astype(qblk.dtype)  # (B,K,R,qb,hdv)

        _, outs = jax.lax.scan(q_step, None, (qs, qps),
                               unroll=scan_unroll())
    out = jnp.moveaxis(outs, 0, 1)               # (B,nqb,K,R,qb,hdv)
    out = jnp.moveaxis(out, 4, 2)                # (B,nqb,qb,K,R,hdv)
    out = out.reshape(B, Tp, H, hdv)[:, :T]
    return out


# ---------------------------------------------------------------------------
# GQA attention (covers MHA kv=H and MQA kv=1)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, hd)),
        "wk": _dense_init(ks[1], (d, K, hd)),
        "wv": _dense_init(ks[2], (d, K, hd)),
        "wo": _dense_init(ks[3], (H, hd, d), scale=(H * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((K, hd), jnp.float32)
        p["bv"] = jnp.zeros((K, hd), jnp.float32)
    return p


def _sdpa(q, k, v, mask, n_rep):
    """q (B,T,H,hd), k (B,S,K,hd), v (B,S,K,hdv); mask (T,S)/(B,T,S) bool.
    hdv may differ from hd (MLA)."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    q = q.reshape(B, T, K, n_rep, hd)
    scores = jnp.einsum("btkrh,bskh->bkrts", q, k) / (hd ** 0.5)
    scores = scores.astype(jnp.float32)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrts,bskh->btkrh", w, v)
    return out.reshape(B, T, H, hdv)


def gqa_apply(p, x, *, cfg: ModelConfig, positions, window=0,
              prefix: int = 0, has_window: bool = False):
    """Training / prefill path.  x (B,T,d); positions (T,) absolute.
    `window` may be traced (hymba); `has_window` marks SWA statically."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dgk->btgk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    cos, sin = rope_tables(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    out = chunked_sdpa(q, k, v, q_pos=positions, kv_pos=positions,
                       window=window, prefix=prefix, has_window=has_window,
                       n_rep=H // K, block_skip=True)
    return jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt)), (k, v)


def gqa_decode(p, x, cache, *, cfg: ModelConfig, pos, window: int,
               prefix: int = 0):
    """One-token decode.  x (B,1,d); cache dict(k,v,(S,K,hd broadcast over B)
    pos_map (S,)); pos scalar int32 absolute position."""
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dgk->btgk", x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dgk->btgk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    cos, sin = rope_tables(pos[None], hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    S = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % S, pos)
    ck = jax.lax.dynamic_update_slice(cache["k"], k,
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v,
                                      (0, slot, 0, 0))
    pos_map = jax.lax.dynamic_update_slice(cache["pos_map"], pos[None],
                                           (slot,))
    occupied = (pos_map >= 0) & (pos_map <= pos)
    valid = occupied
    if window:
        valid &= (pos_map > pos - window) | (pos_map < prefix)
    elif prefix:
        valid |= occupied & (pos_map < prefix)
    out = _sdpa(q, ck, cv, valid[None, None, :], H // K)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))
    return y, {"k": ck, "v": cv, "pos_map": pos_map}


def gqa_empty_cache(cfg: ModelConfig, batch, s_max, window: int, dtype):
    S = min(window, s_max) if window else s_max
    K, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, S, K, hd), dtype),
        "v": jnp.zeros((batch, S, K, hd), dtype),
        "pos_map": jnp.full((S,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-v2 style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": _dense_init(ks[0], (d, cfg.q_lora_rank)),
        "q_norm": rms_norm_init(cfg.q_lora_rank),
        "wq_b": _dense_init(ks[1], (cfg.q_lora_rank, H, qk)),
        "wkv_a": _dense_init(ks[2],
                             (d, cfg.kv_lora_rank + cfg.qk_rope_dim)),
        "kv_norm": rms_norm_init(cfg.kv_lora_rank),
        "wk_b": _dense_init(ks[3], (cfg.kv_lora_rank, H, cfg.qk_nope_dim)),
        "wv_b": _dense_init(ks[4], (cfg.kv_lora_rank, H, cfg.v_head_dim)),
        "wo": _dense_init(ks[5], (H, cfg.v_head_dim, d),
                          scale=(H * cfg.v_head_dim) ** -0.5),
    }


def _mla_latents(p, x, cfg: ModelConfig):
    dt = x.dtype
    kv_a = jnp.einsum("btd,de->bte", x, p["wkv_a"].astype(dt))
    c_kv = rms_norm(p["kv_norm"], kv_a[..., : cfg.kv_lora_rank],
                    cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:]
    return c_kv, k_rope


def _mla_q(p, x, cfg: ModelConfig, positions):
    dt = x.dtype
    q_a = rms_norm(p["q_norm"],
                   jnp.einsum("btd,de->bte", x, p["wq_a"].astype(dt)),
                   cfg.norm_eps)
    q = jnp.einsum("bte,ehk->bthk", q_a, p["wq_b"].astype(dt))
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim:]
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_expand_kv(p, c_kv, k_rope_roped, cfg: ModelConfig):
    dt = c_kv.dtype
    k_nope = jnp.einsum("bte,ehk->bthk", c_kv, p["wk_b"].astype(dt))
    v = jnp.einsum("bte,ehk->bthk", c_kv, p["wv_b"].astype(dt))
    k_rope_h = jnp.broadcast_to(k_rope_roped[:, :, None, :],
                                k_nope.shape[:3] + (cfg.qk_rope_dim,))
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    return k, v


def mla_apply(p, x, *, cfg: ModelConfig, positions, prefix: int = 0):
    c_kv, k_rope = _mla_latents(p, x, cfg)
    cos, sin = rope_tables(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)[:, :, 0, :]
    q = _mla_q(p, x, cfg, positions)
    k, v = _mla_expand_kv(p, c_kv, k_rope, cfg)
    out = chunked_sdpa(q, k, v, q_pos=positions, kv_pos=positions,
                       prefix=prefix, n_rep=1, block_skip=True)
    dt = x.dtype
    return (jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt)),
            (c_kv, k_rope))


def mla_decode(p, x, cache, *, cfg: ModelConfig, pos):
    """Absorbed-form MLA decode: attention runs in the compressed latent
    space, never expanding per-head K/V over the cache.

        q_abs = q_nope . W_kb          (B,1,H,rank)
        s     = q_abs . ckv^T + q_rope . krope^T
        o_lat = softmax(s) . ckv       (B,1,H,rank)
        o     = o_lat . W_vb           (B,1,H,v_dim)

    Memory is O(B*S*rank) instead of O(B*S*H*(qk+v)) -- the naive form
    peaks >16 GB/chip on decode_32k (see EXPERIMENTS.md SS Perf iteration).
    """
    dt = x.dtype
    c_kv_new, k_rope_new = _mla_latents(p, x, cfg)
    cos, sin = rope_tables(pos[None], cfg.qk_rope_dim, cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], cos, sin)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv_new, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope_new,
                                         (0, pos, 0))
    pos_map = jax.lax.dynamic_update_slice(cache["pos_map"], pos[None],
                                           (pos,))
    q = _mla_q(p, x, cfg, pos[None])
    q_nope = q[..., : cfg.qk_nope_dim]
    q_rope = q[..., cfg.qk_nope_dim:]

    q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, p["wk_b"].astype(dt))
    s = (jnp.einsum("bthr,bsr->bhts", q_abs, ckv)
         + jnp.einsum("bthd,bsd->bhts", q_rope, krope))
    s = s.astype(jnp.float32) * ((cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
    valid = (pos_map >= 0) & (pos_map <= pos)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(dt)
    o_lat = jnp.einsum("bhts,bsr->bthr", w, ckv)
    out = jnp.einsum("bthr,rhv->bthv", o_lat, p["wv_b"].astype(dt))
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))
    return y, {"ckv": ckv, "krope": krope, "pos_map": pos_map}


def mla_decode_naive(p, x, cache, *, cfg: ModelConfig, pos):
    """Reference (expanded) MLA decode -- kept as the test oracle for the
    absorbed form and as the paper-faithful-style baseline in SS Perf."""
    dt = x.dtype
    c_kv_new, k_rope_new = _mla_latents(p, x, cfg)
    cos, sin = rope_tables(pos[None], cfg.qk_rope_dim, cfg.rope_theta)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], cos, sin)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], c_kv_new, (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope_new,
                                         (0, pos, 0))
    pos_map = jax.lax.dynamic_update_slice(cache["pos_map"], pos[None],
                                           (pos,))
    q = _mla_q(p, x, cfg, pos[None])
    k, v = _mla_expand_kv(p, ckv, krope, cfg)
    valid = (pos_map >= 0) & (pos_map <= pos)
    out = _sdpa(q, k, v, valid[None, None, :], 1)
    y = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(dt))
    return y, {"ckv": ckv, "krope": krope, "pos_map": pos_map}


def mla_empty_cache(cfg: ModelConfig, batch, s_max, dtype):
    return {
        "ckv": jnp.zeros((batch, s_max, cfg.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, s_max, cfg.qk_rope_dim), dtype),
        "pos_map": jnp.full((s_max,), -1, jnp.int32),
    }


# ---------------------------------------------------------------------------
# Dense FFN (SwiGLU)
# ---------------------------------------------------------------------------

def ffn_init(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f)),
        "w_up": _dense_init(ks[1], (d, f)),
        "w_down": _dense_init(ks[2], (f, d)),
    }


def ffn_apply(p, x):
    dt = x.dtype
    g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(dt))
    u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(dt))
    return jnp.einsum("btf,fd->btd", jax.nn.silu(g) * u,
                      p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# MoE FFN (top-k routing, grouped capacity dispatch; Switch-style groups)
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = cfg.moe_ep_split
    assert f % s == 0, "d_ff must divide moe_ep_split"
    ks = jax.random.split(key, 4)
    # weights stored slot-wise: slot (e*s + j) holds expert e's j-th FFN
    # slice -- exact for SwiGLU (gate/up split along ff columns, down along
    # ff rows; outputs of the slices sum)
    return {
        "router": _dense_init(ks[0], (d, E)),
        "we_gate": _dense_init(ks[1], (E * s, d, f // s)),
        "we_up": _dense_init(ks[2], (E * s, d, f // s)),
        "we_down": _dense_init(ks[3], (E * s, f // s, d)),
    }


def moe_apply(p, x, *, cfg: ModelConfig):
    """x (B, T, d).  Each sequence is a dispatch group (Switch-style), so
    routing stays local to the data shard; capacity drops overflow tokens.

    With moe_ep_split = s > 1 every chosen expert fans out to its s slots
    (the slot outputs sum); capacity per slot stays T*k*cf/E.
    """
    B, T, d = x.shape
    E, k, s = cfg.n_experts, cfg.moe_top_k, cfg.moe_ep_split
    ES, ks_ = E * s, k * s
    cap = max(1, int(T * k * cfg.capacity_factor / E))
    dt = x.dtype

    logits = jnp.einsum("btd,de->bte", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)               # (B, T, k)
    top_p = (top_p / jnp.sum(top_p, -1, keepdims=True)).astype(dt)

    # expand expert choices to slot choices
    slot_e = (top_e[..., None] * s
              + jnp.arange(s, dtype=top_e.dtype)).reshape(B, T, ks_)
    slot_p = jnp.repeat(top_p, s, axis=-1)               # weight per slot

    # position of each (token, choice) inside its slot's capacity buffer.
    # Capacity is granted in router-weight priority order (stable sort,
    # ties broken by sequence position), not raw sequence order: under
    # overflow the *lowest-weight* choices drop, and a token's fate no
    # longer depends on how many earlier-positioned tokens happened to
    # pick the same expert.  Drop-free batches are unaffected (every pos
    # is < cap either way, and pos only selects within a slot's buffer).
    onehot = jax.nn.one_hot(slot_e, ES, dtype=jnp.int32)  # (B, T, ks, ES)
    flat = onehot.reshape(B, T * ks_, ES)
    prio = jnp.argsort(-slot_p.astype(jnp.float32).reshape(B, T * ks_),
                       axis=1, stable=True)              # (B, T*ks)
    ranked = jnp.take_along_axis(flat, prio[..., None], axis=1)
    pos_ranked = jnp.cumsum(ranked, axis=1) - 1
    inv = jnp.argsort(prio, axis=1, stable=True)
    pos_in_e = jnp.take_along_axis(pos_ranked, inv[..., None], axis=1)
    pos = jnp.take_along_axis(
        pos_in_e.reshape(B, T, ks_, ES),
        slot_e[..., None], axis=-1)[..., 0]              # (B, T, ks)
    keep = pos < cap

    def dispatch_one(xb, eb, pb, kb):
        # xb (T,d) -> slot buffers (ES, cap, d)
        buf = jnp.zeros((ES, cap, d), dt)
        e_flat = eb.reshape(-1)
        p_flat = jnp.where(kb.reshape(-1), pb.reshape(-1), cap)  # drop
        xk = jnp.repeat(xb, ks_, axis=0)
        return buf.at[e_flat, p_flat].set(xk, mode="drop")

    from repro.distributed import sharding as shd
    buf = jax.vmap(dispatch_one)(x, slot_e, pos, keep)   # (B, ES, cap, d)
    # expert-parallel dispatch: buf's slot dim follows the expert-weight
    # sharding (EP when ES >= 16), turning the would-be FSDP weight
    # gathers into a token all_to_all (SS Perf, mixtral iteration)
    ep = ES >= 16
    if ep:
        buf = shd.constrain(buf, "dp", "tp", None, None)
    g = jnp.einsum("becd,edf->becf", buf, p["we_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", buf, p["we_up"].astype(dt))
    h = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                   p["we_down"].astype(dt))               # (B, ES, cap, d)
    if ep:
        # SS Perf iteration 3 (EXPERIMENTS.md): an E-sharded h makes GSPMD
        # lower the combine-gather as an all-reduce of the FULL
        # (B, T*ks, d) token tensor in f32 (~8.6 GB/layer); explicitly
        # all-gathering the capacity-bounded bf16 buffers instead is ~6x
        # less traffic, and the gather+weighted-sum below becomes local.
        h = shd.constrain(h, "dp", None, None, None)

    def combine_one(hb, eb, pb, kb, wb):
        e_flat = eb.reshape(-1)
        p_flat = jnp.clip(pb.reshape(-1), 0, cap - 1)
        got = hb[e_flat, p_flat]                          # (T*ks, d)
        got = got * (wb.reshape(-1)[:, None]
                     * kb.reshape(-1)[:, None].astype(dt))
        return got.reshape(T, ks_, d).sum(axis=1)

    out = jax.vmap(combine_one)(h, slot_e, pos, keep, slot_p)
    aux = _load_balance_loss(probs, jax.nn.one_hot(top_e, E,
                                                   dtype=jnp.int32), E)
    return out, aux


def _load_balance_loss(probs, onehot, E):
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    f = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=(0, 1))  # (E,)
    pmean = jnp.mean(probs, axis=(0, 1))
    return E * jnp.sum(f * pmean)


__all__ = [
    "cdtype", "rms_norm_init", "rms_norm", "rope_tables", "apply_rope",
    "causal_mask", "gqa_init", "gqa_apply", "gqa_decode", "gqa_empty_cache",
    "mla_init", "mla_apply", "mla_decode", "mla_empty_cache",
    "ffn_init", "ffn_apply", "moe_init", "moe_apply",
]
