"""Public jit'd wrappers over the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container, unit tests) they execute in interpret mode, which runs the same
kernel bodies element-faithfully.  `use_pallas=False` falls back to the
pure-jnp oracles -- the distributed pipeline exposes this so the dry-run can
compare both lowerings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bitpack, change_ratio, dequant, hist, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def change_ratio_bins(prev, curr, domain_lo, width, *, max_bins,
                      use_pallas: bool = True):
    if not use_pallas:
        return ref.change_ratio_bins_ref(prev, curr, domain_lo, width,
                                         max_bins=max_bins)
    return change_ratio.change_ratio_bins(prev, curr, domain_lo, width,
                                          max_bins=max_bins,
                                          interpret=_interpret())


def pack_bits(idx, *, b_bits, use_pallas: bool = True):
    if not use_pallas:
        return ref.pack_bits_ref(idx, b_bits=b_bits)
    return bitpack.pack_bits(idx, b_bits=b_bits, interpret=_interpret())


def dequantize(idx, prev, centers, *, b_bits, use_pallas: bool = True):
    # The Pallas one-hot-MXU kernel is f32-only; other dtypes (the f64
    # chain under jax_enable_x64) take the dtype-preserving gather path,
    # which is bit-identical for f32 anyway.
    if not use_pallas or jnp.asarray(prev).dtype != jnp.float32:
        return dequant.dequantize_jnp(idx, prev, centers, b_bits=b_bits)
    return dequant.dequantize(idx, prev, centers, b_bits=b_bits,
                              interpret=_interpret())


def histogram(bin_ids, *, max_bins, use_pallas: bool = True):
    if not use_pallas:
        return ref.histogram_ref(bin_ids, max_bins=max_bins)
    return hist.histogram(bin_ids, max_bins=max_bins,
                          interpret=_interpret())


def patch_exceptions(recon, idx, exc_values, *, b_bits):
    """Device-side exception scatter (see kernels.dequant)."""
    return dequant.patch_exceptions(recon, idx, exc_values, b_bits=b_bits)


def chain_advance_core(idx, prev, curr, centers, *, b_bits,
                       use_pallas: bool = True):
    """Unjitted REF_RECONSTRUCTED chain-advance body:

        R_i = prev * (1 + centers[idx]);  R_i[idx == marker] = curr[...]

    The exception patch comes straight from `curr` (the values the
    finalize stage will compact into the exception table), so the result
    is bit-identical to reconstructing from the finalized blob.  The one
    home of the marker-patch semantics: the jitted single-device
    `chain_advance` and the sharded `_advance_shard` stage both call it.
    """
    recon = dequantize(idx, prev, centers, b_bits=b_bits,
                       use_pallas=use_pallas)
    marker = (1 << b_bits) - 1
    return jnp.where(jnp.asarray(idx) == marker,
                     jnp.asarray(curr).astype(recon.dtype), recon)


@functools.partial(jax.jit, static_argnames=("b_bits", "use_pallas"))
def chain_advance(idx, prev, curr, centers, *, b_bits,
                  use_pallas: bool = True):
    """Fused device chain advance (jitted `chain_advance_core`)."""
    return chain_advance_core(idx, prev, curr, centers, b_bits=b_bits,
                              use_pallas=use_pallas)


__all__ = ["change_ratio_bins", "pack_bits", "dequantize",
           "patch_exceptions", "chain_advance", "chain_advance_core",
           "histogram"]
