"""Public jit'd wrappers over the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container, unit tests) they execute in interpret mode, which runs the same
kernel bodies element-faithfully.  `use_pallas=False` falls back to the
pure-jnp oracles -- the distributed pipeline exposes this so the dry-run can
compare both lowerings.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitpack, change_ratio, dequant, hist, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def change_ratio_bins(prev, curr, domain_lo, width, *, max_bins,
                      use_pallas: bool = True):
    if not use_pallas:
        return ref.change_ratio_bins_ref(prev, curr, domain_lo, width,
                                         max_bins=max_bins)
    return change_ratio.change_ratio_bins(prev, curr, domain_lo, width,
                                          max_bins=max_bins,
                                          interpret=_interpret())


def pack_bits(idx, *, b_bits, use_pallas: bool = True):
    if not use_pallas:
        return ref.pack_bits_ref(idx, b_bits=b_bits)
    return bitpack.pack_bits(idx, b_bits=b_bits, interpret=_interpret())


def dequantize(idx, prev, centers, *, b_bits, use_pallas: bool = True):
    # The Pallas one-hot-MXU kernel is f32-only; other dtypes (the f64
    # chain under jax_enable_x64) take the dtype-preserving gather path,
    # which is bit-identical for f32 anyway.
    if not use_pallas or jnp.asarray(prev).dtype != jnp.float32:
        return dequant.dequantize_jnp(idx, prev, centers, b_bits=b_bits)
    return dequant.dequantize(idx, prev, centers, b_bits=b_bits,
                              interpret=_interpret())


def histogram(bin_ids, *, max_bins, use_pallas: bool = True):
    if not use_pallas:
        return ref.histogram_ref(bin_ids, max_bins=max_bins)
    return hist.histogram(bin_ids, max_bins=max_bins,
                          interpret=_interpret())


def patch_exceptions(recon, idx, exc_values, *, b_bits):
    """Device-side exception scatter (see kernels.dequant)."""
    return dequant.patch_exceptions(recon, idx, exc_values, b_bits=b_bits)


def exception_compact(idx, n, marker, block_elems):
    """Device-side incompressible compaction for the encode stage.

    Returns (per-block marker counts (nblocks,) int64, ascending marker
    positions (k,) int64) computed on device -- the host finalize gathers
    the k exception values by position instead of re-scanning the full
    index table with a boolean mask.  The nonzero size is padded to the
    next power of two so the jit cache stays bounded (<= log2(n) entries)
    across steps with varying exception counts.
    """
    flat = jnp.asarray(idx).reshape(-1)[:n]
    mask = flat == marker
    nblocks = -(-n // block_elems)
    padded = jnp.pad(mask, (0, nblocks * block_elems - n))
    counts = np.asarray(
        padded.reshape(nblocks, block_elems).sum(axis=1,
                                                 dtype=jnp.int32)
    ).astype(np.int64)
    k = int(counts.sum())
    if k == 0:
        return counts, np.zeros(0, np.int64)
    size = min(1 << (k - 1).bit_length(), n)
    (pos,) = jnp.nonzero(mask, size=size, fill_value=n)
    return counts, np.asarray(pos)[:k].astype(np.int64)


def chain_advance_core(idx, prev, curr, centers, *, b_bits,
                       use_pallas: bool = True):
    """Unjitted REF_RECONSTRUCTED chain-advance body:

        R_i = prev * (1 + centers[idx]);  R_i[idx == marker] = curr[...]

    The exception patch comes straight from `curr` (the values the
    finalize stage will compact into the exception table), so the result
    is bit-identical to reconstructing from the finalized blob.  The one
    home of the marker-patch semantics: the jitted single-device
    `chain_advance` and the sharded `_advance_shard` stage both call it.
    """
    recon = dequantize(idx, prev, centers, b_bits=b_bits,
                       use_pallas=use_pallas)
    marker = (1 << b_bits) - 1
    return jnp.where(jnp.asarray(idx) == marker,
                     jnp.asarray(curr).astype(recon.dtype), recon)


@functools.partial(jax.jit, static_argnames=("b_bits", "use_pallas"))
def chain_advance(idx, prev, curr, centers, *, b_bits,
                  use_pallas: bool = True):
    """Fused device chain advance (jitted `chain_advance_core`)."""
    return chain_advance_core(idx, prev, curr, centers, b_bits=b_bits,
                              use_pallas=use_pallas)


__all__ = ["change_ratio_bins", "pack_bits", "dequantize",
           "patch_exceptions", "exception_compact", "chain_advance",
           "chain_advance_core", "histogram"]
