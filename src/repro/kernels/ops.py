"""Public jit'd wrappers over the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container, unit tests) they execute in interpret mode, which runs the same
kernel bodies element-faithfully.  `use_pallas=False` falls back to the
pure-jnp oracles -- the distributed pipeline exposes this so the dry-run can
compare both lowerings.
"""
from __future__ import annotations

import jax

from repro.kernels import bitpack, change_ratio, dequant, hist, ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def change_ratio_bins(prev, curr, domain_lo, width, *, max_bins,
                      use_pallas: bool = True):
    if not use_pallas:
        return ref.change_ratio_bins_ref(prev, curr, domain_lo, width,
                                         max_bins=max_bins)
    return change_ratio.change_ratio_bins(prev, curr, domain_lo, width,
                                          max_bins=max_bins,
                                          interpret=_interpret())


def pack_bits(idx, *, b_bits, use_pallas: bool = True):
    if not use_pallas:
        return ref.pack_bits_ref(idx, b_bits=b_bits)
    return bitpack.pack_bits(idx, b_bits=b_bits, interpret=_interpret())


def dequantize(idx, prev, centers, *, b_bits, use_pallas: bool = True):
    if not use_pallas:
        return ref.dequantize_ref(idx, prev, centers, b_bits=b_bits)
    return dequant.dequantize(idx, prev, centers, b_bits=b_bits,
                              interpret=_interpret())


def histogram(bin_ids, *, max_bins, use_pallas: bool = True):
    if not use_pallas:
        return ref.histogram_ref(bin_ids, max_bins=max_bins)
    return hist.histogram(bin_ids, max_bins=max_bins,
                          interpret=_interpret())


__all__ = ["change_ratio_bins", "pack_bits", "dequantize", "histogram"]
