"""Pallas TPU kernel: fused decompression (unpack-free dequantize).

    out = prev * (1 + centers[idx])                 (corrected Eq. 4)

TPU adaptation (DESIGN.md Sec. 3): TPUs have no fast VMEM gather, so the
codebook lookup centers[idx] is computed as a **chunked one-hot matmul on
the MXU** -- for each 1024-wide chunk of the codebook, build the one-hot
matrix of the tile's indices against that chunk and contract with the chunk
of centers.  For B <= 13 this is <= 8 MXU matvecs per tile, all VMEM-resident.

Incompressible lanes (idx == 2^B - 1) are produced as 0 by the raw kernel;
`patch_exceptions` scatters the exception table back over them **on
device** (one `.at[].set`), so full reconstruction never has to leave the
accelerator.  `dequantize_jnp` is the dtype-preserving gather path used
for float64 chains (under jax_enable_x64) and as the no-Pallas fallback;
for float32 it is bit-identical to the Pallas kernel (the one-hot MXU
matmul is an exact select, and the elementwise `prev * (1 + c)` is the
same IEEE f32 op in both lowerings).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
DEFAULT_BLOCK_ROWS = 64
CHUNK = 1024            # codebook elements per one-hot matmul


def _kernel(idx_ref, prev_ref, centers_ref, out_ref, *, k_padded, marker):
    idx = idx_ref[...]                          # (R, LANE) int32
    prev = prev_ref[...]                        # (R, LANE) f32
    r, lanes = idx.shape
    flat = idx.reshape(r * lanes)
    acc = jnp.zeros((r * lanes,), jnp.float32)
    for base in range(0, k_padded, CHUNK):      # static unroll, <= 8 iters
        local = flat - base
        onehot = (local[:, None] ==
                  jnp.arange(CHUNK, dtype=jnp.int32)[None, :])
        chunk = centers_ref[pl.dslice(base, CHUNK)]
        acc = acc + jnp.dot(onehot.astype(jnp.float32), chunk,
                            preferred_element_type=jnp.float32)
    centers_of = acc.reshape(r, lanes)
    compressible = idx != marker
    out = prev * (1.0 + centers_of)
    out_ref[...] = jnp.where(compressible, out, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("b_bits", "block_rows", "interpret"))
def dequantize(idx: jax.Array, prev: jax.Array, centers: jax.Array, *,
               b_bits: int, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False):
    """(n,) i32 idx, (n,) f32 prev, (k,) f32 centers -> (n,) f32 recon.

    Incompressible positions (idx == 2^B - 1) return 0.0; patch them from
    the exception table afterwards.
    """
    n = idx.shape[0]
    marker = (1 << b_bits) - 1
    k_padded = max(CHUNK, pl.cdiv(centers.shape[0], CHUNK) * CHUNK)
    centers_p = jnp.pad(centers.astype(jnp.float32),
                        (0, k_padded - centers.shape[0]))

    rows = pl.cdiv(n, LANE)
    rows_pad = pl.cdiv(rows, block_rows) * block_rows
    pad = rows_pad * LANE - n
    # Pad with the marker so padded lanes don't contribute NaNs.
    idx2 = jnp.pad(idx, (0, pad), constant_values=marker).reshape(rows_pad,
                                                                  LANE)
    prev2 = jnp.pad(prev.astype(jnp.float32), (0, pad)).reshape(rows_pad,
                                                                LANE)
    grid = (rows_pad // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, k_padded=k_padded, marker=marker),
        grid=grid,
        in_specs=[blk, blk,
                  pl.BlockSpec((k_padded,), lambda i: (0,))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32),
        interpret=interpret,
    )(idx2, prev2, centers_p)
    return out.reshape(-1)[:n]


@functools.partial(jax.jit, static_argnames=("b_bits",))
def dequantize_jnp(idx: jax.Array, prev: jax.Array, centers: jax.Array, *,
                   b_bits: int):
    """Dtype-preserving gather dequantize (no Pallas).

    Arithmetic runs in `prev.dtype` -- the float64 chain path under
    jax_enable_x64 -- and for float32 inputs is bit-identical to the
    Pallas one-hot-MXU kernel.  Marker lanes return 0 like `dequantize`.
    """
    idx = jnp.asarray(idx)
    prev = jnp.asarray(prev)
    marker = (1 << b_bits) - 1
    lut = jnp.zeros((marker + 1,), prev.dtype)
    lut = lut.at[: centers.shape[0]].set(centers.astype(prev.dtype))
    comp = prev * (1 + lut[jnp.clip(idx, 0, marker)])
    return jnp.where(idx == marker, jnp.zeros((), prev.dtype), comp)


@functools.partial(jax.jit, static_argnames=("b_bits",))
def patch_exceptions(recon: jax.Array, idx: jax.Array,
                     exc_values: jax.Array, *, b_bits: int):
    """Scatter the compacted exception table over the marker lanes on
    device: one segment-wise ``.at[].set`` replaces the host boolean-mask
    scatter the dequantize kernel used to punt to.

    The exception table is compacted in stream order, which equals the
    per-block offset-table order (blocks partition the stream), so a
    single global scatter patches every block's segment at once; ranged
    readers slice the table by the offset table first and pass the slice.
    ``exc_values`` may be padded past the true marker count -- surplus
    positions resolve to ``idx.size`` and are dropped by the scatter.
    """
    marker = (1 << b_bits) - 1
    m = exc_values.shape[0]
    if m == 0:
        return recon
    pos = jnp.flatnonzero(idx == marker, size=m, fill_value=idx.shape[0])
    return recon.at[pos].set(exc_values.astype(recon.dtype), mode="drop")


__all__ = ["dequantize", "dequantize_jnp", "patch_exceptions"]
