"""Pallas TPU kernel: fused decompression (unpack-free dequantize).

    out = prev * (1 + centers[idx])                 (corrected Eq. 4)

TPU adaptation (DESIGN.md Sec. 3): TPUs have no fast VMEM gather, so the
codebook lookup centers[idx] is computed as a **chunked one-hot matmul on
the MXU** -- for each 1024-wide chunk of the codebook, build the one-hot
matrix of the tile's indices against that chunk and contract with the chunk
of centers.  For B <= 13 this is <= 8 MXU matvecs per tile, all VMEM-resident.

Incompressible lanes (idx == 2^B - 1) are produced as 0 and patched by the
caller from the exception table (irregular scatter stays on host).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024
DEFAULT_BLOCK_ROWS = 64
CHUNK = 1024            # codebook elements per one-hot matmul


def _kernel(idx_ref, prev_ref, centers_ref, out_ref, *, k_padded, marker):
    idx = idx_ref[...]                          # (R, LANE) int32
    prev = prev_ref[...]                        # (R, LANE) f32
    r, l = idx.shape
    flat = idx.reshape(r * l)
    acc = jnp.zeros((r * l,), jnp.float32)
    for base in range(0, k_padded, CHUNK):      # static unroll, <= 8 iters
        local = flat - base
        onehot = (local[:, None] ==
                  jnp.arange(CHUNK, dtype=jnp.int32)[None, :])
        chunk = centers_ref[pl.dslice(base, CHUNK)]
        acc = acc + jnp.dot(onehot.astype(jnp.float32), chunk,
                            preferred_element_type=jnp.float32)
    centers_of = acc.reshape(r, l)
    compressible = idx != marker
    out = prev * (1.0 + centers_of)
    out_ref[...] = jnp.where(compressible, out, 0.0)


@functools.partial(jax.jit,
                   static_argnames=("b_bits", "block_rows", "interpret"))
def dequantize(idx: jax.Array, prev: jax.Array, centers: jax.Array, *,
               b_bits: int, block_rows: int = DEFAULT_BLOCK_ROWS,
               interpret: bool = False):
    """(n,) i32 idx, (n,) f32 prev, (k,) f32 centers -> (n,) f32 recon.

    Incompressible positions (idx == 2^B - 1) return 0.0; patch them from
    the exception table afterwards.
    """
    n = idx.shape[0]
    marker = (1 << b_bits) - 1
    k_padded = max(CHUNK, pl.cdiv(centers.shape[0], CHUNK) * CHUNK)
    centers_p = jnp.pad(centers.astype(jnp.float32),
                        (0, k_padded - centers.shape[0]))

    rows = pl.cdiv(n, LANE)
    rows_pad = pl.cdiv(rows, block_rows) * block_rows
    pad = rows_pad * LANE - n
    # Pad with the marker so padded lanes don't contribute NaNs.
    idx2 = jnp.pad(idx, (0, pad), constant_values=marker).reshape(rows_pad,
                                                                  LANE)
    prev2 = jnp.pad(prev.astype(jnp.float32), (0, pad)).reshape(rows_pad,
                                                                LANE)
    grid = (rows_pad // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    out = pl.pallas_call(
        functools.partial(_kernel, k_padded=k_padded, marker=marker),
        grid=grid,
        in_specs=[blk, blk,
                  pl.BlockSpec((k_padded,), lambda i: (0,))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32),
        interpret=interpret,
    )(idx2, prev2, centers_p)
    return out.reshape(-1)[:n]


__all__ = ["dequantize"]
