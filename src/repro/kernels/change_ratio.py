"""Pallas TPU kernel: fused change-ratio + candidate-bin-id (phases 1+2a).

The paper's hottest per-element loop (change-ratio calculation + "assign
index" pre-pass) fused into one VMEM pass: for each element compute
  r   = (curr - prev) / prev          (Eq. 1)
  bin = floor((r - domain_lo) / width), or -1 if invalid / out of domain.

TPU adaptation: 1-D data is retiled to (rows, 1024) so the VPU sees
(8, 128)-aligned lanes; scalars (domain_lo, width) ride in SMEM.  One HBM
read of prev/curr and one write of ratio/bin_id -- the kernel is purely
memory-bound, so the roofline term is bytes-limited (see EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 1024            # flattened minor dim (8 sublanes x 128 lanes)
DEFAULT_BLOCK_ROWS = 256


def _kernel(scal_ref, prev_ref, curr_ref, ratio_ref, id_ref, *, max_bins):
    lo = scal_ref[0]
    width = scal_ref[1]
    prev = prev_ref[...]
    curr = curr_ref[...]
    denom_ok = prev != 0.0
    safe = jnp.where(denom_ok, prev, 1.0)
    r = (curr - safe) / safe
    ok = denom_ok & jnp.isfinite(r) & jnp.isfinite(curr)
    r = jnp.where(ok, r, 0.0)
    raw = jnp.floor((r - lo) / width)
    ok = ok & (raw >= 0.0) & (raw < float(max_bins))
    ratio_ref[...] = r
    id_ref[...] = jnp.where(ok, raw, -1.0).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("max_bins", "block_rows", "interpret"))
def change_ratio_bins(prev: jax.Array, curr: jax.Array, domain_lo, width,
                      *, max_bins: int, block_rows: int = DEFAULT_BLOCK_ROWS,
                      interpret: bool = False):
    """(n,) f32 x2 -> (ratios f32 (n,), bin_ids i32 (n,)).

    Padding elements (prev=curr=0) come out invalid (bin_id == -1), so the
    histogram downstream is unaffected.
    """
    n = prev.shape[0]
    rows = pl.cdiv(n, LANE)
    rows_pad = pl.cdiv(rows, block_rows) * block_rows
    pad = rows_pad * LANE - n

    def retile(x):
        return jnp.pad(x.astype(jnp.float32), (0, pad)).reshape(rows_pad,
                                                                LANE)

    prev2, curr2 = retile(prev), retile(curr)
    scal = jnp.stack([jnp.asarray(domain_lo, jnp.float32),
                      jnp.asarray(width, jnp.float32)])

    grid = (rows_pad // block_rows,)
    blk = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    ratio, ids = pl.pallas_call(
        functools.partial(_kernel, max_bins=max_bins),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            blk, blk,
        ],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((rows_pad, LANE), jnp.float32),
            jax.ShapeDtypeStruct((rows_pad, LANE), jnp.int32),
        ],
        interpret=interpret,
    )(scal, prev2, curr2)
    return ratio.reshape(-1)[:n], ids.reshape(-1)[:n]


__all__ = ["change_ratio_bins"]
