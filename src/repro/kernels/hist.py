"""Pallas TPU kernel: candidate-bin histogram (phase 2 counting pass).

TPU adaptation: there is no atomic scatter-add on TPU; the histogram is
computed as a **comparison + reduce** over codomain chunks.  Grid is
(element_tiles, bin_chunks); each step counts the tile's hits inside one
1024-bin chunk with a broadcast compare and accumulates into the output
block (sequential TPU grid => safe read-modify-write revisiting).

Invalid elements carry bin_id == -1 and never match a chunk lane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
DEFAULT_BLOCK_ROWS = 64
BIN_CHUNK = 1024


def _kernel(id_ref, out_ref):
    i = pl.program_id(0)        # element tile (major, sequential)
    j = pl.program_id(1)        # bin chunk

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = id_ref[...].reshape(-1)
    base = j * BIN_CHUNK
    local = ids - base
    onehot = (local[:, None] == jnp.arange(BIN_CHUNK,
                                           dtype=jnp.int32)[None, :])
    # Accumulate in the output ref's dtype: under jax_enable_x64 the sum
    # would otherwise promote to int64 and fail the int32 ref store.
    counts = jnp.sum(onehot, axis=0, dtype=out_ref.dtype)
    out_ref[...] += counts


@functools.partial(jax.jit,
                   static_argnames=("max_bins", "block_rows", "interpret"))
def histogram(bin_ids: jax.Array, *, max_bins: int,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False):
    """(n,) int32 in [-1, max_bins) -> (max_bins,) int32 counts."""
    assert max_bins % BIN_CHUNK == 0, "max_bins must be a multiple of 1024"
    n = bin_ids.shape[0]
    rows = pl.cdiv(n, LANE)
    rows_pad = pl.cdiv(rows, block_rows) * block_rows
    ids2 = jnp.pad(bin_ids, (0, rows_pad * LANE - n),
                   constant_values=-1).reshape(rows_pad, LANE)
    grid = (rows_pad // block_rows, max_bins // BIN_CHUNK)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((BIN_CHUNK,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((max_bins,), jnp.int32),
        interpret=interpret,
    )(ids2)
    return out


__all__ = ["histogram"]
