"""Pure-jnp oracles for every Pallas kernel (assert_allclose targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing, ratios


def change_ratio_bins_ref(prev, curr, domain_lo, width, *, max_bins):
    r, valid = ratios.change_ratios(prev, curr)
    ids, _ = ratios.candidate_bin_ids(r, valid, jnp.float32(domain_lo),
                                      jnp.float32(width), max_bins)
    return r, ids


def pack_bits_ref(idx, *, b_bits):
    """uint32 words of the little-endian bitstream (n % 32 == 0).

    Pure-jnp (jit/shard_map safe): bytes from core.packing's jnp path,
    then 4 little-endian bytes -> one uint32 word.
    """
    byts = packing.pack_indices_jnp(jnp.asarray(idx), b_bits)
    pad = (-byts.shape[0]) % 4
    if pad:
        byts = jnp.pad(byts, (0, pad))
    quads = byts.reshape(-1, 4).astype(jnp.uint32)
    return (quads[:, 0] | (quads[:, 1] << 8) | (quads[:, 2] << 16)
            | (quads[:, 3] << 24))


def dequantize_ref(idx, prev, centers, *, b_bits):
    idx = jnp.asarray(idx)
    marker = (1 << b_bits) - 1
    centers = jnp.pad(jnp.asarray(centers, jnp.float32),
                      (0, marker + 1 - centers.shape[0]))
    comp = jnp.asarray(prev, jnp.float32) * (1.0 + centers[idx])
    return jnp.where(idx == marker, 0.0, comp)


def histogram_ref(bin_ids, *, max_bins):
    ids = jnp.clip(jnp.asarray(bin_ids), 0, max_bins - 1)
    ok = (jnp.asarray(bin_ids) >= 0).astype(jnp.int32)
    return jnp.zeros((max_bins,), jnp.int32).at[ids].add(ok)


__all__ = ["change_ratio_bins_ref", "pack_bits_ref", "dequantize_ref",
           "histogram_ref"]
