"""Pallas TPU kernel: B-bit index packing (paper "bits packing" sub-phase).

Packs groups of 32 B-bit indices into B uint32 words of the little-endian
bitstream (layout identical to core.packing).  The MPI implementation
bit-copies "the B least significant bits of the integer to the corresponding
index table entry" one element at a time; on TPU we unroll the 32 static
element positions per word-group, so each tile is pure vector shifts/ors --
no scalar loop, no gather.

Tile: (rows, 32) int32 indices -> (rows, B) uint32 words.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 32              # indices per word-group (32*B bits = B words)
DEFAULT_BLOCK_ROWS = 512


def _kernel(idx_ref, out_ref, *, b_bits):
    idx = idx_ref[...].astype(jnp.uint32)
    mask = jnp.uint32((1 << b_bits) - 1)
    words = [jnp.zeros(idx.shape[:1], jnp.uint32) for _ in range(b_bits)]
    for j in range(GROUP):                      # static unroll
        v = idx[:, j] & mask
        bit0 = j * b_bits
        w, s = bit0 // 32, bit0 % 32
        words[w] = words[w] | (v << s)
        if s + b_bits > 32:                      # spills into the next word
            words[w + 1] = words[w + 1] | (v >> (32 - s))
    out_ref[...] = jnp.stack(words, axis=-1)


@functools.partial(jax.jit,
                   static_argnames=("b_bits", "block_rows", "interpret"))
def pack_bits(idx: jax.Array, *, b_bits: int,
              block_rows: int = DEFAULT_BLOCK_ROWS, interpret: bool = False):
    """(n,) int32 (n % 32 == 0 after padding) -> (n//32*B,) uint32 words.

    Pad indices with 0 to a multiple of 32*block_rows before calling; the
    ops wrapper handles block-aligned padding.
    """
    n = idx.shape[0]
    assert n % GROUP == 0, "pad to a multiple of 32 first"
    rows = n // GROUP
    rows_pad = pl.cdiv(rows, block_rows) * block_rows
    idx2 = jnp.pad(idx, (0, (rows_pad - rows) * GROUP)).reshape(rows_pad,
                                                                GROUP)
    grid = (rows_pad // block_rows,)
    out = pl.pallas_call(
        functools.partial(_kernel, b_bits=b_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, GROUP), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, b_bits), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, b_bits), jnp.uint32),
        interpret=interpret,
    )(idx2)
    return out.reshape(-1)[: rows * b_bits]


__all__ = ["pack_bits", "GROUP"]
