"""Block-parallel interleaved rANS coder (device entropy stage).

The paper's phase-6 entropy stage is host zlib; this module moves it onto
the accelerator.  Each index-table block is compressed *independently*
(so partial decompression keeps its block granularity) by an interleaved
range-asymmetric-numeral-system coder:

  * a block's byte stream is split into ``L`` interleaved lanes (lane l
    owns bytes l, l+L, l+2L, ...); every lane is an independent rANS
    state, so one encode step advances all lanes of all blocks with pure
    vector ALU ops -- the sequential dependency of classic rANS becomes a
    ``lax.scan`` over ``len/L`` steps with lane-parallel bodies (blocks
    map to disjoint lane groups, the grid-tile analogue).
  * 32-bit states with 16-bit renormalization and ``SCALE_BITS``-bit
    frequencies.  With freq >= 1 the renorm emits **exactly 0 or 1**
    uint16 per symbol (state < 2^32 implies post-shift state < 2^16 <=
    freq << (32-SCALE_BITS)), which is what makes the emission schedule
    decodable without per-lane length tables: the decoder replays the
    same schedule in reverse.
  * frequency tables are built from a strided byte sample and normalized
    with a deterministic largest-quota scheme that gives **every** byte
    value a nonzero frequency -- sampling can therefore never break
    correctness, only (marginally) the ratio.

The encode lowering follows the ``core.packing`` pattern: a pure-jnp
device path (``encode_idx_group`` / ``encode_words_body``, jit- and
shard_map-safe) with a NumPy oracle (``encode_np``) that emits
byte-identical streams; the histogram side reuses the same
sample-normalize code on both paths so host- and device-produced blobs
are byte-identical by construction.  Decode (``decompress``) is the host
side used by ``decompress_step`` / ``partial.read_step_range``.

The *decoder* mirrors the encoder on both sides: ``decode_np`` is the
lane-vectorized NumPy oracle and ``decode_blocks_device`` /
``decode_bytes_blocks_device`` are the jnp/``lax.scan`` lowering --
the same L-lane state advance run forward, ingesting the 0-or-1 u16
renorm schedule the encoder emitted, with per-block stream pointers
advanced by an in-block prefix sum.  Slot lookups go through a fused
per-slot u32 table (freq | offset<<12 | symbol<<24) so the hot scan body
is one gather + one take_along_axis per step; alphabets wider than 256
symbols use a second symbol-table gather.  Byte-identity with
``decode_np`` holds by construction (same integer ops per lane), and the
blob validation semantics match: corrupt tables, stream underrun/overrun
and bad final states raise ``ValueError``.

Blob layout (little-endian), self-describing per block:

  v1 (rANS): u32 raw_len | u8 1 | u8 scale_bits | u16 L |
             256*u16 freq | u32 n_emit | L*u32 states | n_emit*u16 stream
  v0 (raw):  u32 raw_len | u8 0 | raw bytes          (store fallback when
             the rANS stream would not beat raw -- near-random blocks)
  v2 (symbol rANS): u32 n_elems | u8 2 | u8 scale_bits | u8 b_bits |
             u16 L | u16 n_sym | n_sym*u16 freq | u32 n_emit |
             L*u32 states | n_emit*u16 stream

v2 codes the *pre-pack* B-bit indices as rANS symbols over the dense
alphabet {rank 0..k-1, marker} (symbol id k == the B-bit marker), so the
pack/unpack stages and the strided byte-sample pass disappear entirely --
the analyze stage's exact global histogram (``counts_desc``) IS the
symbol histogram.  Files carrying v2 blobs are stamped NCK3 by the
container so old readers reject them cleanly.
"""
from __future__ import annotations

import functools
import struct
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

SCALE_BITS = 12
M = 1 << SCALE_BITS                 # total frequency budget per table
STATE_LO = 1 << 16                  # renormalization lower bound
_HDR = struct.Struct("<IBBH")       # raw_len, version, scale_bits, lanes
_RAW_HDR = struct.Struct("<IB")     # raw_len, version=0
# v2 symbol-level header: n_elems, version, scale_bits, b_bits, lanes, n_sym
_HDR2 = struct.Struct("<IBBBHH")
_V_RANS = 1
_V_RAW = 0
_V_SYM = 2

# Below this raw payload (total packed bytes of a step) the drivers keep
# the host codec path: jit-cache churn and per-call dispatch would eat the
# win.  Blobs are byte-identical either way, so this is pure routing.
DEVICE_MIN_BYTES = 256 << 10


def lanes_for(n: int) -> int:
    """Interleave width for an n-byte block (deterministic: part of the
    format -- encoder and decoder must agree).  More lanes amortize the
    scan length; each lane costs 4 bytes of final state."""
    if n >= 512 << 10:
        return 1024
    if n >= 64 << 10:
        return 512
    if n >= 8 << 10:
        return 128
    return 32


def sample_stride(n: int) -> int:
    """Byte-sampling stride for the frequency tables (deterministic, part
    of the format contract between the host and device encoders)."""
    return 16 if n >= 256 << 10 else 1


# ------------------------------------------------------------- tables

def freq_from_counts(counts: np.ndarray) -> np.ndarray:
    """(A,) counts -> (A,) uint16 frequencies summing to M, every symbol
    >= 1 (so unsampled symbols stay encodable).  A <= M required.

    Deterministic largest-quota allocation: each symbol gets 1 plus its
    share of the remaining budget via cumulative integer boundaries --
    one vector pass, no data-dependent iteration, identical results on
    every path.  The byte coders use A=256; the symbol-level v2 coder
    passes the dense rank alphabet (A = k_eff + 1).
    """
    counts = np.asarray(counts, np.uint64)
    A = counts.size
    if A > M:
        raise ValueError(f"alphabet {A} exceeds frequency budget {M}")
    total = int(counts.sum())
    if total == 0:
        base = np.full(A, M // A, np.uint64)
        base[: M - int(base.sum())] += 1      # exact sum for A not | M
        return base.astype(np.uint16)
    budget = np.uint64(M - A)
    bounds = (np.cumsum(counts) * budget) // np.uint64(total)
    extra = np.diff(np.concatenate([[np.uint64(0)], bounds]))
    return (1 + extra).astype(np.uint16)


def freq_table(raw: np.ndarray) -> np.ndarray:
    """Frequency table of a raw byte block (strided sample + normalize)."""
    raw = np.asarray(raw, np.uint8)
    if raw.size == 0:
        return freq_from_counts(np.zeros(256, np.uint64))
    sample = raw[:: sample_stride(raw.size)]
    return freq_from_counts(np.bincount(sample, minlength=256))


def _cum(freq: np.ndarray) -> np.ndarray:
    f = np.asarray(freq, np.uint64)
    return np.concatenate([[np.uint64(0)], np.cumsum(f)[:-1]])


def pack_fc(freq: np.ndarray) -> np.ndarray:
    """Fuse freq+cumfreq into one u32 table (freq in bits 0..12, cum in
    13..24) so the scan body does a single gather per symbol."""
    return (np.asarray(freq, np.uint32)
            | (_cum(freq).astype(np.uint32) << np.uint32(13)))


# ------------------------------------------------- NumPy coder (oracle)

def encode_np(raw: np.ndarray, freq: np.ndarray):
    """Encode one block: (L,) u32 final states + (n_emit,) u16 stream.

    ``raw`` is a symbol array (uint8 bytes, or any int array of ids <
    ``freq.size`` for the symbol-level coder).  Lanes interleave by
    stride L; symbols are visited in reverse row order (standard rANS
    encodes backwards); the emitted stream is laid out in the decoder's
    read order (row ascending, lane ascending).
    """
    raw = np.asarray(raw)
    if raw.dtype != np.uint8:
        raw = raw.astype(np.int64)
    n = raw.size
    L = lanes_for(n)
    m = -(-n // L) if n else 0
    sy = np.zeros(m * L, raw.dtype)
    sy[:n] = raw
    sy = sy.reshape(m, L)
    f64 = np.asarray(freq, np.uint64)
    c64 = _cum(freq)
    f_rows = f64[sy]                    # (m, L) gathered once
    c_rows = c64[sy]
    x = np.full(L, STATE_LO, np.uint64)
    vals = np.zeros((m, L), np.uint16)
    masks = np.zeros((m, L), bool)
    for j in range(m - 1, -1, -1):
        f = f_rows[j]
        mask = x >= (f << np.uint64(32 - SCALE_BITS))
        vals[j] = (x & np.uint64(0xFFFF)).astype(np.uint16)
        masks[j] = mask
        x = np.where(mask, x >> np.uint64(16), x)
        q = x // f
        x = (q << np.uint64(SCALE_BITS)) + (x - q * f) + c_rows[j]
    return x.astype(np.uint32), vals[masks]


def decode_np(states: np.ndarray, stream: np.ndarray, freq: np.ndarray,
              n: int, L: int) -> np.ndarray:
    """Inverse of encode_np (lane-vectorized; validates stream integrity).

    Returns uint8 symbols for byte alphabets (freq.size <= 256), int32
    symbol ids for wider (symbol-level) alphabets.
    """
    m = -(-n // L) if n else 0
    A = np.asarray(freq).size
    f64 = np.asarray(freq, np.uint64)
    c64 = _cum(freq)
    sdt = np.uint8 if A <= 256 else np.int32
    slot2sym = np.repeat(np.arange(A, dtype=sdt),
                         np.asarray(freq, np.int64))
    if slot2sym.size != M:
        raise ValueError("corrupt rANS table: frequencies sum != 2^scale")
    x = np.asarray(states, np.uint64).copy()
    if x.size != L:
        raise ValueError("corrupt rANS blob: state count != lanes")
    out = np.zeros((m, L), sdt)
    ptr = 0
    for j in range(m):
        slot = x & np.uint64(M - 1)
        s = slot2sym[slot]
        out[j] = s
        x = f64[s] * (x >> np.uint64(SCALE_BITS)) + slot - c64[s]
        need = x < STATE_LO
        k = int(need.sum())
        if k:
            nxt = stream[ptr:ptr + k]
            if nxt.size != k:
                raise ValueError("corrupt rANS blob: stream underrun")
            x[need] = (x[need] << np.uint64(16)) | nxt.astype(np.uint64)
            ptr += k
    if ptr != stream.size or (x != STATE_LO).any():
        raise ValueError("corrupt rANS blob: stream not consumed cleanly")
    return out.reshape(-1)[:n]


# ------------------------------------------------------- blob assembly

def blob_nbytes(n_emit: int, L: int) -> int:
    return _HDR.size + 512 + 4 + 4 * L + 2 * n_emit


def assemble_blob(raw_len: int, freq: np.ndarray, states: np.ndarray,
                  stream: np.ndarray,
                  raw_bytes: Optional[Callable[[], bytes]] = None) -> bytes:
    """Assemble the self-describing block blob; falls back to the v0 raw
    container when rANS would not beat store (``raw_bytes`` supplies the
    payload lazily -- only fetched for losing blocks)."""
    L = int(states.size)
    if raw_bytes is not None and \
            blob_nbytes(stream.size, L) >= raw_len + _RAW_HDR.size:
        return _RAW_HDR.pack(raw_len, _V_RAW) + raw_bytes()
    return b"".join([
        _HDR.pack(raw_len, _V_RANS, SCALE_BITS, L),
        np.ascontiguousarray(freq, np.uint16).tobytes(),
        struct.pack("<I", int(stream.size)),
        np.ascontiguousarray(states, np.uint32).tobytes(),
        np.ascontiguousarray(stream, np.uint16).tobytes(),
    ])


def blob_nbytes_sym(n_emit: int, L: int, n_sym: int) -> int:
    return _HDR2.size + 2 * n_sym + 4 + 4 * L + 2 * n_emit


def assemble_symbol_blob(n_elems: int, b_bits: int, freq: np.ndarray,
                         states: np.ndarray, stream: np.ndarray,
                         raw_bytes: Optional[Callable[[], bytes]] = None
                         ) -> bytes:
    """Assemble a v2 symbol-level blob; ``raw_bytes`` supplies the packed
    byte payload lazily for the v0 store fallback (compared against the
    packed size, exactly like the byte coder)."""
    L = int(states.size)
    n_sym = int(np.asarray(freq).size)
    packed_len = n_elems * b_bits // 8
    if raw_bytes is not None and \
            blob_nbytes_sym(stream.size, L, n_sym) >= \
            packed_len + _RAW_HDR.size:
        return _RAW_HDR.pack(packed_len, _V_RAW) + raw_bytes()
    return b"".join([
        _HDR2.pack(n_elems, _V_SYM, SCALE_BITS, b_bits, L, n_sym),
        np.ascontiguousarray(freq, np.uint16).tobytes(),
        struct.pack("<I", int(stream.size)),
        np.ascontiguousarray(states, np.uint32).tobytes(),
        np.ascontiguousarray(stream, np.uint16).tobytes(),
    ])


def symbol_freq(counts_ranks: np.ndarray, k_eff: int,
                total_elems: int) -> np.ndarray:
    """v2 frequency table from the analyze stage's exact global histogram:
    symbol r < k_eff counts ``counts_ranks[r]`` occurrences; the marker
    symbol (id k_eff) absorbs the rest, including block padding."""
    counts = np.zeros(k_eff + 1, np.uint64)
    counts[:k_eff] = np.asarray(counts_ranks[:k_eff], np.uint64)
    used = int(counts[:k_eff].sum())
    counts[k_eff] = max(total_elems - used, 0)
    return freq_from_counts(counts)


def compress_symbols(idx: np.ndarray, b_bits: int,
                     freq: np.ndarray) -> bytes:
    """Host (NumPy) flavor of the symbol-level coder: one block of B-bit
    index values -> self-describing v2 blob (the oracle the device group
    encoder is byte-identical to)."""
    idx = np.asarray(idx, np.int64)
    k_eff = int(np.asarray(freq).size) - 1
    syms = np.minimum(idx, k_eff)
    states, stream = encode_np(syms, freq)

    def raw_bytes() -> bytes:
        from repro.core.packing import pack_indices_np
        nbytes = idx.size * b_bits // 8
        return pack_indices_np(idx, b_bits).tobytes()[:nbytes]

    return assemble_symbol_blob(idx.size, b_bits, freq, states, stream,
                                raw_bytes=raw_bytes)


def compress(raw: bytes) -> bytes:
    """Host (NumPy) flavor: bytes -> self-describing rANS blob."""
    arr = np.frombuffer(raw, np.uint8)
    freq = freq_table(arr)
    states, stream = encode_np(arr, freq)
    return assemble_blob(arr.size, freq, states, stream,
                         raw_bytes=lambda: bytes(raw))


def blob_version(blob: bytes) -> int:
    """Self-described version byte of a block blob (v0/v1/v2)."""
    if len(blob) < _RAW_HDR.size:
        raise ValueError("rANS blob too short")
    return blob[4]


def _parse_v1(blob: bytes):
    """v1 blob -> (n_bytes, L, freq (256,) u16, states, stream)."""
    n, _, sb, L = _HDR.unpack_from(blob)
    if sb != SCALE_BITS:
        raise ValueError(f"unsupported rANS scale_bits {sb}")
    off = _HDR.size
    freq = np.frombuffer(blob, np.uint16, 256, off)
    off += 512
    (n_emit,) = struct.unpack_from("<I", blob, off)
    off += 4
    states = np.frombuffer(blob, np.uint32, L, off)
    off += 4 * L
    stream = np.frombuffer(blob, np.uint16, n_emit, off)
    return n, L, freq, states, stream


def _parse_v2(blob: bytes):
    """v2 blob -> (n_elems, b_bits, L, freq (n_sym,) u16, states, stream)."""
    n, _, sb, b_bits, L, n_sym = _HDR2.unpack_from(blob)
    if sb != SCALE_BITS:
        raise ValueError(f"unsupported rANS scale_bits {sb}")
    off = _HDR2.size
    freq = np.frombuffer(blob, np.uint16, n_sym, off)
    off += 2 * n_sym
    (n_emit,) = struct.unpack_from("<I", blob, off)
    off += 4
    states = np.frombuffer(blob, np.uint32, L, off)
    off += 4 * L
    stream = np.frombuffer(blob, np.uint16, n_emit, off)
    return n, b_bits, L, freq, states, stream


def decompress(blob: bytes) -> bytes:
    """Decode a block blob back to its raw *packed* bytes.

    v0 returns the stored payload, v1 decodes the byte stream, v2 decodes
    the symbol stream and re-packs the B-bit values -- so every consumer
    of packed bytes (``blocks.inflate_block``, partial reads, the host
    decompressors) works unchanged whatever the blob flavor.
    """
    version = blob_version(blob)
    if version == _V_RAW:
        (n, _) = _RAW_HDR.unpack_from(blob)
        out = blob[_RAW_HDR.size:_RAW_HDR.size + n]
        if len(out) != n:
            raise ValueError("corrupt raw blob: truncated payload")
        return out
    if version == _V_RANS:
        n, L, freq, states, stream = _parse_v1(blob)
        return decode_np(states, stream, freq, n, L).tobytes()
    if version == _V_SYM:
        n, b_bits, L, freq, states, stream = _parse_v2(blob)
        syms = decode_np(states, stream, freq, n, L).astype(np.int64)
        marker = (1 << b_bits) - 1
        k_eff = freq.size - 1
        vals = np.where(syms >= k_eff, marker, syms)
        from repro.core.packing import pack_indices_np
        nbytes = n * b_bits // 8
        return pack_indices_np(vals, b_bits).tobytes()[:nbytes]
    raise ValueError(f"unknown rANS blob version {version}")


# ------------------------------------------------------ device lowering

def words_to_bytes(words: jax.Array) -> jax.Array:
    """(..., w) u32 words -> (..., 4w) u8, little-endian (matches the
    ``astype('<u4').tobytes()`` host fetch byte for byte)."""
    parts = [((words >> jnp.uint32(8 * k)) & jnp.uint32(0xFF))
             for k in range(4)]
    stacked = jnp.stack(parts, axis=-1)
    return stacked.reshape(*words.shape[:-1], -1).astype(jnp.uint8)


def pack_words(idx2d: jax.Array, b_bits: int) -> jax.Array:
    """(nb, be) int32 indices -> (nb, be*b/32) u32 words of the
    little-endian bitstream (same math as the Pallas bitpack kernel,
    vectorized over blocks; be must be a multiple of 32)."""
    nb, be = idx2d.shape
    g = idx2d.reshape(nb, be // 32, 32).astype(jnp.uint32)
    maskv = jnp.uint32((1 << b_bits) - 1)
    words = [jnp.zeros((nb, be // 32), jnp.uint32) for _ in range(b_bits)]
    for j in range(32):                       # static unroll
        v = g[:, :, j] & maskv
        bit0 = j * b_bits
        w, s = divmod(bit0, 32)
        words[w] = words[w] | (v << jnp.uint32(s))
        if s + b_bits > 32:                   # spills into the next word
            words[w + 1] = words[w + 1] | (v >> jnp.uint32(32 - s))
    return jnp.stack(words, axis=-1).reshape(nb, -1)


def encode_bytes_body(byts: jax.Array, fc: jax.Array, L: int,
                      alphabet: int = 256):
    """Shared scan body (jit- and shard_map-safe): encode every block of
    ``byts`` (nb, n) symbols (u8 bytes, or i32 ids < ``alphabet`` for the
    symbol-level coder) with its fused table row of ``fc`` (nb, alphabet)
    u32.  Returns (states (nb, L) u32, vals (nb, m*L) u16, masks
    (nb, m*L) bool) with each block's emissions laid out contiguously in
    decoder order (j ascending, lane ascending): the host compacts a
    block's stream with one contiguous boolean index
    ``vals[k][masks[k]]``.  (An on-device prefix-sum scatter was
    benchmarked instead and lost badly -- XLA CPU scatters are
    scalarized.)"""
    nb, nbytes = byts.shape
    m = -(-nbytes // L)
    pad = m * L - nbytes
    if pad:
        byts = jnp.pad(byts, ((0, 0), (0, pad)))
    sy = byts.reshape(nb, m, L).astype(jnp.int32)
    sy = jnp.transpose(sy, (1, 0, 2)).reshape(m, nb * L)[::-1]
    base = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), L) * alphabet
    fc_flat = fc.reshape(-1)

    def body(x, s):
        v = fc_flat[base + s]
        f = v & jnp.uint32(0x1FFF)
        c = v >> jnp.uint32(13)
        mask = (x >> jnp.uint32(32 - SCALE_BITS)) >= f
        val = (x & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        x = jnp.where(mask, x >> jnp.uint32(16), x)
        q = x // f
        x = (q << jnp.uint32(SCALE_BITS)) + (x - q * f) + c
        return x, (val, mask)

    x0 = jnp.full((nb * L,), jnp.uint32(STATE_LO))
    xf, (vals, masks) = jax.lax.scan(body, x0, sy)
    # decoder order per block: j ascending (undo the scan flip), lanes
    # ascending, contiguous per block.
    vals = jnp.transpose(vals[::-1].reshape(m, nb, L),
                         (1, 0, 2)).reshape(nb, m * L)
    masks = jnp.transpose(masks[::-1].reshape(m, nb, L),
                          (1, 0, 2)).reshape(nb, m * L)
    return xf.reshape(nb, L), vals, masks


@functools.partial(jax.jit, static_argnames=("b_bits", "L"))
def encode_idx_group(idx2d: jax.Array, fc: jax.Array, b_bits: int, L: int):
    """Device encode of a block group straight from B-bit indices:
    bit-pack (word math of the bitpack kernel) -> bytes -> rANS scan."""
    return encode_bytes_body(words_to_bytes(pack_words(idx2d, b_bits)),
                             fc, L)


@functools.partial(jax.jit, static_argnames=("k_eff", "L"))
def encode_sym_group(idx2d: jax.Array, fc: jax.Array, k_eff: int, L: int):
    """Device symbol-level encode of a block group: map B-bit index
    values onto the dense rank alphabet (marker -> id ``k_eff``) and rANS
    the symbols directly -- no bit-pack, no byte sampling."""
    syms = jnp.minimum(idx2d.astype(jnp.int32), jnp.int32(k_eff))
    g = idx2d.shape[0]
    fc2d = jnp.broadcast_to(fc, (g, k_eff + 1))
    return encode_bytes_body(syms, fc2d, L, alphabet=k_eff + 1)


@functools.partial(jax.jit, static_argnames=("b_bits", "stride"))
def sampled_idx_bytes(idx2d: jax.Array, b_bits: int,
                      stride: int) -> jax.Array:
    """Every ``stride``-th byte of each block's packed stream, computed
    directly from the indices (no full bit-pack needed): byte k mixes the
    <= 7//b + 2 indices straddling bits [8k, 8k+8)."""
    nb, be = idx2d.shape
    nbytes = be * b_bits // 8
    p = np.arange(0, nbytes, stride, dtype=np.int32)
    bit0 = 8 * p
    i0 = bit0 // b_bits
    maskv = jnp.uint32((1 << b_bits) - 1)
    acc = jnp.zeros((nb, p.size), jnp.uint32)
    for t in range(7 // b_bits + 2):          # static unroll
        i = i0 + t
        sh = i * b_bits - bit0                # alignment shift per byte
        keep = (i < be) & (sh < 8)            # bits >= 8 never reach byte k
        iv = np.where(i < be, i, 0).astype(np.int32)
        v = idx2d[:, iv].astype(jnp.uint32) & maskv
        shp = jnp.asarray(np.clip(sh, 0, 31).astype(np.uint32))[None, :]
        shn = jnp.asarray(np.clip(-sh, 0, 31).astype(np.uint32))[None, :]
        contrib = jnp.where(jnp.asarray(sh >= 0)[None, :],
                            v << shp, v >> shn)
        acc = acc | jnp.where(jnp.asarray(keep)[None, :], contrib, 0)
    return (acc & jnp.uint32(0xFF)).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("stride",))
def sample_words(words2d: jax.Array, stride: int) -> jax.Array:
    """Strided byte sample of per-row packed words (sharded driver path;
    bit-equal to ``raw[::stride]`` of the row's little-endian bytes)."""
    if stride == 1:
        return words_to_bytes(words2d)
    assert stride % 4 == 0, "stride must be 1 or a multiple of 4"
    return (words2d[:, ::stride // 4] & jnp.uint32(0xFF)).astype(jnp.uint8)


def tables_from_samples(samples: np.ndarray):
    """Per-block (freq (nb, 256) u16, fused fc (nb, 256) u32) from the
    sampled bytes of each block."""
    freqs = np.stack([freq_from_counts(np.bincount(row, minlength=256))
                      for row in np.asarray(samples, np.uint8)])
    fcs = np.stack([pack_fc(f) for f in freqs])
    return freqs, fcs


def _group_spans(nblocks: int, pool) -> List[tuple]:
    """Split ``nblocks`` into contiguous spans, one per pool worker."""
    workers = getattr(pool, "_max_workers", 1) if pool is not None else 1
    ngroups = max(1, min(nblocks, workers))
    gsize = -(-nblocks // ngroups)
    return [(s, min(s + gsize, nblocks)) for s in range(0, nblocks, gsize)]


def compress_blocks_device(idx_dev: jax.Array, b_bits: int, nblocks: int,
                           block_elems: int,
                           pool=None) -> List[bytes]:
    """Single-device entropy stage: marker-padded indices (nblocks *
    block_elems,) on device -> one self-describing rANS blob per block.

    Blocks are split into groups dispatched over ``pool`` threads; each
    group runs one jitted pack+scan executable (jax releases the GIL
    during execution, so groups run device-parallel) and compacts its
    emissions on the worker.  Byte-identical to the host
    ``rans.compress`` of the same packed bytes by construction.
    """
    be = block_elems
    nbytes = be * b_bits // 8
    stride = sample_stride(nbytes)
    L = lanes_for(nbytes)
    idx2d = idx_dev.reshape(nblocks, be)
    # Frequency tables are built host-side from the strided samples --
    # the one designed sync of the encode path.
    # repro-lint: disable=host-sync-in-device-path
    samples = np.asarray(sampled_idx_bytes(idx2d, b_bits, stride))
    freqs, fcs = tables_from_samples(samples)
    fc_dev = jnp.asarray(fcs)

    spans = _group_spans(nblocks, pool)

    def encode_span(span) -> List[bytes]:
        g0, g1 = span
        st, vals, masks = encode_idx_group(idx2d[g0:g1], fc_dev[g0:g1],
                                           b_bits, L)
        st = np.asarray(st)
        vals = np.asarray(vals)
        masks = np.asarray(masks)
        blobs = []
        for k in range(g1 - g0):
            def raw_bytes(k=k):
                idx_h = np.asarray(idx2d[g0 + k]).astype(np.int64)
                from repro.core.packing import pack_indices_np
                return pack_indices_np(idx_h, b_bits).tobytes()[:nbytes]

            blobs.append(assemble_blob(nbytes, freqs[g0 + k], st[k],
                                       vals[k][masks[k]],
                                       raw_bytes=raw_bytes))
        return blobs

    if pool is not None and len(spans) > 1:
        parts = list(pool.map(encode_span, spans))
    else:
        parts = [encode_span(s) for s in spans]
    return [b for part in parts for b in part]


def compress_blocks_device_symbols(idx_dev: jax.Array, b_bits: int,
                                   k_eff: int, nblocks: int,
                                   block_elems: int,
                                   counts_ranks: np.ndarray,
                                   pool=None) -> List[bytes]:
    """Symbol-level device entropy stage (v2 blobs): code the pre-pack
    B-bit indices directly over the dense {rank, marker} alphabet.  The
    analyze stage's exact global histogram ``counts_ranks`` supplies one
    shared frequency table for every block -- no strided sample pass, no
    bit-pack.  Byte-identical to the host ``compress_symbols`` oracle by
    construction."""
    be = block_elems
    nbytes = be * b_bits // 8
    # counts_ranks is already a host array (analyze-boundary metadata).
    # repro-lint: disable=host-sync-in-device-path
    freq = symbol_freq(np.asarray(counts_ranks), k_eff, nblocks * be)
    fc_dev = jnp.asarray(pack_fc(freq))
    L = lanes_for(be)
    idx2d = idx_dev.reshape(nblocks, be)
    spans = _group_spans(nblocks, pool)

    def encode_span(span) -> List[bytes]:
        g0, g1 = span
        st, vals, masks = encode_sym_group(idx2d[g0:g1], fc_dev, k_eff, L)
        st = np.asarray(st)
        vals = np.asarray(vals)
        masks = np.asarray(masks)
        blobs = []
        for k in range(g1 - g0):
            def raw_bytes(k=k):
                idx_h = np.asarray(idx2d[g0 + k]).astype(np.int64)
                from repro.core.packing import pack_indices_np
                return pack_indices_np(idx_h, b_bits).tobytes()[:nbytes]

            blobs.append(assemble_symbol_blob(be, b_bits, freq, st[k],
                                              vals[k][masks[k]],
                                              raw_bytes=raw_bytes))
        return blobs

    if pool is not None and len(spans) > 1:
        parts = list(pool.map(encode_span, spans))
    else:
        parts = [encode_span(s) for s in spans]
    return [b for part in parts for b in part]


# ------------------------------------------------- device decode lowering

def bytes_to_words(byts: jax.Array) -> jax.Array:
    """(..., 4w) u8 -> (..., w) u32 little-endian words (inverse of
    ``words_to_bytes``)."""
    b4 = byts.reshape(*byts.shape[:-1], -1, 4).astype(jnp.uint32)
    return (b4[..., 0] | (b4[..., 1] << jnp.uint32(8))
            | (b4[..., 2] << jnp.uint32(16))
            | (b4[..., 3] << jnp.uint32(24)))


def unpack_words(words2d: jax.Array, b_bits: int, be: int) -> jax.Array:
    """(nb, be*b/32) u32 packed words -> (nb, be) int32 indices (inverse
    of ``pack_words``; same static 32-symbol unroll run backwards)."""
    nb = words2d.shape[0]
    g = words2d.reshape(nb, -1, b_bits)       # word groups of 32 symbols
    maskv = jnp.uint32((1 << b_bits) - 1)
    cols = []
    for j in range(32):                       # static unroll
        bit0 = j * b_bits
        w, s = divmod(bit0, 32)
        v = g[:, :, w] >> jnp.uint32(s)
        if s + b_bits > 32:                   # spilled into the next word
            v = v | (g[:, :, w + 1] << jnp.uint32(32 - s))
        cols.append(v & maskv)
    idx = jnp.stack(cols, axis=-1).reshape(nb, -1)
    return idx[:, :be].astype(jnp.int32)


def _decode_tables(freq: np.ndarray):
    """Per-slot decode tables for one frequency table: a fused u32
    ``freq | offset<<12 | symbol<<24`` (alphabets <= 256) or the fused
    freq/offset word plus a separate int32 slot->symbol table (wider
    symbol-level alphabets).  Raises ValueError on corrupt tables, like
    ``decode_np``."""
    f64 = np.asarray(freq, np.int64)
    A = f64.size
    slot2sym = np.repeat(np.arange(A, dtype=np.int64), f64)
    if A < 2 or slot2sym.size != M:
        raise ValueError("corrupt rANS table: frequencies sum != 2^scale")
    f_slot = f64[slot2sym].astype(np.uint32)
    cum = np.concatenate([[0], np.cumsum(f64)[:-1]])
    off = (np.arange(M, dtype=np.int64) - cum[slot2sym]).astype(np.uint32)
    fused = f_slot | (off << np.uint32(12))
    if A <= 256:
        return fused | (slot2sym.astype(np.uint32) << np.uint32(24)), None
    return fused, slot2sym.astype(np.int32)


def decode_scan_body(dec: jax.Array, sym_tab, states: jax.Array,
                     stream: jax.Array, m: int, L: int):
    """Forward L-lane rANS decode of a block group (jit- and
    shard_map-safe).  ``dec`` is (nb, M) fused decode tables, ``states``
    (nb, L) u32, ``stream`` (nb, S) u16 zero-padded to the group max.
    Each step advances every lane of every block and ingests the 0-or-1
    u16 renorm emissions in lane order via an in-block inclusive prefix
    sum over the per-block stream pointer -- the exact replay of
    ``encode_bytes_body``'s emission schedule, so the integer trajectory
    matches ``decode_np`` lane for lane.  Returns (syms (nb, m*L),
    final states (nb, L) u32, final pointers (nb,) i32)."""
    nb = dec.shape[0]
    S = stream.shape[1]
    base = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), L) * M
    dec_flat = dec.reshape(-1)
    sym_flat = None if sym_tab is None else sym_tab.reshape(-1)

    def body(carry, _):
        x, ptr = carry
        slot = (x & jnp.uint32(M - 1)).astype(jnp.int32)
        t = dec_flat[base + slot]
        f = t & jnp.uint32(0xFFF)
        off = (t >> jnp.uint32(12)) & jnp.uint32(0xFFF)
        if sym_flat is None:
            sym = (t >> jnp.uint32(24)).astype(jnp.uint8)
        else:
            sym = sym_flat[base + slot]
        x = f * (x >> jnp.uint32(SCALE_BITS)) + off
        need = (x < jnp.uint32(STATE_LO)).reshape(nb, L)
        inc = jnp.cumsum(need.astype(jnp.int32), axis=1)
        pos = jnp.clip(ptr[:, None] + inc - 1, 0, S - 1)
        nxt = jnp.take_along_axis(stream, pos, axis=1).astype(jnp.uint32)
        x = jnp.where(need.reshape(-1),
                      (x << jnp.uint32(16)) | nxt.reshape(-1), x)
        ptr = ptr + inc[:, -1]
        return (x, ptr), sym

    x0 = states.reshape(-1)
    ptr0 = jnp.zeros((nb,), jnp.int32)
    (xf, ptrf), syms = jax.lax.scan(body, (x0, ptr0), None, length=m)
    syms = jnp.transpose(syms.reshape(m, nb, L),
                         (1, 0, 2)).reshape(nb, m * L)
    return syms, xf.reshape(nb, L), ptrf


@functools.partial(jax.jit, static_argnames=("m", "L", "b_bits", "be"))
def decode_idx_group_packed(dec: jax.Array, states: jax.Array,
                            stream: jax.Array, m: int, L: int,
                            b_bits: int, be: int):
    """v1 group decode fused with unpack: rANS bytes -> packed words ->
    (g, be) int32 indices, all on device."""
    syms, xf, ptrf = decode_scan_body(dec, None, states, stream, m, L)
    nbytes = be * b_bits // 8
    idx = unpack_words(bytes_to_words(syms[:, :nbytes]), b_bits, be)
    return idx, xf, ptrf


@functools.partial(jax.jit,
                   static_argnames=("m", "L", "n_sym", "b_bits", "be"))
def decode_idx_group_syms(dec: jax.Array, sym_tab, states: jax.Array,
                          stream: jax.Array, m: int, L: int, n_sym: int,
                          b_bits: int, be: int):
    """v2 group decode: rANS symbol ids -> B-bit index values (marker id
    ``n_sym - 1`` maps back to the B-bit marker); no unpack stage."""
    syms, xf, ptrf = decode_scan_body(dec, sym_tab, states, stream, m, L)
    syms = syms[:, :be].astype(jnp.int32)
    marker = jnp.int32((1 << b_bits) - 1)
    idx = jnp.where(syms >= jnp.int32(n_sym - 1), marker, syms)
    return idx, xf, ptrf


@functools.partial(jax.jit, static_argnames=("m", "L", "nbytes"))
def decode_bytes_group(dec: jax.Array, states: jax.Array,
                       stream: jax.Array, m: int, L: int, nbytes: int):
    """v1 group decode to raw bytes (anchor payloads)."""
    syms, xf, ptrf = decode_scan_body(dec, None, states, stream, m, L)
    return syms[:, :nbytes], xf, ptrf


@functools.partial(jax.jit, static_argnames=("b_bits", "be"))
def unpack_group(byts: jax.Array, b_bits: int, be: int) -> jax.Array:
    """(g, nbytes) u8 packed payloads -> (g, be) int32 indices."""
    return unpack_words(bytes_to_words(byts), b_bits, be)


def _check_decoded(xf: np.ndarray, ptrf: np.ndarray,
                   n_emit: np.ndarray) -> None:
    """Host-side stream-integrity check of a decoded group (forces the
    device computation; mirrors ``decode_np`` validation)."""
    if (np.asarray(ptrf, np.int64) != np.asarray(n_emit, np.int64)).any() \
            or (np.asarray(xf) != np.uint32(STATE_LO)).any():
        raise ValueError("corrupt rANS blob: stream not consumed cleanly")


def _batch_group(parsed: List[dict]):
    """Stack a homogeneous parsed-blob group for one jitted decode call:
    fused decode tables (cached per distinct frequency table), states,
    zero-padded stream matrix and per-block emission counts."""
    g = len(parsed)
    smax = max(1, max(p["stream"].size for p in parsed))
    states = np.stack([p["states"] for p in parsed]).astype(np.uint32)
    stream = np.zeros((g, smax), np.uint16)
    dec = np.empty((g, M), np.uint32)
    sym = None
    cache: dict = {}
    for i, p in enumerate(parsed):
        stream[i, :p["stream"].size] = p["stream"]
        key = p["freq"].tobytes()
        if key not in cache:
            cache[key] = _decode_tables(p["freq"])
        d, s = cache[key]
        dec[i] = d
        if s is not None:
            if sym is None:
                sym = np.empty((g, M), np.int32)
            sym[i] = s
    n_emit = np.array([p["stream"].size for p in parsed], np.int64)
    return dec, sym, states, stream, n_emit


def decode_blocks_device(blobs: Sequence[bytes], b_bits: int,
                         block_elems: int, pool=None) -> jax.Array:
    """Device entropy decode of a step's index blocks: self-describing
    blobs (v0/v1/v2, freely mixed) -> (nblocks, block_elems) int32 index
    values on device.  Blobs are parsed and grouped by shape on host,
    each group decodes through one jitted scan executable, and groups are
    span-split over ``pool`` threads exactly like
    ``compress_blocks_device``.  Raises ValueError on corrupt blobs,
    matching the host ``decompress`` semantics."""
    be = block_elems
    nblocks = len(blobs)
    nbytes = be * b_bits // 8
    groups: dict = {}
    for i, blob in enumerate(blobs):
        v = blob_version(blob)
        if v == _V_RAW:
            n, _ = _RAW_HDR.unpack_from(blob)
            payload = blob[_RAW_HDR.size:_RAW_HDR.size + n]
            if n != nbytes or len(payload) != n:
                raise ValueError("corrupt raw blob: payload size mismatch")
            key, rec = ("raw",), {"payload": payload}
        elif v == _V_RANS:
            n, L, freq, states, stream = _parse_v1(blob)
            if n != nbytes:
                raise ValueError("rANS blob does not match block shape")
            key = ("v1", L)
            rec = {"freq": freq, "states": states, "stream": stream}
        elif v == _V_SYM:
            n, bb, L, freq, states, stream = _parse_v2(blob)
            if n != be or bb != b_bits:
                raise ValueError("rANS blob does not match block shape")
            key = ("v2", L, freq.size)
            rec = {"freq": freq, "states": states, "stream": stream}
        else:
            raise ValueError(f"unknown rANS blob version {v}")
        groups.setdefault(key, ([], []))
        groups[key][0].append(i)
        groups[key][1].append(rec)

    tasks = []
    for key, (idxs, parsed) in groups.items():
        for g0, g1 in _group_spans(len(idxs), pool):
            tasks.append((key, idxs[g0:g1], parsed[g0:g1]))

    def run(task):
        key, idxs, parsed = task
        if key[0] == "raw":
            byts = np.stack([np.frombuffer(p["payload"], np.uint8)
                             for p in parsed])
            return idxs, unpack_group(jnp.asarray(byts), b_bits, be)
        dec, sym, states, stream, n_emit = _batch_group(parsed)
        if key[0] == "v1":
            L = key[1]
            m = -(-nbytes // L)
            idx, xf, ptrf = decode_idx_group_packed(
                jnp.asarray(dec), jnp.asarray(states),
                jnp.asarray(stream), m, L, b_bits, be)
        else:
            _, L, n_sym = key
            m = -(-be // L)
            sym_dev = None if sym is None else jnp.asarray(sym)
            idx, xf, ptrf = decode_idx_group_syms(
                jnp.asarray(dec), sym_dev, jnp.asarray(states),
                jnp.asarray(stream), m, L, n_sym, b_bits, be)
        _check_decoded(xf, ptrf, n_emit)
        return idxs, idx

    if pool is not None and len(tasks) > 1:
        pieces = list(pool.map(run, tasks))
    else:
        pieces = [run(t) for t in tasks]

    # Host-side block-order bookkeeping: `ix` is the task's host index
    # array, and the permutation never touches the device until the
    # single jnp.take below.
    # repro-lint: disable=host-sync-in-device-path, dtype-hazard
    order = np.concatenate([np.asarray(ix, np.int64) for ix, _ in pieces])
    arrs = [a for _, a in pieces]
    cat = jnp.concatenate(arrs, axis=0) if len(arrs) > 1 else arrs[0]
    perm = np.argsort(order, kind="stable")
    if not np.array_equal(perm, np.arange(nblocks)):
        cat = jnp.take(cat, jnp.asarray(perm), axis=0)
    return cat


def decode_bytes_blocks_device(blobs: Sequence[bytes],
                               pool=None) -> jax.Array:
    """Device entropy decode of anchor byte blocks (possibly ragged
    lengths) -> one flat (total_bytes,) uint8 device array in block
    order.  v0 payloads upload directly; v1 groups (keyed by exact byte
    length and lane count) decode on device."""
    pieces: List = [None] * len(blobs)
    groups: dict = {}
    for i, blob in enumerate(blobs):
        v = blob_version(blob)
        if v == _V_RAW:
            n, _ = _RAW_HDR.unpack_from(blob)
            payload = blob[_RAW_HDR.size:_RAW_HDR.size + n]
            if len(payload) != n:
                raise ValueError("corrupt raw blob: payload size mismatch")
            pieces[i] = np.frombuffer(payload, np.uint8)
        elif v == _V_RANS:
            n, L, freq, states, stream = _parse_v1(blob)
            groups.setdefault((n, L), ([], []))
            groups[(n, L)][0].append(i)
            groups[(n, L)][1].append(
                {"freq": freq, "states": states, "stream": stream})
        else:
            raise ValueError(f"unknown rANS blob version {v}")

    tasks = []
    for (n, L), (idxs, parsed) in groups.items():
        for g0, g1 in _group_spans(len(idxs), pool):
            tasks.append((n, L, idxs[g0:g1], parsed[g0:g1]))

    def run(task):
        n, L, idxs, parsed = task
        dec, _, states, stream, n_emit = _batch_group(parsed)
        m = -(-n // L)
        byts, xf, ptrf = decode_bytes_group(
            jnp.asarray(dec), jnp.asarray(states), jnp.asarray(stream),
            m, L, n)
        _check_decoded(xf, ptrf, n_emit)
        return idxs, byts

    if pool is not None and len(tasks) > 1:
        results = list(pool.map(run, tasks))
    else:
        results = [run(t) for t in tasks]
    for idxs, byts in results:
        for k, i in enumerate(idxs):
            pieces[i] = byts[k]
    if not pieces:
        return jnp.zeros((0,), jnp.uint8)
    if len(pieces) == 1:
        return jnp.asarray(pieces[0])
    return jnp.concatenate([jnp.asarray(p) for p in pieces])


__all__ = ["SCALE_BITS", "M", "STATE_LO", "DEVICE_MIN_BYTES", "lanes_for",
           "sample_stride", "freq_from_counts", "freq_table", "pack_fc",
           "encode_np", "decode_np", "blob_nbytes", "assemble_blob",
           "blob_nbytes_sym", "assemble_symbol_blob", "symbol_freq",
           "compress_symbols", "blob_version", "compress", "decompress",
           "words_to_bytes", "bytes_to_words", "pack_words",
           "unpack_words", "encode_bytes_body", "encode_idx_group",
           "encode_sym_group", "sampled_idx_bytes", "sample_words",
           "tables_from_samples", "compress_blocks_device",
           "compress_blocks_device_symbols", "decode_scan_body",
           "decode_idx_group_packed", "decode_idx_group_syms",
           "decode_bytes_group", "unpack_group", "decode_blocks_device",
           "decode_bytes_blocks_device"]
