"""Block-parallel interleaved rANS coder (device entropy stage).

The paper's phase-6 entropy stage is host zlib; this module moves it onto
the accelerator.  Each index-table block is compressed *independently*
(so partial decompression keeps its block granularity) by an interleaved
range-asymmetric-numeral-system coder:

  * a block's byte stream is split into ``L`` interleaved lanes (lane l
    owns bytes l, l+L, l+2L, ...); every lane is an independent rANS
    state, so one encode step advances all lanes of all blocks with pure
    vector ALU ops -- the sequential dependency of classic rANS becomes a
    ``lax.scan`` over ``len/L`` steps with lane-parallel bodies (blocks
    map to disjoint lane groups, the grid-tile analogue).
  * 32-bit states with 16-bit renormalization and ``SCALE_BITS``-bit
    frequencies.  With freq >= 1 the renorm emits **exactly 0 or 1**
    uint16 per symbol (state < 2^32 implies post-shift state < 2^16 <=
    freq << (32-SCALE_BITS)), which is what makes the emission schedule
    decodable without per-lane length tables: the decoder replays the
    same schedule in reverse.
  * frequency tables are built from a strided byte sample and normalized
    with a deterministic largest-quota scheme that gives **every** byte
    value a nonzero frequency -- sampling can therefore never break
    correctness, only (marginally) the ratio.

The encode lowering follows the ``core.packing`` pattern: a pure-jnp
device path (``encode_idx_group`` / ``encode_words_body``, jit- and
shard_map-safe) with a NumPy oracle (``encode_np``) that emits
byte-identical streams; the histogram side reuses the same
sample-normalize code on both paths so host- and device-produced blobs
are byte-identical by construction.  Decode (``decompress``) is the host
side used by ``decompress_step`` / ``partial.read_step_range``.

Blob layout (little-endian), self-describing per block:

  v1 (rANS): u32 raw_len | u8 1 | u8 scale_bits | u16 L |
             256*u16 freq | u32 n_emit | L*u32 states | n_emit*u16 stream
  v0 (raw):  u32 raw_len | u8 0 | raw bytes          (store fallback when
             the rANS stream would not beat raw -- near-random blocks)
"""
from __future__ import annotations

import functools
import struct
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

SCALE_BITS = 12
M = 1 << SCALE_BITS                 # total frequency budget per table
STATE_LO = 1 << 16                  # renormalization lower bound
_HDR = struct.Struct("<IBBH")       # raw_len, version, scale_bits, lanes
_RAW_HDR = struct.Struct("<IB")     # raw_len, version=0
_V_RANS = 1
_V_RAW = 0

# Below this raw payload (total packed bytes of a step) the drivers keep
# the host codec path: jit-cache churn and per-call dispatch would eat the
# win.  Blobs are byte-identical either way, so this is pure routing.
DEVICE_MIN_BYTES = 256 << 10


def lanes_for(n: int) -> int:
    """Interleave width for an n-byte block (deterministic: part of the
    format -- encoder and decoder must agree).  More lanes amortize the
    scan length; each lane costs 4 bytes of final state."""
    if n >= 512 << 10:
        return 1024
    if n >= 64 << 10:
        return 512
    if n >= 8 << 10:
        return 128
    return 32


def sample_stride(n: int) -> int:
    """Byte-sampling stride for the frequency tables (deterministic, part
    of the format contract between the host and device encoders)."""
    return 16 if n >= 256 << 10 else 1


# ------------------------------------------------------------- tables

def freq_from_counts(counts: np.ndarray) -> np.ndarray:
    """(256,) counts -> (256,) uint16 frequencies summing to M, every
    symbol >= 1 (so unsampled bytes stay encodable).

    Deterministic largest-quota allocation: each symbol gets 1 plus its
    share of the remaining budget via cumulative integer boundaries --
    one vector pass, no data-dependent iteration, identical results on
    every path.
    """
    counts = np.asarray(counts, np.uint64)
    total = int(counts.sum())
    if total == 0:
        return np.full(256, M // 256, np.uint16)
    budget = np.uint64(M - 256)
    bounds = (np.cumsum(counts) * budget) // np.uint64(total)
    extra = np.diff(np.concatenate([[np.uint64(0)], bounds]))
    return (1 + extra).astype(np.uint16)


def freq_table(raw: np.ndarray) -> np.ndarray:
    """Frequency table of a raw byte block (strided sample + normalize)."""
    raw = np.asarray(raw, np.uint8)
    if raw.size == 0:
        return freq_from_counts(np.zeros(256, np.uint64))
    sample = raw[:: sample_stride(raw.size)]
    return freq_from_counts(np.bincount(sample, minlength=256))


def _cum(freq: np.ndarray) -> np.ndarray:
    f = np.asarray(freq, np.uint64)
    return np.concatenate([[np.uint64(0)], np.cumsum(f)[:-1]])


def pack_fc(freq: np.ndarray) -> np.ndarray:
    """Fuse freq+cumfreq into one u32 table (freq in bits 0..12, cum in
    13..24) so the scan body does a single gather per symbol."""
    return (np.asarray(freq, np.uint32)
            | (_cum(freq).astype(np.uint32) << np.uint32(13)))


# ------------------------------------------------- NumPy coder (oracle)

def encode_np(raw: np.ndarray, freq: np.ndarray):
    """Encode one block: (L,) u32 final states + (n_emit,) u16 stream.

    Lanes interleave by stride L; symbols are visited in reverse row
    order (standard rANS encodes backwards); the emitted stream is laid
    out in the decoder's read order (row ascending, lane ascending).
    """
    raw = np.asarray(raw, np.uint8)
    n = raw.size
    L = lanes_for(n)
    m = -(-n // L) if n else 0
    sy = np.zeros(m * L, np.uint8)
    sy[:n] = raw
    sy = sy.reshape(m, L)
    f64 = np.asarray(freq, np.uint64)
    c64 = _cum(freq)
    f_rows = f64[sy]                    # (m, L) gathered once
    c_rows = c64[sy]
    x = np.full(L, STATE_LO, np.uint64)
    vals = np.zeros((m, L), np.uint16)
    masks = np.zeros((m, L), bool)
    for j in range(m - 1, -1, -1):
        f = f_rows[j]
        mask = x >= (f << np.uint64(32 - SCALE_BITS))
        vals[j] = (x & np.uint64(0xFFFF)).astype(np.uint16)
        masks[j] = mask
        x = np.where(mask, x >> np.uint64(16), x)
        q = x // f
        x = (q << np.uint64(SCALE_BITS)) + (x - q * f) + c_rows[j]
    return x.astype(np.uint32), vals[masks]


def decode_np(states: np.ndarray, stream: np.ndarray, freq: np.ndarray,
              n: int, L: int) -> np.ndarray:
    """Inverse of encode_np (lane-vectorized; validates stream integrity)."""
    m = -(-n // L) if n else 0
    f64 = np.asarray(freq, np.uint64)
    c64 = _cum(freq)
    slot2sym = np.repeat(np.arange(256, dtype=np.uint8),
                         np.asarray(freq, np.int64))
    if slot2sym.size != M:
        raise ValueError("corrupt rANS table: frequencies sum != 2^scale")
    x = np.asarray(states, np.uint64).copy()
    if x.size != L:
        raise ValueError("corrupt rANS blob: state count != lanes")
    out = np.zeros((m, L), np.uint8)
    ptr = 0
    for j in range(m):
        slot = x & np.uint64(M - 1)
        s = slot2sym[slot]
        out[j] = s
        x = f64[s] * (x >> np.uint64(SCALE_BITS)) + slot - c64[s]
        need = x < STATE_LO
        k = int(need.sum())
        if k:
            nxt = stream[ptr:ptr + k]
            if nxt.size != k:
                raise ValueError("corrupt rANS blob: stream underrun")
            x[need] = (x[need] << np.uint64(16)) | nxt.astype(np.uint64)
            ptr += k
    if ptr != stream.size or (x != STATE_LO).any():
        raise ValueError("corrupt rANS blob: stream not consumed cleanly")
    return out.reshape(-1)[:n]


# ------------------------------------------------------- blob assembly

def blob_nbytes(n_emit: int, L: int) -> int:
    return _HDR.size + 512 + 4 + 4 * L + 2 * n_emit


def assemble_blob(raw_len: int, freq: np.ndarray, states: np.ndarray,
                  stream: np.ndarray,
                  raw_bytes: Optional[Callable[[], bytes]] = None) -> bytes:
    """Assemble the self-describing block blob; falls back to the v0 raw
    container when rANS would not beat store (``raw_bytes`` supplies the
    payload lazily -- only fetched for losing blocks)."""
    L = int(states.size)
    if raw_bytes is not None and \
            blob_nbytes(stream.size, L) >= raw_len + _RAW_HDR.size:
        return _RAW_HDR.pack(raw_len, _V_RAW) + raw_bytes()
    return b"".join([
        _HDR.pack(raw_len, _V_RANS, SCALE_BITS, L),
        np.ascontiguousarray(freq, np.uint16).tobytes(),
        struct.pack("<I", int(stream.size)),
        np.ascontiguousarray(states, np.uint32).tobytes(),
        np.ascontiguousarray(stream, np.uint16).tobytes(),
    ])


def compress(raw: bytes) -> bytes:
    """Host (NumPy) flavor: bytes -> self-describing rANS blob."""
    arr = np.frombuffer(raw, np.uint8)
    freq = freq_table(arr)
    states, stream = encode_np(arr, freq)
    return assemble_blob(arr.size, freq, states, stream,
                         raw_bytes=lambda: bytes(raw))


def decompress(blob: bytes) -> bytes:
    """Decode a block blob (v0 raw or v1 rANS) back to its raw bytes."""
    if len(blob) < _RAW_HDR.size:
        raise ValueError("rANS blob too short")
    n, version = _RAW_HDR.unpack_from(blob)
    if version == _V_RAW:
        out = blob[_RAW_HDR.size:_RAW_HDR.size + n]
        if len(out) != n:
            raise ValueError("corrupt raw blob: truncated payload")
        return out
    if version != _V_RANS:
        raise ValueError(f"unknown rANS blob version {version}")
    n, _, sb, L = _HDR.unpack_from(blob)
    if sb != SCALE_BITS:
        raise ValueError(f"unsupported rANS scale_bits {sb}")
    off = _HDR.size
    freq = np.frombuffer(blob, np.uint16, 256, off)
    off += 512
    (n_emit,) = struct.unpack_from("<I", blob, off)
    off += 4
    states = np.frombuffer(blob, np.uint32, L, off)
    off += 4 * L
    stream = np.frombuffer(blob, np.uint16, n_emit, off)
    return decode_np(states, stream, freq, n, L).tobytes()


# ------------------------------------------------------ device lowering

def words_to_bytes(words: jax.Array) -> jax.Array:
    """(..., w) u32 words -> (..., 4w) u8, little-endian (matches the
    ``astype('<u4').tobytes()`` host fetch byte for byte)."""
    parts = [((words >> jnp.uint32(8 * k)) & jnp.uint32(0xFF))
             for k in range(4)]
    stacked = jnp.stack(parts, axis=-1)
    return stacked.reshape(*words.shape[:-1], -1).astype(jnp.uint8)


def pack_words(idx2d: jax.Array, b_bits: int) -> jax.Array:
    """(nb, be) int32 indices -> (nb, be*b/32) u32 words of the
    little-endian bitstream (same math as the Pallas bitpack kernel,
    vectorized over blocks; be must be a multiple of 32)."""
    nb, be = idx2d.shape
    g = idx2d.reshape(nb, be // 32, 32).astype(jnp.uint32)
    maskv = jnp.uint32((1 << b_bits) - 1)
    words = [jnp.zeros((nb, be // 32), jnp.uint32) for _ in range(b_bits)]
    for j in range(32):                       # static unroll
        v = g[:, :, j] & maskv
        bit0 = j * b_bits
        w, s = divmod(bit0, 32)
        words[w] = words[w] | (v << jnp.uint32(s))
        if s + b_bits > 32:                   # spills into the next word
            words[w + 1] = words[w + 1] | (v >> jnp.uint32(32 - s))
    return jnp.stack(words, axis=-1).reshape(nb, -1)


def encode_bytes_body(byts: jax.Array, fc: jax.Array, L: int):
    """Shared scan body (jit- and shard_map-safe): encode every block of
    ``byts`` (nb, nbytes) u8 with its fused table row of ``fc`` (nb, 256)
    u32.  Returns (states (nb, L) u32, vals (nb, m*L) u16, masks
    (nb, m*L) bool) with each block's emissions laid out contiguously in
    decoder order (j ascending, lane ascending): the host compacts a
    block's stream with one contiguous boolean index
    ``vals[k][masks[k]]``.  (An on-device prefix-sum scatter was
    benchmarked instead and lost badly -- XLA CPU scatters are
    scalarized.)"""
    nb, nbytes = byts.shape
    m = -(-nbytes // L)
    pad = m * L - nbytes
    if pad:
        byts = jnp.pad(byts, ((0, 0), (0, pad)))
    sy = byts.reshape(nb, m, L).astype(jnp.int32)
    sy = jnp.transpose(sy, (1, 0, 2)).reshape(m, nb * L)[::-1]
    base = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), L) * 256
    fc_flat = fc.reshape(-1)

    def body(x, s):
        v = fc_flat[base + s]
        f = v & jnp.uint32(0x1FFF)
        c = v >> jnp.uint32(13)
        mask = (x >> jnp.uint32(32 - SCALE_BITS)) >= f
        val = (x & jnp.uint32(0xFFFF)).astype(jnp.uint16)
        x = jnp.where(mask, x >> jnp.uint32(16), x)
        q = x // f
        x = (q << jnp.uint32(SCALE_BITS)) + (x - q * f) + c
        return x, (val, mask)

    x0 = jnp.full((nb * L,), jnp.uint32(STATE_LO))
    xf, (vals, masks) = jax.lax.scan(body, x0, sy)
    # decoder order per block: j ascending (undo the scan flip), lanes
    # ascending, contiguous per block.
    vals = jnp.transpose(vals[::-1].reshape(m, nb, L),
                         (1, 0, 2)).reshape(nb, m * L)
    masks = jnp.transpose(masks[::-1].reshape(m, nb, L),
                          (1, 0, 2)).reshape(nb, m * L)
    return xf.reshape(nb, L), vals, masks


@functools.partial(jax.jit, static_argnames=("b_bits", "L"))
def encode_idx_group(idx2d: jax.Array, fc: jax.Array, b_bits: int, L: int):
    """Device encode of a block group straight from B-bit indices:
    bit-pack (word math of the bitpack kernel) -> bytes -> rANS scan."""
    return encode_bytes_body(words_to_bytes(pack_words(idx2d, b_bits)),
                             fc, L)


@functools.partial(jax.jit, static_argnames=("b_bits", "stride"))
def sampled_idx_bytes(idx2d: jax.Array, b_bits: int,
                      stride: int) -> jax.Array:
    """Every ``stride``-th byte of each block's packed stream, computed
    directly from the indices (no full bit-pack needed): byte k mixes the
    <= 7//b + 2 indices straddling bits [8k, 8k+8)."""
    nb, be = idx2d.shape
    nbytes = be * b_bits // 8
    p = np.arange(0, nbytes, stride, dtype=np.int64)
    bit0 = 8 * p
    i0 = bit0 // b_bits
    maskv = jnp.uint32((1 << b_bits) - 1)
    acc = jnp.zeros((nb, p.size), jnp.uint32)
    for t in range(7 // b_bits + 2):          # static unroll
        i = i0 + t
        sh = i * b_bits - bit0                # alignment shift per byte
        keep = (i < be) & (sh < 8)            # bits >= 8 never reach byte k
        iv = np.where(i < be, i, 0).astype(np.int32)
        v = idx2d[:, iv].astype(jnp.uint32) & maskv
        shp = jnp.asarray(np.clip(sh, 0, 31).astype(np.uint32))[None, :]
        shn = jnp.asarray(np.clip(-sh, 0, 31).astype(np.uint32))[None, :]
        contrib = jnp.where(jnp.asarray(sh >= 0)[None, :],
                            v << shp, v >> shn)
        acc = acc | jnp.where(jnp.asarray(keep)[None, :], contrib, 0)
    return (acc & jnp.uint32(0xFF)).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("stride",))
def sample_words(words2d: jax.Array, stride: int) -> jax.Array:
    """Strided byte sample of per-row packed words (sharded driver path;
    bit-equal to ``raw[::stride]`` of the row's little-endian bytes)."""
    if stride == 1:
        return words_to_bytes(words2d)
    assert stride % 4 == 0, "stride must be 1 or a multiple of 4"
    return (words2d[:, ::stride // 4] & jnp.uint32(0xFF)).astype(jnp.uint8)


def tables_from_samples(samples: np.ndarray):
    """Per-block (freq (nb, 256) u16, fused fc (nb, 256) u32) from the
    sampled bytes of each block."""
    freqs = np.stack([freq_from_counts(np.bincount(row, minlength=256))
                      for row in np.asarray(samples, np.uint8)])
    fcs = np.stack([pack_fc(f) for f in freqs])
    return freqs, fcs


def compress_blocks_device(idx_dev: jax.Array, b_bits: int, nblocks: int,
                           block_elems: int,
                           pool=None) -> List[bytes]:
    """Single-device entropy stage: marker-padded indices (nblocks *
    block_elems,) on device -> one self-describing rANS blob per block.

    Blocks are split into groups dispatched over ``pool`` threads; each
    group runs one jitted pack+scan executable (jax releases the GIL
    during execution, so groups run device-parallel) and compacts its
    emissions on the worker.  Byte-identical to the host
    ``rans.compress`` of the same packed bytes by construction.
    """
    be = block_elems
    nbytes = be * b_bits // 8
    stride = sample_stride(nbytes)
    L = lanes_for(nbytes)
    idx2d = idx_dev.reshape(nblocks, be)
    samples = np.asarray(sampled_idx_bytes(idx2d, b_bits, stride))
    freqs, fcs = tables_from_samples(samples)
    fc_dev = jnp.asarray(fcs)

    workers = getattr(pool, "_max_workers", 1) if pool is not None else 1
    ngroups = max(1, min(nblocks, workers))
    gsize = -(-nblocks // ngroups)
    spans = [(s, min(s + gsize, nblocks))
             for s in range(0, nblocks, gsize)]

    def encode_span(span) -> List[bytes]:
        g0, g1 = span
        st, vals, masks = encode_idx_group(idx2d[g0:g1], fc_dev[g0:g1],
                                           b_bits, L)
        st = np.asarray(st)
        vals = np.asarray(vals)
        masks = np.asarray(masks)
        blobs = []
        for k in range(g1 - g0):
            def raw_bytes(k=k):
                idx_h = np.asarray(idx2d[g0 + k]).astype(np.int64)
                from repro.core.packing import pack_indices_np
                return pack_indices_np(idx_h, b_bits).tobytes()[:nbytes]

            blobs.append(assemble_blob(nbytes, freqs[g0 + k], st[k],
                                       vals[k][masks[k]],
                                       raw_bytes=raw_bytes))
        return blobs

    if pool is not None and len(spans) > 1:
        parts = list(pool.map(encode_span, spans))
    else:
        parts = [encode_span(s) for s in spans]
    return [b for part in parts for b in part]


__all__ = ["SCALE_BITS", "M", "STATE_LO", "DEVICE_MIN_BYTES", "lanes_for",
           "sample_stride", "freq_from_counts", "freq_table", "pack_fc",
           "encode_np", "decode_np", "blob_nbytes", "assemble_blob",
           "compress", "decompress", "words_to_bytes", "pack_words",
           "encode_bytes_body", "encode_idx_group", "sampled_idx_bytes",
           "sample_words", "tables_from_samples",
           "compress_blocks_device"]
