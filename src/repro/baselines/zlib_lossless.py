"""ZLIB lossless baseline (paper Sec. II: 'may not achieve a good
compression ratio for high entropy data')."""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np


@dataclass
class ZlibBlob:
    payload: bytes
    dtype: str
    shape: tuple

    @property
    def nbytes(self) -> int:
        return len(self.payload) + 16


def compress(data: np.ndarray, level: int = 6) -> ZlibBlob:
    arr = np.ascontiguousarray(data)
    return ZlibBlob(zlib.compress(arr.tobytes(), level), str(arr.dtype),
                    tuple(arr.shape))


def decompress(blob: ZlibBlob) -> np.ndarray:
    raw = zlib.decompress(blob.payload)
    return np.frombuffer(raw, blob.dtype).reshape(blob.shape).copy()


__all__ = ["compress", "decompress", "ZlibBlob"]
