"""ISABELA-like baseline (Lakshminarasimhan et al., Euro-Par 2011).

In-situ Sort-And-B-spline Error-bounded Lossy Abatement, three stages as in
the original:
  1. SORT each window (the pre-conditioner: high-entropy data becomes a
     monotone curve); store the permutation at log2(W) bits/element.
  2. Fit the monotone curve with a small coefficient vector (knots).
  3. ERROR QUANTIZATION: per-element relative correction ratios
     e = v/fit cluster tightly around 1, so they are quantized into
     width-2E bins and entropy-coded (this is what achieves the bound; the
     original stores these as small ints too).
Elements whose correction can't be expressed (sign flip / zero fit /
|bin| > 2^15) are exceptions stored exactly.

Simplification vs the original (DESIGN.md): monotone linear interpolation
between knots instead of cubic B-splines -- stage 3 absorbs the difference.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class IsabelaBlob:
    window: int
    n: int
    n_knots: int
    payload: bytes          # zlib'd: knots + perms + corrections + excs
    meta: dict

    @property
    def nbytes(self) -> int:
        return len(self.payload) + 32


def _perm_bits(window: int) -> int:
    return max(1, int(np.ceil(np.log2(window))))


def compress(data: np.ndarray, error_bound: float = 1e-3,
             window: int = 1024, n_knots: int = 32) -> IsabelaBlob:
    flat = np.asarray(data, np.float64).reshape(-1)
    n = flat.size
    E = float(error_bound)
    knots_all: List[np.ndarray] = []
    perm_all: List[np.ndarray] = []
    corr_all: List[np.ndarray] = []
    exc_idx_all: List[np.ndarray] = []
    exc_val_all: List[np.ndarray] = []
    for s in range(0, n, window):
        w = flat[s: s + window]
        order = np.argsort(w, kind="stable")
        sw = w[order]
        m = min(n_knots, sw.size)
        knot_pos = np.linspace(0, sw.size - 1, m)
        knots = np.interp(knot_pos, np.arange(sw.size), sw
                          ).astype(np.float32)
        fit = np.interp(np.arange(sw.size), knot_pos,
                        knots.astype(np.float64))
        # stage 3: quantized correction ratios, bins of width 2E around 1
        ok = (fit != 0) & np.isfinite(sw) & (np.sign(fit) == np.sign(sw))
        ratio = np.where(ok, sw / np.where(fit == 0, 1.0, fit), 1.0)
        bins = np.round((ratio - 1.0) / (2 * E))
        ok &= np.abs(bins) < 32767
        # verify the bound on the decoded value (f32 storage included)
        dec = (fit * (1.0 + bins * 2 * E)).astype(data.dtype
                                                  ).astype(np.float64)
        denom = np.maximum(np.abs(sw), 1e-30)
        ok &= np.abs(dec - sw) / denom <= E
        bins = np.where(ok, bins, 0).astype(np.int16)
        bad = ~ok
        exc_idx_all.append((order[bad].astype(np.int64) + s
                            ).astype(np.int64))
        exc_val_all.append(w[order[bad]].astype(data.dtype))
        knots_all.append(knots)
        perm_all.append(order.astype(np.int32))
        corr_all.append(bins)

    from repro.core import packing
    bits = _perm_bits(window)
    perm = (np.concatenate(perm_all) if perm_all
            else np.zeros(0, np.int32))
    perm_packed = packing.pack_indices_np(perm, bits)
    corr = (np.concatenate(corr_all) if corr_all
            else np.zeros(0, np.int16))
    payload = zlib.compress(
        np.concatenate(knots_all).astype(np.float32).tobytes()
        + perm_packed.tobytes()
        + corr.tobytes()
        + np.concatenate(exc_idx_all).astype(np.int64).tobytes()
        + np.concatenate(exc_val_all).tobytes(), 6)
    n_exc = int(sum(len(e) for e in exc_idx_all))
    return IsabelaBlob(window=window, n=n, n_knots=n_knots, payload=payload,
                       meta={"n_exceptions": n_exc,
                             "exception_ratio": n_exc / max(n, 1),
                             "error_bound": E,
                             "knots": knots_all, "perms": perm_all,
                             "corr": corr_all,
                             "exc_idx": exc_idx_all,
                             "exc_val": exc_val_all,
                             "dtype": str(data.dtype),
                             "shape": tuple(np.shape(data))})


def decompress(blob: IsabelaBlob) -> np.ndarray:
    out = np.empty(blob.n, np.float64)
    m = blob.meta
    E = m["error_bound"]
    pos = 0
    for knots, perm, bins in zip(m["knots"], m["perms"], m["corr"]):
        size = perm.size
        knot_pos = np.linspace(0, size - 1, min(blob.n_knots, size))
        fit = np.interp(np.arange(size), knot_pos,
                        knots.astype(np.float64))
        dec = (fit * (1.0 + bins.astype(np.float64) * 2 * E)
               ).astype(m["dtype"]).astype(np.float64)
        w = np.empty(size, np.float64)
        w[perm] = dec
        out[pos: pos + size] = w
        pos += size
    for idx, val in zip(m["exc_idx"], m["exc_val"]):
        out[idx] = val
    return out.astype(m["dtype"]).reshape(m["shape"])


__all__ = ["compress", "decompress", "IsabelaBlob"]
