"""Comparison compressors: ISABELA-like, ZFP-like, ZLIB lossless."""
