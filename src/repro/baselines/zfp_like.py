"""ZFP-like baseline (Lindstrom, 2014) -- fixed-accuracy transform coder.

Per 4-element 1-D block: align to the block's common exponent, convert to
fixed point, apply ZFP's orthogonal lifting transform, and keep only the
bit planes above the absolute-error threshold; per-block bit widths are
stored so blocks pack densely.

Simplifications vs real ZFP (documented in DESIGN.md): 1-D 4-blocks on the
flattened array (real ZFP uses 4^d blocks and negabinary group testing);
entropy coding is per-block minimal-width packing.  Absolute error bound
only -- exactly the limitation the paper discusses (Sec. II): the bench
sets tol = mean(|data|) * rel_bound the same way the paper does.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass

import numpy as np

_Q = 26                       # fixed-point fraction bits


@dataclass
class ZfpBlob:
    n: int
    payload: bytes
    meta: dict

    @property
    def nbytes(self) -> int:
        return len(self.payload) + 16


def _transform(q):
    """Forward transform per block (q int64 (nb, 4))."""
    x, y, z, w = (q[:, 0].copy(), q[:, 1].copy(), q[:, 2].copy(),
                  q[:, 3].copy())
    # zfp's non-orthogonal lifted transform (decorrelates smooth data)
    x += w; x >>= 1; w -= x
    z += y; z >>= 1; y -= z
    x += z; x >>= 1; z -= x
    w += y; w >>= 1; y -= w
    w += y >> 1; y -= w >> 1
    return np.stack([x, z, w, y], axis=-1)


def _inv_transform(t):
    x, z, w, y = (t[:, 0].copy(), t[:, 1].copy(), t[:, 2].copy(),
                  t[:, 3].copy())
    y += w >> 1; w -= y >> 1
    y += w; w <<= 1; w -= y
    z += x; x <<= 1; x -= z
    y += z; z <<= 1; z -= y
    w += x; x <<= 1; x -= w
    return np.stack([x, y, z, w], axis=-1)


def compress(data: np.ndarray, tol_abs: float) -> ZfpBlob:
    flat = np.asarray(data, np.float64).reshape(-1)
    n = flat.size
    pad = (-n) % 4
    flat_p = np.pad(flat, (0, pad))
    blocks = flat_p.reshape(-1, 4)

    # common exponent per block
    amax = np.abs(blocks).max(axis=1)
    e = np.where(amax > 0, np.ceil(np.log2(np.maximum(amax, 1e-300))),
                 0).astype(np.int32)
    scale = np.exp2(_Q - e.astype(np.float64))
    q = np.round(blocks * scale[:, None]).astype(np.int64)
    t = _transform(q)

    # drop bit planes below the error threshold: keep `bits` such that the
    # dropped quantum 2^(e-Q) * 2^drop <= tol
    # per-block allowed drop bits:
    quantum = np.exp2(e.astype(np.float64) - _Q)        # value of 1 LSB
    drop = np.floor(np.log2(np.maximum(tol_abs / np.maximum(quantum, 1e-300),
                                       1.0))).astype(np.int64)
    drop = np.clip(drop, 0, _Q + 8)
    tq = t >> drop[:, None]

    # per-block bit width of the shifted coefficients
    mag = np.abs(tq).max(axis=1)
    width = np.where(mag > 0,
                     np.floor(np.log2(np.maximum(mag, 1))) + 2,
                     1).astype(np.int64)   # +1 sign, +1 ceil

    # serialize: e (int8 via offset), drop (uint8), width (uint8),
    # then coeffs packed at `width` bits each (zigzag)
    zig = ((tq << 1) ^ (tq >> 63)).astype(np.uint64)
    parts = [np.clip(e + 128, 0, 255).astype(np.uint8).tobytes(),
             drop.astype(np.uint8).tobytes(),
             width.astype(np.uint8).tobytes()]
    # bit-pack coefficients blockwise (vectorized variable-width pack)
    vals = zig.reshape(-1)
    elem_w = np.repeat(width, 4)
    total = int(elem_w.sum())
    starts = np.concatenate([[0], np.cumsum(elem_w)])[:-1]
    bit_owner = np.repeat(np.arange(vals.size), elem_w)
    bit_index = np.arange(total) - np.repeat(starts, elem_w)
    out_bits = ((vals[bit_owner] >> bit_index.astype(np.uint64)) & 1
                ).astype(np.uint8)
    parts.append(np.packbits(out_bits, bitorder="little").tobytes())
    payload = zlib.compress(b"".join(parts), 1)
    return ZfpBlob(n=n, payload=payload,
                   meta={"e": e, "drop": drop, "width": width, "tq": tq,
                         "dtype": str(data.dtype),
                         "shape": tuple(np.shape(data))})


def decompress(blob: ZfpBlob) -> np.ndarray:
    m = blob.meta
    t = m["tq"] << m["drop"][:, None]
    q = _inv_transform(t)
    scale = np.exp2(m["e"].astype(np.float64) - _Q)
    vals = q.astype(np.float64) * scale[:, None]
    return vals.reshape(-1)[: blob.n].astype(m["dtype"]).reshape(m["shape"])


__all__ = ["compress", "decompress", "ZfpBlob"]
