"""Fault tolerance layer: structured integrity errors, deterministic
fault injection, and bounded-retry helpers.

The paper's parallel NUMARCK targets 12800 MPI processes; at that scale
rank crashes, torn writes and flipped bits are the steady state.  This
package is the one home for how the repo *reacts* to them:

  * :mod:`repro.faults.errors` -- the structured error taxonomy every
    read/commit path raises instead of decoding garbage or dying deep in
    a codec (``IntegrityError`` and friends name the file, variable,
    block and digests involved).
  * :mod:`repro.faults.inject` -- seedable injection points
    (``REPRO_FAULTS=`` env or explicit ``configure``) for rank crashes,
    stragglers, torn/bit-flipped shard publishes, fsync/rename failures
    and entropy-pool worker deaths.  Disabled (the default) it is a
    single attribute check per site -- the same "disabled is free"
    discipline as ``repro.obs.telemetry``.
  * :mod:`repro.faults.retry` -- the bounded, jittered exponential
    backoff every retry loop in the tree uses (repro-lint's
    ``retry-discipline`` pass rejects unbounded poll loops).
"""
from repro.faults.errors import (CommitTimeoutError, CorruptBlockError,
                                 CorruptShardError, InjectedFault,
                                 IntegrityError)
from repro.faults.retry import Backoff

__all__ = ["IntegrityError", "CorruptBlockError", "CorruptShardError",
           "CommitTimeoutError", "InjectedFault", "Backoff"]
