"""Deterministic fault injection: seedable failure points for the fleet.

Activation: set ``REPRO_FAULTS`` in the environment (picked up at import
and by every spawned rank) or call :func:`configure` explicitly in
tests.  Disabled -- the default -- every site compiles down to a single
module-attribute check (``_PLAN is None``), the same "disabled is free"
discipline as ``repro.obs.telemetry``.

Spec grammar (comma-separated entries)::

    REPRO_FAULTS = entry[,entry...]
    entry        = site['@'rank]['='value]['*'count]

``site``   one of :data:`SITES` below
``rank``   only fire on this fleet rank (default: every rank); matched
           against ``REPRO_PROCESS_ID`` at fire time, so one spec string
           handed to every spawned worker targets a single rank
``value``  site parameter (straggler seconds, torn-byte count, flip
           offset, ...); float
``count``  how many times the entry fires before exhausting (default 1)

Sites and what they do when they fire:

  ``rank_crash``            raise :class:`InjectedFault` (worker dies
                            mid-encode, before publishing its shard)
  ``straggler``             sleep ``value`` seconds (default 1.0)
  ``torn_shard``            truncate the next published ``.rank`` file
                            by ``value`` bytes (default 64) -- a torn
                            write that *looks* atomically published
  ``bitflip_shard``         XOR one bit of the next published ``.rank``
                            file at byte offset ``value`` (mod size)
  ``fsync_fail``            raise ``OSError`` from the publish fsync
  ``rename_fail``           raise ``OSError`` from the publish rename
  ``entropy_worker_death``  raise inside the entropy process-pool worker
                            (exercises the retire-and-degrade path)

Example -- rank 1 publishes a torn shard, rank 0 must quarantine it and
roll back::

    REPRO_FAULTS="torn_shard@1=64" python worker.py
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional

from repro.faults.errors import InjectedFault

ENV_FAULTS = "REPRO_FAULTS"

SITES = ("rank_crash", "straggler", "torn_shard", "bitflip_shard",
         "fsync_fail", "rename_fail", "entropy_worker_death")

# File-mangling sites only apply to per-rank shard publishes (the fleet
# write path under test), never to manifests or checkpoint files.
_SHARD_MARKER = ".rank"


class _Entry:
    __slots__ = ("site", "rank", "value", "remaining")

    def __init__(self, site: str, rank: Optional[int], value: Optional[float],
                 count: int):
        self.site = site
        self.rank = rank
        self.value = value
        self.remaining = count

    def matches(self, site: str) -> bool:
        if self.site != site or self.remaining <= 0:
            return False
        if self.rank is not None and self.rank != _current_rank():
            return False
        return True

    def take(self) -> None:
        self.remaining -= 1


def _current_rank() -> int:
    # Late-bound: spawned ranks set REPRO_PROCESS_ID after import time.
    try:
        return int(os.environ.get("REPRO_PROCESS_ID", "0"))
    except ValueError:
        return 0


class FaultPlan:
    """Parsed injection plan.  Deterministic: entries fire in spec order,
    each at most ``count`` times, rank-matched at fire time."""

    def __init__(self, spec: str):
        self.spec = spec
        self.entries: List[_Entry] = []
        self.fired: List[Dict] = []      # audit log for tests/reports
        for raw in spec.split(","):
            raw = raw.strip()
            if not raw:
                continue
            count = 1
            if "*" in raw:
                raw, c = raw.rsplit("*", 1)
                count = int(c)
            value: Optional[float] = None
            if "=" in raw:
                raw, v = raw.split("=", 1)
                value = float(v)
            rank: Optional[int] = None
            if "@" in raw:
                raw, r = raw.split("@", 1)
                rank = int(r)
            site = raw.strip()
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r} in {ENV_FAULTS} spec "
                    f"(known: {', '.join(SITES)})")
            self.entries.append(_Entry(site, rank, value, count))

    def _claim(self, site: str) -> Optional[_Entry]:
        for e in self.entries:
            if e.matches(site):
                e.take()
                self.fired.append({"site": site, "rank": _current_rank(),
                                   "value": e.value})
                return e
        return None

    def fire(self, site: str, **ctx) -> None:
        e = self._claim(site)
        if e is None:
            return
        if site == "straggler":
            time.sleep(e.value if e.value is not None else 1.0)
            return
        if site in ("fsync_fail", "rename_fail"):
            raise OSError(f"injected {site} ({ctx.get('path', '?')})")
        raise InjectedFault(site, detail=", ".join(
            f"{k}={v}" for k, v in sorted(ctx.items())))

    def mangle_file(self, tmp: str, target: str) -> None:
        """Corrupt the not-yet-published tmp file of a ``.rank`` shard
        publish (torn / bit-flipped), so the damage rides the atomic
        rename exactly like real silent corruption would."""
        if _SHARD_MARKER not in os.path.basename(target):
            return
        e = self._claim("torn_shard")
        if e is not None:
            drop = int(e.value if e.value is not None else 64)
            size = os.path.getsize(tmp)
            with open(tmp, "r+b") as f:
                f.truncate(max(size - drop, 0))
            return
        e = self._claim("bitflip_shard")
        if e is not None:
            size = os.path.getsize(tmp)
            if size == 0:
                return
            off = int(e.value if e.value is not None else 0) % size
            with open(tmp, "r+b") as f:
                f.seek(off)
                b = f.read(1)
                f.seek(off)
                f.write(bytes([b[0] ^ 0x01]))


# One module-global plan slot (telemetry's registry-slot discipline):
# ``None`` means disabled, and every site entry point below is then a
# single attribute check -- no dict lookups, no string parsing.
_PLAN: Optional[FaultPlan] = None


def enabled() -> bool:
    return _PLAN is not None


def plan() -> Optional[FaultPlan]:
    return _PLAN


def configure(spec: Optional[str]) -> Optional[FaultPlan]:
    """Install (or, with ``None``/empty, clear) the process fault plan."""
    global _PLAN
    _PLAN = FaultPlan(spec) if spec else None
    return _PLAN


def reset() -> None:
    configure(None)


def fire(site: str, **ctx) -> None:
    """Injection point: no-op unless a plan entry matches ``site`` for
    the current rank.  May raise or sleep; see the module docstring."""
    if _PLAN is None:
        return
    _PLAN.fire(site, **ctx)


def mangle_file(tmp: str, target: str) -> None:
    """Shard-publish corruption hook (called by ``atomic_commit`` between
    fsync and rename); no-op unless a torn/bitflip entry is armed."""
    if _PLAN is None:
        return
    _PLAN.mangle_file(tmp, target)


# Environment pickup at import: spawned fleet ranks activate by env var
# alone, with no code changes in the worker.
configure(os.environ.get(ENV_FAULTS))

__all__ = ["ENV_FAULTS", "SITES", "FaultPlan", "enabled", "plan",
           "configure", "reset", "fire", "mangle_file"]
