"""Structured error taxonomy for the integrity + fault-tolerance layer.

Hierarchy (chosen so existing callers keep working):

  * :class:`IntegrityError` subclasses ``ValueError`` -- every pre-PR-10
    corruption check raised ``ValueError``, so ``except ValueError`` /
    ``pytest.raises(ValueError)`` call sites see no behaviour change,
    while new code can catch the precise class.
  * :class:`CommitTimeoutError` subclasses ``TimeoutError`` -- rank 0's
    manifest commit timed out before PR 10 too; the subclass carries the
    structured rollback report instead of a bare message.
  * :class:`InjectedFault` subclasses ``RuntimeError`` and is raised
    ONLY by :mod:`repro.faults.inject` -- seeing it outside a
    ``REPRO_FAULTS``-configured run is itself a bug.

Every class renders a message that names the damaged artifact (file,
variable, block index, expected/actual digest) so a fleet log line is
actionable without re-running under a debugger.
"""
from __future__ import annotations

from typing import List, Optional


class IntegrityError(ValueError):
    """A persisted artifact failed verification (checksum mismatch,
    truncation, unparseable header).  The read path raises this instead
    of returning silently wrong data."""


class CorruptBlockError(IntegrityError):
    """One variable (or one block of one variable) inside an NCK
    container failed its CRC-32 check."""

    def __init__(self, path: str, variable: str, block: Optional[int],
                 expected: int, actual: int):
        self.path = path
        self.variable = variable
        self.block = block
        self.expected = int(expected)
        self.actual = int(actual)
        where = (f"variable {variable!r}" if block is None
                 else f"variable {variable!r} block {block}")
        super().__init__(
            f"{path}: {where} checksum mismatch: expected "
            f"crc32=0x{self.expected:08x}, got 0x{self.actual:08x} "
            "(corrupt or torn write; refusing to decode)")


class CorruptShardError(IntegrityError):
    """A per-rank shard file referenced by an NCKM manifest is missing
    its recorded size/checksum, or failed structural verification."""

    def __init__(self, path: str, shard: str, rank: int, reason: str):
        self.path = path
        self.shard = shard
        self.rank = rank
        self.reason = reason
        super().__init__(
            f"manifest {path}: shard file {shard} (rank {rank}) failed "
            f"verification: {reason}")


class CommitTimeoutError(TimeoutError):
    """Rank 0's manifest commit exhausted its deadline.  ``report``
    carries the structured rollback state: which ranks never published,
    which published files were quarantined as corrupt, and the
    generation the logical file rolled back to (the previous durable
    manifest is untouched, byte for byte)."""

    def __init__(self, message: str, report: Optional[dict] = None):
        super().__init__(message)
        self.report = report or {}

    @property
    def missing_ranks(self) -> List[int]:
        return list(self.report.get("missing_ranks", []))

    @property
    def quarantined(self) -> List[str]:
        return list(self.report.get("quarantined", []))


class InjectedFault(RuntimeError):
    """Deliberate failure raised by an active fault-injection plan
    (``REPRO_FAULTS=`` / ``faults.inject.configure``)."""

    def __init__(self, site: str, detail: str = ""):
        self.site = site
        super().__init__(f"injected fault at site {site!r}"
                         + (f": {detail}" if detail else ""))


__all__ = ["IntegrityError", "CorruptBlockError", "CorruptShardError",
           "CommitTimeoutError", "InjectedFault"]
