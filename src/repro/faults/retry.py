"""Bounded, jittered exponential backoff -- the one retry schedule.

Every retry loop in ``src/repro`` must have a bounded attempt count and
a growing, jittered sleep (repro-lint's ``retry-discipline`` pass flags
unbounded ``while True: ... time.sleep(...)`` shapes).  This module is
the sanctioned way to write one:

    for delay in Backoff(attempts=5, base=0.1).delays():
        if try_thing():
            break
        time.sleep(delay)
    else:
        raise TimeoutError(...)

Jitter is multiplicative (up to ``jitter`` fractional extra) so a fleet
of ranks polling the same file does not phase-lock into thundering
herds; pass ``seed`` for a reproducible schedule in tests.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Iterator, Optional


@dataclass(frozen=True)
class Backoff:
    """``attempts`` sleeps starting at ``base`` seconds, multiplied by
    ``factor`` each time, capped at ``cap``, each stretched by up to
    ``jitter`` fractional random extra."""

    attempts: int = 5
    base: float = 0.05
    factor: float = 2.0
    cap: float = 2.0
    jitter: float = 0.25
    seed: Optional[int] = None

    def delays(self) -> Iterator[float]:
        rng = random.Random(self.seed)
        d = self.base
        for _ in range(max(1, self.attempts)):
            yield min(d, self.cap) * (1.0 + self.jitter * rng.random())
            d *= self.factor

    def sleep_until(self, deadline: float) -> Iterator[float]:
        """Delays clipped to a ``time.monotonic()`` deadline: yields until
        the deadline passes, then stops (the caller raises its structured
        timeout).  The final sleep never overshoots the deadline, so a
        0.3 s commit timeout still polls more than once."""
        for d in self.delays():
            left = deadline - time.monotonic()
            if left <= 0:
                return
            yield min(d, left)

    def repolling(self) -> "Backoff":
        """An unbounded-attempts view for deadline-bounded loops (the
        bound is the deadline, enforced by ``sleep_until``)."""
        return Backoff(attempts=1 << 30, base=self.base, factor=self.factor,
                       cap=self.cap, jitter=self.jitter, seed=self.seed)


__all__ = ["Backoff"]
