"""minicpm3-4b [dense, MLA] -- hf:openbmb/MiniCPM3-4B.

62L d_model=2560 40H (kv=40 via MLA latent) d_ff=6400 vocab=73448.
MLA dims follow the HF config: q_lora=768, kv_lora=256, qk_nope=64,
qk_rope=32, v_head=64.  Pure full attention -> long_500k skipped
(DESIGN.md Sec. 5; the MLA latent cache is small but attention is full).
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=6400, vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    rope_theta=10000.0,
    remat="block",
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG)
