"""mixtral-8x7b [moe] -- arXiv:2401.04088.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (4096).  SWA bounds the KV cache -> long_500k RUNS
for this arch (window 4096 cache regardless of context length).
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=14336, vocab_size=32000,
    attn_kind="gqa", rope_theta=1000000.0,
    sliding_window=4096,
    n_experts=8, moe_top_k=2,
    # SS Perf iteration (EXPERIMENTS.md): 8x2 = 16 expert slots -> clean
    # expert parallelism on the 16-way model axis (kills the ~90 GB/dev
    # per-step FSDP weight gathers)
    moe_ep_split=2,
    remat="block",
    supports_long_context=True,
)


def smoke():
    return reduced(CONFIG)
