"""musicgen-medium [audio] -- arXiv:2306.05284.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 (EnCodec codebook).
The EnCodec frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, S, d_model); the backbone is the
decoder-only transformer.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    attn_kind="gqa", rope_theta=10000.0,
    frontend="frames",
    # SS Perf iteration (EXPERIMENTS.md): 48 MHA layers with no remat save
    # every intermediate for backward -> train_4k memory term 10.7 s;
    # block remat trades ~1.3x FLOPs for a ~4x bytes reduction.
    remat="block",
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG, frontend="frames")
