"""Architecture registry: --arch <id> -> ModelConfig (full + smoke)."""
from __future__ import annotations

import importlib

ARCHS = {
    "minicpm3-4b": "minicpm3_4b",
    "llama3.2-1b": "llama3_2_1b",
    "qwen1.5-110b": "qwen1_5_110b",
    "deepseek-7b": "deepseek_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "musicgen-medium": "musicgen_medium",
    "mamba2-780m": "mamba2_780m",
    "paligemma-3b": "paligemma_3b",
    "hymba-1.5b": "hymba_1_5b",
}


def _module(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch_id]}")


def get_config(arch_id: str):
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str):
    return _module(arch_id).smoke()


def list_archs():
    return sorted(ARCHS)


__all__ = ["ARCHS", "get_config", "get_smoke_config", "list_archs"]
