"""mamba2-780m [ssm] -- arXiv:2405.21060 (SSD, state-space duality).

48L d_model=1536 attention-free, vocab=50280, ssm_state=128, expand=2
(d_inner=3072, 48 heads of head_dim 64), conv width 4, SSD chunk 256.
O(1)-state decode -> long_500k RUNS.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    attn_kind="none",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
    supports_long_context=True,
)


def smoke():
    return reduced(CONFIG, ssm_state=16, d_ff=0)
