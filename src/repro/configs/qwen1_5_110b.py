"""qwen1.5-110b [dense] -- Qwen1.5 family (QKV bias).

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.  head_dim=128.
The largest assigned arch: needs FSDP+TP 2-D weight sharding and block
remat to fit 16 GB/chip on the (16,16) mesh.  Full attention -> long_500k
skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    head_dim=128, d_ff=49152, vocab_size=152064,
    attn_kind="gqa", qkv_bias=True, rope_theta=1000000.0,
    remat="block",
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG, qkv_bias=True)
