"""deepseek-7b [dense] -- arXiv:2401.02954 (llama-arch, MHA).

30L d_model=4096 32H (GQA kv=32 = MHA) d_ff=11008 vocab=102400.
head_dim=128.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    head_dim=128, d_ff=11008, vocab_size=102400,
    attn_kind="gqa", rope_theta=10000.0,
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG, n_kv_heads=4)
