"""phi3.5-moe-42b-a6.6b [moe] -- hf:microsoft/Phi-3.5-MoE-instruct.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, 16 experts top-2.
16 experts == the 16-way model axis -> pure expert parallelism.
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    head_dim=128, d_ff=6400, vocab_size=32064,
    attn_kind="gqa", rope_theta=10000.0,
    n_experts=16, moe_top_k=2,
    remat="block",
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG)
