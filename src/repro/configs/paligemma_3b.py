"""paligemma-3b [vlm] -- arXiv:2407.07726 (SigLIP + gemma backbone).

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.  head_dim=256.
The SigLIP vision tower is a STUB per the assignment: input_specs()
provides 256 precomputed patch embeddings (B, 256, d_model); text tokens
attend with a prefix-LM mask (full over patches, causal over text).
Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    head_dim=256, d_ff=16384, vocab_size=257216,
    attn_kind="gqa", rope_theta=10000.0,
    frontend="patches", n_prefix=256,
    tie_embeddings=True,
    supports_long_context=False,
)


def smoke():
    return reduced(CONFIG, frontend="patches", n_prefix=8)
