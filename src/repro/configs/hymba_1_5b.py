"""hymba-1.5b [hybrid] -- arXiv:2411.13676 (parallel attn + mamba heads).

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention heads and SSD heads in PARALLEL on the same
input; the normalized branch outputs are averaged (paper Sec. 2.1; meta
tokens omitted, noted in DESIGN.md).  SWA(1024) everywhere except 3 global
layers {0, 15, 31} -> long_500k RUNS (bounded cache + SSM state).
ssm_expand=1 so the mamba branch also has 25 heads of dim 64.
"""
from repro.models.config import ModelConfig, reduced

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    attn_kind="gqa", rope_theta=10000.0,
    sliding_window=1024, global_attn_layers=(0, 15, 31),
    ssm_state=16, ssm_expand=1, ssm_head_dim=64, ssm_chunk=256,
    conv_width=4,
    supports_long_context=True,
)


def smoke():
    return reduced(CONFIG, n_heads=4, n_kv_heads=2, head_dim=16,
                   ssm_head_dim=16, ssm_expand=1, d_model=64)
