"""Phase 3 bit-packing: B-bit indices <-> byte streams (paper Sec. IV-C).

Layout: little-endian bitstream, LSB-first -- element j occupies stream bits
[j*B, (j+1)*B); stream bit t lives at bit (t % 8) of byte (t // 8).  Each
index-table *block* is packed independently and byte-aligned ("there may
exist several unused bits at the end of each index block").

Two implementations: jnp (device; also the oracle for the Pallas bitpack
kernel) and numpy (host finalize / decompression path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def packed_nbytes(n: int, b_bits: int) -> int:
    return (n * b_bits + 7) // 8


def pack_indices_jnp(idx: jax.Array, b_bits: int) -> jax.Array:
    """(n,) int32 -> (ceil(n*B/8),) uint8."""
    n = idx.shape[0]
    bits = (idx[:, None] >> jnp.arange(b_bits, dtype=jnp.int32)) & 1
    bits = bits.reshape(-1)
    pad = (-(n * b_bits)) % 8
    if pad:
        bits = jnp.pad(bits, (0, pad))
    weights = (1 << jnp.arange(8, dtype=jnp.int32))
    byts = (bits.reshape(-1, 8) * weights).sum(axis=-1)
    return byts.astype(jnp.uint8)


def unpack_indices_jnp(packed: jax.Array, n: int, b_bits: int) -> jax.Array:
    """(nbytes,) uint8 -> (n,) int32."""
    bits = (packed[:, None].astype(jnp.int32) >> jnp.arange(8)) & 1
    bits = bits.reshape(-1)[: n * b_bits].reshape(n, b_bits)
    weights = (1 << jnp.arange(b_bits, dtype=jnp.int32))
    return (bits * weights).sum(axis=-1).astype(jnp.int32)


def pack_indices_np(idx: np.ndarray, b_bits: int) -> np.ndarray:
    idx = np.asarray(idx, dtype=np.int64)
    bits = ((idx[:, None] >> np.arange(b_bits)) & 1).astype(np.uint8)
    return np.packbits(bits.reshape(-1), bitorder="little")


def unpack_indices_np(packed: np.ndarray, n: int, b_bits: int) -> np.ndarray:
    bits = np.unpackbits(np.asarray(packed, np.uint8), bitorder="little")
    bits = bits[: n * b_bits].reshape(n, b_bits).astype(np.int64)
    return (bits << np.arange(b_bits)).sum(axis=-1).astype(np.int32)


__all__ = ["packed_nbytes", "pack_indices_jnp", "unpack_indices_jnp",
           "pack_indices_np", "unpack_indices_np"]
