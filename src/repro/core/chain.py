"""ReferenceChain: one owner for the prev->recon temporal state.

The paper's temporal chain -- "reconstructed data of step i becomes the
reference of step i+1" (Sec. III) -- used to be an ad-hoc ndarray juggled
by every consumer (TemporalCompressor, ShardedCompressor,
CheckpointManager, serve sessions), and it always dropped to NumPy on the
host between steps.  This module makes the chain a first-class object
with two residencies:

  host    -- NumPy state, advanced by ``pipeline.reconstruct_from_indices``
             (the original behavior; also the fallback for dtypes the
             device cannot hold, e.g. float64 without jax_enable_x64).
  device  -- jax.Array state, advanced by the fused
             ``kernels.ops.chain_advance`` (dequantize + on-device
             exception patch), so the hottest loop in the codebase never
             round-trips through the host.

Both residencies are **bit-identical**: reconstruction arithmetic runs in
the source precision on every path (``pipeline.reconstruction_dtype``),
so a series compressed with a device chain emits byte-identical blobs to
the host chain.  ``to_host()`` is the one explicit boundary where state
is copied off the accelerator (durable writes: checkpoints, session
snapshots, user inspection).

The sharded driver subclasses :class:`ReferenceChain` with a mesh-resident
flavor (``distributed.pipeline``); this module holds the single-device
flavors plus the residency policy.
"""
from __future__ import annotations

import copy
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pipe
from repro.kernels import ops as kops

CHAIN_HOST = "host"
CHAIN_DEVICE = "device"
CHAIN_AUTO = "auto"
RESIDENCIES = (CHAIN_HOST, CHAIN_DEVICE, CHAIN_AUTO)


def device_supports(dtype) -> bool:
    """Can a device-resident chain hold `dtype` bit-exactly?

    f32 always; f64 only under jax_enable_x64 (without it jnp would
    silently downcast and the chain would drift from the host chain).
    Narrower floats compute in f32 but must *store* in their own dtype to
    stay bit-identical with the host chain's per-step rounding -- keep
    them on the host where that rounding is explicit.
    """
    dt = np.dtype(dtype)
    if dt == np.float32:
        return True
    if dt == np.float64:
        return bool(jax.config.jax_enable_x64)
    return False


def resolve_residency(requested: str, dtype) -> str:
    """Residency policy: honor an explicit choice, pick for "auto"."""
    if requested not in RESIDENCIES:
        raise ValueError(f"unknown chain residency {requested!r}; "
                         f"expected one of {RESIDENCIES}")
    if requested == CHAIN_HOST:
        return CHAIN_HOST
    supported = device_supports(dtype)
    if requested == CHAIN_DEVICE:
        if not supported:
            raise ValueError(
                f"device-resident chain cannot hold dtype {np.dtype(dtype)} "
                "bit-exactly (float64 needs jax_enable_x64); use "
                "chain='host' or 'auto'")
        return CHAIN_DEVICE
    return CHAIN_DEVICE if supported else CHAIN_HOST


class ReferenceChain:
    """Owns the prev->recon temporal state of one variable.

    Lifecycle: ``seed(arr)`` on the anchor step, then per delta step
    either ``advance(dev, curr)`` (REF_RECONSTRUCTED: R_i from the
    pre-entropy encode result) or ``replace(arr)`` (REF_ORIGINAL).
    ``peek()`` hands the state back to the driver's encode stage in the
    chain's own residency; ``to_host()`` is the explicit host-copy
    boundary.  Chains treat state arrays as immutable, so ``fork()`` is a
    cheap handle copy -- consumers that must stage an advance and commit
    it later (checkpoint durability ordering) fork, advance the fork, and
    swap it in after the write is durable.
    """

    residency: str = "?"

    def __init__(self):
        self._state: Optional[Any] = None

    @property
    def empty(self) -> bool:
        return self._state is None

    def reset(self) -> None:
        self._state = None

    def fork(self) -> "ReferenceChain":
        return copy.copy(self)

    # -- interface ---------------------------------------------------------
    def seed(self, arr) -> None:
        raise NotImplementedError

    def replace(self, arr) -> None:
        raise NotImplementedError

    def advance(self, dev: pipe.DeviceEncoded, curr) -> None:
        raise NotImplementedError

    def peek(self):
        return self._state

    def to_host(self) -> np.ndarray:
        raise NotImplementedError


class HostReferenceChain(ReferenceChain):
    """NumPy-resident chain (the original behavior)."""

    residency = CHAIN_HOST

    def seed(self, arr) -> None:
        # Private copy: callers may reuse/mutate their buffers.
        self._state = np.array(np.asarray(arr), copy=True)

    def replace(self, arr) -> None:
        self.seed(arr)

    def advance(self, dev: pipe.DeviceEncoded, curr) -> None:
        self._state = pipe.reconstruct_from_indices(
            self._state, dev.enc, dev.centers, self._state.dtype,
            curr=np.asarray(curr))

    def to_host(self) -> np.ndarray:
        # A writable *copy*: chains treat state as immutable (fork()
        # relies on it), so the live array must never escape.
        return self._state.copy()


class DeviceReferenceChain(ReferenceChain):
    """jax.Array-resident chain advanced by the fused dequantize kernel.

    ``use_pallas=None`` picks the Pallas lowering on TPU and the (bit-
    identical) gather lowering elsewhere -- interpret-mode Pallas is for
    kernel tests, not for a per-step hot loop on CPU hosts.
    """

    residency = CHAIN_DEVICE

    def __init__(self, use_pallas: Optional[bool] = None):
        super().__init__()
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        self._use_pallas = bool(use_pallas)
        self._shape: Optional[tuple] = None

    def seed(self, arr) -> None:
        if not device_supports(np.asarray(arr).dtype):
            raise ValueError(
                f"device chain cannot hold {np.asarray(arr).dtype} "
                "bit-exactly (float64 needs jax_enable_x64)")
        # jnp.array, not asarray: on CPU backends asarray can zero-copy
        # alias the caller's buffer, and callers are allowed to reuse
        # their buffers (same contract as the host chain's seed copy).
        self._state = jnp.array(arr)
        self._shape = self._state.shape

    def replace(self, arr) -> None:
        self.seed(arr)

    def advance(self, dev: pipe.DeviceEncoded, curr) -> None:
        idx = (dev.idx_dev if dev.idx_dev is not None
               else jnp.asarray(dev.enc.idx))
        curr_dev = (dev.curr_dev if dev.curr_dev is not None
                    else jnp.array(curr))     # private copy (see seed)
        # Centers are a float64 view of values already rounded to the data
        # dtype, so this cast is exact.
        centers = jnp.asarray(
            np.asarray(dev.centers).astype(self._state.dtype))
        new = kops.chain_advance(idx, self._state.reshape(-1),
                                 curr_dev.reshape(-1), centers,
                                 b_bits=dev.enc.b_bits,
                                 use_pallas=self._use_pallas)
        self._state = new.reshape(self._shape)

    def to_host(self) -> np.ndarray:
        # np.array (not asarray): jax may hand back a read-only zero-copy
        # view on CPU backends; to_host promises a writable private copy.
        return np.array(self._state)


def make_reference_chain(residency: str, dtype,
                         use_pallas: Optional[bool] = None
                         ) -> ReferenceChain:
    """Factory used by the single-device drivers (compressor, checkpoint)."""
    if resolve_residency(residency, dtype) == CHAIN_DEVICE:
        return DeviceReferenceChain(use_pallas=use_pallas)
    return HostReferenceChain()


# -- serve-side session state ----------------------------------------------

def tree_to_host(tree) -> Any:
    """Copy a pytree of (device) arrays to host numpy leaves."""
    return jax.tree_util.tree_map(np.asarray, tree)


class SessionChain:
    """Handle for device-resident session state (a pytree of jax.Arrays).

    The serve-side analogue of a ReferenceChain: decode caches, resume
    token and position stay on device between requests; ``to_host()`` is
    the explicit durable-write boundary (session snapshots to disk).
    """

    def __init__(self, tree: Dict[str, Any]):
        self._tree = tree

    def __getitem__(self, key: str):
        return self._tree[key]

    @property
    def tree(self) -> Dict[str, Any]:
        return self._tree

    def to_host(self) -> Dict[str, Any]:
        return tree_to_host(self._tree)


__all__ = ["ReferenceChain", "HostReferenceChain", "DeviceReferenceChain",
           "SessionChain", "make_reference_chain", "resolve_residency",
           "device_supports", "tree_to_host",
           "CHAIN_HOST", "CHAIN_DEVICE", "CHAIN_AUTO", "RESIDENCIES"]
