"""Auto-selection of the index length B (paper Sec. IV-B-2, Eq. 6).

    file_size(B) = 2^B * L  +  n * B / 8  +  n * alpha(B) * L

where alpha(B) is the incompressible ratio when keeping the top (2^B - 1)
candidate bins.  Every process holds the same global histogram, so the scan
over B needs no communication (paper: "no inter-process communication is
needed in this phase").

The model deliberately ignores the downstream ZLIB pass -- reproducing the
paper's known mis-prediction on Sedov-like data (Figs. 16/17, Table 9).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def estimated_file_sizes(counts_desc: jax.Array, n: int, elem_bytes: int,
                         b_max: int):
    """Eq. (6) for B in [1, b_max].  Returns float32 (b_max,) byte sizes."""
    m = counts_desc.shape[0]
    cum = jnp.cumsum(counts_desc.astype(jnp.float32))
    bs = jnp.arange(1, b_max + 1, dtype=jnp.float32)
    ks = jnp.minimum((2.0 ** bs - 1.0), float(m)).astype(jnp.int32)
    covered = cum[jnp.clip(ks - 1, 0, m - 1)]
    covered = jnp.where(ks > 0, covered, 0.0)
    incompressible = jnp.maximum(float(n) - covered, 0.0)
    center_bytes = (2.0 ** bs) * elem_bytes
    index_bytes = float(n) * bs / 8.0
    exception_bytes = incompressible * elem_bytes
    return center_bytes + index_bytes + exception_bytes


def choose_b(counts_desc: jax.Array, n: int, elem_bytes: int, b_max: int):
    """argmin_B file_size(B); returns (B int32, sizes (b_max,))."""
    sizes = estimated_file_sizes(counts_desc, n, elem_bytes, b_max)
    b = jnp.argmin(sizes).astype(jnp.int32) + 1
    return b, sizes


def choose_b_host(counts_desc: np.ndarray, n: int, elem_bytes: int,
                  b_max: int) -> int:
    sizes = np.asarray(
        estimated_file_sizes(jnp.asarray(counts_desc), n, elem_bytes, b_max))
    return int(np.argmin(sizes)) + 1


__all__ = ["estimated_file_sizes", "choose_b", "choose_b_host"]
