"""NUMARCK core: the paper's contribution as a composable JAX module."""
from repro.core.chain import (CHAIN_AUTO, CHAIN_DEVICE, CHAIN_HOST,
                              DeviceReferenceChain, HostReferenceChain,
                              ReferenceChain, SessionChain,
                              make_reference_chain, resolve_residency)
from repro.core.compress import (TemporalCompressor, TemporalDecompressor,
                                 compress_series, compress_step,
                                 decompress_series, decompress_step,
                                 encode_device, make_anchor)
from repro.core.container import NCKReader, NCKWriter
from repro.core.entropy import (codec_names, get_codec, register_codec)
from repro.core.partial import TemporalArchive, read_step_range
from repro.core.pipeline import (DeviceEncoded, EncodedIndices,
                                 finalize_step, reconstruction_dtype)
from repro.core.types import (CompressedStep, NumarckParams,
                              mean_error_rate)

__all__ = [
    "NumarckParams", "CompressedStep", "mean_error_rate",
    "compress_step", "decompress_step", "make_anchor", "encode_device",
    "compress_series", "decompress_series",
    "TemporalCompressor", "TemporalDecompressor",
    "ReferenceChain", "HostReferenceChain", "DeviceReferenceChain",
    "SessionChain", "make_reference_chain", "resolve_residency",
    "CHAIN_HOST", "CHAIN_DEVICE", "CHAIN_AUTO",
    "EncodedIndices", "DeviceEncoded", "finalize_step",
    "reconstruction_dtype",
    "codec_names", "get_codec", "register_codec",
    "NCKWriter", "NCKReader", "TemporalArchive", "read_step_range",
]
