"""Single-device NUMARCK compress / decompress driver.

Device (jit) stages:
  1. `_analyze`     -- ratios, candidate histogram, descending sort, auto-B
  2. `_encode_topk` -- rank LUT + per-element index assignment (top-k)
     `_encode_centers` -- nearest-center assignment (equal/log/kmeans)
Host finalize is the *shared* stage in ``core.pipeline`` (exception
compaction, parallel entropy coding via the ``core.entropy`` codec
registry, blob assembly); the sharded driver
(``repro.distributed.pipeline``) lands in the same finalize, so the two
paths emit byte-identical blobs.

`TemporalCompressor(overlap=True)` / `compress_series(..., overlap=True)`
double-buffer the device/host split (paper Sec. IV-C I/O overlap): the
device analyze/encode of step i+1 runs while a background thread runs the
host entropy stage of step i.  The REF_RECONSTRUCTED chain is a
``core.chain.ReferenceChain``: device-resident by default (f32, or f64
under jax_enable_x64) so R_i never leaves the accelerator between steps,
host-resident (``pipeline.reconstruct_from_indices``) otherwise --
byte-identical blobs either way.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import Future
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binning, blocks, entropy, ratios, select_b
from repro.core import chain as chainmod
from repro.core import pipeline as pipe
from repro.core.overlap import FinalizeQueue
from repro.core.pipeline import DeviceEncoded
from repro.kernels import ops as kops
from repro.kernels import rans
from repro.obs import telemetry
from repro.core.types import (CompressedStep, NumarckParams, REF_ORIGINAL,
                              REF_RECONSTRUCTED, STRATEGY_EQUAL,
                              STRATEGY_KMEANS, STRATEGY_LOG, STRATEGY_TOPK,
                              dtype_nbytes)


@partial(jax.jit, static_argnames=("max_bins", "b_max", "elem_bytes"))
def _analyze(prev, curr, error_bound, max_bins, b_max, elem_bytes):
    r, valid = ratios.change_ratios(prev, curr)
    lo, hi = ratios.ratio_range(r, valid)
    domain_lo, width = ratios.histogram_domain(lo, hi, error_bound, max_bins)
    bin_ids, ok = ratios.candidate_bin_ids(r, valid, domain_lo, width,
                                           max_bins)
    counts = binning.local_histogram(bin_ids, ok, max_bins)
    counts_desc, ids_desc = binning.sort_histogram(counts)
    b_auto, est_sizes = select_b.choose_b(counts_desc, r.shape[0], elem_bytes,
                                          b_max)
    return dict(ratios=r, valid=valid, bin_ids=bin_ids, counts=counts,
                counts_desc=counts_desc, ids_desc=ids_desc,
                domain_lo=domain_lo, width=width, b_auto=b_auto,
                est_sizes=est_sizes, lo=lo, hi=hi)


@partial(jax.jit, static_argnames=("b_bits", "k_eff", "max_bins"))
def _encode_topk(bin_ids, ids_desc, b_bits, k_eff, max_bins):
    marker = (1 << b_bits) - 1
    lut = binning.rank_lut(ids_desc[:k_eff], k_eff, max_bins)
    # rank_lut fills non-selected with k_eff; remap to the B-bit marker.
    ranks = lut[jnp.clip(bin_ids, 0, max_bins - 1)]
    ranks = jnp.where(ranks >= k_eff, marker, ranks)
    return jnp.where(bin_ids >= 0, ranks, marker).astype(jnp.int32)


@partial(jax.jit, static_argnames=("b_bits",))
def _encode_centers(r, valid, centers_sorted, error_bound, b_bits):
    marker = (1 << b_bits) - 1
    idx = binning.assign_nearest(r, valid, centers_sorted, error_bound)
    return jnp.where(idx >= centers_sorted.shape[0], marker, idx)


def make_anchor(arr: np.ndarray, params: NumarckParams) -> CompressedStep:
    """Losslessly stored first iteration (no previous step to diff against).

    Stored in entropy-coded *blocks* like the index table so that partial
    decompression works from iteration 0 onwards.
    """
    return pipe.finalize_anchor(arr, params)


def decode_anchor(step: CompressedStep) -> np.ndarray:
    raw = b"".join(entropy.decompress_blocks(step.index_blocks, step.codec))
    return np.frombuffer(raw, dtype=step.dtype).reshape(step.shape).copy()


def device_entropy_route(params: NumarckParams, n: int, b_bits: int) -> bool:
    """Route the entropy stage to the codec's device encoder?  Blobs are
    byte-identical either way; this is purely a wall-clock decision, so
    small payloads stay on the (cheaper-to-dispatch) host path."""
    if not params.device_entropy or params.codec == entropy.AUTO_CODEC:
        return False
    try:
        codec = entropy.get_codec(params.codec)
    except ValueError:
        return False
    return codec.device and n * b_bits // 8 >= rans.DEVICE_MIN_BYTES


def encode_device(prev, curr, params: NumarckParams,
                  need_host_idx: bool = True) -> DeviceEncoded:
    """Device stages for one step: analyze + strategy dispatch + indexing.

    `prev`/`curr` may be host ndarrays or device jax.Arrays (a
    device-resident ReferenceChain feeds its state straight back in
    without a host copy); the returned ``DeviceEncoded`` carries device
    handles of the index table and `curr` for the chain advance.

    ``need_host_idx=False`` (callers whose reference chain is
    device-resident) skips the host fetch of the index table when the
    device entropy stage also ran -- finalize then reads only the
    pre-compressed blobs and the compacted exceptions, so nothing
    host-side ever touches the table.
    """
    if not isinstance(prev, jax.Array):
        prev = np.asarray(prev)
    if not isinstance(curr, jax.Array):
        curr = np.asarray(curr)
    if prev.shape != curr.shape:
        raise ValueError("temporal steps must share a shape")
    ebytes = dtype_nbytes(curr.dtype)
    # Telemetry-enabled runs block after each device stage so span
    # durations mean "stage time", not "async dispatch time"; with
    # telemetry disabled dispatch stays fully asynchronous.
    tele = telemetry.enabled()
    with telemetry.span("encode.analyze", annotate=True) as sp_an:
        a = _analyze(prev.reshape(-1), curr.reshape(-1),
                     np.float32(params.error_bound), params.max_bins,
                     params.b_max, ebytes)
        if tele:
            jax.block_until_ready(a)

    with telemetry.span("encode.index", annotate=True,
                        strategy=params.strategy) as sp_idx:
        if params.strategy == STRATEGY_TOPK:
            b_bits = int(params.b_bits if params.b_bits is not None
                         else a["b_auto"])
            k_eff = min((1 << b_bits) - 1, params.max_bins)
            idx = _encode_topk(a["bin_ids"], a["ids_desc"], b_bits, k_eff,
                               params.max_bins)
            centers = pipe.topk_centers(np.asarray(a["ids_desc"]), k_eff,
                                        float(a["domain_lo"]),
                                        float(a["width"]))
        else:
            b_bits = int(params.b_bits if params.b_bits is not None else 8)
            k_eff = (1 << b_bits) - 1
            if params.strategy == STRATEGY_EQUAL:
                cs = binning.equal_width_centers(a["lo"], a["hi"], k_eff)
            elif params.strategy == STRATEGY_LOG:
                cs = binning.log_scale_centers(a["ratios"], a["valid"],
                                               k_eff)
            elif params.strategy == STRATEGY_KMEANS:
                k_km = min(k_eff, params.kmeans_max_k)
                cs = binning.kmeans_centers(a["counts"], a["domain_lo"],
                                            a["width"], k_km,
                                            params.kmeans_iters)
            else:  # pragma: no cover
                raise ValueError(params.strategy)
            cs = jnp.sort(cs)
            idx = _encode_centers(a["ratios"], a["valid"], cs,
                                  np.float32(params.error_bound), b_bits)
            centers = np.asarray(cs, np.float64)
        if tele:
            jax.block_until_ready(idx)

    centers = pipe.round_centers(centers, curr.dtype)
    n = int(np.prod(curr.shape))
    be = params.block_elems(b_bits)
    marker = (1 << b_bits) - 1
    # Exception compaction on device: finalize gathers values by position
    # instead of re-scanning the index table with a host mask.
    exc_counts = exc_pos = None
    with telemetry.span("encode.exceptions") as sp_exc:
        if n:
            exc_counts, exc_pos = kops.exception_compact(idx, n, marker, be)
    # Device entropy stage: pack + rANS-code the blocks on device; the
    # finalize consumes the finished blobs (byte-identical to the host
    # codec flavor, so routing never changes the file format).
    coded = coded_name = None
    with telemetry.span("encode.device_entropy", annotate=True) as sp_de:
        if device_entropy_route(params, n, b_bits):
            nblocks = -(-n // be)
            idx_pad = jnp.pad(idx, (0, nblocks * be - n),
                              constant_values=marker)
            coded = rans.compress_blocks_device(
                idx_pad, b_bits, nblocks, be, pool=entropy._shared_pool())
            coded_name = params.codec
    with telemetry.span("encode.idx_fetch") as sp_fetch:
        idx_host = (np.asarray(idx) if need_host_idx or coded is None
                    else None)
    enc = pipe.EncodedIndices(idx=idx_host, b_bits=b_bits,
                              block_elems=be, n=n,
                              entropy_coded=coded, entropy_codec=coded_name,
                              exc_positions=exc_pos,
                              exc_block_counts=exc_counts)
    meta = {"b_auto": int(a["b_auto"]),
            "est_sizes": np.asarray(a["est_sizes"]).tolist(),
            "ratio_min": float(a["lo"]), "ratio_max": float(a["hi"])}
    if tele:
        # Driver stage timings; finalize_step folds them into the
        # canonical per-step meta["telemetry"] record and pops this dict,
        # so the key never reaches the persisted container attrs.
        meta["telemetry"] = {
            "analyze_s": sp_an.duration,
            "encode_s": (sp_idx.duration + sp_exc.duration
                         + sp_fetch.duration),
            "device_entropy_s": sp_de.duration,
        }
    return DeviceEncoded(enc=enc, centers=centers,
                         domain_lo=float(a["domain_lo"]),
                         width=float(a["width"]), meta=meta,
                         idx_dev=idx,
                         curr_dev=curr if isinstance(curr, jax.Array)
                         else None)


def compress_step(prev: np.ndarray, curr: np.ndarray,
                  params: NumarckParams) -> CompressedStep:
    """Compress `curr` against the reference state `prev` (Eq. 1/4).

    `prev` is the original previous iteration in REF_ORIGINAL mode, or the
    previously *reconstructed* state in REF_RECONSTRUCTED mode (the
    TemporalCompressor picks the right one).
    """
    dev = encode_device(prev, curr, params, need_host_idx=False)
    return pipe.finalize_step(curr, dev.enc, dev.centers, dev.domain_lo,
                              dev.width, params, dev.meta)


def decompress_step(step: CompressedStep,
                    prev: Optional[np.ndarray]) -> np.ndarray:
    """Reconstruct R_i = R_{i-1} * (1 + center)  (corrected Eq. 4).

    Arithmetic runs in the step's source precision
    (``pipeline.reconstruction_dtype``) so the replayed chain is
    bit-identical to the compressor's reference chain, host- or
    device-resident, for float32 and float64 data alike.
    """
    if step.is_anchor:
        return decode_anchor(step)
    assert prev is not None, "non-anchor steps need the previous state"
    cdt = pipe.reconstruction_dtype(step.dtype)
    prev_flat = np.asarray(prev).reshape(-1).astype(cdt, copy=False)
    out = np.empty(step.n, dtype=cdt)
    marker = (1 << step.b_bits) - 1
    centers = np.concatenate([step.centers,
                              np.zeros(marker + 1 - step.centers.size)
                              ]).astype(cdt)
    ptr_base = step.incomp_block_offsets
    for bi, (s, e) in enumerate(blocks.block_slices(step.n,
                                                    step.block_elems)):
        idx = blocks.inflate_block(step.index_blocks[bi], e - s, step.b_bits,
                                   codec=step.codec_for_block(bi))
        comp = prev_flat[s:e] * (1 + centers[idx])
        mask = idx == marker
        if mask.any():
            start = int(ptr_base[bi])
            stop = start + int(mask.sum())
            comp[mask] = step.incomp_values[start:stop].astype(cdt)
        out[s:e] = comp
    return out.astype(step.dtype).reshape(step.shape)


class TemporalCompressor:
    """Streaming compressor over a temporal series (paper Sec. III).

    With ``overlap=True`` the host finalize of step i (entropy stage +
    blob assembly) runs on a background thread while the caller's next
    ``add``/``add_async`` drives the device encode of step i+1.  Results
    are identical to the serial path; only wall-clock changes.

    ``chain`` picks the residency of the prev->recon reference chain
    (``core.chain``): "auto" (default) keeps it device-resident whenever
    the device can hold the dtype bit-exactly, "host" forces the original
    NumPy chain, "device" forces the accelerator chain.  Blobs are
    byte-identical across residencies.
    """

    def __init__(self, params: NumarckParams = NumarckParams(),
                 overlap: bool = False, chain: str = chainmod.CHAIN_AUTO):
        if chain not in chainmod.RESIDENCIES:
            raise ValueError(f"unknown chain residency {chain!r}")
        self.params = params
        self.overlap = overlap
        self.chain = chain
        self._chain: Optional[chainmod.ReferenceChain] = None
        # Bounded at two in-flight finalizes (one executing + one queued),
        # so direct add_async callers get the same ~2-step host-memory
        # bound as compress_series / the sharded driver.
        self._q = FinalizeQueue(overlap)
        self._step = 0

    def add_async(self, arr: np.ndarray) -> "Future[CompressedStep]":
        """Device-encode `arr` now; return a future of the finalized step.

        The internal reference chain advances before returning, so the
        next call may be issued immediately.
        """
        arr = np.asarray(arr)
        step_i, self._step = self._step, self._step + 1
        if self._chain is None or self._chain.empty:
            self._chain = chainmod.make_reference_chain(self.chain,
                                                        arr.dtype)
            self._chain.seed(arr)
            return self._q.submit(pipe.finalize_anchor, arr.copy(),
                                  self.params,
                                  label=f"anchor step {step_i}")
        # One H2D of `curr`, reused by both the encode and the chain
        # advance when the chain lives on device.  jnp.array (a private
        # copy, never a zero-copy alias): the chain advance reads it
        # asynchronously after add_async returns, and callers are allowed
        # to reuse their buffers immediately.
        curr_in = (jnp.array(arr)
                   if self._chain.residency == chainmod.CHAIN_DEVICE
                   else arr)
        dev = encode_device(
            self._chain.peek(), curr_in, self.params,
            need_host_idx=self._chain.residency == chainmod.CHAIN_HOST)
        if self.params.reference == REF_RECONSTRUCTED:
            self._chain.advance(dev, arr)
        else:
            self._chain.replace(arr)
        # The background finalize reads `arr` (exception values); snapshot
        # it so callers may reuse/mutate their buffer immediately.
        curr = arr.copy() if self.overlap else arr
        return self._q.submit(pipe.finalize_step, curr, dev.enc,
                              dev.centers, dev.domain_lo, dev.width,
                              self.params, dev.meta,
                              label=f"finalize step {step_i}")

    def add(self, arr: np.ndarray) -> CompressedStep:
        return self.add_async(arr).result()

    def reference_state(self) -> Optional[np.ndarray]:
        """Host copy of the current chain state (None before the anchor).
        This is the only place the device-resident chain crosses to host;
        the hot loop never does."""
        if self._chain is None or self._chain.empty:
            return None
        return self._chain.to_host()

    def flush(self):
        """Block until every in-flight finalize has completed (re-raises
        the first background exception, if any)."""
        self._q.flush()

    def close(self):
        self._q.close()

    def reset(self):
        self._chain = None
        self._step = 0


class TemporalDecompressor:
    """Streaming decompressor; mirrors TemporalCompressor state chaining."""

    def __init__(self):
        self._state: Optional[np.ndarray] = None

    def add(self, step: CompressedStep) -> np.ndarray:
        self._state = decompress_step(step, self._state)
        return self._state

    def reset(self):
        self._state = None


def compress_series(arrays, params: NumarckParams = NumarckParams(),
                    overlap: bool = False,
                    chain: str = chainmod.CHAIN_AUTO) -> List[CompressedStep]:
    """Compress a temporal series; ``overlap=True`` double-buffers the
    device encode of step i+1 against the host finalize of step i.

    At most two finalizes are in flight at once, so host memory stays
    bounded at ~2 steps regardless of series length.
    """
    c = TemporalCompressor(params, overlap=overlap, chain=chain)
    out: List[CompressedStep] = []
    pending: deque = deque()
    try:
        for a in arrays:
            pending.append(c.add_async(a))
            while len(pending) > 2:
                out.append(pending.popleft().result())
        out.extend(f.result() for f in pending)
        return out
    finally:
        c.close()


def decompress_series(steps: List[CompressedStep]) -> List[np.ndarray]:
    d = TemporalDecompressor()
    return [d.add(s) for s in steps]


__all__ = ["compress_step", "decompress_step", "make_anchor", "decode_anchor",
           "encode_device", "device_entropy_route", "DeviceEncoded",
           "TemporalCompressor", "TemporalDecompressor", "compress_series",
           "decompress_series"]
