"""Single-device NUMARCK compress / decompress driver.

Device (jit) stages:
  1. `_analyze`     -- ratios, candidate histogram, descending sort, auto-B
  2. `_encode_topk` -- rank LUT + per-element index assignment (top-k)
     `_encode_centers` -- nearest-center assignment (equal/log/kmeans)
Host finalize is the *shared* stage in ``core.pipeline`` (exception
compaction, parallel entropy coding via the ``core.entropy`` codec
registry, blob assembly); the sharded driver
(``repro.distributed.pipeline``) lands in the same finalize, so the two
paths emit byte-identical blobs.

`TemporalCompressor(overlap=True)` / `compress_series(..., overlap=True)`
double-buffer the device/host split (paper Sec. IV-C I/O overlap): the
device analyze/encode of step i+1 runs while a background thread runs the
host entropy stage of step i.  The REF_RECONSTRUCTED chain is a
``core.chain.ReferenceChain``: device-resident by default (f32, or f64
under jax_enable_x64) so R_i never leaves the accelerator between steps,
host-resident (``pipeline.reconstruct_from_indices``) otherwise --
byte-identical blobs either way.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import Future
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binning, blocks, entropy, ratios, select_b
from repro.core import chain as chainmod
from repro.core import pipeline as pipe
from repro.core.overlap import FinalizeQueue
from repro.core.pipeline import DeviceEncoded
from repro.faults.errors import IntegrityError
from repro.kernels import ops as kops
from repro.kernels import rans
from repro.obs import telemetry
from repro.core.types import (CompressedStep, NumarckParams,
                              REF_RECONSTRUCTED, STRATEGY_EQUAL,
                              STRATEGY_KMEANS, STRATEGY_LOG, STRATEGY_TOPK,
                              dtype_nbytes)


@partial(jax.jit, static_argnames=("max_bins", "b_max", "elem_bytes"))
def _analyze(prev, curr, error_bound, max_bins, b_max, elem_bytes):
    r, valid = ratios.change_ratios(prev, curr)
    lo, hi = ratios.ratio_range(r, valid)
    domain_lo, width = ratios.histogram_domain(lo, hi, error_bound, max_bins)
    bin_ids, ok = ratios.candidate_bin_ids(r, valid, domain_lo, width,
                                           max_bins)
    counts = binning.local_histogram(bin_ids, ok, max_bins)
    counts_desc, ids_desc = binning.sort_histogram(counts)
    b_auto, est_sizes = select_b.choose_b(counts_desc, r.shape[0], elem_bytes,
                                          b_max)
    return dict(ratios=r, valid=valid, bin_ids=bin_ids, counts=counts,
                counts_desc=counts_desc, ids_desc=ids_desc,
                domain_lo=domain_lo, width=width, b_auto=b_auto,
                est_sizes=est_sizes, lo=lo, hi=hi)


@partial(jax.jit, static_argnames=("b_bits", "k_eff", "max_bins"))
def _encode_topk(bin_ids, ids_desc, b_bits, k_eff, max_bins):
    marker = (1 << b_bits) - 1
    lut = binning.rank_lut(ids_desc[:k_eff], k_eff, max_bins)
    # rank_lut fills non-selected with k_eff; remap to the B-bit marker.
    ranks = lut[jnp.clip(bin_ids, 0, max_bins - 1)]
    ranks = jnp.where(ranks >= k_eff, marker, ranks)
    return jnp.where(bin_ids >= 0, ranks, marker).astype(jnp.int32)


@partial(jax.jit, static_argnames=("b_bits",))
def _encode_centers(r, valid, centers_sorted, error_bound, b_bits):
    marker = (1 << b_bits) - 1
    idx = binning.assign_nearest(r, valid, centers_sorted, error_bound)
    return jnp.where(idx >= centers_sorted.shape[0], marker, idx)


def make_anchor(arr: np.ndarray, params: NumarckParams) -> CompressedStep:
    """Losslessly stored first iteration (no previous step to diff against).

    Stored in entropy-coded *blocks* like the index table so that partial
    decompression works from iteration 0 onwards.
    """
    return pipe.finalize_anchor(arr, params)


def decode_anchor(step: CompressedStep) -> np.ndarray:
    """Host reconstruction of a losslessly stored anchor step.  When the
    step qualifies for the device decode route the entropy stage runs as
    one block-group-parallel device scan (``decode_bytes_blocks_device``)
    and only the finished bytes cross back; otherwise the host codec
    registry inflates the blocks (pool-parallel)."""
    tele = telemetry.enabled()
    with telemetry.span("decode.entropy", annotate=True) as sp_e:
        if device_decode_route(step):
            flat = rans.decode_bytes_blocks_device(
                step.index_blocks, pool=entropy._shared_pool())
            raw = np.asarray(flat).tobytes()
        else:
            raw = b"".join(entropy.decompress_blocks(step.index_blocks,
                                                     step.codec))
    try:
        out = np.frombuffer(raw, dtype=step.dtype).reshape(step.shape).copy()
    except ValueError as e:
        # Blocks inflated "successfully" but to the wrong total size:
        # corruption the codec stream itself could not detect.
        raise IntegrityError(
            f"anchor decode produced {len(raw)} bytes, expected "
            f"{step.n * np.dtype(step.dtype).itemsize} for shape "
            f"{tuple(step.shape)} {step.dtype} ({e}) -- payload corrupt "
            "or truncated") from e
    if tele:
        _record_read(step, entropy_s=sp_e.duration,
                     device=device_decode_route(step))
    return out


def decode_anchor_device(step: CompressedStep) -> jax.Array:
    """Anchor decode that leaves the reconstruction on device (serve-tier
    session restore).  The entropy stage decodes on device and the bytes
    bitcast in place to ``step.dtype`` when the device can hold it
    bit-exactly; otherwise this falls back to the host decode plus one
    upload -- the result is identical either way."""
    dt = np.dtype(step.dtype)
    device_ok = (dt in (np.dtype(np.float32), np.dtype(np.int32),
                        np.dtype(np.uint32))
                 or (dt.itemsize == 8 and jax.config.jax_enable_x64))
    if not (device_ok and device_decode_route(step)):
        return jnp.asarray(decode_anchor(step))
    tele = telemetry.enabled()
    with telemetry.span("decode.entropy", annotate=True) as sp_e:
        flat = rans.decode_bytes_blocks_device(
            step.index_blocks, pool=entropy._shared_pool())
        out = jax.lax.bitcast_convert_type(
            flat.reshape(-1, dt.itemsize), dt).reshape(step.shape)
        if tele:
            jax.block_until_ready(out)
    if tele:
        _record_read(step, entropy_s=sp_e.duration, device=True)
    return out


def device_decode_route(step: CompressedStep) -> bool:
    """Route a read through the device decode pipeline?  The
    reconstruction is bit-identical either way (same IEEE ops, same
    blobs), so -- like ``device_entropy_route`` -- this is purely a
    wall-clock decision: homogeneous device-codec blocks and a payload
    big enough to amortize dispatch."""
    if step.block_codecs is not None:
        return False
    try:
        codec = entropy.get_codec(step.codec)
    except ValueError:
        return False
    if not codec.device:
        return False
    if step.is_anchor:
        nbytes = step.n * np.dtype(step.dtype).itemsize
    else:
        cdt = pipe.reconstruction_dtype(step.dtype)
        if cdt == np.float64 and not jax.config.jax_enable_x64:
            return False
        nbytes = step.n * step.b_bits // 8
    return nbytes >= rans.DEVICE_MIN_BYTES


def symbol_entropy_route(params: NumarckParams, b_bits: int,
                         k_eff: int) -> bool:
    """Use the symbol-level (v2/NCK3) coder for this step's blocks?
    Top-k only: the analyze stage's ``counts_desc`` is the exact global
    rank histogram there, and the dense {rank, marker} alphabet must fit
    the frequency budget (k_eff + 1 <= 2^SCALE_BITS)."""
    return (params.symbol_rans and params.strategy == STRATEGY_TOPK
            and k_eff + 1 <= rans.M)


def device_entropy_route(params: NumarckParams, n: int, b_bits: int) -> bool:
    """Route the entropy stage to the codec's device encoder?  Blobs are
    byte-identical either way; this is purely a wall-clock decision, so
    small payloads stay on the (cheaper-to-dispatch) host path."""
    if not params.device_entropy or params.codec == entropy.AUTO_CODEC:
        return False
    try:
        codec = entropy.get_codec(params.codec)
    except ValueError:
        return False
    return codec.device and n * b_bits // 8 >= rans.DEVICE_MIN_BYTES


def encode_device(prev, curr, params: NumarckParams,
                  need_host_idx: bool = True) -> DeviceEncoded:
    """Device stages for one step: analyze + strategy dispatch + indexing.

    `prev`/`curr` may be host ndarrays or device jax.Arrays (a
    device-resident ReferenceChain feeds its state straight back in
    without a host copy); the returned ``DeviceEncoded`` carries device
    handles of the index table and `curr` for the chain advance.

    ``need_host_idx=False`` (callers whose reference chain is
    device-resident) skips the host fetch of the index table when the
    device entropy stage also ran -- finalize then reads only the
    pre-compressed blobs and the compacted exceptions, so nothing
    host-side ever touches the table.
    """
    # Host-ndarray inputs are normalized in place -- no device round-trip.
    if not isinstance(prev, jax.Array):
        prev = np.asarray(prev)   # repro-lint: disable=host-sync-in-device-path
    if not isinstance(curr, jax.Array):
        curr = np.asarray(curr)   # repro-lint: disable=host-sync-in-device-path
    if prev.shape != curr.shape:
        raise ValueError("temporal steps must share a shape")
    ebytes = dtype_nbytes(curr.dtype)
    # Telemetry-enabled runs block after each device stage so span
    # durations mean "stage time", not "async dispatch time"; with
    # telemetry disabled dispatch stays fully asynchronous.
    tele = telemetry.enabled()
    with telemetry.span("encode.analyze", annotate=True) as sp_an:
        a = _analyze(prev.reshape(-1), curr.reshape(-1),
                     np.float32(params.error_bound), params.max_bins,
                     params.b_max, ebytes)
        if tele:
            jax.block_until_ready(a)

    with telemetry.span("encode.index", annotate=True,
                        strategy=params.strategy) as sp_idx:
        if params.strategy == STRATEGY_TOPK:
            b_bits = int(params.b_bits if params.b_bits is not None
                         else a["b_auto"])
            k_eff = min((1 << b_bits) - 1, params.max_bins)
            idx = _encode_topk(a["bin_ids"], a["ids_desc"], b_bits, k_eff,
                               params.max_bins)
            centers = pipe.topk_centers(np.asarray(a["ids_desc"]), k_eff,
                                        float(a["domain_lo"]),
                                        float(a["width"]))
        else:
            b_bits = int(params.b_bits if params.b_bits is not None else 8)
            k_eff = (1 << b_bits) - 1
            if params.strategy == STRATEGY_EQUAL:
                cs = binning.equal_width_centers(a["lo"], a["hi"], k_eff)
            elif params.strategy == STRATEGY_LOG:
                cs = binning.log_scale_centers(a["ratios"], a["valid"],
                                               k_eff)
            elif params.strategy == STRATEGY_KMEANS:
                k_km = min(k_eff, params.kmeans_max_k)
                cs = binning.kmeans_centers(a["counts"], a["domain_lo"],
                                            a["width"], k_km,
                                            params.kmeans_iters)
            else:  # pragma: no cover
                raise ValueError(params.strategy)
            cs = jnp.sort(cs)
            idx = _encode_centers(a["ratios"], a["valid"], cs,
                                  np.float32(params.error_bound), b_bits)
            centers = np.asarray(cs, np.float64)
        if tele:
            jax.block_until_ready(idx)

    centers = pipe.round_centers(centers, curr.dtype)
    n = int(np.prod(curr.shape))
    be = params.block_elems(b_bits)
    marker = (1 << b_bits) - 1
    # Exception compaction on device: finalize gathers values by position
    # instead of re-scanning the index table with a host mask.
    exc_counts = exc_pos = None
    with telemetry.span("encode.exceptions") as sp_exc:
        if n:
            exc_counts, exc_pos = kops.exception_compact(idx, n, marker, be)
    # Device entropy stage: pack + rANS-code the blocks on device; the
    # finalize consumes the finished blobs (byte-identical to the host
    # codec flavor, so routing never changes the file format).
    coded = coded_name = None
    with telemetry.span("encode.device_entropy", annotate=True) as sp_de:
        if device_entropy_route(params, n, b_bits):
            nblocks = -(-n // be)
            idx_pad = jnp.pad(idx, (0, nblocks * be - n),
                              constant_values=marker)
            if symbol_entropy_route(params, b_bits, k_eff):
                counts_ranks = np.asarray(a["counts_desc"])[:k_eff]
                coded = rans.compress_blocks_device_symbols(
                    idx_pad, b_bits, k_eff, nblocks, be, counts_ranks,
                    pool=entropy._shared_pool())
            else:
                coded = rans.compress_blocks_device(
                    idx_pad, b_bits, nblocks, be,
                    pool=entropy._shared_pool())
            coded_name = params.codec
    with telemetry.span("encode.idx_fetch") as sp_fetch:
        # The one designed host fetch of the table; skipped entirely when
        # the caller's chain is device-resident (need_host_idx=False).
        # repro-lint: disable=host-sync-in-device-path
        idx_host = (np.asarray(idx) if need_host_idx or coded is None
                    else None)
    enc = pipe.EncodedIndices(idx=idx_host, b_bits=b_bits,
                              block_elems=be, n=n,
                              entropy_coded=coded, entropy_codec=coded_name,
                              exc_positions=exc_pos,
                              exc_block_counts=exc_counts)
    meta = {"b_auto": int(a["b_auto"]),
            "est_sizes": np.asarray(a["est_sizes"]).tolist(),
            "ratio_min": float(a["lo"]), "ratio_max": float(a["hi"])}
    if tele:
        # Driver stage timings; finalize_step folds them into the
        # canonical per-step meta["telemetry"] record and pops this dict,
        # so the key never reaches the persisted container attrs.
        meta["telemetry"] = {
            "analyze_s": sp_an.duration,
            "encode_s": (sp_idx.duration + sp_exc.duration
                         + sp_fetch.duration),
            "device_entropy_s": sp_de.duration,
        }
    return DeviceEncoded(enc=enc, centers=centers,
                         domain_lo=float(a["domain_lo"]),
                         width=float(a["width"]), meta=meta,
                         idx_dev=idx,
                         curr_dev=curr if isinstance(curr, jax.Array)
                         else None)


def compress_step(prev: np.ndarray, curr: np.ndarray,
                  params: NumarckParams) -> CompressedStep:
    """Compress `curr` against the reference state `prev` (Eq. 1/4).

    `prev` is the original previous iteration in REF_ORIGINAL mode, or the
    previously *reconstructed* state in REF_RECONSTRUCTED mode (the
    TemporalCompressor picks the right one).
    """
    dev = encode_device(prev, curr, params, need_host_idx=False)
    return pipe.finalize_step(curr, dev.enc, dev.centers, dev.domain_lo,
                              dev.width, params, dev.meta)


def _record_read(step: CompressedStep, entropy_s: float = 0.0,
                 dequant_s: float = 0.0, patch_s: float = 0.0,
                 fetch_s: float = 0.0, device: bool = False) -> None:
    """Fold the decode-side span durations into the canonical per-read
    telemetry record (``obs.report.READ_TELEMETRY_KEYS``), identical
    across the single-device, sharded, and anchor read paths."""
    from repro.obs import report
    rec = {"entropy_s": entropy_s, "dequant_s": dequant_s,
           "patch_s": patch_s, "fetch_s": fetch_s,
           "bytes_in": int(sum(len(b) for b in step.index_blocks)),
           "bytes_out": int(step.n) * np.dtype(step.dtype).itemsize,
           "codec": step.codec, "device_decode": bool(device)}
    assert tuple(rec) == report.READ_TELEMETRY_KEYS
    step.meta["telemetry_read"] = rec


def _decode_index_host(step: CompressedStep) -> np.ndarray:
    """Inflate every index block of a step into one preallocated (n,)
    int32 buffer, block-parallel over the shared entropy pool for
    payloads worth the dispatch."""
    idx = np.empty(step.n, np.int32)
    slices = list(blocks.block_slices(step.n, step.block_elems))

    def inflate(bi: int) -> None:
        s, e = slices[bi]
        idx[s:e] = blocks.inflate_block(step.index_blocks[bi], e - s,
                                        step.b_bits,
                                        codec=step.codec_for_block(bi))

    payload = sum(len(b) for b in step.index_blocks)
    if len(slices) > 1 and payload >= entropy._MIN_PARALLEL_BYTES:
        list(entropy._shared_pool().map(inflate, range(len(slices))))
    else:
        for bi in range(len(slices)):
            inflate(bi)
    return idx


def _centers_lut(step: CompressedStep, cdt) -> np.ndarray:
    marker = (1 << step.b_bits) - 1
    return np.concatenate([step.centers,
                           np.zeros(marker + 1 - step.centers.size)
                           ]).astype(cdt)


def decompress_step_device(step: CompressedStep, prev) -> jax.Array:
    """Device-resident reconstruction of one delta step: blob -> device
    rANS decode -> fused dequantize -> exception patch, zero host round
    trips.  ``prev`` may be a host ndarray or a device array (the
    device-resident decompressor chain feeds its state straight back).
    Returns the reconstruction as a (step.shape) device array of the
    source dtype; bit-identical to the host ``decompress_step`` by the
    same argument as the encode side (same IEEE ops on the same data).
    """
    assert prev is not None, "non-anchor steps need the previous state"
    tele = telemetry.enabled()
    cdt = pipe.reconstruction_dtype(step.dtype)
    with telemetry.span("decode.entropy", annotate=True) as sp_e:
        idx2d = rans.decode_blocks_device(step.index_blocks, step.b_bits,
                                          step.block_elems,
                                          pool=entropy._shared_pool())
        idx = idx2d.reshape(-1)[:step.n]
        if tele:
            jax.block_until_ready(idx)
    with telemetry.span("decode.dequant", annotate=True) as sp_d:
        prev_dev = jnp.asarray(prev).reshape(-1).astype(cdt)
        centers = jnp.asarray(_centers_lut(step, cdt))
        recon = kops.dequantize(idx, prev_dev, centers, b_bits=step.b_bits,
                                use_pallas=not kops._interpret())
        if tele:
            jax.block_until_ready(recon)
    with telemetry.span("decode.patch", annotate=True) as sp_p:
        if step.n_incompressible:
            recon = kops.patch_exceptions(recon, idx,
                                          jnp.asarray(step.incomp_values),
                                          b_bits=step.b_bits)
        out = recon.astype(step.dtype).reshape(step.shape)
        if tele:
            jax.block_until_ready(out)
    if tele:
        _record_read(step, entropy_s=sp_e.duration, dequant_s=sp_d.duration,
                     patch_s=sp_p.duration, device=True)
    return out


def decompress_step(step: CompressedStep,
                    prev: Optional[np.ndarray]) -> np.ndarray:
    """Reconstruct R_i = R_{i-1} * (1 + center)  (corrected Eq. 4).

    Arithmetic runs in the step's source precision
    (``pipeline.reconstruction_dtype``) so the replayed chain is
    bit-identical to the compressor's reference chain, host- or
    device-resident, for float32 and float64 data alike.  Steps that
    qualify for the device decode route (``device_decode_route``) run
    blob -> device rANS decode -> fused dequantize -> exception patch
    with one final fetch; everything else takes the pool-parallel host
    path.  Results are bit-identical across routes.
    """
    if step.is_anchor:
        return decode_anchor(step)
    if device_decode_route(step):
        dev = decompress_step_device(step, prev)
        with telemetry.span("decode.fetch", annotate=True) as sp_f:
            out = np.asarray(dev)
        if telemetry.enabled() and "telemetry_read" in step.meta:
            step.meta["telemetry_read"]["fetch_s"] = sp_f.duration
        return out
    assert prev is not None, "non-anchor steps need the previous state"
    tele = telemetry.enabled()
    cdt = pipe.reconstruction_dtype(step.dtype)
    marker = (1 << step.b_bits) - 1
    with telemetry.span("decode.entropy", annotate=True) as sp_e:
        idx = _decode_index_host(step)
    with telemetry.span("decode.dequant", annotate=True) as sp_d:
        prev_flat = np.asarray(prev).reshape(-1).astype(cdt, copy=False)
        centers = _centers_lut(step, cdt)
        out = prev_flat * (1 + centers[idx])
    with telemetry.span("decode.patch", annotate=True) as sp_p:
        if step.n_incompressible:
            # Exception values are compacted in stream order == block
            # order, so one global boolean scatter equals the per-block
            # patch loop.
            out[idx == marker] = step.incomp_values.astype(cdt)
    if tele:
        _record_read(step, entropy_s=sp_e.duration, dequant_s=sp_d.duration,
                     patch_s=sp_p.duration, device=False)
    return out.astype(step.dtype).reshape(step.shape)


class TemporalCompressor:
    """Streaming compressor over a temporal series (paper Sec. III).

    With ``overlap=True`` the host finalize of step i (entropy stage +
    blob assembly) runs on a background thread while the caller's next
    ``add``/``add_async`` drives the device encode of step i+1.  Results
    are identical to the serial path; only wall-clock changes.

    ``chain`` picks the residency of the prev->recon reference chain
    (``core.chain``): "auto" (default) keeps it device-resident whenever
    the device can hold the dtype bit-exactly, "host" forces the original
    NumPy chain, "device" forces the accelerator chain.  Blobs are
    byte-identical across residencies.
    """

    def __init__(self, params: NumarckParams = NumarckParams(),
                 overlap: bool = False, chain: str = chainmod.CHAIN_AUTO):
        if chain not in chainmod.RESIDENCIES:
            raise ValueError(f"unknown chain residency {chain!r}")
        self.params = params
        self.overlap = overlap
        self.chain = chain
        self._chain: Optional[chainmod.ReferenceChain] = None
        # Bounded at two in-flight finalizes (one executing + one queued),
        # so direct add_async callers get the same ~2-step host-memory
        # bound as compress_series / the sharded driver.
        self._q = FinalizeQueue(overlap)
        self._step = 0

    def add_async(self, arr: np.ndarray) -> "Future[CompressedStep]":
        """Device-encode `arr` now; return a future of the finalized step.

        The internal reference chain advances before returning, so the
        next call may be issued immediately.
        """
        arr = np.asarray(arr)
        step_i, self._step = self._step, self._step + 1
        if self._chain is None or self._chain.empty:
            self._chain = chainmod.make_reference_chain(self.chain,
                                                        arr.dtype)
            self._chain.seed(arr)
            return self._q.submit(pipe.finalize_anchor, arr.copy(),
                                  self.params,
                                  label=f"anchor step {step_i}")
        # One H2D of `curr`, reused by both the encode and the chain
        # advance when the chain lives on device.  jnp.array (a private
        # copy, never a zero-copy alias): the chain advance reads it
        # asynchronously after add_async returns, and callers are allowed
        # to reuse their buffers immediately.
        curr_in = (jnp.array(arr)
                   if self._chain.residency == chainmod.CHAIN_DEVICE
                   else arr)
        dev = encode_device(
            self._chain.peek(), curr_in, self.params,
            need_host_idx=self._chain.residency == chainmod.CHAIN_HOST)
        if self.params.reference == REF_RECONSTRUCTED:
            self._chain.advance(dev, arr)
        else:
            self._chain.replace(arr)
        # The background finalize reads `arr` (exception values); snapshot
        # it so callers may reuse/mutate their buffer immediately.
        curr = arr.copy() if self.overlap else arr
        return self._q.submit(pipe.finalize_step, curr, dev.enc,
                              dev.centers, dev.domain_lo, dev.width,
                              self.params, dev.meta,
                              label=f"finalize step {step_i}")

    def add(self, arr: np.ndarray) -> CompressedStep:
        return self.add_async(arr).result()

    def reference_state(self) -> Optional[np.ndarray]:
        """Host copy of the current chain state (None before the anchor).
        This is the only place the device-resident chain crosses to host;
        the hot loop never does."""
        if self._chain is None or self._chain.empty:
            return None
        return self._chain.to_host()

    def flush(self):
        """Block until every in-flight finalize has completed (re-raises
        the first background exception, if any)."""
        self._q.flush()

    def close(self):
        self._q.close()

    def reset(self):
        self._chain = None
        self._step = 0


class TemporalDecompressor:
    """Streaming decompressor; mirrors TemporalCompressor state chaining.

    When consecutive steps qualify for the device decode route the chain
    state stays device-resident between steps (the next step's dequantize
    reads it without an upload); ``add`` still returns a host ndarray.
    Mixed routes are fine -- the state crosses the boundary at most once
    per route switch, and reconstructions are bit-identical throughout
    (the state round-trips through the source dtype each step on both
    routes).
    """

    def __init__(self):
        self._state = None          # np.ndarray or device jax.Array

    def add(self, step: CompressedStep) -> np.ndarray:
        if not step.is_anchor and device_decode_route(step):
            self._state = decompress_step_device(step, self._state)
            with telemetry.span("decode.fetch", annotate=True) as sp_f:
                out = np.asarray(self._state)
            if telemetry.enabled() and "telemetry_read" in step.meta:
                step.meta["telemetry_read"]["fetch_s"] = sp_f.duration
            return out
        prev = (np.asarray(self._state)
                if isinstance(self._state, jax.Array) else self._state)
        self._state = decompress_step(step, prev)
        return self._state

    def reset(self):
        self._state = None


def compress_series(arrays, params: NumarckParams = NumarckParams(),
                    overlap: bool = False,
                    chain: str = chainmod.CHAIN_AUTO) -> List[CompressedStep]:
    """Compress a temporal series; ``overlap=True`` double-buffers the
    device encode of step i+1 against the host finalize of step i.

    At most two finalizes are in flight at once, so host memory stays
    bounded at ~2 steps regardless of series length.
    """
    c = TemporalCompressor(params, overlap=overlap, chain=chain)
    out: List[CompressedStep] = []
    pending: deque = deque()
    try:
        for a in arrays:
            pending.append(c.add_async(a))
            while len(pending) > 2:
                out.append(pending.popleft().result())
        out.extend(f.result() for f in pending)
        return out
    finally:
        c.close()


def decompress_series(steps: List[CompressedStep]) -> List[np.ndarray]:
    d = TemporalDecompressor()
    return [d.add(s) for s in steps]


__all__ = ["compress_step", "decompress_step", "decompress_step_device",
           "make_anchor", "decode_anchor", "decode_anchor_device",
           "encode_device", "device_entropy_route", "device_decode_route",
           "symbol_entropy_route", "DeviceEncoded",
           "TemporalCompressor", "TemporalDecompressor", "compress_series",
           "decompress_series"]
