"""Single-device NUMARCK compress / decompress orchestration.

Device (jit) stages:
  1. `_analyze`     -- ratios, candidate histogram, descending sort, auto-B
  2. `_encode_topk` -- rank LUT + per-element index assignment (top-k)
     `_encode_centers` -- nearest-center assignment (equal/log/kmeans)
Host finalize: exception compaction (original dtype), per-block bit-pack +
ZLIB, blob assembly.  The distributed pipeline (repro.distributed.pipeline)
re-uses stages 1-2 inside shard_map.
"""
from __future__ import annotations

import zlib
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binning, blocks, ratios, select_b
from repro.core.types import (CompressedStep, NumarckParams, REF_ORIGINAL,
                              REF_RECONSTRUCTED, STRATEGY_EQUAL,
                              STRATEGY_KMEANS, STRATEGY_LOG, STRATEGY_TOPK,
                              dtype_nbytes)


@partial(jax.jit, static_argnames=("max_bins", "b_max", "elem_bytes"))
def _analyze(prev, curr, error_bound, max_bins, b_max, elem_bytes):
    r, valid = ratios.change_ratios(prev, curr)
    lo, hi = ratios.ratio_range(r, valid)
    domain_lo, width = ratios.histogram_domain(lo, hi, error_bound, max_bins)
    bin_ids, ok = ratios.candidate_bin_ids(r, valid, domain_lo, width,
                                           max_bins)
    counts = binning.local_histogram(bin_ids, ok, max_bins)
    counts_desc, ids_desc = binning.sort_histogram(counts)
    b_auto, est_sizes = select_b.choose_b(counts_desc, r.shape[0], elem_bytes,
                                          b_max)
    return dict(ratios=r, valid=valid, bin_ids=bin_ids, counts=counts,
                counts_desc=counts_desc, ids_desc=ids_desc,
                domain_lo=domain_lo, width=width, b_auto=b_auto,
                est_sizes=est_sizes, lo=lo, hi=hi)


@partial(jax.jit, static_argnames=("b_bits", "k_eff", "max_bins"))
def _encode_topk(bin_ids, ids_desc, b_bits, k_eff, max_bins):
    marker = (1 << b_bits) - 1
    lut = binning.rank_lut(ids_desc[:k_eff], k_eff, max_bins)
    # rank_lut fills non-selected with k_eff; remap to the B-bit marker.
    ranks = lut[jnp.clip(bin_ids, 0, max_bins - 1)]
    ranks = jnp.where(ranks >= k_eff, marker, ranks)
    return jnp.where(bin_ids >= 0, ranks, marker).astype(jnp.int32)


@partial(jax.jit, static_argnames=("b_bits",))
def _encode_centers(r, valid, centers_sorted, error_bound, b_bits):
    marker = (1 << b_bits) - 1
    idx = binning.assign_nearest(r, valid, centers_sorted, error_bound)
    return jnp.where(idx >= centers_sorted.shape[0], marker, idx)


def make_anchor(arr: np.ndarray, params: NumarckParams) -> CompressedStep:
    """Losslessly stored first iteration (no previous step to diff against).

    Stored in deflated *blocks* like the index table so that partial
    decompression works from iteration 0 onwards.
    """
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    block_elems = max(1, params.block_bytes // flat.dtype.itemsize)
    blks = []
    for s, e in blocks.block_slices(flat.size, block_elems):
        blks.append(zlib.compress(flat[s:e].tobytes(), params.zlib_level))
    return CompressedStep(
        n=arr.size, shape=tuple(arr.shape), dtype=str(arr.dtype),
        b_bits=0, error_bound=params.error_bound, strategy=params.strategy,
        reference=params.reference, domain_lo=0.0, bin_width=0.0,
        centers=np.zeros(0), block_elems=block_elems, index_blocks=blks,
        meta={"kind": "anchor"})


def decode_anchor(step: CompressedStep) -> np.ndarray:
    raw = b"".join(zlib.decompress(b) for b in step.index_blocks)
    return np.frombuffer(raw, dtype=step.dtype).reshape(step.shape).copy()


def compress_step(prev: np.ndarray, curr: np.ndarray,
                  params: NumarckParams) -> CompressedStep:
    """Compress `curr` against the reference state `prev` (Eq. 1/4).

    `prev` is the original previous iteration in REF_ORIGINAL mode, or the
    previously *reconstructed* state in REF_RECONSTRUCTED mode (the
    TemporalCompressor picks the right one).
    """
    prev = np.asarray(prev)
    curr = np.asarray(curr)
    if prev.shape != curr.shape:
        raise ValueError("temporal steps must share a shape")
    n = curr.size
    ebytes = dtype_nbytes(curr.dtype)
    a = _analyze(prev.reshape(-1), curr.reshape(-1),
                 np.float32(params.error_bound), params.max_bins,
                 params.b_max, ebytes)

    if params.strategy == STRATEGY_TOPK:
        b_bits = int(params.b_bits if params.b_bits is not None
                     else a["b_auto"])
        k_eff = min((1 << b_bits) - 1, params.max_bins)
        idx = _encode_topk(a["bin_ids"], a["ids_desc"], b_bits, k_eff,
                           params.max_bins)
        sel = np.asarray(a["ids_desc"][:k_eff])
        centers = (np.float64(a["domain_lo"])
                   + (sel.astype(np.float64) + 0.5) * np.float64(a["width"]))
    else:
        b_bits = int(params.b_bits if params.b_bits is not None else 8)
        k_eff = (1 << b_bits) - 1
        if params.strategy == STRATEGY_EQUAL:
            cs = binning.equal_width_centers(a["lo"], a["hi"], k_eff)
        elif params.strategy == STRATEGY_LOG:
            cs = binning.log_scale_centers(a["ratios"], a["valid"], k_eff)
        elif params.strategy == STRATEGY_KMEANS:
            k_km = min(k_eff, params.kmeans_max_k)
            cs = binning.kmeans_centers(a["counts"], a["domain_lo"],
                                        a["width"], k_km,
                                        params.kmeans_iters)
        else:  # pragma: no cover
            raise ValueError(params.strategy)
        cs = jnp.sort(cs)
        idx = _encode_centers(a["ratios"], a["valid"], cs,
                              np.float32(params.error_bound), b_bits)
        centers = np.asarray(cs, np.float64)

    # Paper stores bin centers in the data's own float type (Fig. 2); round
    # now so in-memory and from-file reconstructions agree bit-exactly.
    centers = centers.astype(curr.dtype).astype(np.float64)

    idx_np = np.asarray(idx)
    marker = (1 << b_bits) - 1
    incomp_mask = idx_np == marker
    incomp_values = curr.reshape(-1)[incomp_mask]

    block_elems = params.block_elems(b_bits)
    blks, raw_sizes, incomp_off = blocks.deflate_blocks(
        idx_np, b_bits, block_elems, params.zlib_level)

    return CompressedStep(
        n=n, shape=tuple(curr.shape), dtype=str(curr.dtype), b_bits=b_bits,
        error_bound=params.error_bound, strategy=params.strategy,
        reference=params.reference, domain_lo=float(a["domain_lo"]),
        bin_width=float(a["width"]),
        centers=centers[:marker] if centers.size > marker else centers,
        block_elems=block_elems, index_blocks=blks,
        index_block_nbytes=raw_sizes, incomp_values=incomp_values,
        incomp_block_offsets=incomp_off,
        meta={
            "b_auto": int(a["b_auto"]),
            "est_sizes": np.asarray(a["est_sizes"]).tolist(),
            "ratio_min": float(a["lo"]), "ratio_max": float(a["hi"]),
            "zlib_ratio": blocks.zlib_ratio(blks, raw_sizes),
        })


def decompress_step(step: CompressedStep,
                    prev: Optional[np.ndarray]) -> np.ndarray:
    """Reconstruct R_i = R_{i-1} * (1 + center)  (corrected Eq. 4)."""
    if step.is_anchor:
        return decode_anchor(step)
    assert prev is not None, "non-anchor steps need the previous state"
    prev_flat = np.asarray(prev, np.float64).reshape(-1)
    out = np.empty(step.n, dtype=np.float64)
    marker = (1 << step.b_bits) - 1
    centers = np.concatenate([step.centers,
                              np.zeros(marker + 1 - step.centers.size)])
    ptr_base = step.incomp_block_offsets
    for bi, (s, e) in enumerate(blocks.block_slices(step.n,
                                                    step.block_elems)):
        idx = blocks.inflate_block(step.index_blocks[bi], e - s, step.b_bits)
        comp = prev_flat[s:e] * (1.0 + centers[idx])
        mask = idx == marker
        if mask.any():
            start = int(ptr_base[bi])
            stop = start + int(mask.sum())
            comp[mask] = step.incomp_values[start:stop].astype(np.float64)
        out[s:e] = comp
    return out.astype(step.dtype).reshape(step.shape)


class TemporalCompressor:
    """Streaming compressor over a temporal series (paper Sec. III)."""

    def __init__(self, params: NumarckParams = NumarckParams()):
        self.params = params
        self._state: Optional[np.ndarray] = None

    def add(self, arr: np.ndarray) -> CompressedStep:
        arr = np.asarray(arr)
        if self._state is None:
            step = make_anchor(arr, self.params)
            self._state = arr.copy()
            return step
        step = compress_step(self._state, arr, self.params)
        if self.params.reference == REF_RECONSTRUCTED:
            self._state = decompress_step(step, self._state)
        else:
            self._state = arr.copy()
        return step

    def reset(self):
        self._state = None


class TemporalDecompressor:
    """Streaming decompressor; mirrors TemporalCompressor state chaining."""

    def __init__(self):
        self._state: Optional[np.ndarray] = None

    def add(self, step: CompressedStep) -> np.ndarray:
        self._state = decompress_step(step, self._state)
        return self._state

    def reset(self):
        self._state = None


def compress_series(arrays, params: NumarckParams = NumarckParams()
                    ) -> List[CompressedStep]:
    c = TemporalCompressor(params)
    return [c.add(a) for a in arrays]


def decompress_series(steps: List[CompressedStep]) -> List[np.ndarray]:
    d = TemporalDecompressor()
    return [d.add(s) for s in steps]


__all__ = ["compress_step", "decompress_step", "make_anchor", "decode_anchor",
           "TemporalCompressor", "TemporalDecompressor", "compress_series",
           "decompress_series"]
