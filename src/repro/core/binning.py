"""Phase 2: bin construction (paper Sec. III-B / IV-B).

Implements the paper's new *top-k* strategy plus the three earlier ones
(equal-width, log-scale, k-means).  All strategies emit a sorted array of bin
centers; top-k additionally reuses the candidate histogram for auto-B
selection (select_b.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def local_histogram(bin_ids: jax.Array, ok: jax.Array, max_bins: int):
    """Count valid ratios per candidate bin.  int32 counts.

    This is the per-process histogram of Sec. IV-B; the distributed pipeline
    psums it (the MPI_Allreduce analogue).
    """
    ids = jnp.clip(bin_ids, 0, max_bins - 1)
    w = ok.astype(jnp.int32)
    return jnp.zeros((max_bins,), jnp.int32).at[ids].add(w)


def sort_histogram(counts: jax.Array):
    """Full descending sort of the histogram: (counts_desc, bin_ids_desc).

    Replicated on every process, exactly like the paper's top-k selection
    ("regarded as a serial part", Table 3).
    """
    m = counts.shape[0]
    return jax.lax.top_k(counts, m)


def topk_centers(bin_ids_desc: jax.Array, k: int, domain_lo, width):
    """Centers of the k most populated candidate bins (Fig. 1 red ticks)."""
    sel = bin_ids_desc[:k]
    return domain_lo + (sel.astype(jnp.float32) + 0.5) * width, sel


def rank_lut(selected_bins: jax.Array, k: int, max_bins: int):
    """LUT: candidate bin id -> index rank in [0,k), else k (incompressible)."""
    lut = jnp.full((max_bins,), k, jnp.int32)
    return lut.at[selected_bins].set(jnp.arange(k, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# Earlier strategies (parallelized in Sec. IV-B-3).
# ---------------------------------------------------------------------------

def equal_width_centers(lo, hi, k: int):
    """Evenly split [lo, hi] into k chunks; centers of the chunks."""
    lo = jnp.asarray(lo, jnp.float32)
    hi = jnp.asarray(hi, jnp.float32)
    w = (hi - lo) / k
    return lo + (jnp.arange(k, dtype=jnp.float32) + 0.5) * w


def log_scale_centers(ratios_arr, valid, k: int, eps: float = 1e-12):
    """Log-scale bins over |ratio|, sign-symmetric.

    Half the budget covers negative ratios, half positive; each side splits
    [log(max(E_like, min|r|)), log(max|r|)] evenly in log space.
    """
    absr = jnp.where(valid, jnp.abs(ratios_arr), jnp.nan)
    amin = jnp.nanmin(jnp.where(absr > eps, absr, jnp.nan))
    amax = jnp.nanmax(absr)
    amin = jnp.where(jnp.isfinite(amin), amin, eps)
    amax = jnp.where(jnp.isfinite(amax) & (amax > amin), amax, amin * 10.0)
    kh = max(k // 2, 1)
    lg = jnp.linspace(jnp.log(amin), jnp.log(amax), kh)
    pos = jnp.exp(lg)
    neg = -pos[::-1]
    cs = jnp.concatenate([neg, jnp.zeros((k - 2 * kh + 1,)), pos])[:k]
    return jnp.sort(cs)


def kmeans_centers(counts: jax.Array, domain_lo, width, k: int,
                   iters: int = 20):
    """Weighted 1-D k-means over candidate-bin centers (Lloyd iterations).

    The paper clusters the raw change ratios (O(n * 2^B * I) via the MPI
    k-means package); we cluster the histogram instead -- O(m * k * I) with
    identical centers up to the 2E candidate resolution (DESIGN.md Sec. 3).
    """
    m = counts.shape[0]
    xs = domain_lo + (jnp.arange(m, dtype=jnp.float32) + 0.5) * width
    w = counts.astype(jnp.float32)
    # Init: quantiles of the weighted distribution.
    cw = jnp.cumsum(w)
    total = cw[-1]
    targets = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k * total
    init_idx = jnp.searchsorted(cw, targets)
    centers = xs[jnp.clip(init_idx, 0, m - 1)]

    def body(_, centers):
        # Assign each candidate bin to nearest center (1-D: searchsorted on
        # sorted centers against midpoints).
        centers = jnp.sort(centers)
        mids = 0.5 * (centers[1:] + centers[:-1])
        assign = jnp.searchsorted(mids, xs)
        sw = jnp.zeros((k,), jnp.float32).at[assign].add(w)
        sx = jnp.zeros((k,), jnp.float32).at[assign].add(w * xs)
        return jnp.where(sw > 0, sx / jnp.maximum(sw, 1.0), centers)

    centers = jax.lax.fori_loop(0, iters, body, centers)
    return jnp.sort(centers)


def assign_nearest(ratios_arr: jax.Array, valid: jax.Array,
                   centers_sorted: jax.Array, error_bound: float):
    """Index = nearest center if within E, else k (incompressible).

    Used by equal/log/kmeans, whose bins may be wider than 2E -- the original
    NUMARCK marks points farther than E from their center incompressible.
    """
    k = centers_sorted.shape[0]
    mids = 0.5 * (centers_sorted[1:] + centers_sorted[:-1])
    idx = jnp.searchsorted(mids, ratios_arr).astype(jnp.int32)
    err = jnp.abs(ratios_arr - centers_sorted[jnp.clip(idx, 0, k - 1)])
    ok = valid & (err <= error_bound)
    return jnp.where(ok, idx, k).astype(jnp.int32)


__all__ = [
    "local_histogram", "sort_histogram", "topk_centers", "rank_lut",
    "equal_width_centers", "log_scale_centers", "kmeans_centers",
    "assign_nearest",
]
