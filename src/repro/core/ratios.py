"""Phase 1: element-wise change-ratio calculation (paper Sec. III-A / IV-A).

    dD[i,j] = (D[i,j] - D[i-1,j]) / D[i-1,j]                     (Eq. 1)

A ratio is *valid* (candidate for binning) iff the previous value is nonzero
and the ratio is finite.  Invalid elements are incompressible by definition.
All device math is float32 (DESIGN.md Sec. 3: E >> f32 eps; incompressible
values round-trip in the original dtype on the host side).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def change_ratios(prev: jax.Array, curr: jax.Array):
    """Return (ratios f32, valid bool), flattened to 1-D.

    The paper tracks the global min/max alongside (via MPI_Allreduce); the
    single-device variant exposes them through `ratio_range`.
    """
    prev = jnp.asarray(prev, jnp.float32).reshape(-1)
    curr = jnp.asarray(curr, jnp.float32).reshape(-1)
    denom_ok = prev != 0.0
    safe_prev = jnp.where(denom_ok, prev, 1.0)
    ratios = (curr - safe_prev) / safe_prev
    valid = denom_ok & jnp.isfinite(ratios) & jnp.isfinite(curr)
    ratios = jnp.where(valid, ratios, 0.0)
    return ratios, valid


def ratio_range(ratios: jax.Array, valid: jax.Array):
    """(min, max) over valid ratios; (0, 0) when none are valid."""
    any_valid = jnp.any(valid)
    lo = jnp.min(jnp.where(valid, ratios, jnp.inf))
    hi = jnp.max(jnp.where(valid, ratios, -jnp.inf))
    lo = jnp.where(any_valid, lo, 0.0)
    hi = jnp.where(any_valid, hi, 0.0)
    return lo, hi


def histogram_domain(lo: jax.Array, hi: jax.Array, error_bound: float,
                     max_bins: int):
    """Pick the (domain_lo, width, m) for the candidate-bin histogram.

    Paper: bins of width 2E anchored at the global minimum.  We keep m static
    (= max_bins) for jit; when the data range fits inside max_bins * 2E the
    domain is anchored at the global min (paper-faithful), otherwise it is
    centred on zero (temporal change ratios cluster there; out-of-domain
    points become incompressible).  See DESIGN.md "Histogram domain capping".
    """
    width = jnp.float32(2.0 * error_bound)
    coverage = width * max_bins
    data_range = hi - lo
    fits = data_range <= coverage
    domain_lo = jnp.where(fits, lo, -0.5 * coverage)
    return domain_lo, width


def candidate_bin_ids(ratios: jax.Array, valid: jax.Array,
                      domain_lo: jax.Array, width: jax.Array, max_bins: int):
    """Map each ratio to its candidate histogram bin; -1 if not binnable."""
    raw = jnp.floor((ratios - domain_lo) / width)
    in_domain = (raw >= 0) & (raw < max_bins)
    ok = valid & in_domain
    return jnp.where(ok, raw, -1).astype(jnp.int32), ok


__all__ = ["change_ratios", "ratio_range", "histogram_domain",
           "candidate_bin_ids"]
