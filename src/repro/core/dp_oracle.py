"""Dynamic-programming optimal binning oracle (paper Sec. V-D, Fig. 15).

    OPT(i, j) = max( OPT(i+1, j),  OPT(i + c(i), j-1) + c(i) )

where c(i) is the number of points covered by the window [v_i, v_i + W]
starting at sorted point i.  (The paper's pseudo-code prints the recurrence
with the two branch arguments swapped; the text's description above is the
correct one and is what we implement.)

No binning strategy can cover more points with k width-W bins than this DP;
it is the oracle the paper compares top-k against (Figs. 13/14).  The paper
notes the O(n * 2^B) memory makes it impractical at scale -- here it exists
for tests and the binning benchmark only.

We run the DP over *unique* sorted values with multiplicities, which is
equivalent (a bin covering any point at value v covers all duplicates) and
keeps memory at O(n_unique * k).
"""
from __future__ import annotations

from itertools import combinations

import numpy as np


def _prep(values: np.ndarray):
    vals = np.sort(np.asarray(values, np.float64).ravel())
    uniq, counts = np.unique(vals, return_counts=True)
    cum = np.concatenate([[0], np.cumsum(counts)])  # points before uniq[i]
    return uniq, counts, cum


def dp_max_coverage(values: np.ndarray, width: float, k: int) -> int:
    """Max number of points coverable by k closed windows of width W."""
    uniq, counts, cum = _prep(values)
    nu = uniq.size
    if nu == 0 or k <= 0:
        return 0
    # nxt[i]: first unique index with value > uniq[i] + width
    nxt = np.searchsorted(uniq, uniq + width, side="right")
    cover = cum[nxt] - cum[:-1]          # c(i) in point counts

    # Bottom-up over i descending; opt[j] == OPT(i, j) for current i.
    opt = np.zeros((nu + 1, k + 1), dtype=np.int64)
    for i in range(nu - 1, -1, -1):
        skip = opt[i + 1]
        take = opt[nxt[i]]
        opt[i, 1:] = np.maximum(skip[1:], take[:-1] + cover[i])
    return int(opt[0, k])


def dp_select_bins(values: np.ndarray, width: float, k: int):
    """Like dp_max_coverage but also backtracks the chosen window starts."""
    uniq, counts, cum = _prep(values)
    nu = uniq.size
    if nu == 0 or k <= 0:
        return 0, np.zeros(0)
    nxt = np.searchsorted(uniq, uniq + width, side="right")
    cover = cum[nxt] - cum[:-1]
    opt = np.zeros((nu + 1, k + 1), dtype=np.int64)
    for i in range(nu - 1, -1, -1):
        opt[i, 1:] = np.maximum(opt[i + 1, 1:], opt[nxt[i], :-1] + cover[i])
    starts = []
    i, j = 0, k
    while i < nu and j > 0:
        if opt[i, j] == opt[i + 1, j]:
            i += 1
        else:
            starts.append(uniq[i])
            i, j = nxt[i], j - 1
    return int(opt[0, k]), np.asarray(starts)


def brute_force_max_coverage(values: np.ndarray, width: float,
                             k: int) -> int:
    """Exponential check for tiny inputs (tests): windows anchored at points.

    An optimal solution always exists with every window starting at a data
    point (slide each window right until it hits one), so enumerating
    anchor subsets is exact.
    """
    uniq, counts, cum = _prep(values)
    nu = uniq.size
    if nu == 0 or k <= 0:
        return 0
    nxt = np.searchsorted(uniq, uniq + width, side="right")
    best = 0
    for combo in combinations(range(nu), min(k, nu)):
        covered = np.zeros(nu, bool)
        for i in combo:
            covered[i:nxt[i]] = True
        best = max(best, int(counts[covered].sum()))
    return best


def coverage_of_centers(values: np.ndarray, centers: np.ndarray,
                        error_bound: float) -> int:
    """#points within error_bound of some center (strategy comparison)."""
    vals = np.sort(np.asarray(values, np.float64).ravel())
    centers = np.sort(np.asarray(centers, np.float64).ravel())
    covered = 0
    for c in centers:
        lo = np.searchsorted(vals, c - error_bound, side="left")
        hi = np.searchsorted(vals, c + error_bound, side="right")
        covered += hi - lo
        vals = np.concatenate([vals[:lo], vals[hi:]])
    return int(covered)


__all__ = ["dp_max_coverage", "dp_select_bins", "brute_force_max_coverage",
           "coverage_of_centers"]
