"""Index-table blocking + per-block ZLIB (paper Sec. IV-C).

The index table is split into fixed-element-count blocks, each deflated
independently so that partial decompression only inflates the overlapped
blocks.  Two offset tables accompany the blocks (paper Fig. 2):
  * index_table_offset        -- byte offset of each deflated block
  * incompressible_table_offset -- number of incompressible elements before
                                   each block (locates exceptions)
"""
from __future__ import annotations

import zlib
from typing import List, Tuple

import numpy as np

from repro.core import packing


def block_slices(n: int, block_elems: int) -> List[Tuple[int, int]]:
    return [(s, min(s + block_elems, n)) for s in range(0, n, block_elems)]


def deflate_blocks(idx: np.ndarray, b_bits: int, block_elems: int,
                   level: int = 6):
    """Pack + deflate each block.  Returns (blocks, raw_sizes, incomp_offsets).

    incomp_offsets[i] = number of incompressible markers (== 2**B - 1) in
    blocks [0, i) -- the exclusive prefix the decompressor needs.
    """
    marker = (1 << b_bits) - 1
    blocks: List[bytes] = []
    raw_sizes = []
    incomp_offsets = []
    seen_incomp = 0
    for s, e in block_slices(idx.size, block_elems):
        chunk = idx[s:e]
        if e - s < block_elems:
            # Pad the final block with markers so every block packs to the
            # same bit length (decompressors only read n valid elements;
            # keeps host and sharded-kernel byte streams identical).
            chunk = np.concatenate(
                [chunk, np.full(block_elems - (e - s), marker, idx.dtype)])
        packed = packing.pack_indices_np(chunk, b_bits)
        blocks.append(zlib.compress(packed.tobytes(), level))
        raw_sizes.append(packed.size)
        incomp_offsets.append(seen_incomp)
        seen_incomp += int(np.count_nonzero(idx[s:e] == marker))
    return (blocks, np.asarray(raw_sizes, np.int64),
            np.asarray(incomp_offsets, np.int64))


def inflate_block(blob: bytes, n_elems: int, b_bits: int) -> np.ndarray:
    packed = np.frombuffer(zlib.decompress(blob), dtype=np.uint8)
    return packing.unpack_indices_np(packed, n_elems, b_bits)


def zlib_ratio(blocks: List[bytes], raw_sizes: np.ndarray) -> float:
    """Average ZLIB compression ratio of the index table (paper Table 9)."""
    comp = sum(len(b) for b in blocks)
    return float(raw_sizes.sum()) / max(comp, 1)


__all__ = ["block_slices", "deflate_blocks", "inflate_block", "zlib_ratio"]
