"""Index-table blocking + per-block entropy coding (paper Sec. IV-C).

The index table is split into fixed-element-count blocks, each entropy-
coded independently so that partial decompression only decodes the
overlapped blocks.  Two offset tables accompany the blocks (paper Fig. 2):
  * index_table_offset        -- byte offset of each coded block
  * incompressible_table_offset -- number of incompressible elements before
                                   each block (locates exceptions)

Packing and entropy coding themselves live in the shared stage modules
(``core.pipeline``, ``core.entropy``); this module keeps the thin
block-level API the decompressors and baselines use.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core import entropy, packing
from repro.core import pipeline as pipe


def block_slices(n: int, block_elems: int) -> List[Tuple[int, int]]:
    return pipe.block_slices(n, block_elems)


def deflate_blocks(idx: np.ndarray, b_bits: int, block_elems: int,
                   level: int = 6, codec: str = entropy.DEFAULT_CODEC,
                   parallel: bool = True):
    """Pack + entropy-code each block.
    Returns (blocks, raw_sizes, incomp_offsets).

    incomp_offsets[i] = number of incompressible markers (== 2**B - 1) in
    blocks [0, i) -- the exclusive prefix the decompressor needs.
    """
    raws = pipe.pack_blocks_host(idx, b_bits, block_elems)
    blocks = entropy.compress_blocks(raws, codec=codec, level=level,
                                     parallel=parallel)
    raw_sizes = np.asarray([len(r) for r in raws], np.int64)
    marker = (1 << b_bits) - 1
    incomp_offsets = pipe.exception_offsets(
        np.asarray(idx).reshape(-1) == marker, block_elems)
    return blocks, raw_sizes, incomp_offsets


def inflate_block(blob: bytes, n_elems: int, b_bits: int,
                  codec: str = entropy.DEFAULT_CODEC) -> np.ndarray:
    packed = np.frombuffer(entropy.decompress_block(blob, codec),
                           dtype=np.uint8)
    return packing.unpack_indices_np(packed, n_elems, b_bits)


def zlib_ratio(blocks: List[bytes], raw_sizes: np.ndarray) -> float:
    """Average entropy compression ratio of the index table (paper
    Table 9).  Name kept from the zlib-only days for compatibility."""
    return pipe.entropy_ratio(blocks, raw_sizes)


__all__ = ["block_slices", "deflate_blocks", "inflate_block", "zlib_ratio"]
