"""Double-buffer discipline shared by every overlapped host stage.

The overlapped compressors (``TemporalCompressor``, ``ShardedCompressor``)
and the async checkpoint writer all follow the same pattern: one background
worker thread, at most two tasks in flight (one executing + one queued),
submit blocks past the bound so host memory stays bounded regardless of
stream length, and completed futures are ``.result()``-ed on the next
submit/flush so background failures surface instead of vanishing with
their Future.  This is that pattern, once.

Observability (``repro.obs``): every queue emits, under its own name,

  ``<name>.depth``          gauge   in-flight tasks after each submit
  ``<name>.queue_wait_s``   hist    submit -> worker-start latency
  ``<name>.stall_s``        counter time the *caller* blocked because the
                                    queue was full (the flush-stall the
                                    overlap is supposed to hide)
  ``<name>.task``           span    task execution on the worker lane
                                    (records the failure when it raises)

and worker exceptions carry the stage/step context of the task that died:
the submit-side ``label`` is appended to the exception message (type and
traceback preserved), so a failed background finalize names which step
and stage failed instead of re-raising a bare Future error.

Fault tolerance: construct with ``timeout=<seconds>`` and every wait on a
background task is bounded.  A wedged worker surfaces as a ``TimeoutError``
naming the stuck task's ``label`` -- instead of hanging the driver forever
-- and the worker thread is retired and replaced (shutdown without
waiting, pending futures cancelled; the next submit gets a fresh worker),
the same discipline ``core.entropy`` applies to wedged process pools.
"""
from __future__ import annotations

import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from typing import Deque, Optional, Tuple

from repro.obs import telemetry


def _attach_context(e: BaseException, queue: str, label: str):
    """Append ``[queue worker: label]`` to the exception message so the
    failing stage/step is visible wherever the Future is re-raised.  The
    exception type, args structure and traceback are preserved (the
    original message stays a prefix, so ``pytest.raises(match=...)`` on
    it keeps working); double-attachment on re-surfaced futures is
    suppressed."""
    if getattr(e, "_overlap_context", None) is not None:
        return
    ctx = f"[{queue} worker: {label}]"
    try:
        e._overlap_context = ctx  # type: ignore[attr-defined]
        if e.args and isinstance(e.args[0], str):
            e.args = (f"{e.args[0]} {ctx}",) + e.args[1:]
        else:
            e.args = e.args + (ctx,)
    except Exception:  # exotic exception types: context stays best-effort
        pass


class FinalizeQueue:
    """Bounded single-worker task queue with an inline (serial) mode.

    With ``overlap=False`` every ``submit`` runs the callable inline and
    returns an already-resolved Future -- identical interface, serial
    semantics, so callers never branch on the mode.

    ``timeout`` (seconds, ``None`` = wait forever, the historical
    behaviour) bounds every internal wait on a background task: drain on
    submit, the full-queue stall, and ``flush``.  On expiry the worker is
    retired (it may be wedged in a C call that ignores interrupts) and a
    ``TimeoutError`` naming the stuck task's label is raised.
    """

    def __init__(self, overlap: bool, name: str = "finalize",
                 max_in_flight: int = 2, timeout: Optional[float] = None):
        self.overlap = overlap
        self._name = name
        self._max = max(1, max_in_flight)
        self._timeout = timeout
        self._ex: Optional[ThreadPoolExecutor] = None
        self._pending: Deque[Tuple[Future, str]] = deque()

    def _retire_worker(self):
        """Abandon a wedged worker thread (entropy-pool discipline:
        shutdown without waiting, cancel what never started, forget the
        executor so the next submit builds a fresh one)."""
        if self._ex is not None:
            self._ex.shutdown(wait=False, cancel_futures=True)
            self._ex = None
        self._pending.clear()

    def _drain_one(self) -> None:
        """Resolve the oldest pending task, bounded by ``timeout``."""
        f, label = self._pending.popleft()
        try:
            f.result(timeout=self._timeout)
        except _FutureTimeout:
            # py3.10: concurrent.futures.TimeoutError is NOT the builtin.
            self._pending.appendleft((f, label))
            self._retire_worker()
            raise TimeoutError(
                f"{self._name} worker wedged: task [label={label}] did not "
                f"complete within {self._timeout}s; worker retired and "
                "replaced") from None

    def submit(self, fn, *args, label: Optional[str] = None) -> Future:
        """Run ``fn(*args)`` (inline or on the worker).  ``label`` names
        the task for telemetry spans and exception context -- pass the
        stage/step (e.g. ``"finalize step 12"``) so background failures
        are attributable."""
        label = label or getattr(fn, "__name__", "task")
        if not self.overlap:
            f: Future = Future()
            try:
                with telemetry.span(f"{self._name}.task", label=label):
                    f.set_result(fn(*args))
            except BaseException as e:  # noqa: BLE001 -- mirror executor
                _attach_context(e, self._name, label)
                f.set_exception(e)
            return f
        # .result() on completed futures too: a failed background task must
        # surface on the next submit/flush, not vanish with its Future.
        while self._pending and self._pending[0][0].done():
            self._drain_one()
        if len(self._pending) >= self._max:
            # Queue full: the caller stalls here until the oldest task
            # drains -- the stall the overlap exists to hide, so meter it.
            t_stall = time.perf_counter()
            while len(self._pending) >= self._max:
                self._drain_one()
            telemetry.counter(f"{self._name}.stall_s",
                              time.perf_counter() - t_stall)
        if self._ex is None:
            self._ex = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix=self._name)
        t_submit = time.perf_counter()

        def run():
            telemetry.histo(f"{self._name}.queue_wait_s",
                            time.perf_counter() - t_submit)
            try:
                with telemetry.span(f"{self._name}.task", label=label):
                    return fn(*args)
            except BaseException as e:  # noqa: BLE001 -- context then re-raise
                _attach_context(e, self._name, label)
                raise

        f = self._ex.submit(run)
        self._pending.append((f, label))
        telemetry.gauge(f"{self._name}.depth", len(self._pending))
        return f

    def flush(self):
        """Barrier: block until every in-flight task has completed
        (re-raises the first background exception, if any; with a
        ``timeout`` configured, a wedged task raises a labeled
        TimeoutError instead of blocking forever)."""
        with telemetry.span(f"{self._name}.flush",
                            pending=len(self._pending)):
            while self._pending:
                self._drain_one()

    # Checkpoint manager calls this name; keep both as the public barrier.
    wait = flush

    def close(self):
        self.flush()
        if self._ex is not None:
            self._ex.shutdown(wait=True)
            self._ex = None


__all__ = ["FinalizeQueue"]
