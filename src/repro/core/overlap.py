"""Double-buffer discipline shared by every overlapped host stage.

The overlapped compressors (``TemporalCompressor``, ``ShardedCompressor``)
and the async checkpoint writer all follow the same pattern: one background
worker thread, at most two tasks in flight (one executing + one queued),
submit blocks past the bound so host memory stays bounded regardless of
stream length, and completed futures are ``.result()``-ed on the next
submit/flush so background failures surface instead of vanishing with
their Future.  This is that pattern, once.
"""
from __future__ import annotations

from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Deque, Optional


class FinalizeQueue:
    """Bounded single-worker task queue with an inline (serial) mode.

    With ``overlap=False`` every ``submit`` runs the callable inline and
    returns an already-resolved Future -- identical interface, serial
    semantics, so callers never branch on the mode.
    """

    def __init__(self, overlap: bool, name: str = "finalize",
                 max_in_flight: int = 2):
        self.overlap = overlap
        self._name = name
        self._max = max(1, max_in_flight)
        self._ex: Optional[ThreadPoolExecutor] = None
        self._pending: Deque[Future] = deque()

    def submit(self, fn, *args) -> Future:
        if not self.overlap:
            f: Future = Future()
            try:
                f.set_result(fn(*args))
            except BaseException as e:  # noqa: BLE001 -- mirror executor
                f.set_exception(e)
            return f
        # .result() on completed futures too: a failed background task must
        # surface on the next submit/flush, not vanish with its Future.
        while self._pending and self._pending[0].done():
            self._pending.popleft().result()
        while len(self._pending) >= self._max:
            self._pending.popleft().result()
        if self._ex is None:
            self._ex = ThreadPoolExecutor(max_workers=1,
                                          thread_name_prefix=self._name)
        f = self._ex.submit(fn, *args)
        self._pending.append(f)
        return f

    def flush(self):
        """Barrier: block until every in-flight task has completed
        (re-raises the first background exception, if any)."""
        while self._pending:
            self._pending.popleft().result()

    def close(self):
        self.flush()
        if self._ex is not None:
            self._ex.shutdown(wait=True)
            self._ex = None


__all__ = ["FinalizeQueue"]
