"""NCK container: netCDF-analogue file format (paper Sec. IV-D, Fig. 2).

No netCDF library is available in this environment, so we use a
self-describing single-file container with the *same logical layout* as the
paper's netCDF output:

  magic "NCK1" | u64 header_len | JSON header | pad->64 | section bytes ...

The JSON header mirrors netCDF dimensions/variables/attributes.  Each
compressed variable V (one per iteration per field) stores, exactly as in
Fig. 2:

  V_info                      -- attributes (total_data_num, bin_centers_number,
                                 elements_per_block, B, E, strategy, ...)
  V_bin_centers               -- float array
  V_index_table_offset        -- int64 byte offsets of deflated blocks
  V_incompressible_table_offset -- int64 per-block exception count prefix
  V_index_table               -- concatenated deflated blocks (byte array)
  V_incompressible_table      -- original-dtype exception values

Multiple variables per file are supported (paper: "NUMARCK allows multiple
compressed variables stored in one netCDF file").  Reads are offset-based so
partial decompression touches only the needed byte ranges.

Format versions: files whose steps all use one codec per step keep the
original "NCK1" magic (readable by every reader ever shipped); files
carrying per-*block* codec ids -- a layout older readers cannot decode
correctly -- are stamped "NCK2", so old readers reject them cleanly at
open instead of mis-decoding blocks.  Files carrying symbol-level rANS
blocks (kernels.rans v2 blobs, coding pre-pack B-bit indices -- bytes
older rANS decoders cannot parse) are stamped "NCK3" by the same
mechanism: the writer peeks each rans block's self-describing version
byte when the step is added.  This reader accepts all three.
"""
from __future__ import annotations

import json
import os
import struct
from typing import Dict, List, Optional

import numpy as np

from repro.core.types import CompressedStep
from repro.obs import telemetry

_MAGIC_V1 = b"NCK1"
_MAGIC_V2 = b"NCK2"
_MAGIC_V3 = b"NCK3"
_MAGICS = {_MAGIC_V1: 1, _MAGIC_V2: 2, _MAGIC_V3: 3}
_MAGIC = _MAGIC_V1              # legacy alias (default / pre-PR files)
_ALIGN = 64


def _has_symbol_blobs(step: CompressedStep) -> bool:
    """Does any rans block of this step carry the symbol-level (v2) blob
    format?  Old readers' rANS decoders cannot parse those bytes, so the
    file must not present itself as NCK1/NCK2."""
    from repro.kernels import rans
    for bi, blob in enumerate(step.index_blocks):
        if step.codec_for_block(bi) != "rans" or len(blob) < 5:
            continue
        if rans.blob_version(blob) == 2:
            return True
    return False


def _pad(n: int) -> int:
    return (-n) % _ALIGN


class NCKWriter:
    """Assemble sections then write the file in one shot (or via append)."""

    def __init__(self):
        self._sections: List[bytes] = []
        self._vars: Dict[str, dict] = {}
        self._dims: Dict[str, int] = {}
        self._offset = 0
        # Bumped to 2 the moment a step with per-block codec ids is added;
        # NCK1 files must stay readable by pre-per-block readers.
        self._format_version = 1

    def add_array(self, name: str, arr: np.ndarray, attrs: Optional[dict] = None):
        arr = np.ascontiguousarray(arr)
        self._add_bytes(name, arr.tobytes(), str(arr.dtype), list(arr.shape),
                        attrs)

    def add_bytes(self, name: str, raw: bytes, attrs: Optional[dict] = None):
        self._add_bytes(name, raw, "uint8", [len(raw)], attrs)

    def _add_bytes(self, name, raw, dtype, shape, attrs):
        if name in self._vars:
            raise ValueError(f"duplicate variable {name}")
        self._vars[name] = dict(dtype=dtype, shape=shape, offset=self._offset,
                                nbytes=len(raw), attributes=attrs or {})
        self._dims[f"{name}_dim"] = int(np.prod(shape)) if shape else 1
        self._sections.append(raw)
        self._offset += len(raw) + _pad(len(raw))

    def add_step(self, name: str, step: CompressedStep):
        """Store one CompressedStep under variable prefix `name` (Fig. 2)."""
        info = dict(
            total_data_num=step.n, shape=list(step.shape), dtype=step.dtype,
            bin_centers_number=int(step.centers.size),
            elements_per_block=step.block_elems, B=step.b_bits,
            error_bound=step.error_bound, strategy=step.strategy,
            reference=step.reference, domain_lo=step.domain_lo,
            bin_width=step.bin_width, is_anchor=bool(step.is_anchor),
            n_blocks=step.n_blocks,
            n_incompressible=step.n_incompressible,
            codec=step.codec,
        )
        if step.block_codecs is not None:
            info["block_codecs"] = [str(c) for c in step.block_codecs]
            self._format_version = max(self._format_version, 2)
        if _has_symbol_blobs(step):
            self._format_version = 3
        offs_all = np.concatenate(
            [step.index_table_offsets(),
             [sum(len(b) for b in step.index_blocks)]]).astype(np.int64)
        if step.is_anchor:
            self.add_array(f"{name}_anchor_info", np.zeros(1, np.int32),
                           attrs=info)
            self.add_array(f"{name}_anchor_offset", offs_all)
            self.add_bytes(f"{name}_anchor", b"".join(step.index_blocks))
            return
        self.add_array(f"{name}_info", np.zeros(1, np.int32), attrs=info)
        self.add_array(f"{name}_bin_centers",
                       step.centers.astype(step.dtype))
        self.add_array(f"{name}_index_table_offset", offs_all)
        self.add_array(f"{name}_incompressible_table_offset",
                       np.asarray(step.incomp_block_offsets, np.int64))
        self.add_bytes(f"{name}_index_table",
                       b"".join(step.index_blocks))
        self.add_array(f"{name}_incompressible_table", step.incomp_values)

    def write(self, path: str):
        header = json.dumps({"dimensions": self._dims,
                             "variables": self._vars}).encode()
        tmp = path + ".tmp"
        magic = {1: _MAGIC_V1, 2: _MAGIC_V2,
                 3: _MAGIC_V3}[self._format_version]
        with telemetry.span("nck.write", path=path,
                            sections=len(self._sections)):
            with open(tmp, "wb") as f:
                f.write(magic)
                f.write(struct.pack("<Q", len(header)))
                f.write(header)
                f.write(b"\0" * _pad(len(_MAGIC) + 8 + len(header)))
                for raw in self._sections:
                    f.write(raw)
                    f.write(b"\0" * _pad(len(raw)))
                f.flush()
                # durable BEFORE the rename publishes it
                with telemetry.span("nck.fsync"):
                    os.fsync(f.fileno())
            with telemetry.span("nck.rename"):
                os.replace(tmp, path)  # atomic publish (fault tolerance)


class NCKReader:
    """Offset-based reader; `read` pulls only the requested byte range."""

    def __init__(self, path: str):
        self.path = path
        with open(path, "rb") as f:
            magic = f.read(4)
            if magic not in _MAGICS:
                raise ValueError(f"{path}: not an NCK file")
            self.format_version = _MAGICS[magic]
            (hlen,) = struct.unpack("<Q", f.read(8))
            header = json.loads(f.read(hlen))
        self.variables = header["variables"]
        self.dimensions = header["dimensions"]
        self._data_start = 4 + 8 + hlen + _pad(4 + 8 + hlen)

    def attrs(self, name: str) -> dict:
        return self.variables[name]["attributes"]

    def read(self, name: str, byte_start: int = 0,
             byte_stop: Optional[int] = None) -> bytes:
        v = self.variables[name]
        stop = v["nbytes"] if byte_stop is None else min(byte_stop,
                                                         v["nbytes"])
        with open(self.path, "rb") as f:
            f.seek(self._data_start + v["offset"] + byte_start)
            return f.read(max(stop - byte_start, 0))

    def read_array(self, name: str) -> np.ndarray:
        v = self.variables[name]
        raw = self.read(name)
        return np.frombuffer(raw, dtype=v["dtype"]).reshape(v["shape"])

    def read_step(self, name: str) -> CompressedStep:
        """Inverse of NCKWriter.add_step."""
        if f"{name}_anchor" in self.variables:
            info = self.attrs(f"{name}_anchor_info")
            offs = self.read_array(f"{name}_anchor_offset")
            table = self.read(f"{name}_anchor")
            blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
            return CompressedStep(
                n=info["total_data_num"], shape=tuple(info["shape"]),
                dtype=info["dtype"], b_bits=0,
                error_bound=info["error_bound"], strategy=info["strategy"],
                reference=info["reference"], domain_lo=0.0, bin_width=0.0,
                centers=np.zeros(0),
                block_elems=info["elements_per_block"],
                codec=info.get("codec", "zlib"), index_blocks=blks)
        info = self.attrs(f"{name}_info")
        offs = self.read_array(f"{name}_index_table_offset")
        table = self.read(f"{name}_index_table")
        blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
        return CompressedStep(
            n=info["total_data_num"], shape=tuple(info["shape"]),
            dtype=info["dtype"], b_bits=info["B"],
            error_bound=info["error_bound"], strategy=info["strategy"],
            reference=info["reference"], domain_lo=info["domain_lo"],
            bin_width=info["bin_width"],
            centers=self.read_array(f"{name}_bin_centers").astype(np.float64),
            block_elems=info["elements_per_block"],
            codec=info.get("codec", "zlib"),
            block_codecs=info.get("block_codecs"), index_blocks=blks,
            incomp_values=self.read_array(f"{name}_incompressible_table"),
            incomp_block_offsets=self.read_array(
                f"{name}_incompressible_table_offset"))

    def step_names(self) -> List[str]:
        names = set()
        for v in self.variables:
            if v.endswith("_anchor_info"):
                names.add(v[: -len("_anchor_info")])
            elif v.endswith("_info"):
                names.add(v[: -len("_info")])
        return sorted(names)


__all__ = ["NCKWriter", "NCKReader"]
