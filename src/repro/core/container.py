"""NCK container: netCDF-analogue file format (paper Sec. IV-D, Fig. 2).

No netCDF library is available in this environment, so we use a
self-describing single-file container with the *same logical layout* as the
paper's netCDF output:

  magic "NCK1" | u64 header_len | JSON header | pad->64 | section bytes ...

The JSON header mirrors netCDF dimensions/variables/attributes.  Each
compressed variable V (one per iteration per field) stores, exactly as in
Fig. 2:

  V_info                      -- attributes (total_data_num, bin_centers_number,
                                 elements_per_block, B, E, strategy, ...)
  V_bin_centers               -- float array
  V_index_table_offset        -- int64 byte offsets of deflated blocks
  V_incompressible_table_offset -- int64 per-block exception count prefix
  V_index_table               -- concatenated deflated blocks (byte array)
  V_incompressible_table      -- original-dtype exception values

Multiple variables per file are supported (paper: "NUMARCK allows multiple
compressed variables stored in one netCDF file").  Reads are offset-based so
partial decompression touches only the needed byte ranges.

Format versions: files whose steps all use one codec per step keep the
original "NCK1" magic (readable by every reader ever shipped); files
carrying per-*block* codec ids -- a layout older readers cannot decode
correctly -- are stamped "NCK2", so old readers reject them cleanly at
open instead of mis-decoding blocks.  Files carrying symbol-level rANS
blocks (kernels.rans v2 blobs, coding pre-pack B-bit indices -- bytes
older rANS decoders cannot parse) are stamped "NCK3" by the same
mechanism: the writer peeks each rans block's self-describing version
byte when the step is added.  Files carrying the *checksum frame* --
CRC-32 digests stamped into the header so every read path can verify
payload bytes before decoding them -- are "NCK4":

  magic "NCK4" | u64 header_len | u32 header_crc | JSON header | pad->64
              | section bytes ...

``header_crc`` is crc32(header + pad), so a flipped bit anywhere in the
metadata is caught before it can misdirect a read.  Each variable record
carries ``crc32`` (whole payload); blocked variables (index tables,
anchors, fragment tables) additionally carry ``block_crc32``, a per-block
digest list, so partial and sharded reads verify exactly the blocks they
slice.  Writers stamp the frame by default (``checksums=False`` restores
the NCK1/2/3 matrix for compatibility tests); this reader accepts all
four versions and raises a structured
:class:`repro.faults.errors.CorruptBlockError` -- naming file, variable,
block and both digests -- instead of decoding garbage.

Multi-process output (paper Sec. IV-D collective write analogue): each
process writes only its own blocks to a generation-suffixed rank file
``<path>.g<gen>.rank<k>`` -- a normal NCK file holding *step fragments*
-- and rank 0 publishes ``<path>`` as an "NCKM" manifest naming the rank
files.  Payload bytes never cross processes; `NCKReader` opens the
manifest as one logical file and merges fragments back into
`CompressedStep`s byte-identical to a single-process write.  All file
publishes (rank files, manifest, checkpoint manifests) go through
`atomic_commit`: content is fsynced *before* the rename makes it
visible, so a crashed rank can never leave a half-written file under a
published name, and a failed commit leaves the previous manifest (and
the rank files it references) untouched.

Manifest schema 2 adds its own integrity + self-healing layer: the NCKM
payload ends in a u32 crc32 trailer, records each rank file's size AND
whole-file crc32, and embeds the previous durable generation's entries
under ``previous``.  Rank 0's commit verifies every rank file before
referencing it, quarantines corrupt ones (renamed aside so a re-publish
can land), polls with bounded jittered backoff, and on deadline raises
:class:`repro.faults.errors.CommitTimeoutError` carrying a structured
rollback report -- the previous manifest is untouched byte for byte.
`NCKReader` mirrors this: when the newest generation fails verification
it falls back to the ``previous`` entries and records
``recovered_generation``.
"""
from __future__ import annotations

import glob
import json
import os
import struct
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core.types import CompressedStep
from repro.faults import inject
from repro.faults.errors import (CommitTimeoutError, CorruptBlockError,
                                 CorruptShardError, IntegrityError)
from repro.faults.retry import Backoff
from repro.obs import telemetry

_MAGIC_V1 = b"NCK1"
_MAGIC_V2 = b"NCK2"
_MAGIC_V3 = b"NCK3"
_MAGIC_V4 = b"NCK4"
_MAGICS = {_MAGIC_V1: 1, _MAGIC_V2: 2, _MAGIC_V3: 3, _MAGIC_V4: 4}
_MAGIC = _MAGIC_V1              # legacy alias (default / pre-PR files)
_MANIFEST_MAGIC = b"NCKM"       # multi-process manifest (not a data file)
_ALIGN = 64

# Checksum frame keys inside each variable record (NCK4 only).
_CRC_KEY = "crc32"              # crc32 of the whole variable payload
_BLOCK_CRC_KEY = "block_crc32"  # per-block crc32 list for blocked variables

_MANIFEST_SCHEMA = 2            # 2: crc trailer + per-rank crcs + previous


def atomic_commit(path: str, data: Union[bytes, Iterable[bytes]]) -> None:
    """Durable atomic publish: write to `path`.tmp, fsync, then rename.

    The one sanctioned way to make a file appear under a published name
    (NCK files, multi-process manifests, checkpoint manifests all route
    here; repro-lint's format pass flags any other os.replace/os.rename
    in the tree).  fsync runs BEFORE the rename so a crash can never
    publish a name whose content is not yet on disk.

    Fault-injection sites (active only under ``REPRO_FAULTS=``):
    ``fsync_fail`` / ``rename_fail`` raise OSError at the corresponding
    syscall; ``torn_shard`` / ``bitflip_shard`` corrupt the tmp file of a
    ``.rank`` shard publish so the damage rides the atomic rename exactly
    like real silent corruption would.
    """
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        if isinstance(data, (bytes, bytearray, memoryview)):
            f.write(data)
        else:
            for chunk in data:
                f.write(chunk)
        f.flush()
        inject.fire("fsync_fail", path=path)
        # durable BEFORE the rename publishes it
        with telemetry.span("nck.fsync"):
            os.fsync(f.fileno())
    inject.mangle_file(tmp, path)
    inject.fire("rename_fail", path=path)
    with telemetry.span("nck.rename"):
        os.replace(tmp, path)  # atomic publish (fault tolerance)


def _blobs_have_symbol_rans(blobs: List[bytes], codec: str,
                            block_codecs: Optional[List[str]]) -> bool:
    """Does any rans blob in this list carry the symbol-level (v2) blob
    format?  Old readers' rANS decoders cannot parse those bytes, so the
    file must not present itself as NCK1/NCK2."""
    from repro.kernels import rans
    for bi, blob in enumerate(blobs):
        c = block_codecs[bi] if block_codecs else codec
        if c != "rans" or len(blob) < 5:
            continue
        if rans.blob_version(blob) == 2:
            return True
    return False


def _has_symbol_blobs(step: CompressedStep) -> bool:
    return _blobs_have_symbol_rans(step.index_blocks, step.codec,
                                   step.block_codecs)


def _pad(n: int) -> int:
    return (-n) % _ALIGN


def _file_crc32(path: str) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


class NCKWriter:
    """Assemble sections then write the file in one shot (or via append).

    ``checksums=True`` (the default) stamps the NCK4 checksum frame:
    header crc + per-variable (and per-block, where blocked) payload
    digests.  ``checksums=False`` restores the NCK1/2/3 magic matrix for
    compatibility with pre-checksum readers.
    """

    def __init__(self, *, checksums: bool = True):
        self._sections: List[bytes] = []
        self._vars: Dict[str, dict] = {}
        self._dims: Dict[str, int] = {}
        self._offset = 0
        self._checksums = bool(checksums)
        # Bumped to 2 the moment a step with per-block codec ids is added;
        # NCK1 files must stay readable by pre-per-block readers.
        self._format_version = 1

    @property
    def checksums(self) -> bool:
        return self._checksums

    def add_array(self, name: str, arr: np.ndarray, attrs: Optional[dict] = None):
        arr = np.ascontiguousarray(arr)
        self._add_bytes(name, arr.tobytes(), str(arr.dtype), list(arr.shape),
                        attrs)

    def add_bytes(self, name: str, raw: bytes, attrs: Optional[dict] = None,
                  *, block_crcs: Optional[Sequence[int]] = None):
        self._add_bytes(name, raw, "uint8", [len(raw)], attrs,
                        block_crcs=block_crcs)

    def _add_bytes(self, name, raw, dtype, shape, attrs, *, block_crcs=None):
        if name in self._vars:
            raise ValueError(f"duplicate variable {name}")
        rec = dict(dtype=dtype, shape=shape, offset=self._offset,
                   nbytes=len(raw), attributes=attrs or {})
        if self._checksums:
            rec[_CRC_KEY] = zlib.crc32(raw)
            if block_crcs is not None:
                rec[_BLOCK_CRC_KEY] = [int(c) for c in block_crcs]
        self._vars[name] = rec
        self._dims[f"{name}_dim"] = int(np.prod(shape)) if shape else 1
        self._sections.append(raw)
        self._offset += len(raw) + _pad(len(raw))

    def _block_crcs(self, blocks: List[bytes]) -> Optional[List[int]]:
        if not self._checksums:
            return None
        return [zlib.crc32(b) for b in blocks]

    def add_step(self, name: str, step: CompressedStep):
        """Store one CompressedStep under variable prefix `name` (Fig. 2)."""
        info = dict(
            total_data_num=step.n, shape=list(step.shape), dtype=step.dtype,
            bin_centers_number=int(step.centers.size),
            elements_per_block=step.block_elems, B=step.b_bits,
            error_bound=step.error_bound, strategy=step.strategy,
            reference=step.reference, domain_lo=step.domain_lo,
            bin_width=step.bin_width, is_anchor=bool(step.is_anchor),
            n_blocks=step.n_blocks,
            n_incompressible=step.n_incompressible,
            codec=step.codec,
        )
        if step.block_codecs is not None:
            info["block_codecs"] = [str(c) for c in step.block_codecs]
            self._format_version = max(self._format_version, 2)
        if _has_symbol_blobs(step):
            self._format_version = 3
        offs_all = np.concatenate(
            [step.index_table_offsets(),
             [sum(len(b) for b in step.index_blocks)]]).astype(np.int64)
        if step.is_anchor:
            self.add_array(f"{name}_anchor_info", np.zeros(1, np.int32),
                           attrs=info)
            self.add_array(f"{name}_anchor_offset", offs_all)
            self.add_bytes(f"{name}_anchor", b"".join(step.index_blocks),
                           block_crcs=self._block_crcs(step.index_blocks))
            return
        self.add_array(f"{name}_info", np.zeros(1, np.int32), attrs=info)
        self.add_array(f"{name}_bin_centers",
                       step.centers.astype(step.dtype))
        self.add_array(f"{name}_index_table_offset", offs_all)
        self.add_array(f"{name}_incompressible_table_offset",
                       np.asarray(step.incomp_block_offsets, np.int64))
        self.add_bytes(f"{name}_index_table",
                       b"".join(step.index_blocks),
                       block_crcs=self._block_crcs(step.index_blocks))
        self.add_array(f"{name}_incompressible_table", step.incomp_values)

    def bump_format(self, version: int):
        """Raise the file format floor (2: per-block codec ids, 3: symbol
        rANS blobs) -- `add_step` does this itself; fragment writers that
        assemble steps from raw variables declare it explicitly."""
        self._format_version = max(self._format_version, version)

    def _chunks(self) -> Iterable[bytes]:
        header = json.dumps({"dimensions": self._dims,
                             "variables": self._vars}).encode()
        version = 4 if self._checksums else self._format_version
        magic = {1: _MAGIC_V1, 2: _MAGIC_V2, 3: _MAGIC_V3,
                 4: _MAGIC_V4}[version]
        prefix = len(magic) + 8 + (4 if version >= 4 else 0)
        pad = b"\0" * _pad(prefix + len(header))
        yield magic
        yield struct.pack("<Q", len(header))
        if version >= 4:
            # Header digest covers header + pad: a flipped bit anywhere in
            # the metadata region is caught before it misdirects a read.
            yield struct.pack("<I", zlib.crc32(header + pad))
        yield header
        yield pad
        for raw in self._sections:
            yield raw
            yield b"\0" * _pad(len(raw))

    def write(self, path: str):
        with telemetry.span("nck.write", path=path,
                            sections=len(self._sections)):
            atomic_commit(path, self._chunks())


# --------------------------------------------------------------------------
# Multi-process tier: per-rank fragment files + rank-0 manifest.
# --------------------------------------------------------------------------

@dataclass
class StepFragment:
    """One process's contiguous slice of a CompressedStep (paper Sec.
    IV-D: every rank writes its own blocks; nothing is gathered).

    ``info`` carries the *global* step attributes every rank knows from
    the replicated analyze outputs (n, shape, B, domain, codec, ...);
    ``block_start`` anchors this fragment's blocks in the global block
    order.  ``centers`` is set on rank 0 only -- it is replicated data,
    so one copy per logical file suffices.
    """

    is_anchor: bool
    block_start: int
    info: dict
    index_blocks: List[bytes] = field(default_factory=list)
    centers: Optional[np.ndarray] = None
    incomp_values: Optional[np.ndarray] = None
    incomp_block_counts: Optional[np.ndarray] = None
    block_codecs: Optional[List[str]] = None
    # Driver telemetry (per-rank phase seconds etc.); never persisted --
    # the rank file stores `info` attrs only, mirroring CompressedStep.
    meta: dict = field(default_factory=dict)


def rank_file_path(path: str, generation: int, rank: int) -> str:
    """Per-rank NCK shard file name: ``<path>.g<gen>.rank<k>``.  The
    generation suffix keeps a crashed save's partial output disjoint from
    every published generation -- a mixed-generation file set can never
    be referenced by one manifest."""
    return f"{path}.g{generation:04d}.rank{rank}"


def _manifest_bytes(payload: dict) -> bytes:
    """Serialize a manifest payload with its u32 crc32 trailer (schema 2:
    the digest covers magic + length + JSON, so any flip in the committed
    manifest -- even inside the length field -- fails verification)."""
    body = json.dumps(payload).encode()
    head = _MANIFEST_MAGIC + struct.pack("<Q", len(body)) + body
    return head + struct.pack("<I", zlib.crc32(head))


def read_manifest(path: str) -> Optional[dict]:
    """Parse an NCKM manifest at `path`; None when absent or not a
    manifest (plain NCK data files return None).  Schema-2 manifests are
    crc-verified; any truncation or flip raises IntegrityError -- a
    damaged manifest must never be mistaken for a durable one."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except FileNotFoundError:
        return None
    if raw[:4] != _MANIFEST_MAGIC:
        return None
    if len(raw) < 12:
        raise IntegrityError(
            f"{path}: truncated NCKM manifest ({len(raw)} bytes; even the "
            "magic+length prefix is incomplete)")
    (hlen,) = struct.unpack("<Q", raw[4:12])
    body_end = 12 + hlen
    if len(raw) == body_end + 4:
        (stored,) = struct.unpack("<I", raw[body_end:body_end + 4])
        actual = zlib.crc32(raw[:body_end])
        if stored != actual:
            raise CorruptBlockError(path, "<manifest>", None, stored, actual)
    elif len(raw) != body_end:
        raise IntegrityError(
            f"{path}: manifest is {len(raw)} bytes; header declares "
            f"{body_end} (+4-byte checksum trailer) -- truncated or corrupt")
    try:
        m = json.loads(raw[12:body_end])
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise IntegrityError(
            f"{path}: manifest JSON unparseable ({e}) -- corrupt or "
            "truncated") from e
    if not isinstance(m, dict):
        raise IntegrityError(f"{path}: manifest payload is not an object")
    # A schema>=2 manifest is ALWAYS written with its trailer; seeing one
    # without it means the trailer was truncated away.
    if int(m.get("schema", 1)) >= _MANIFEST_SCHEMA and len(raw) == body_end:
        raise IntegrityError(
            f"{path}: schema {m['schema']} manifest is missing its checksum "
            "trailer (truncated)")
    return m


def next_generation(path: str) -> int:
    """Generation for the next multi-process save at `path` (0 when no
    manifest exists yet).  Every rank derives this from the same on-disk
    state before any rank writes, so the fleet agrees without a
    collective."""
    m = read_manifest(path)
    return int(m["generation"]) + 1 if m else 0


def _gc_stale_generations(path: str, keep: Iterable[int]) -> None:
    """Drop rank files of unreferenced generations after a successful
    publish.  ``keep`` is the set of generations the just-committed
    manifest can reach: the current one plus the embedded ``previous``
    (the rollback target must stay loadable)."""
    keep_set = {int(k) for k in keep}
    prefix = path + ".g"
    for f in glob.glob(glob.escape(path) + ".g*.rank*"):
        try:
            gen = int(f[len(prefix):].split(".rank")[0])
        except ValueError:
            continue
        if gen not in keep_set:
            try:
                os.remove(f)
            except OSError:
                pass


def _quarantine(path: str) -> str:
    """Move a corrupt rank file aside as ``<path>.quarantine`` so a
    healthy re-publish of the same name can land while the evidence is
    preserved for postmortem."""
    q = path + ".quarantine"
    i = 0
    while os.path.exists(q):
        i += 1
        q = f"{path}.quarantine{i}"
    # Not a durable publish: the corrupt bytes are LEAVING the committed
    # namespace, and fsyncing known-garbage buys nothing.
    os.replace(path, q)  # repro-lint: disable=format-closure
    return q


def write_manifest(path: str, generation: int, num_ranks: int,
                   steps: List[str], *, timeout: float = 60.0,
                   poll: float = 0.05) -> str:
    """Rank 0's self-healing commit: poll (bounded jittered backoff, hard
    deadline) until every rank file of this generation is published AND
    verifies -- structure, header crc, per-variable digests.  A published
    file that fails verification is quarantined aside and treated as
    not-yet-complete (the writing rank may still re-publish).  Only then
    is the schema-2 manifest (rank sizes + crcs + previous generation)
    atomically committed, and stale generations GC'd -- keeping the
    previous generation as the rollback target.

    On deadline, raises :class:`CommitTimeoutError` BEFORE the manifest
    is touched: its ``report`` names the missing ranks, the quarantined
    files and the generation the logical file remains at.  The previous
    manifest and its rank files stay intact byte for byte.
    """
    files = [rank_file_path(path, generation, r) for r in range(num_ranks)]
    previous = read_manifest(path)  # last durable generation (may be None)
    deadline = time.monotonic() + timeout
    backoff = Backoff(base=poll, factor=1.6, cap=max(poll * 8, 0.25),
                      jitter=0.25).repolling()
    quarantined: List[dict] = []
    crcs: Dict[int, int] = {}

    def scan() -> List[int]:
        missing = []
        for r, f in enumerate(files):
            if r in crcs:
                continue
            if not os.path.exists(f):
                missing.append(r)
                continue
            try:
                verify_nck(f)
                crcs[r] = _file_crc32(f)
            except IntegrityError as e:
                q = _quarantine(f)
                quarantined.append({
                    "rank": r, "file": os.path.basename(f),
                    "quarantined_as": os.path.basename(q),
                    "error": str(e)})
                missing.append(r)  # checksum mismatch == not yet complete
        return missing

    missing = scan()
    for delay in backoff.sleep_until(deadline):
        if not missing:
            break
        time.sleep(delay)
        missing = scan()
    if missing:
        prev_gen = int(previous["generation"]) if previous else None
        report = {
            "path": path, "generation": int(generation),
            "missing_ranks": sorted(missing),
            "quarantined": [q["quarantined_as"] for q in quarantined],
            "quarantine_detail": quarantined,
            "rolled_back_to": prev_gen,
        }
        names = ", ".join(os.path.basename(files[r]) for r in sorted(missing))
        rollback = (f"rolled back to durable generation {prev_gen}"
                    if prev_gen is not None
                    else "no previous durable generation exists")
        raise CommitTimeoutError(
            f"manifest commit for {path}: rank file(s) {names} missing or "
            f"quarantined after {timeout:.0f}s; previous manifest left "
            f"intact ({rollback})", report)
    entries = [{"rank": r, "file": os.path.basename(f),
                "nbytes": os.path.getsize(f), _CRC_KEY: crcs[r]}
               for r, f in enumerate(files)]
    payload = {"schema": _MANIFEST_SCHEMA, "generation": int(generation),
               "num_ranks": int(num_ranks), "ranks": entries,
               "steps": list(steps)}
    keep = {int(generation)}
    if previous is not None:
        # Embed the rollback target (one level deep: its own `previous`
        # is dropped, bounding manifest growth at two generations).
        payload["previous"] = {k: v for k, v in previous.items()
                               if k != "previous"}
        keep.add(int(previous["generation"]))
    with telemetry.span("nck.manifest", path=path, ranks=num_ranks):
        atomic_commit(path, _manifest_bytes(payload))
    _gc_stale_generations(path, keep)
    return path


class ShardNCKWriter:
    """Per-process shard file writer: collects this rank's StepFragments
    and publishes them as one normal NCK file (same magic matrix, same
    atomic_commit discipline).  Rank 0 additionally commits the manifest
    via `commit_manifest` once every rank's file is visible."""

    def __init__(self, path: str, rank: int, num_ranks: int,
                 generation: Optional[int] = None, *,
                 checksums: bool = True):
        self.path = path
        self.rank = rank
        self.num_ranks = num_ranks
        self.generation = (next_generation(path) if generation is None
                           else generation)
        self._w = NCKWriter(checksums=checksums)
        self.steps: List[str] = []

    @property
    def rank_path(self) -> str:
        return rank_file_path(self.path, self.generation, self.rank)

    def add_fragment(self, name: str, frag: StepFragment):
        info = dict(frag.info)
        info["block_start"] = int(frag.block_start)
        info["frag_blocks"] = len(frag.index_blocks)
        info["frag_rank"] = self.rank
        if frag.block_codecs is not None:
            info["block_codecs"] = [str(c) for c in frag.block_codecs]
            self._w.bump_format(2)
        if _blobs_have_symbol_rans(frag.index_blocks,
                                   info.get("codec", "zlib"),
                                   frag.block_codecs):
            self._w.bump_format(3)
        sizes = np.array([len(b) for b in frag.index_blocks], np.int64)
        offs = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        counts = None
        if not frag.is_anchor:
            counts = (frag.incomp_block_counts
                      if frag.incomp_block_counts is not None
                      else np.zeros(len(frag.index_blocks), np.int64))
            info["frag_n_incompressible"] = int(np.sum(counts))
        self._w.add_array(f"{name}_frag_info", np.zeros(1, np.int32),
                          attrs=info)
        self._w.add_array(f"{name}_frag_index_table_offset", offs)
        self._w.add_bytes(f"{name}_frag_index_table",
                          b"".join(frag.index_blocks),
                          block_crcs=self._w._block_crcs(frag.index_blocks))
        if not frag.is_anchor:
            self._w.add_array(f"{name}_frag_incompressible_counts",
                              np.asarray(counts, np.int64))
            values = (frag.incomp_values if frag.incomp_values is not None
                      else np.zeros(0, info.get("dtype", "float32")))
            self._w.add_array(f"{name}_frag_incompressible_table", values)
            if frag.centers is not None:
                self._w.add_array(f"{name}_bin_centers",
                                  frag.centers.astype(info["dtype"]))
        self.steps.append(name)

    def write(self) -> str:
        """Atomically publish this rank's shard file; returns its path."""
        self._w.write(self.rank_path)
        return self.rank_path

    def commit_manifest(self, *, timeout: float = 60.0) -> str:
        """Rank 0 only: publish the manifest once all rank files exist."""
        if self.rank != 0:
            raise ValueError("only rank 0 commits the manifest")
        return write_manifest(self.path, self.generation, self.num_ranks,
                              self.steps, timeout=timeout)


class NCKReader:
    """Offset-based reader; `read` pulls only the requested byte range.

    Opening an NCKM manifest presents the per-rank shard files as one
    logical file: `step_names`/`read_step`/`attrs`/`read_array` work
    unchanged, with fragments merged back into CompressedSteps identical
    to a single-process write.  A manifest referencing a missing or
    damaged rank file is rejected at open with an error naming the shard
    -- unless the manifest embeds a previous durable generation, in which
    case the reader falls back to it (``recovered_generation`` records
    the fallback, ``fallback_cause`` the error that forced it).

    Integrity: NCK4 headers are crc-verified at open; every version gets
    a structural truncation check (file size vs. variable extents); full
    reads verify the whole-variable digest and block-sliced reads verify
    per-block digests via :meth:`verify_blocks`.  Parse failures surface
    as :class:`IntegrityError`, never a raw json/struct traceback.
    """

    def __init__(self, path: str):
        self.path = path
        self.manifest: Optional[dict] = None
        self._rank_readers: List["NCKReader"] = []
        self.recovered_generation: Optional[int] = None
        self.fallback_cause: Optional[Exception] = None
        with open(path, "rb") as f:
            magic = f.read(4)
            if magic == _MANIFEST_MAGIC:
                self.manifest = read_manifest(path)
                if self.manifest is None:
                    raise IntegrityError(f"{path}: unreadable NCKM manifest")
                try:
                    self._open_ranks(path)
                except (FileNotFoundError, IntegrityError) as e:
                    prev = self.manifest.get("previous")
                    if not prev:
                        raise
                    # Newest generation unverifiable: fall back to the
                    # last durable one (its rank files survive GC).
                    self._rank_readers = []
                    self.manifest = prev
                    self._open_ranks(path)
                    self.recovered_generation = int(prev["generation"])
                    self.fallback_cause = e
                return
            if magic not in _MAGICS:
                raise IntegrityError(
                    f"{path}: not an NCK file (magic {magic!r} unknown; "
                    "corrupt, truncated, or not written by this format)")
            self.format_version = _MAGICS[magic]
            raw8 = f.read(8)
            if len(raw8) != 8:
                raise IntegrityError(f"{path}: truncated NCK length prefix")
            (hlen,) = struct.unpack("<Q", raw8)
            # Bound the declared length BEFORE allocating for it: a
            # flipped high bit in the u64 must raise, not MemoryError.
            if hlen > os.path.getsize(path):
                raise IntegrityError(
                    f"{path}: header length field claims {hlen} bytes in a "
                    f"{os.path.getsize(path)}-byte file (corrupt length "
                    "prefix)")
            prefix = 4 + 8
            stored_crc: Optional[int] = None
            if self.format_version >= 4:
                raw4 = f.read(4)
                if len(raw4) != 4:
                    raise IntegrityError(
                        f"{path}: truncated NCK4 header checksum")
                (stored_crc,) = struct.unpack("<I", raw4)
                prefix += 4
            hdr = f.read(hlen)
            if len(hdr) != hlen:
                raise IntegrityError(
                    f"{path}: truncated NCK header ({len(hdr)} of {hlen} "
                    "bytes)")
            padlen = _pad(prefix + hlen)
            pad = f.read(padlen)
            if stored_crc is not None:
                actual = zlib.crc32(hdr + pad)
                if len(pad) != padlen or actual != stored_crc:
                    raise CorruptBlockError(path, "<header>", None,
                                            stored_crc, actual)
            try:
                header = json.loads(hdr)
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                raise IntegrityError(
                    f"{path}: NCK header is not valid JSON ({e}) -- file "
                    "corrupt or truncated") from e
        try:
            self.variables = header["variables"]
            self.dimensions = header["dimensions"]
            end = max((int(v["offset"]) + int(v["nbytes"])
                       for v in self.variables.values()), default=0)
        except (KeyError, TypeError, ValueError, AttributeError) as e:
            raise IntegrityError(
                f"{path}: NCK header is structurally malformed ({e!r}) -- "
                "file corrupt") from e
        self._data_start = prefix + hlen + padlen
        size = os.path.getsize(path)
        if size < self._data_start + end:
            raise IntegrityError(
                f"{path}: file is {size} bytes but variables extend to "
                f"byte {self._data_start + end} (truncated)")

    # ------------------------------------------------- manifest handling
    def _open_ranks(self, path: str):
        base = os.path.dirname(os.path.abspath(path))
        for e in self.manifest["ranks"]:
            rp = os.path.join(base, e["file"])
            if not os.path.exists(rp):
                raise FileNotFoundError(
                    f"manifest {path} references missing shard file "
                    f"{e['file']} (rank {e['rank']}); the rank file set "
                    "is incomplete")
            size = os.path.getsize(rp)
            if size != e["nbytes"]:
                raise CorruptShardError(
                    path, e["file"], e["rank"],
                    f"file is {size} bytes, manifest recorded "
                    f"{e['nbytes']} (modified or torn after commit)")
            if _CRC_KEY in e:
                actual = _file_crc32(rp)
                if actual != e[_CRC_KEY]:
                    raise CorruptShardError(
                        path, e["file"], e["rank"],
                        f"whole-file checksum mismatch: expected "
                        f"crc32=0x{e[_CRC_KEY]:08x}, got 0x{actual:08x}")
            try:
                self._rank_readers.append(NCKReader(rp))
            except IntegrityError as err:
                raise CorruptShardError(path, e["file"], e["rank"],
                                        str(err)) from err
        self.format_version = max(r.format_version
                                  for r in self._rank_readers)
        # Union view of the per-rank variable spaces (fragment names are
        # disjoint across ranks except replicated extras like centers,
        # where any copy serves).
        self.variables = {}
        self.dimensions = {}
        self._var_owner: Dict[str, "NCKReader"] = {}
        for r in self._rank_readers:
            for v, rec in r.variables.items():
                if v not in self.variables:
                    self.variables[v] = rec
                    self._var_owner[v] = r
            self.dimensions.update(r.dimensions)

    def attrs(self, name: str) -> dict:
        return self.variables[name]["attributes"]

    def read(self, name: str, byte_start: int = 0,
             byte_stop: Optional[int] = None) -> bytes:
        if self.manifest is not None:
            return self._var_owner[name].read(name, byte_start, byte_stop)
        v = self.variables[name]
        stop = v["nbytes"] if byte_stop is None else min(byte_stop,
                                                         v["nbytes"])
        want = max(stop - byte_start, 0)
        with open(self.path, "rb") as f:
            f.seek(self._data_start + v["offset"] + byte_start)
            data = f.read(want)
        if len(data) != want:
            raise IntegrityError(
                f"{self.path}: variable {name!r} byte range [{byte_start},"
                f"{stop}) short by {want - len(data)} bytes (file "
                "truncated)")
        # Full reads of unblocked variables verify the whole-payload
        # digest here; blocked variables are verified per sliced block at
        # the slicing site (verify_blocks) to avoid digesting twice.
        if (byte_start == 0 and stop == v["nbytes"] and _CRC_KEY in v
                and _BLOCK_CRC_KEY not in v):
            actual = zlib.crc32(data)
            if actual != v[_CRC_KEY]:
                raise CorruptBlockError(self.path, name, None,
                                        v[_CRC_KEY], actual)
        return data

    def read_array(self, name: str) -> np.ndarray:
        v = self.variables[name]
        raw = self.read(name)
        try:
            return np.frombuffer(raw, dtype=v["dtype"]).reshape(v["shape"])
        except (ValueError, TypeError) as e:
            raise IntegrityError(
                f"{self.path}: variable {name!r} payload does not match "
                f"its recorded dtype/shape ({e}) -- header or data "
                "corrupt") from e

    def verify_blocks(self, name: str, blocks: Sequence[bytes],
                      first_block: int = 0) -> None:
        """Check sliced block payloads against the per-block checksum
        frame.  No-op for files without one (NCK1/2/3 or checksums=False
        writers); raises :class:`CorruptBlockError` naming the first bad
        block otherwise.  ``first_block`` is the global index of
        ``blocks[0]`` (partial reads verify only the slice they touch)."""
        if self.manifest is not None:
            return self._var_owner[name].verify_blocks(name, blocks,
                                                       first_block)
        crcs = self.variables[name].get(_BLOCK_CRC_KEY)
        if crcs is None:
            return
        for i, b in enumerate(blocks):
            bi = first_block + i
            if bi >= len(crcs):
                raise IntegrityError(
                    f"{self.path}: variable {name!r} records "
                    f"{len(crcs)} checksummed blocks but block {bi} was "
                    "requested (offset table corrupt)")
            actual = zlib.crc32(b)
            if actual != crcs[bi]:
                raise CorruptBlockError(self.path, name, bi, crcs[bi],
                                        actual)

    def _read_step_merged(self, name: str) -> CompressedStep:
        """Merge one step's per-rank fragments (inverse of the
        ShardNCKWriter tier): blocks, exception values and per-block
        counts concatenate in global block order; replicated attrs come
        from the lowest-ranked fragment.  The result is field-identical
        to the same data written by a single process."""
        frags = []
        for r in self._rank_readers:
            if f"{name}_frag_info" in r.variables:
                frags.append((r.attrs(f"{name}_frag_info"), r))
        if not frags:
            raise KeyError(f"step {name} not present in any shard file "
                           f"of manifest {self.path}")
        frags.sort(key=lambda fr: fr[0]["block_start"])
        info = frags[0][0]
        blks: List[bytes] = []
        for fi, r in frags:
            offs = r.read_array(f"{name}_frag_index_table_offset")
            table = r.read(f"{name}_frag_index_table")
            fr_blks = [table[offs[i]:offs[i + 1]]
                       for i in range(len(offs) - 1)]
            r.verify_blocks(f"{name}_frag_index_table", fr_blks)
            blks += fr_blks
        if info["is_anchor"]:
            return CompressedStep(
                n=info["total_data_num"], shape=tuple(info["shape"]),
                dtype=info["dtype"], b_bits=0,
                error_bound=info["error_bound"], strategy=info["strategy"],
                reference=info["reference"], domain_lo=0.0, bin_width=0.0,
                centers=np.zeros(0),
                block_elems=info["elements_per_block"],
                codec=info.get("codec", "zlib"), index_blocks=blks)
        counts = np.concatenate(
            [r.read_array(f"{name}_frag_incompressible_counts")
             for _, r in frags]) if frags else np.zeros(0, np.int64)
        values = np.concatenate(
            [r.read_array(f"{name}_frag_incompressible_table")
             for _, r in frags])
        incomp_off = np.concatenate(
            [[0], np.cumsum(counts)])[:-1].astype(np.int64)
        # Per-block codec ids merge in block order; a uniform result
        # collapses back to the step-level codec (format parity with the
        # single-process writer).
        per: List[str] = []
        for fi, r in frags:
            nb = fi["frag_blocks"]
            per += (list(fi["block_codecs"]) if "block_codecs" in fi
                    else [fi.get("codec", "zlib")] * nb)
        block_codecs: Optional[List[str]] = None
        codec = info.get("codec", "zlib")
        if len(set(per)) > 1:
            from repro.core.pipeline import _primary_codec
            block_codecs, codec = per, _primary_codec(per)
        return CompressedStep(
            n=info["total_data_num"], shape=tuple(info["shape"]),
            dtype=info["dtype"], b_bits=info["B"],
            error_bound=info["error_bound"], strategy=info["strategy"],
            reference=info["reference"], domain_lo=info["domain_lo"],
            bin_width=info["bin_width"],
            centers=self.read_array(f"{name}_bin_centers"
                                    ).astype(np.float64),
            block_elems=info["elements_per_block"], codec=codec,
            block_codecs=block_codecs, index_blocks=blks,
            incomp_values=values, incomp_block_offsets=incomp_off)

    def read_step(self, name: str) -> CompressedStep:
        """Inverse of NCKWriter.add_step."""
        if self.manifest is not None:
            return self._read_step_merged(name)
        if f"{name}_anchor" in self.variables:
            info = self.attrs(f"{name}_anchor_info")
            offs = self.read_array(f"{name}_anchor_offset")
            table = self.read(f"{name}_anchor")
            blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
            self.verify_blocks(f"{name}_anchor", blks)
            return CompressedStep(
                n=info["total_data_num"], shape=tuple(info["shape"]),
                dtype=info["dtype"], b_bits=0,
                error_bound=info["error_bound"], strategy=info["strategy"],
                reference=info["reference"], domain_lo=0.0, bin_width=0.0,
                centers=np.zeros(0),
                block_elems=info["elements_per_block"],
                codec=info.get("codec", "zlib"), index_blocks=blks)
        info = self.attrs(f"{name}_info")
        offs = self.read_array(f"{name}_index_table_offset")
        table = self.read(f"{name}_index_table")
        blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
        self.verify_blocks(f"{name}_index_table", blks)
        return CompressedStep(
            n=info["total_data_num"], shape=tuple(info["shape"]),
            dtype=info["dtype"], b_bits=info["B"],
            error_bound=info["error_bound"], strategy=info["strategy"],
            reference=info["reference"], domain_lo=info["domain_lo"],
            bin_width=info["bin_width"],
            centers=self.read_array(f"{name}_bin_centers").astype(np.float64),
            block_elems=info["elements_per_block"],
            codec=info.get("codec", "zlib"),
            block_codecs=info.get("block_codecs"), index_blocks=blks,
            incomp_values=self.read_array(f"{name}_incompressible_table"),
            incomp_block_offsets=self.read_array(
                f"{name}_incompressible_table_offset"))

    def step_names(self) -> List[str]:
        if self.manifest is not None:
            return sorted(set(self.manifest["steps"]))
        names = set()
        for v in self.variables:
            if v.endswith("_anchor_info"):
                names.add(v[: -len("_anchor_info")])
            elif v.endswith("_frag_info"):
                names.add(v[: -len("_frag_info")])
            elif v.endswith("_info"):
                names.add(v[: -len("_info")])
        return sorted(names)


def verify_nck(path: str) -> None:
    """Full structural + checksum verification of one NCK data file:
    header parse, truncation extents, every variable's whole-payload
    digest (NCK4).  Raises :class:`IntegrityError` (or a subclass) on
    any damage; returns None on a clean file.  Used by rank 0's manifest
    commit to decide published-and-complete vs. quarantine."""
    r = NCKReader(path)
    if r.manifest is not None:
        raise IntegrityError(f"{path}: is an NCKM manifest, not a data file")
    for name, v in r.variables.items():
        data = r.read(name)  # verifies unblocked digests itself
        if _CRC_KEY in v and _BLOCK_CRC_KEY in v:
            actual = zlib.crc32(data)
            if actual != v[_CRC_KEY]:
                raise CorruptBlockError(path, name, None, v[_CRC_KEY],
                                        actual)


__all__ = ["NCKWriter", "NCKReader", "ShardNCKWriter", "StepFragment",
           "atomic_commit", "write_manifest", "read_manifest",
           "next_generation", "rank_file_path", "verify_nck"]
