"""NCK container: netCDF-analogue file format (paper Sec. IV-D, Fig. 2).

No netCDF library is available in this environment, so we use a
self-describing single-file container with the *same logical layout* as the
paper's netCDF output:

  magic "NCK1" | u64 header_len | JSON header | pad->64 | section bytes ...

The JSON header mirrors netCDF dimensions/variables/attributes.  Each
compressed variable V (one per iteration per field) stores, exactly as in
Fig. 2:

  V_info                      -- attributes (total_data_num, bin_centers_number,
                                 elements_per_block, B, E, strategy, ...)
  V_bin_centers               -- float array
  V_index_table_offset        -- int64 byte offsets of deflated blocks
  V_incompressible_table_offset -- int64 per-block exception count prefix
  V_index_table               -- concatenated deflated blocks (byte array)
  V_incompressible_table      -- original-dtype exception values

Multiple variables per file are supported (paper: "NUMARCK allows multiple
compressed variables stored in one netCDF file").  Reads are offset-based so
partial decompression touches only the needed byte ranges.

Format versions: files whose steps all use one codec per step keep the
original "NCK1" magic (readable by every reader ever shipped); files
carrying per-*block* codec ids -- a layout older readers cannot decode
correctly -- are stamped "NCK2", so old readers reject them cleanly at
open instead of mis-decoding blocks.  Files carrying symbol-level rANS
blocks (kernels.rans v2 blobs, coding pre-pack B-bit indices -- bytes
older rANS decoders cannot parse) are stamped "NCK3" by the same
mechanism: the writer peeks each rans block's self-describing version
byte when the step is added.  This reader accepts all three.

Multi-process output (paper Sec. IV-D collective write analogue): each
process writes only its own blocks to a generation-suffixed rank file
``<path>.g<gen>.rank<k>`` -- a normal NCK file holding *step fragments*
-- and rank 0 publishes ``<path>`` as an "NCKM" manifest naming the rank
files.  Payload bytes never cross processes; `NCKReader` opens the
manifest as one logical file and merges fragments back into
`CompressedStep`s byte-identical to a single-process write.  All file
publishes (rank files, manifest, checkpoint manifests) go through
`atomic_commit`: content is fsynced *before* the rename makes it
visible, so a crashed rank can never leave a half-written file under a
published name, and a failed commit leaves the previous manifest (and
the rank files it references) untouched.
"""
from __future__ import annotations

import glob
import json
import os
import struct
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Union

import numpy as np

from repro.core.types import CompressedStep
from repro.obs import telemetry

_MAGIC_V1 = b"NCK1"
_MAGIC_V2 = b"NCK2"
_MAGIC_V3 = b"NCK3"
_MAGICS = {_MAGIC_V1: 1, _MAGIC_V2: 2, _MAGIC_V3: 3}
_MAGIC = _MAGIC_V1              # legacy alias (default / pre-PR files)
_MANIFEST_MAGIC = b"NCKM"       # multi-process manifest (not a data file)
_ALIGN = 64


def atomic_commit(path: str, data: Union[bytes, Iterable[bytes]]) -> None:
    """Durable atomic publish: write to `path`.tmp, fsync, then rename.

    The one sanctioned way to make a file appear under a published name
    (NCK files, multi-process manifests, checkpoint manifests all route
    here; repro-lint's format pass flags any other os.replace/os.rename
    in the tree).  fsync runs BEFORE the rename so a crash can never
    publish a name whose content is not yet on disk.
    """
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        if isinstance(data, (bytes, bytearray, memoryview)):
            f.write(data)
        else:
            for chunk in data:
                f.write(chunk)
        f.flush()
        # durable BEFORE the rename publishes it
        with telemetry.span("nck.fsync"):
            os.fsync(f.fileno())
    with telemetry.span("nck.rename"):
        os.replace(tmp, path)  # atomic publish (fault tolerance)


def _blobs_have_symbol_rans(blobs: List[bytes], codec: str,
                            block_codecs: Optional[List[str]]) -> bool:
    """Does any rans blob in this list carry the symbol-level (v2) blob
    format?  Old readers' rANS decoders cannot parse those bytes, so the
    file must not present itself as NCK1/NCK2."""
    from repro.kernels import rans
    for bi, blob in enumerate(blobs):
        c = block_codecs[bi] if block_codecs else codec
        if c != "rans" or len(blob) < 5:
            continue
        if rans.blob_version(blob) == 2:
            return True
    return False


def _has_symbol_blobs(step: CompressedStep) -> bool:
    return _blobs_have_symbol_rans(step.index_blocks, step.codec,
                                   step.block_codecs)


def _pad(n: int) -> int:
    return (-n) % _ALIGN


class NCKWriter:
    """Assemble sections then write the file in one shot (or via append)."""

    def __init__(self):
        self._sections: List[bytes] = []
        self._vars: Dict[str, dict] = {}
        self._dims: Dict[str, int] = {}
        self._offset = 0
        # Bumped to 2 the moment a step with per-block codec ids is added;
        # NCK1 files must stay readable by pre-per-block readers.
        self._format_version = 1

    def add_array(self, name: str, arr: np.ndarray, attrs: Optional[dict] = None):
        arr = np.ascontiguousarray(arr)
        self._add_bytes(name, arr.tobytes(), str(arr.dtype), list(arr.shape),
                        attrs)

    def add_bytes(self, name: str, raw: bytes, attrs: Optional[dict] = None):
        self._add_bytes(name, raw, "uint8", [len(raw)], attrs)

    def _add_bytes(self, name, raw, dtype, shape, attrs):
        if name in self._vars:
            raise ValueError(f"duplicate variable {name}")
        self._vars[name] = dict(dtype=dtype, shape=shape, offset=self._offset,
                                nbytes=len(raw), attributes=attrs or {})
        self._dims[f"{name}_dim"] = int(np.prod(shape)) if shape else 1
        self._sections.append(raw)
        self._offset += len(raw) + _pad(len(raw))

    def add_step(self, name: str, step: CompressedStep):
        """Store one CompressedStep under variable prefix `name` (Fig. 2)."""
        info = dict(
            total_data_num=step.n, shape=list(step.shape), dtype=step.dtype,
            bin_centers_number=int(step.centers.size),
            elements_per_block=step.block_elems, B=step.b_bits,
            error_bound=step.error_bound, strategy=step.strategy,
            reference=step.reference, domain_lo=step.domain_lo,
            bin_width=step.bin_width, is_anchor=bool(step.is_anchor),
            n_blocks=step.n_blocks,
            n_incompressible=step.n_incompressible,
            codec=step.codec,
        )
        if step.block_codecs is not None:
            info["block_codecs"] = [str(c) for c in step.block_codecs]
            self._format_version = max(self._format_version, 2)
        if _has_symbol_blobs(step):
            self._format_version = 3
        offs_all = np.concatenate(
            [step.index_table_offsets(),
             [sum(len(b) for b in step.index_blocks)]]).astype(np.int64)
        if step.is_anchor:
            self.add_array(f"{name}_anchor_info", np.zeros(1, np.int32),
                           attrs=info)
            self.add_array(f"{name}_anchor_offset", offs_all)
            self.add_bytes(f"{name}_anchor", b"".join(step.index_blocks))
            return
        self.add_array(f"{name}_info", np.zeros(1, np.int32), attrs=info)
        self.add_array(f"{name}_bin_centers",
                       step.centers.astype(step.dtype))
        self.add_array(f"{name}_index_table_offset", offs_all)
        self.add_array(f"{name}_incompressible_table_offset",
                       np.asarray(step.incomp_block_offsets, np.int64))
        self.add_bytes(f"{name}_index_table",
                       b"".join(step.index_blocks))
        self.add_array(f"{name}_incompressible_table", step.incomp_values)

    def bump_format(self, version: int):
        """Raise the file format floor (2: per-block codec ids, 3: symbol
        rANS blobs) -- `add_step` does this itself; fragment writers that
        assemble steps from raw variables declare it explicitly."""
        self._format_version = max(self._format_version, version)

    def _chunks(self) -> Iterable[bytes]:
        header = json.dumps({"dimensions": self._dims,
                             "variables": self._vars}).encode()
        magic = {1: _MAGIC_V1, 2: _MAGIC_V2,
                 3: _MAGIC_V3}[self._format_version]
        yield magic
        yield struct.pack("<Q", len(header))
        yield header
        yield b"\0" * _pad(len(_MAGIC) + 8 + len(header))
        for raw in self._sections:
            yield raw
            yield b"\0" * _pad(len(raw))

    def write(self, path: str):
        with telemetry.span("nck.write", path=path,
                            sections=len(self._sections)):
            atomic_commit(path, self._chunks())


# --------------------------------------------------------------------------
# Multi-process tier: per-rank fragment files + rank-0 manifest.
# --------------------------------------------------------------------------

@dataclass
class StepFragment:
    """One process's contiguous slice of a CompressedStep (paper Sec.
    IV-D: every rank writes its own blocks; nothing is gathered).

    ``info`` carries the *global* step attributes every rank knows from
    the replicated analyze outputs (n, shape, B, domain, codec, ...);
    ``block_start`` anchors this fragment's blocks in the global block
    order.  ``centers`` is set on rank 0 only -- it is replicated data,
    so one copy per logical file suffices.
    """

    is_anchor: bool
    block_start: int
    info: dict
    index_blocks: List[bytes] = field(default_factory=list)
    centers: Optional[np.ndarray] = None
    incomp_values: Optional[np.ndarray] = None
    incomp_block_counts: Optional[np.ndarray] = None
    block_codecs: Optional[List[str]] = None
    # Driver telemetry (per-rank phase seconds etc.); never persisted --
    # the rank file stores `info` attrs only, mirroring CompressedStep.
    meta: dict = field(default_factory=dict)


def rank_file_path(path: str, generation: int, rank: int) -> str:
    """Per-rank NCK shard file name: ``<path>.g<gen>.rank<k>``.  The
    generation suffix keeps a crashed save's partial output disjoint from
    every published generation -- a mixed-generation file set can never
    be referenced by one manifest."""
    return f"{path}.g{generation:04d}.rank{rank}"


def read_manifest(path: str) -> Optional[dict]:
    """Parse an NCKM manifest at `path`; None when absent or not a
    manifest (plain NCK data files return None)."""
    try:
        with open(path, "rb") as f:
            if f.read(4) != _MANIFEST_MAGIC:
                return None
            (hlen,) = struct.unpack("<Q", f.read(8))
            return json.loads(f.read(hlen))
    except FileNotFoundError:
        return None


def next_generation(path: str) -> int:
    """Generation for the next multi-process save at `path` (0 when no
    manifest exists yet).  Every rank derives this from the same on-disk
    state before any rank writes, so the fleet agrees without a
    collective."""
    m = read_manifest(path)
    return int(m["generation"]) + 1 if m else 0


def _gc_stale_generations(path: str, keep: int) -> None:
    """Drop rank files of other generations after a successful publish
    (they are unreferenced: the just-committed manifest is the only
    reader entry point)."""
    prefix = path + ".g"
    for f in glob.glob(glob.escape(path) + ".g*.rank*"):
        try:
            gen = int(f[len(prefix):].split(".rank")[0])
        except ValueError:
            continue
        if gen != keep:
            try:
                os.remove(f)
            except OSError:
                pass


def write_manifest(path: str, generation: int, num_ranks: int,
                   steps: List[str], *, timeout: float = 60.0,
                   poll: float = 0.05) -> str:
    """Rank 0's commit: wait for every rank file of this generation to be
    published (rank files appear atomically, so existence == complete),
    then atomically publish the manifest and GC stale generations.

    A missing rank file (crashed rank) raises TimeoutError BEFORE the
    manifest is touched: the previous generation's manifest and rank
    files stay intact and loadable.
    """
    files = [rank_file_path(path, generation, r) for r in range(num_ranks)]
    deadline = time.monotonic() + timeout
    for f in files:
        while not os.path.exists(f):
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"manifest commit for {path}: rank file "
                    f"{os.path.basename(f)} missing after {timeout:.0f}s; "
                    "previous manifest left intact")
            time.sleep(poll)
    entries = [{"rank": r, "file": os.path.basename(f),
                "nbytes": os.path.getsize(f)}
               for r, f in enumerate(files)]
    payload = json.dumps({"schema": 1, "generation": int(generation),
                          "num_ranks": int(num_ranks), "ranks": entries,
                          "steps": list(steps)}).encode()
    with telemetry.span("nck.manifest", path=path, ranks=num_ranks):
        atomic_commit(path,
                      _MANIFEST_MAGIC + struct.pack("<Q", len(payload))
                      + payload)
    _gc_stale_generations(path, generation)
    return path


class ShardNCKWriter:
    """Per-process shard file writer: collects this rank's StepFragments
    and publishes them as one normal NCK file (same magic matrix, same
    atomic_commit discipline).  Rank 0 additionally commits the manifest
    via `commit_manifest` once every rank's file is visible."""

    def __init__(self, path: str, rank: int, num_ranks: int,
                 generation: Optional[int] = None):
        self.path = path
        self.rank = rank
        self.num_ranks = num_ranks
        self.generation = (next_generation(path) if generation is None
                           else generation)
        self._w = NCKWriter()
        self.steps: List[str] = []

    @property
    def rank_path(self) -> str:
        return rank_file_path(self.path, self.generation, self.rank)

    def add_fragment(self, name: str, frag: StepFragment):
        info = dict(frag.info)
        info["block_start"] = int(frag.block_start)
        info["frag_blocks"] = len(frag.index_blocks)
        info["frag_rank"] = self.rank
        if frag.block_codecs is not None:
            info["block_codecs"] = [str(c) for c in frag.block_codecs]
            self._w.bump_format(2)
        if _blobs_have_symbol_rans(frag.index_blocks,
                                   info.get("codec", "zlib"),
                                   frag.block_codecs):
            self._w.bump_format(3)
        sizes = np.array([len(b) for b in frag.index_blocks], np.int64)
        offs = np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)
        counts = None
        if not frag.is_anchor:
            counts = (frag.incomp_block_counts
                      if frag.incomp_block_counts is not None
                      else np.zeros(len(frag.index_blocks), np.int64))
            info["frag_n_incompressible"] = int(np.sum(counts))
        self._w.add_array(f"{name}_frag_info", np.zeros(1, np.int32),
                          attrs=info)
        self._w.add_array(f"{name}_frag_index_table_offset", offs)
        self._w.add_bytes(f"{name}_frag_index_table",
                          b"".join(frag.index_blocks))
        if not frag.is_anchor:
            self._w.add_array(f"{name}_frag_incompressible_counts",
                              np.asarray(counts, np.int64))
            values = (frag.incomp_values if frag.incomp_values is not None
                      else np.zeros(0, info.get("dtype", "float32")))
            self._w.add_array(f"{name}_frag_incompressible_table", values)
            if frag.centers is not None:
                self._w.add_array(f"{name}_bin_centers",
                                  frag.centers.astype(info["dtype"]))
        self.steps.append(name)

    def write(self) -> str:
        """Atomically publish this rank's shard file; returns its path."""
        self._w.write(self.rank_path)
        return self.rank_path

    def commit_manifest(self, *, timeout: float = 60.0) -> str:
        """Rank 0 only: publish the manifest once all rank files exist."""
        if self.rank != 0:
            raise ValueError("only rank 0 commits the manifest")
        return write_manifest(self.path, self.generation, self.num_ranks,
                              self.steps, timeout=timeout)


class NCKReader:
    """Offset-based reader; `read` pulls only the requested byte range.

    Opening an NCKM manifest presents the per-rank shard files as one
    logical file: `step_names`/`read_step`/`attrs`/`read_array` work
    unchanged, with fragments merged back into CompressedSteps identical
    to a single-process write.  A manifest referencing a missing or
    truncated rank file is rejected at open with an error naming the
    shard -- it never silently reads a partial save.
    """

    def __init__(self, path: str):
        self.path = path
        self.manifest: Optional[dict] = None
        self._rank_readers: List["NCKReader"] = []
        with open(path, "rb") as f:
            magic = f.read(4)
            if magic == _MANIFEST_MAGIC:
                (hlen,) = struct.unpack("<Q", f.read(8))
                self.manifest = json.loads(f.read(hlen))
                self._open_ranks(path)
                return
            if magic not in _MAGICS:
                raise ValueError(f"{path}: not an NCK file")
            self.format_version = _MAGICS[magic]
            (hlen,) = struct.unpack("<Q", f.read(8))
            header = json.loads(f.read(hlen))
        self.variables = header["variables"]
        self.dimensions = header["dimensions"]
        self._data_start = 4 + 8 + hlen + _pad(4 + 8 + hlen)

    # ------------------------------------------------- manifest handling
    def _open_ranks(self, path: str):
        base = os.path.dirname(os.path.abspath(path))
        for e in self.manifest["ranks"]:
            rp = os.path.join(base, e["file"])
            if not os.path.exists(rp):
                raise FileNotFoundError(
                    f"manifest {path} references missing shard file "
                    f"{e['file']} (rank {e['rank']}); the rank file set "
                    "is incomplete")
            size = os.path.getsize(rp)
            if size != e["nbytes"]:
                raise ValueError(
                    f"manifest {path}: shard file {e['file']} is {size} "
                    f"bytes, manifest recorded {e['nbytes']} (rank "
                    f"{e['rank']} file was modified after commit)")
            self._rank_readers.append(NCKReader(rp))
        self.format_version = max(r.format_version
                                  for r in self._rank_readers)
        # Union view of the per-rank variable spaces (fragment names are
        # disjoint across ranks except replicated extras like centers,
        # where any copy serves).
        self.variables = {}
        self.dimensions = {}
        self._var_owner: Dict[str, "NCKReader"] = {}
        for r in self._rank_readers:
            for v, rec in r.variables.items():
                if v not in self.variables:
                    self.variables[v] = rec
                    self._var_owner[v] = r
            self.dimensions.update(r.dimensions)

    def attrs(self, name: str) -> dict:
        return self.variables[name]["attributes"]

    def read(self, name: str, byte_start: int = 0,
             byte_stop: Optional[int] = None) -> bytes:
        if self.manifest is not None:
            return self._var_owner[name].read(name, byte_start, byte_stop)
        v = self.variables[name]
        stop = v["nbytes"] if byte_stop is None else min(byte_stop,
                                                         v["nbytes"])
        with open(self.path, "rb") as f:
            f.seek(self._data_start + v["offset"] + byte_start)
            return f.read(max(stop - byte_start, 0))

    def read_array(self, name: str) -> np.ndarray:
        v = self.variables[name]
        raw = self.read(name)
        return np.frombuffer(raw, dtype=v["dtype"]).reshape(v["shape"])

    def _read_step_merged(self, name: str) -> CompressedStep:
        """Merge one step's per-rank fragments (inverse of the
        ShardNCKWriter tier): blocks, exception values and per-block
        counts concatenate in global block order; replicated attrs come
        from the lowest-ranked fragment.  The result is field-identical
        to the same data written by a single process."""
        frags = []
        for r in self._rank_readers:
            if f"{name}_frag_info" in r.variables:
                frags.append((r.attrs(f"{name}_frag_info"), r))
        if not frags:
            raise KeyError(f"step {name} not present in any shard file "
                           f"of manifest {self.path}")
        frags.sort(key=lambda fr: fr[0]["block_start"])
        info = frags[0][0]
        blks: List[bytes] = []
        for fi, r in frags:
            offs = r.read_array(f"{name}_frag_index_table_offset")
            table = r.read(f"{name}_frag_index_table")
            blks += [table[offs[i]:offs[i + 1]]
                     for i in range(len(offs) - 1)]
        if info["is_anchor"]:
            return CompressedStep(
                n=info["total_data_num"], shape=tuple(info["shape"]),
                dtype=info["dtype"], b_bits=0,
                error_bound=info["error_bound"], strategy=info["strategy"],
                reference=info["reference"], domain_lo=0.0, bin_width=0.0,
                centers=np.zeros(0),
                block_elems=info["elements_per_block"],
                codec=info.get("codec", "zlib"), index_blocks=blks)
        counts = np.concatenate(
            [r.read_array(f"{name}_frag_incompressible_counts")
             for _, r in frags]) if frags else np.zeros(0, np.int64)
        values = np.concatenate(
            [r.read_array(f"{name}_frag_incompressible_table")
             for _, r in frags])
        incomp_off = np.concatenate(
            [[0], np.cumsum(counts)])[:-1].astype(np.int64)
        # Per-block codec ids merge in block order; a uniform result
        # collapses back to the step-level codec (format parity with the
        # single-process writer).
        per: List[str] = []
        for fi, r in frags:
            nb = fi["frag_blocks"]
            per += (list(fi["block_codecs"]) if "block_codecs" in fi
                    else [fi.get("codec", "zlib")] * nb)
        block_codecs: Optional[List[str]] = None
        codec = info.get("codec", "zlib")
        if len(set(per)) > 1:
            from repro.core.pipeline import _primary_codec
            block_codecs, codec = per, _primary_codec(per)
        return CompressedStep(
            n=info["total_data_num"], shape=tuple(info["shape"]),
            dtype=info["dtype"], b_bits=info["B"],
            error_bound=info["error_bound"], strategy=info["strategy"],
            reference=info["reference"], domain_lo=info["domain_lo"],
            bin_width=info["bin_width"],
            centers=self.read_array(f"{name}_bin_centers"
                                    ).astype(np.float64),
            block_elems=info["elements_per_block"], codec=codec,
            block_codecs=block_codecs, index_blocks=blks,
            incomp_values=values, incomp_block_offsets=incomp_off)

    def read_step(self, name: str) -> CompressedStep:
        """Inverse of NCKWriter.add_step."""
        if self.manifest is not None:
            return self._read_step_merged(name)
        if f"{name}_anchor" in self.variables:
            info = self.attrs(f"{name}_anchor_info")
            offs = self.read_array(f"{name}_anchor_offset")
            table = self.read(f"{name}_anchor")
            blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
            return CompressedStep(
                n=info["total_data_num"], shape=tuple(info["shape"]),
                dtype=info["dtype"], b_bits=0,
                error_bound=info["error_bound"], strategy=info["strategy"],
                reference=info["reference"], domain_lo=0.0, bin_width=0.0,
                centers=np.zeros(0),
                block_elems=info["elements_per_block"],
                codec=info.get("codec", "zlib"), index_blocks=blks)
        info = self.attrs(f"{name}_info")
        offs = self.read_array(f"{name}_index_table_offset")
        table = self.read(f"{name}_index_table")
        blks = [table[offs[i]:offs[i + 1]] for i in range(len(offs) - 1)]
        return CompressedStep(
            n=info["total_data_num"], shape=tuple(info["shape"]),
            dtype=info["dtype"], b_bits=info["B"],
            error_bound=info["error_bound"], strategy=info["strategy"],
            reference=info["reference"], domain_lo=info["domain_lo"],
            bin_width=info["bin_width"],
            centers=self.read_array(f"{name}_bin_centers").astype(np.float64),
            block_elems=info["elements_per_block"],
            codec=info.get("codec", "zlib"),
            block_codecs=info.get("block_codecs"), index_blocks=blks,
            incomp_values=self.read_array(f"{name}_incompressible_table"),
            incomp_block_offsets=self.read_array(
                f"{name}_incompressible_table_offset"))

    def step_names(self) -> List[str]:
        if self.manifest is not None:
            return sorted(set(self.manifest["steps"]))
        names = set()
        for v in self.variables:
            if v.endswith("_anchor_info"):
                names.add(v[: -len("_anchor_info")])
            elif v.endswith("_frag_info"):
                names.add(v[: -len("_frag_info")])
            elif v.endswith("_info"):
                names.add(v[: -len("_info")])
        return sorted(names)


__all__ = ["NCKWriter", "NCKReader", "ShardNCKWriter", "StepFragment",
           "atomic_commit", "write_manifest", "read_manifest",
           "next_generation", "rank_file_path"]
