"""Partial data decompression (paper Sec. IV contributions #5, Sec. V-C).

Only the index-table blocks overlapping the requested element range are read
from disk and inflated; per-block incompressible-count offsets locate the
needed slice of the exception table.  For a temporal archive (anchor +
deltas) the request chains backwards through iterations -- each level reads
only the same element range, so work is O(range * n_iterations), which the
paper measures as the near-linear Table 7 behaviour.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import blocks, entropy
from repro.core.container import NCKReader, NCKWriter
from repro.core.pipeline import reconstruction_dtype


def _range_blocks(start: int, stop: int, block_elems: int):
    b0 = start // block_elems
    b1 = (stop - 1) // block_elems
    return b0, b1


def read_step_range(reader: NCKReader, name: str, start: int, stop: int,
                    prev_slice: Optional[np.ndarray]) -> np.ndarray:
    """Decompress elements [start, stop) of one stored step.

    `prev_slice` must hold the reconstructed previous-iteration values for
    exactly [start, stop) (None for anchors).  IO is block-granular.
    """
    is_anchor = f"{name}_anchor" in reader.variables
    info = reader.attrs(f"{name}_anchor_info" if is_anchor
                        else f"{name}_info")
    n = info["total_data_num"]
    if not (0 <= start < stop <= n):
        raise IndexError(f"range [{start},{stop}) outside [0,{n})")
    be = info["elements_per_block"]
    codec = info.get("codec", "zlib")
    # Per-block codec ids (NCK2 files); fall back to the step codec.
    block_codecs = info.get("block_codecs")
    b0, b1 = _range_blocks(start, stop, be)

    if is_anchor:
        offs = reader.read_array(f"{name}_anchor_offset")
        raw = reader.read(f"{name}_anchor", int(offs[b0]), int(offs[b1 + 1]))
        starts = np.concatenate(
            [[0], np.cumsum(np.diff(offs[b0:b1 + 2]))]).astype(np.int64)
        # Verify exactly the sliced blocks against the NCK4 checksum
        # frame before any codec touches them (no-op on NCK1/2/3).
        reader.verify_blocks(
            f"{name}_anchor",
            [raw[int(starts[k]):int(starts[k + 1])]
             for k in range(b1 - b0 + 1)], first_block=b0)
        esize = np.dtype(info["dtype"]).itemsize
        # Exact decompressed byte span of each block (the last block of a
        # step is shorter): assemble straight into one preallocated
        # buffer, block-parallel over the shared entropy pool.
        blk_bytes = np.array(
            [(min((bi + 1) * be, n) - bi * be) * esize
             for bi in range(b0, b1 + 1)], np.int64)
        outs = np.concatenate([[0], np.cumsum(blk_bytes)])
        buf = np.empty(int(outs[-1]), np.uint8)

        def inflate(k: int) -> None:
            data = entropy.decompress_block(
                raw[int(starts[k]):int(starts[k + 1])], codec)
            buf[int(outs[k]):int(outs[k + 1])] = np.frombuffer(data,
                                                               np.uint8)

        if b1 > b0 and len(raw) >= entropy._MIN_PARALLEL_BYTES:
            list(entropy._shared_pool().map(inflate, range(b1 - b0 + 1)))
        else:
            for k in range(b1 - b0 + 1):
                inflate(k)
        arr = np.frombuffer(buf.data, dtype=info["dtype"])
        lo = b0 * be
        return arr[start - lo: stop - lo].copy()

    b_bits = info["B"]
    marker = (1 << b_bits) - 1
    # Reconstruction arithmetic in the source precision (matches
    # decompress_step and the reference chain bit-exactly).
    cdt = reconstruction_dtype(info["dtype"])
    centers = reader.read_array(f"{name}_bin_centers").astype(cdt)
    centers = np.concatenate([centers,
                              np.zeros(marker + 1 - centers.size, cdt)])
    offs = reader.read_array(f"{name}_index_table_offset")
    inc_offs = reader.read_array(f"{name}_incompressible_table_offset")
    n_incomp = info["n_incompressible"]
    nblocks = info["n_blocks"]

    # One contiguous read for the overlapped deflated blocks...
    raw = reader.read(f"{name}_index_table", int(offs[b0]), int(offs[b1 + 1]))
    # ...and one for the exception values they may reference.
    inc_lo = int(inc_offs[b0])
    inc_hi = int(inc_offs[b1 + 1]) if b1 + 1 < nblocks else n_incomp
    esize = np.dtype(info["dtype"]).itemsize
    inc_vals = np.frombuffer(
        reader.read(f"{name}_incompressible_table", inc_lo * esize,
                    inc_hi * esize), dtype=info["dtype"])

    prev_slice = np.asarray(prev_slice).reshape(-1).astype(cdt, copy=False)
    assert prev_slice.size == stop - start
    out = np.empty(stop - start, cdt)

    # Inflate the overlapped blocks block-parallel over the shared
    # entropy pool (same fix as the anchor path); the reconstruction
    # loop below then only does vector arithmetic.
    starts = np.concatenate(
        [[0], np.cumsum(np.diff(offs[b0:b1 + 2]))]).astype(np.int64)
    reader.verify_blocks(
        f"{name}_index_table",
        [raw[int(starts[k]):int(starts[k + 1])]
         for k in range(b1 - b0 + 1)], first_block=b0)
    idx_parts: list = [None] * (b1 - b0 + 1)

    def inflate(k: int) -> None:
        bi = b0 + k
        blk_lo = bi * be
        idx_parts[k] = blocks.inflate_block(
            raw[int(starts[k]):int(starts[k + 1])],
            min(blk_lo + be, n) - blk_lo, b_bits,
            codec=block_codecs[bi] if block_codecs else codec)

    if b1 > b0 and len(raw) >= entropy._MIN_PARALLEL_BYTES:
        list(entropy._shared_pool().map(inflate, range(b1 - b0 + 1)))
    else:
        for k in range(b1 - b0 + 1):
            inflate(k)

    for bi in range(b0, b1 + 1):
        blk_lo = bi * be
        blk_hi = min(blk_lo + be, n)
        idx = idx_parts[bi - b0]
        s = max(start, blk_lo)
        e = min(stop, blk_hi)
        sub = idx[s - blk_lo: e - blk_lo]
        mask = sub == marker
        pv = prev_slice[s - start: e - start]
        comp = pv * (1 + centers[sub])
        if mask.any():
            # exceptions preceding `s` inside this block:
            lead = int(np.count_nonzero(idx[: s - blk_lo] == marker))
            first = int(inc_offs[bi]) - inc_lo + lead
            comp[mask] = inc_vals[first: first + int(mask.sum())]
        out[s - start: e - start] = comp
    return out.astype(info["dtype"])


class TemporalArchive:
    """A sequence of compressed iterations of one variable in one NCK file."""

    def __init__(self, path: str):
        self.path = path
        self._reader: Optional[NCKReader] = None

    @staticmethod
    def step_name(var: str, it: int) -> str:
        return f"{var}_it{it:05d}"

    @staticmethod
    def write(path: str, var: str, steps, *, checksums: bool = True) -> None:
        w = NCKWriter(checksums=checksums)
        for i, st in enumerate(steps):
            w.add_step(TemporalArchive.step_name(var, i), st)
        w.write(path)

    @property
    def reader(self) -> NCKReader:
        if self._reader is None:
            self._reader = NCKReader(self.path)
        return self._reader

    def n_iterations(self, var: str) -> int:
        prefix = f"{var}_it"
        return len({v for v in self.reader.step_names()
                    if v.startswith(prefix)})

    def read_range(self, var: str, it: int, start: int,
                   stop: int) -> np.ndarray:
        """Elements [start, stop) of iteration `it` -- chained partial read.

        Starts at the latest anchor at-or-before `it` (periodic anchors bound
        the chain length; see checkpoint.manager).
        """
        first = it
        while first > 0 and (f"{self.step_name(var, first)}_anchor"
                             not in self.reader.variables):
            first -= 1
        prev = None
        for i in range(first, it + 1):
            name = self.step_name(var, i)
            is_anchor = f"{name}_anchor" in self.reader.variables
            if is_anchor:
                prev = read_step_range(self.reader, name, start, stop, None)
            else:
                prev = read_step_range(self.reader, name, start, stop, prev)
        return prev

    def read_full(self, var: str, it: int) -> np.ndarray:
        info_name = self.step_name(var, it)
        is_anchor = f"{info_name}_anchor" in self.reader.variables
        info = self.reader.attrs(
            f"{info_name}_anchor_info" if is_anchor else f"{info_name}_info")
        flat = self.read_range(var, it, 0, info["total_data_num"])
        return flat.reshape(info["shape"])


__all__ = ["read_step_range", "TemporalArchive"]
