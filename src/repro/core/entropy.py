"""Pluggable host-side entropy stage with a parallel block dispatcher.

The paper runs ZLIB on the CPU cores as the final compression phase
(Sec. IV-C); arXiv:1903.07761 generalizes that into a stage-structured
pipeline whose entropy back-end is *pluggable* and thread-parallel.  This
module is our version of that idea:

  * a codec registry -- ``zlib`` (default), ``raw`` (store), ``lzma`` and
    ``bz2`` behind one two-method interface; new codecs register with
    :func:`register_codec` and are persisted by name in the NCK container
    so files remain self-describing.
  * :func:`compress_blocks` -- the one entropy entry point used by every
    compressor (single-device, sharded, anchors).  Blocks are batched and
    dispatched over a shared ``ThreadPoolExecutor``; zlib/bz2/lzma all
    release the GIL on the C side, so threads give real parallel speedup
    (see ``benchmarks/bench_entropy.py``).  Codecs that *hold* the GIL
    (``Codec.holds_gil = True``) are dispatched over a forked
    ``ProcessPoolExecutor`` instead, with a transparent serial fallback
    when process pools are unavailable.
  * the ``"auto"`` pseudo-codec id -- :func:`resolve_codec` probes a
    sampled prefix of the payload with a fast zlib pass and picks
    raw / zlib / lzma from the measured compressibility (the per-chunk
    adaptive codec choice of LCP, arXiv:2411.00761).  ``"auto"`` is a
    *parameter-level* id only: finalize resolves it per step and the NCK
    container always persists a concrete registry name.

Batching heuristic (benchmarked in bench_entropy.py): tasks are groups of
consecutive blocks sized so that (a) every worker gets work and (b) each
task carries at least ``_TARGET_TASK_BYTES`` of payload so submission
overhead stays <1% even for tiny blocks.
"""
from __future__ import annotations

import bz2
import lzma
import multiprocessing
import os
import threading
import time
import zlib
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

from repro.faults import inject
from repro.faults.errors import IntegrityError
from repro.obs import telemetry

# --------------------------------------------------------------------- codecs


class Codec:
    """Entropy codec interface: bytes -> bytes, self-inverse via decompress."""

    name: str = "abstract"
    # Pure-python codecs that never release the GIL get no speedup from the
    # thread pool; mark them and compress_blocks dispatches them over a
    # forked process pool instead.
    holds_gil: bool = False
    # Codecs with a device-resident encoder: the drivers can entropy-code
    # index blocks on the accelerator (kernels.rans) and hand finalize
    # pre-compressed blobs byte-identical to this host flavor.
    device: bool = False

    def compress(self, raw: bytes, level: int) -> bytes:
        raise NotImplementedError

    def decompress(self, blob: bytes) -> bytes:
        raise NotImplementedError


class ZlibCodec(Codec):
    name = "zlib"

    def compress(self, raw: bytes, level: int) -> bytes:
        return zlib.compress(raw, level)

    def decompress(self, blob: bytes) -> bytes:
        return zlib.decompress(blob)


class RawCodec(Codec):
    """Store-only codec: no entropy coding (fastest finalize, CR from
    binning alone).  Useful when the index table is near-incompressible or
    the host is the bottleneck."""

    name = "raw"

    def compress(self, raw: bytes, level: int) -> bytes:
        return raw

    def decompress(self, blob: bytes) -> bytes:
        return blob


class LzmaCodec(Codec):
    """LZMA: slowest, highest ratio; level maps to preset 0-9."""

    name = "lzma"

    def compress(self, raw: bytes, level: int) -> bytes:
        return lzma.compress(raw, preset=min(max(level, 0), 9))

    def decompress(self, blob: bytes) -> bytes:
        return lzma.decompress(blob)


class Bz2Codec(Codec):
    name = "bz2"

    def compress(self, raw: bytes, level: int) -> bytes:
        return bz2.compress(raw, compresslevel=min(max(level, 1), 9))

    def decompress(self, blob: bytes) -> bytes:
        return bz2.decompress(blob)


class RansCodec(Codec):
    """Block-parallel interleaved rANS (kernels.rans).

    This registry entry is the *host* (NumPy) flavor -- a lane-vectorized
    python loop, hence ``holds_gil``.  ``device=True`` advertises the
    accelerator encoder: drivers route index blocks through
    ``kernels.rans.compress_blocks_device`` (or the sharded shard_map
    stage) and finalize consumes the pre-compressed blobs; both flavors
    emit byte-identical self-describing blobs, so files do not record
    which one produced them.  The kernels module is imported lazily to
    keep this module import-light (process-pool workers, NumarckParams
    validation).
    """

    name = "rans"
    # Deliberately NOT holds_gil: the process-pool dispatch would fork
    # while the device entropy stage may be running jax on other threads
    # (fork-after-jax is the hazard the pool's timeout only mitigates).
    # The host flavor therefore serializes under the GIL -- it is the
    # correctness/fallback path; throughput comes from the device stage.
    device = True

    def compress(self, raw: bytes, level: int) -> bytes:
        from repro.kernels import rans
        return rans.compress(raw)

    def decompress(self, blob: bytes) -> bytes:
        from repro.kernels import rans
        return rans.decompress(blob)


DEFAULT_CODEC = "zlib"
AUTO_CODEC = "auto"
_REGISTRY: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def codec_names() -> List[str]:
    return sorted(_REGISTRY)


def validate_codec_id(name: str) -> str:
    """Accept any registered codec plus the ``"auto"`` pseudo-id.

    Parameters may carry ``"auto"``; persisted steps never do (finalize
    resolves it to a concrete registry name first).
    """
    if name != AUTO_CODEC:
        get_codec(name)                  # raises on unknown codec
    return name


for _c in (ZlibCodec(), RawCodec(), LzmaCodec(), Bz2Codec(), RansCodec()):
    register_codec(_c)

# ------------------------------------------------------ adaptive selection

# Probe: deflate a bounded prefix at level 1 (cheap, ~100 MB/s) and read the
# achieved ratio.  Thresholds picked from benchmarks/bench_entropy.py on
# zipf index tables vs random bytes: near-incompressible payloads waste
# zlib time for <3% size, while highly redundant payloads close most of
# the lzma-vs-zlib gap at acceptable cost.
_AUTO_SAMPLE_BYTES = 64 << 10
_AUTO_RAW_THRESHOLD = 0.95       # probe ratio above this -> store raw
_AUTO_LZMA_THRESHOLD = 0.30      # probe ratio below this -> lzma pays off
# lzma is 10-40x slower than zlib; cap the payload size we are willing to
# hand it so finalize latency stays bounded on huge steps.
_AUTO_LZMA_MAX_BYTES = 256 << 20


def _probe_one(raw: bytes, allow_lzma: bool = True) -> str:
    """One compressibility probe -> concrete codec (the auto policy)."""
    if not raw:
        return DEFAULT_CODEC
    sample = raw[:_AUTO_SAMPLE_BYTES]
    ratio = len(zlib.compress(sample, 1)) / len(sample)
    if ratio >= _AUTO_RAW_THRESHOLD:
        return "raw"
    if ratio <= _AUTO_LZMA_THRESHOLD and allow_lzma:
        return "lzma"
    return DEFAULT_CODEC


def choose_codec(raws: Sequence[bytes], level: int = 6) -> str:
    """Pick a concrete codec from the measured compressibility of a sampled
    block prefix (LCP-style per-chunk adaptivity, arXiv:2411.00761)."""
    del level
    total = sum(len(r) for r in raws)
    for r in raws:
        if r:
            return _probe_one(r, allow_lzma=total <= _AUTO_LZMA_MAX_BYTES)
    return DEFAULT_CODEC


def resolve_codec(codec: str, raws: Sequence[bytes], level: int = 6) -> str:
    """Map the parameter-level codec id to the concrete one used for this
    payload.  Identity for everything but ``"auto"``."""
    if codec == AUTO_CODEC:
        return choose_codec(raws, level)
    get_codec(codec)
    return codec


def choose_block_codecs(raws: Sequence[bytes], level: int = 6) -> List[str]:
    """Per-*block* codec choice: the ``"auto"`` probe applied to every
    block rather than only the first one, so mixed hot/cold ranges get
    mixed codecs (near-incompressible blocks go raw, highly redundant
    blocks go lzma) and the NCK container persists one id per block.

    The lzma latency cap stays a *total*-payload bound, exactly as in
    :func:`choose_codec` -- a huge step must not go 10-40x slower just
    because each individual block is small.  Probes are dispatched over
    the shared thread pool on large payloads (zlib releases the GIL), so
    the per-block policy adds no serial stall to the finalize path.
    """
    del level
    total = sum(len(r) for r in raws)
    allow_lzma = total <= _AUTO_LZMA_MAX_BYTES
    if len(raws) >= 4 and total >= _MIN_PARALLEL_BYTES:
        picks = list(_shared_pool().map(
            lambda r: _probe_one(r, allow_lzma), raws))
    else:
        picks = [_probe_one(r, allow_lzma) for r in raws]
    if telemetry.enabled():
        for p in set(picks):
            telemetry.counter(f"entropy.auto.pick.{p}",
                              float(picks.count(p)))
    return picks

# ----------------------------------------------------------- parallel stage

# Below this total payload the pool overhead exceeds the win; stay serial.
_MIN_PARALLEL_BYTES = 1 << 20
# Batch consecutive blocks until each task carries at least this much.
_TARGET_TASK_BYTES = 2 << 20
# Per-task ceiling for process-pool results; beyond it the pool is marked
# broken and the codec degrades to the (serializing but correct) threads.
_PROC_RESULT_TIMEOUT_S = 120.0

_pool_lock = threading.Lock()
_pool: Optional[ThreadPoolExecutor] = None
_proc_pool: Optional[ProcessPoolExecutor] = None
_proc_pool_broken = False


def _shared_pool() -> ThreadPoolExecutor:
    """Process-wide entropy pool (lazily created; sized to the host CPUs)."""
    global _pool
    with _pool_lock:
        if _pool is None:
            workers = min(32, os.cpu_count() or 1)
            _pool = ThreadPoolExecutor(max_workers=workers,
                                       thread_name_prefix="entropy")
        return _pool


def _shared_proc_pool() -> Optional[ProcessPoolExecutor]:
    """Forked process pool for GIL-holding codecs.

    Fork (not spawn) so workers inherit the codec registry, including
    codecs registered after import; codecs registered after the pool's
    first use are not visible to workers -- register before compressing.
    Returns None where fork is unavailable (callers fall back to the
    thread pool, which is correct, just not parallel).
    """
    global _proc_pool, _proc_pool_broken
    with _pool_lock:
        if _proc_pool is None and not _proc_pool_broken:
            try:
                ctx = multiprocessing.get_context("fork")
                workers = min(8, os.cpu_count() or 1)
                _proc_pool = ProcessPoolExecutor(max_workers=workers,
                                                 mp_context=ctx)
            except (ValueError, OSError):
                _proc_pool_broken = True
        return _proc_pool


def _retire_proc_pool(px: ProcessPoolExecutor):
    """Permanently disable process dispatch and tear the pool down (without
    waiting on possibly-wedged workers)."""
    global _proc_pool, _proc_pool_broken
    with _pool_lock:
        _proc_pool_broken = True
        if _proc_pool is px:
            _proc_pool = None
    px.shutdown(wait=False, cancel_futures=True)


def _compress_batch(codec_name: str, raws: List[bytes],
                    level: int) -> List[bytes]:
    """Process-pool task body: resolve the codec by name in the worker."""
    # Injection site: a dying pool worker must exercise the
    # retire-and-degrade path in _dispatch_blocks, not hang the driver.
    inject.fire("entropy_worker_death", codec=codec_name, blocks=len(raws))
    c = get_codec(codec_name)
    return [c.compress(r, level) for r in raws]


def _task_plan(sizes: Sequence[int], workers: int) -> List[range]:
    """Group consecutive block indices into tasks.

    At least `workers` tasks (so every core is busy) unless the payload is
    small; no task smaller than one block; tasks cover blocks in order so
    output order is positional.
    """
    total = sum(sizes)
    n = len(sizes)
    n_tasks = max(workers, total // _TARGET_TASK_BYTES)
    n_tasks = max(1, min(n, n_tasks))
    step = -(-n // n_tasks)
    return [range(s, min(s + step, n)) for s in range(0, n, step)]


def compress_blocks(raws: Sequence[bytes], codec: str = DEFAULT_CODEC,
                    level: int = 6, parallel: bool = True,
                    pool: Optional[ThreadPoolExecutor] = None) -> List[bytes]:
    """Entropy-code every block; the single finalize entry point.

    Serial for small payloads, thread-parallel (shared pool, batched tasks)
    otherwise.  Output is byte-identical to the serial loop in both modes --
    per-block codec streams are independent.
    """
    codec = resolve_codec(codec, raws, level)
    c = get_codec(codec)
    sizes = [len(r) for r in raws]
    with telemetry.span("entropy.compress", codec=codec,
                        blocks=len(raws)) as sp:
        out = _dispatch_blocks(c, codec, raws, sizes, level, parallel, pool)
        if telemetry.enabled():
            bytes_in, bytes_out = sum(sizes), sum(len(b) for b in out)
            telemetry.counter(f"entropy.bytes_in.{codec}", float(bytes_in))
            telemetry.counter(f"entropy.bytes_out.{codec}", float(bytes_out))
            sp.set(bytes_in=bytes_in, bytes_out=bytes_out)
    return out


def _dispatch_blocks(c: Codec, codec: str, raws: Sequence[bytes],
                     sizes: List[int], level: int, parallel: bool,
                     pool: Optional[ThreadPoolExecutor]) -> List[bytes]:
    """Serial / thread-pool / process-pool dispatch of compress_blocks."""
    if (not parallel or len(raws) < 2
            or sum(sizes) < _MIN_PARALLEL_BYTES):
        return [c.compress(r, level) for r in raws]

    if c.holds_gil and pool is None:
        # GIL-holding codec: threads would serialize, so fan batches out to
        # forked worker processes instead (payload ships by pickle; the
        # >= _TARGET_TASK_BYTES batching keeps the IPC amortized).  Workers
        # run pure-python codec code only -- never jax -- which keeps the
        # fork-after-jax-init hazard theoretical; the result timeout is the
        # backstop: a wedged child degrades us to the thread path instead
        # of hanging the finalize stage.
        px = _shared_proc_pool()
        if px is not None:
            workers = getattr(px, "_max_workers", os.cpu_count() or 1)
            plan = _task_plan(sizes, workers)
            try:
                futs = [px.submit(_compress_batch, codec,
                                  [raws[i] for i in rng], level)
                        for rng in plan]
                out = []
                for f in futs:
                    out.extend(f.result(timeout=_PROC_RESULT_TIMEOUT_S))
                return out
            except Exception:
                # Sandboxed fork, wedged worker, codec error in the child:
                # retire the pool entirely (a wedged pool would otherwise
                # re-stall every later call) and degrade to threads.  If
                # the codec itself is at fault the thread path below
                # re-raises the same error to the caller.
                _retire_proc_pool(px)

    ex = pool or _shared_pool()
    workers = getattr(ex, "_max_workers", os.cpu_count() or 1)
    # Submit->start latency of each pool task: a loaded pool shows up as a
    # fat entropy.queue_wait_s histogram, not as mystery finalize time.
    tele = telemetry.enabled()
    t_submit = time.perf_counter() if tele else 0.0

    def run(rng: range) -> List[bytes]:
        if not tele:
            return [c.compress(raws[i], level) for i in rng]
        telemetry.histo("entropy.queue_wait_s",
                        time.perf_counter() - t_submit)
        with telemetry.span("entropy.batch", codec=codec, blocks=len(rng)):
            return [c.compress(raws[i], level) for i in rng]

    out: List[bytes] = []
    for part in ex.map(run, _task_plan(sizes, workers)):
        out.extend(part)
    return out


def compress_blocks_per_codec(raws: Sequence[bytes], codecs: Sequence[str],
                              level: int = 6,
                              parallel: bool = True) -> List[bytes]:
    """Entropy-code every block with its *own* codec id.

    One pool dispatch over all blocks (codecs interleaved, parallel
    threshold on the *step* total, not per-codec-group totals), so a
    small lzma group never serializes behind a big zlib group.  Per-block
    output is byte-identical to compressing every block alone -- block
    streams are independent whatever the dispatch.  GIL-holding codecs
    stay correct here but serialize; the mixed-codec path is only used
    by the ``"auto"`` palette (raw/zlib/lzma), which releases the GIL.
    """
    assert len(raws) == len(codecs)
    pairs = [(r, get_codec(c)) for r, c in zip(raws, codecs)]
    with telemetry.span("entropy.compress_per_codec", blocks=len(raws)):
        if (not parallel or len(raws) < 2
                or sum(len(r) for r in raws) < _MIN_PARALLEL_BYTES):
            out = [c.compress(r, level) for r, c in pairs]
        else:
            ex = _shared_pool()
            out = list(ex.map(lambda rc: rc[1].compress(rc[0], level),
                              pairs))
    if telemetry.enabled():
        for cname in set(codecs):
            bi = sum(len(r) for r, c in zip(raws, codecs) if c == cname)
            bo = sum(len(b) for b, c in zip(out, codecs) if c == cname)
            telemetry.counter(f"entropy.bytes_in.{cname}", float(bi))
            telemetry.counter(f"entropy.bytes_out.{cname}", float(bo))
    return out


def _decompress_one(c: Codec, codec: str, blob: bytes) -> bytes:
    """Decode one blob, converting codec-internal failures (zlib.error,
    lzma format errors, rANS final-state mismatches ...) into a
    structured :class:`IntegrityError` -- a corrupt block must fail
    loudly at the entropy stage, never as a traceback from deep inside a
    codec (and never as silently wrong bytes)."""
    try:
        return c.decompress(blob)
    except IntegrityError:
        raise
    except Exception as e:
        raise IntegrityError(
            f"entropy decode failed: codec {codec!r} rejected a "
            f"{len(blob)}-byte blob ({e!r}) -- block is corrupt or "
            "truncated") from e


def decompress_block(blob: bytes, codec: str = DEFAULT_CODEC) -> bytes:
    return _decompress_one(get_codec(codec), codec, blob)


def decompress_blocks(blobs: Sequence[bytes], codec: str = DEFAULT_CODEC,
                      parallel: bool = True) -> List[bytes]:
    """Inverse of compress_blocks (parallel when the payload warrants it)."""
    c = get_codec(codec)
    if not parallel or len(blobs) < 2 \
            or sum(len(b) for b in blobs) < _MIN_PARALLEL_BYTES:
        return [_decompress_one(c, codec, b) for b in blobs]
    ex = _shared_pool()
    return list(ex.map(lambda b: _decompress_one(c, codec, b), blobs))


__all__ = ["Codec", "ZlibCodec", "RawCodec", "LzmaCodec", "Bz2Codec",
           "RansCodec", "DEFAULT_CODEC", "AUTO_CODEC", "register_codec",
           "get_codec", "codec_names", "validate_codec_id", "choose_codec",
           "choose_block_codecs", "resolve_codec", "compress_blocks",
           "compress_blocks_per_codec", "decompress_block",
           "decompress_blocks"]
