"""Pluggable host-side entropy stage with a parallel block dispatcher.

The paper runs ZLIB on the CPU cores as the final compression phase
(Sec. IV-C); arXiv:1903.07761 generalizes that into a stage-structured
pipeline whose entropy back-end is *pluggable* and thread-parallel.  This
module is our version of that idea:

  * a codec registry -- ``zlib`` (default), ``raw`` (store), ``lzma`` and
    ``bz2`` behind one two-method interface; new codecs register with
    :func:`register_codec` and are persisted by name in the NCK container
    so files remain self-describing.
  * :func:`compress_blocks` -- the one entropy entry point used by every
    compressor (single-device, sharded, anchors).  Blocks are batched and
    dispatched over a shared ``ThreadPoolExecutor``; zlib/bz2/lzma all
    release the GIL on the C side, so threads give real parallel speedup
    (see ``benchmarks/bench_entropy.py``).

Batching heuristic (benchmarked in bench_entropy.py): tasks are groups of
consecutive blocks sized so that (a) every worker gets work and (b) each
task carries at least ``_TARGET_TASK_BYTES`` of payload so submission
overhead stays <1% even for tiny blocks.
"""
from __future__ import annotations

import bz2
import lzma
import os
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

# --------------------------------------------------------------------- codecs


class Codec:
    """Entropy codec interface: bytes -> bytes, self-inverse via decompress."""

    name: str = "abstract"

    def compress(self, raw: bytes, level: int) -> bytes:
        raise NotImplementedError

    def decompress(self, blob: bytes) -> bytes:
        raise NotImplementedError


class ZlibCodec(Codec):
    name = "zlib"

    def compress(self, raw: bytes, level: int) -> bytes:
        return zlib.compress(raw, level)

    def decompress(self, blob: bytes) -> bytes:
        return zlib.decompress(blob)


class RawCodec(Codec):
    """Store-only codec: no entropy coding (fastest finalize, CR from
    binning alone).  Useful when the index table is near-incompressible or
    the host is the bottleneck."""

    name = "raw"

    def compress(self, raw: bytes, level: int) -> bytes:
        return raw

    def decompress(self, blob: bytes) -> bytes:
        return blob


class LzmaCodec(Codec):
    """LZMA: slowest, highest ratio; level maps to preset 0-9."""

    name = "lzma"

    def compress(self, raw: bytes, level: int) -> bytes:
        return lzma.compress(raw, preset=min(max(level, 0), 9))

    def decompress(self, blob: bytes) -> bytes:
        return lzma.decompress(blob)


class Bz2Codec(Codec):
    name = "bz2"

    def compress(self, raw: bytes, level: int) -> bytes:
        return bz2.compress(raw, compresslevel=min(max(level, 1), 9))

    def decompress(self, blob: bytes) -> bytes:
        return bz2.decompress(blob)


DEFAULT_CODEC = "zlib"
_REGISTRY: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    _REGISTRY[codec.name] = codec
    return codec


def get_codec(name: str) -> Codec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def codec_names() -> List[str]:
    return sorted(_REGISTRY)


for _c in (ZlibCodec(), RawCodec(), LzmaCodec(), Bz2Codec()):
    register_codec(_c)

# ----------------------------------------------------------- parallel stage

# Below this total payload the pool overhead exceeds the win; stay serial.
_MIN_PARALLEL_BYTES = 1 << 20
# Batch consecutive blocks until each task carries at least this much.
_TARGET_TASK_BYTES = 2 << 20

_pool_lock = threading.Lock()
_pool: Optional[ThreadPoolExecutor] = None


def _shared_pool() -> ThreadPoolExecutor:
    """Process-wide entropy pool (lazily created; sized to the host CPUs)."""
    global _pool
    with _pool_lock:
        if _pool is None:
            workers = min(32, os.cpu_count() or 1)
            _pool = ThreadPoolExecutor(max_workers=workers,
                                       thread_name_prefix="entropy")
        return _pool


def _task_plan(sizes: Sequence[int], workers: int) -> List[range]:
    """Group consecutive block indices into tasks.

    At least `workers` tasks (so every core is busy) unless the payload is
    small; no task smaller than one block; tasks cover blocks in order so
    output order is positional.
    """
    total = sum(sizes)
    n = len(sizes)
    n_tasks = max(workers, total // _TARGET_TASK_BYTES)
    n_tasks = max(1, min(n, n_tasks))
    step = -(-n // n_tasks)
    return [range(s, min(s + step, n)) for s in range(0, n, step)]


def compress_blocks(raws: Sequence[bytes], codec: str = DEFAULT_CODEC,
                    level: int = 6, parallel: bool = True,
                    pool: Optional[ThreadPoolExecutor] = None) -> List[bytes]:
    """Entropy-code every block; the single finalize entry point.

    Serial for small payloads, thread-parallel (shared pool, batched tasks)
    otherwise.  Output is byte-identical to the serial loop in both modes --
    per-block codec streams are independent.
    """
    c = get_codec(codec)
    sizes = [len(r) for r in raws]
    if (not parallel or len(raws) < 2
            or sum(sizes) < _MIN_PARALLEL_BYTES):
        return [c.compress(r, level) for r in raws]
    ex = pool or _shared_pool()
    workers = getattr(ex, "_max_workers", os.cpu_count() or 1)

    def run(rng: range) -> List[bytes]:
        return [c.compress(raws[i], level) for i in rng]

    out: List[bytes] = []
    for part in ex.map(run, _task_plan(sizes, workers)):
        out.extend(part)
    return out


def decompress_block(blob: bytes, codec: str = DEFAULT_CODEC) -> bytes:
    return get_codec(codec).decompress(blob)


def decompress_blocks(blobs: Sequence[bytes], codec: str = DEFAULT_CODEC,
                      parallel: bool = True) -> List[bytes]:
    """Inverse of compress_blocks (parallel when the payload warrants it)."""
    c = get_codec(codec)
    if not parallel or len(blobs) < 2 \
            or sum(len(b) for b in blobs) < _MIN_PARALLEL_BYTES:
        return [c.decompress(b) for b in blobs]
    ex = _shared_pool()
    return list(ex.map(c.decompress, blobs))


__all__ = ["Codec", "ZlibCodec", "RawCodec", "LzmaCodec", "Bz2Codec",
           "DEFAULT_CODEC", "register_codec", "get_codec", "codec_names",
           "compress_blocks", "decompress_block", "decompress_blocks"]
