"""Core datatypes for the NUMARCK compression pipeline.

Terminology follows the paper:
  E        -- user-defined tolerable (relative) error bound
  B        -- number of bits used to index a data point
  k        -- number of bins = 2**B - 1 (index 2**B - 1 marks incompressible)
  n        -- number of data points in the variable
  alpha    -- incompressible-data ratio (Eq. 5)
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# Strategy names (paper Sec. III-B / IV-B).
STRATEGY_TOPK = "topk"
STRATEGY_EQUAL = "equal"
STRATEGY_LOG = "log"
STRATEGY_KMEANS = "kmeans"
STRATEGIES = (STRATEGY_TOPK, STRATEGY_EQUAL, STRATEGY_LOG, STRATEGY_KMEANS)

# Reference modes (DESIGN.md Sec. 3): the paper compresses step i against the
# *original* previous step but reconstructs against the *reconstructed* one,
# so errors compound; "reconstructed" closes the loop and keeps the per-step
# bound exact.
REF_ORIGINAL = "original"
REF_RECONSTRUCTED = "reconstructed"


@dataclass(frozen=True)
class NumarckParams:
    """User-controllable parameters (paper Sec. IV contributions #4)."""

    error_bound: float = 1e-3          # E
    b_bits: Optional[int] = None       # None => auto-select via Eq. (6)
    b_max: int = 16                    # search range for auto-B
    max_bins: int = 1 << 16            # histogram candidate-bin cap (DESIGN 3)
    strategy: str = STRATEGY_TOPK
    block_bytes: int = 1 << 20         # index-table block size (paper: 1 MB)
    codec: str = "zlib"                # entropy codec (registry id or "auto")
    zlib_level: int = 6                # codec level (name kept for compat)
    parallel_entropy: bool = True      # thread-pool host finalize
    # Route the entropy stage through the codec's device encoder when it
    # has one (Codec.device, e.g. "rans"): blocks are entropy-coded on
    # the accelerator and finalize consumes pre-compressed blobs.  Blobs
    # are byte-identical to the host flavor either way.
    device_entropy: bool = True
    # Symbol-level rANS (top-k only): entropy-code the pre-pack B-bit
    # indices over the dense {rank, marker} alphabet using the analyze
    # stage's exact global histogram -- no strided sample pass, no
    # bit-pack/unpack stage on either side.  Steps carrying such blocks
    # are stamped NCK3 by the container (old readers reject them
    # cleanly; NCK1/NCK2 files still load either way).
    symbol_rans: bool = False
    reference: str = REF_RECONSTRUCTED
    kmeans_iters: int = 20
    kmeans_max_k: int = 4096           # tractability cap for k-means binning
    # SS Perf (EXPERIMENTS.md): skip the min/max range pass and use the
    # 0-centred capped domain directly.  Saves one full read of prev/curr
    # (the paper's phase-1 Allreduce disappears); ratios outside
    # +-max_bins*E become exceptions, which for temporal data is the far
    # tail anyway.  Off by default (paper-faithful domain selection).
    fixed_domain: bool = False

    def __post_init__(self):
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}")
        if self.reference not in (REF_ORIGINAL, REF_RECONSTRUCTED):
            raise ValueError(f"unknown reference mode {self.reference!r}")
        if not (0 < self.error_bound < 1):
            raise ValueError("error_bound must be in (0, 1)")
        if self.b_bits is not None and not (1 <= self.b_bits <= 24):
            raise ValueError("b_bits must be in [1, 24]")
        if self.max_bins < 2:
            raise ValueError("max_bins must be >= 2")
        from repro.core import entropy  # stdlib-only; no import cycle
        entropy.validate_codec_id(self.codec)  # registry name or "auto"

    def block_elems(self, b_bits: int) -> int:
        """Indices per index-table block (paper: block_bits / B).

        Rounded down to a multiple of 32 -- the Pallas bit-pack kernel
        processes 32-index word groups, and this keeps the single-device and
        sharded byte streams identical.
        """
        return max(32, ((self.block_bytes * 8) // b_bits) // 32 * 32)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "NumarckParams":
        return NumarckParams(**json.loads(s))


@dataclass
class CompressedStep:
    """One compressed iteration of one variable.

    Mirrors the netCDF layout of paper Fig. 2: bin centers, blocked+deflated
    index table with a byte-offset table, incompressible value table with a
    per-block count-offset table, and an info/attribute record.
    """

    n: int                              # total_data_num
    shape: tuple                        # original array shape
    dtype: str                          # original dtype string
    b_bits: int                         # index length B
    error_bound: float
    strategy: str
    reference: str
    domain_lo: float                    # histogram domain start (top-k)
    bin_width: float                    # 2E for top-k
    centers: np.ndarray                 # float64 (k,) bin centers
    block_elems: int                    # elements_per_block
    codec: str = "zlib"                 # entropy codec id (registry name)
    # Per-block codec ids (mixed hot/cold ranges); None => every block
    # uses `codec`.  Persisted by the NCK container (format version 2).
    block_codecs: Optional[list] = None
    index_blocks: list = field(default_factory=list)   # entropy-coded bytes
    index_block_nbytes: Optional[np.ndarray] = None    # raw (pre-zlib) sizes
    incomp_values: Optional[np.ndarray] = None         # original dtype
    incomp_block_offsets: Optional[np.ndarray] = None  # int64 (nblocks,)
    meta: dict = field(default_factory=dict)

    def codec_for_block(self, bi: int) -> str:
        """Entropy codec of block `bi` (the per-block id when present)."""
        return self.block_codecs[bi] if self.block_codecs else self.codec

    @property
    def is_anchor(self) -> bool:
        """Anchors (losslessly stored steps) are marked by b_bits == 0; their
        raw value blocks live in index_blocks (deflated, block_elems each)."""
        return self.b_bits == 0

    @property
    def n_blocks(self) -> int:
        return len(self.index_blocks)

    @property
    def n_incompressible(self) -> int:
        return 0 if self.incomp_values is None else int(self.incomp_values.size)

    @property
    def alpha(self) -> float:
        """Incompressible data ratio (Eq. 5)."""
        return self.n_incompressible / max(self.n, 1)

    def index_table_offsets(self) -> np.ndarray:
        """Start byte offset of each deflated block (paper's offset table)."""
        sizes = np.array([len(b) for b in self.index_blocks], dtype=np.int64)
        return np.concatenate([[0], np.cumsum(sizes)])[:-1]

    @property
    def nbytes(self) -> int:
        """Compressed payload size as laid out in the NCK container."""
        if self.is_anchor:
            return (sum(len(b) for b in self.index_blocks)
                    + 8 * (self.n_blocks + 1))
        total = int(self.centers.size) * np.dtype(self.dtype).itemsize
        total += sum(len(b) for b in self.index_blocks)
        total += 8 * (self.n_blocks + 1) * 2          # two offset tables
        if self.incomp_values is not None:
            total += int(self.incomp_values.nbytes)
        return total

    def compression_ratio(self) -> float:
        """CR = original size / compressed size (Eq. 2)."""
        orig = self.n * np.dtype(self.dtype).itemsize
        return orig / max(self.nbytes, 1)


def mean_error_rate(original: np.ndarray, recon: np.ndarray) -> float:
    """ME (Eq. 3): mean |D - R| / |D| over elements with D != 0."""
    original = np.asarray(original, dtype=np.float64).ravel()
    recon = np.asarray(recon, dtype=np.float64).ravel()
    nz = original != 0
    if not nz.any():
        return 0.0
    return float(np.mean(np.abs((original[nz] - recon[nz]) / original[nz])))


def dtype_nbytes(dtype) -> int:
    return int(np.dtype(dtype).itemsize)


def required_b_for_k(k: int) -> int:
    """Smallest B such that 2**B - 1 >= k."""
    b = 1
    while (1 << b) - 1 < k:
        b += 1
    return b


__all__ = [
    "NumarckParams",
    "CompressedStep",
    "mean_error_rate",
    "dtype_nbytes",
    "required_b_for_k",
    "STRATEGIES",
    "STRATEGY_TOPK",
    "STRATEGY_EQUAL",
    "STRATEGY_LOG",
    "STRATEGY_KMEANS",
    "REF_ORIGINAL",
    "REF_RECONSTRUCTED",
]
