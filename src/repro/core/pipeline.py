"""Shared NUMARCK pipeline stages: analyze -> encode -> finalize.

Both drivers -- ``core.compress`` (single device) and
``distributed.pipeline`` (shard_map) -- used to reimplement the host half
of the pipeline: center computation, exception compaction, per-block
entropy coding and blob assembly.  This module is the single home of those
stages, following the stage-structured design of arXiv:1903.07761 (and
LCP, arXiv:2411.00761): a driver produces an :class:`EncodedIndices`
(device work) and everything after that is shared, so the two paths emit
byte-identical ``CompressedStep`` blobs by construction.

Stage map:

  analyze   device  ratios, global range, histogram, auto-B   (per driver)
  encode    device  rank-LUT indexing + bit-packing           (per driver)
  finalize  host    exceptions, entropy stage, blob assembly  (HERE)

The finalize entropy stage is the pluggable parallel codec dispatcher in
``core.entropy``; the codec id is recorded on the step and persisted by
the NCK container.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core import entropy, packing
from repro.core.types import CompressedStep, NumarckParams
from repro.obs import telemetry


class StepMeta(dict):
    """Step metadata dict with the deprecated ``"zlib_ratio"`` alias.

    ``"zlib_ratio"`` predates the pluggable entropy registry; the stage
    ratio has been codec-agnostic ``"entropy_ratio"`` since the registry
    landed.  Reading the alias warns once per process and keeps working.
    """

    _warned = False

    @classmethod
    def _warn_alias(cls):
        if not cls._warned:
            cls._warned = True
            warnings.warn(
                "meta['zlib_ratio'] is deprecated: the entropy stage is "
                "codec-pluggable; read meta['entropy_ratio'] instead",
                DeprecationWarning, stacklevel=4)

    def __getitem__(self, key):
        if key == "zlib_ratio":
            self._warn_alias()
        return dict.__getitem__(self, key)

    def get(self, key, default=None):
        if key == "zlib_ratio":
            self._warn_alias()
        return dict.get(self, key, default)


def reconstruction_dtype(dtype) -> np.dtype:
    """Arithmetic precision of the reconstruction R_i = R_{i-1}*(1+c).

    Reconstruction runs in the *source* precision -- float64 data in
    float64, everything else in float32 -- so the host chain, the device
    chain (Pallas or gather lowering) and every decompressor produce
    bit-identical state.  Sub-f32 dtypes still compute in f32 (their
    epsilon is comparable to typical error bounds) and round once at the
    end, exactly like every path does.
    """
    dt = np.dtype(dtype)
    return np.dtype(np.float64) if dt == np.float64 else np.dtype(np.float32)


def block_slices(n: int, block_elems: int) -> List[Tuple[int, int]]:
    return [(s, min(s + block_elems, n)) for s in range(0, n, block_elems)]


@dataclass
class EncodedIndices:
    """Driver-produced encode output: the contract between encode/finalize.

    ``packed`` holds the raw (pre-entropy) packed bytes of every index
    block in global order; the final block is marker-padded to the full
    ``block_elems`` so host and device packers emit identical streams.

    ``entropy_coded`` is the already-entropy-coded variant of that
    contract: drivers with a device entropy stage (kernels.rans) hand
    finalize the finished per-block blobs (+ the codec that made them)
    and finalize skips the host entropy stage entirely.

    ``exc_positions``/``exc_block_counts`` carry the device-computed
    exception compaction (kernels.ops.exception_compact): finalize
    gathers the incompressible values by position instead of re-scanning
    the full index table with a host boolean mask.
    """

    # (n,) int32 bin ranks, marker = 2**B - 1.  May be None when the
    # driver entropy-coded and exception-compacted on device AND nothing
    # host-side (host reference chain) will read the table -- set ``n``
    # then, so finalize never forces a device->host fetch of it.
    idx: Optional[np.ndarray]
    b_bits: int
    block_elems: int
    n: Optional[int] = None    # element count; defaults to idx.size
    # Raw packed bytes per block.  Sharded driver fills this from the
    # device bit-pack kernel; None defers packing to the finalize stage
    # (host packer), which lets the overlapped stream keep the device
    # critical path free of host byte work.
    packed: Optional[List[bytes]] = None
    # Already-entropy-coded blocks (device entropy stage) + their codec.
    entropy_coded: Optional[List[bytes]] = None
    entropy_codec: Optional[str] = None
    # Device-compacted exceptions: ascending marker positions + per-block
    # marker counts (int64).  None => finalize falls back to the host scan.
    exc_positions: Optional[np.ndarray] = None
    exc_block_counts: Optional[np.ndarray] = None

    @property
    def marker(self) -> int:
        return (1 << self.b_bits) - 1


@dataclass
class DeviceEncoded:
    """Output of the device analyze+encode stages (pre-entropy).

    ``idx_dev``/``curr_dev`` are optional device handles (jax.Array) of
    the index table and the current step, kept so a device-resident
    ReferenceChain can advance without a host round-trip.  ``curr_dev``
    uses the driver's own layout (the sharded driver hands over its
    padded, mesh-sharded f32 copy).  Host consumers only read ``enc``.
    """

    enc: EncodedIndices
    centers: np.ndarray          # rounded to the data dtype (float64 view)
    domain_lo: float
    width: float
    meta: dict
    idx_dev: Optional[Any] = None
    curr_dev: Optional[Any] = None


def topk_centers(ids_desc: np.ndarray, k_eff: int, domain_lo: float,
                 width: float) -> np.ndarray:
    """Bin centers of the top-k candidate bins (paper Eq. centre of bin)."""
    sel = np.asarray(ids_desc)[:k_eff]
    return (np.float64(domain_lo)
            + (sel.astype(np.float64) + 0.5) * np.float64(width))


def round_centers(centers: np.ndarray, dtype) -> np.ndarray:
    """Paper stores centers in the data's own float type (Fig. 2); round now
    so in-memory and from-file reconstructions agree bit-exactly."""
    return np.asarray(centers).astype(dtype).astype(np.float64)


def pack_blocks_host(idx: np.ndarray, b_bits: int,
                     block_elems: int) -> List[bytes]:
    """Host bit-pack stage: B-bit indices -> raw bytes per block.

    The final partial block is padded with markers so every block packs to
    the same byte length (mirrors the device packer; decompressors only
    read the valid prefix).

    One vectorized ``np.packbits`` over the marker-padded table, sliced at
    block boundaries: every block spans a whole number of bytes
    (block_elems is a multiple of 32, so block_elems * B is divisible by
    8), hence packing the concatenation equals packing each block alone --
    byte-identical to the per-block loop it replaced (asserted in
    tests/test_rans.py).
    """
    marker = (1 << b_bits) - 1
    n = idx.size
    if n == 0:
        return []
    nblocks = -(-n // block_elems)
    total = nblocks * block_elems
    padded = idx if total == n else np.concatenate(
        [idx, np.full(total - n, marker, idx.dtype)])
    packed = packing.pack_indices_np(padded, b_bits).tobytes()
    bpb = block_elems * b_bits // 8          # bytes per block (exact)
    return [packed[s:s + bpb] for s in range(0, nblocks * bpb, bpb)]


def exception_offsets(incomp_mask: np.ndarray,
                      block_elems: int) -> np.ndarray:
    """Exclusive per-block prefix of incompressible counts (the
    decompressor's MPI_Scan analogue, done on host metadata)."""
    n = incomp_mask.size
    per_block = np.add.reduceat(incomp_mask,
                                np.arange(0, n, block_elems)).astype(np.int64)
    return np.concatenate([[0], np.cumsum(per_block)])[:-1]


def exception_table(idx: np.ndarray, marker: int, block_elems: int,
                    curr_flat: np.ndarray
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Compact incompressible values + their per-block offset table."""
    incomp_mask = idx == marker
    return curr_flat[incomp_mask], exception_offsets(incomp_mask, block_elems)


def entropy_ratio(blobs: List[bytes], raw_sizes: np.ndarray) -> float:
    """Average entropy-stage compression ratio (paper Table 9)."""
    comp = sum(len(b) for b in blobs)
    return float(np.asarray(raw_sizes).sum()) / max(comp, 1)


def _primary_codec(block_codecs: List[str]) -> str:
    """Most common per-block codec (deterministic: ties break by name);
    recorded as the step-level codec field alongside the per-block ids."""
    counts: dict = {}
    for c in block_codecs:
        counts[c] = counts.get(c, 0) + 1
    return max(sorted(counts), key=lambda c: counts[c])


def finalize_step(curr: np.ndarray, enc: EncodedIndices,
                  centers: np.ndarray, domain_lo: float, width: float,
                  params: NumarckParams,
                  meta: Optional[dict] = None) -> CompressedStep:
    """Shared host finalize: exceptions, parallel entropy stage, assembly.

    Single-device and sharded drivers both land here, so their output
    blobs are byte-identical for identical encode results.

    Exceptions: when the encode stage compacted them on device
    (``enc.exc_positions``), finalize gathers the k values by position --
    the full index table is never re-scanned here.  Entropy: when the
    encode stage already entropy-coded the blocks on device
    (``enc.entropy_coded``), finalize consumes the blobs as-is; otherwise
    the host codec stage runs, per-block adaptive under ``codec="auto"``
    (a codec id per block, persisted by the NCK container).
    """
    curr = np.asarray(curr)
    n = int(enc.n if enc.n is not None else enc.idx.size)
    # Driver-side stage timings (encode_device/_device_encode attach them
    # when telemetry is enabled); never persisted into blob bytes -- the
    # NCK container stores `info` attrs, not `meta`.
    meta = dict(meta or {})
    drv_tele = meta.pop("telemetry", None) or {}
    with telemetry.span("finalize", n=n, b_bits=enc.b_bits) as sp_fin:
        with telemetry.span("finalize.exceptions") as sp_exc:
            if enc.exc_positions is not None:
                incomp_values = curr.reshape(-1)[enc.exc_positions]
                incomp_off = np.concatenate(
                    [[0],
                     np.cumsum(enc.exc_block_counts)])[:-1].astype(np.int64)
            else:
                incomp_values, incomp_off = exception_table(
                    enc.idx, enc.marker, enc.block_elems, curr.reshape(-1))

        block_codecs: Optional[List[str]] = None
        with telemetry.span("finalize.entropy") as sp_ent:
            if enc.entropy_coded is not None:
                blks = enc.entropy_coded
                codec = enc.entropy_codec or entropy.DEFAULT_CODEC
                bpb = enc.block_elems * enc.b_bits // 8
                raw_sizes = np.full(len(blks), bpb, np.int64)
            else:
                raws = (enc.packed if enc.packed is not None
                        else pack_blocks_host(enc.idx, enc.b_bits,
                                              enc.block_elems))
                raw_sizes = np.asarray([len(r) for r in raws], np.int64)
                if params.codec == entropy.AUTO_CODEC and len(raws) > 1:
                    # Per-block adaptive pick; the step and the container
                    # record concrete ids only (one per block when they
                    # differ).
                    per = entropy.choose_block_codecs(raws,
                                                      params.zlib_level)
                    if len(set(per)) > 1:
                        codec = _primary_codec(per)
                        block_codecs = per
                        blks = entropy.compress_blocks_per_codec(
                            raws, per, level=params.zlib_level,
                            parallel=params.parallel_entropy)
                    else:
                        codec = per[0]
                        blks = entropy.compress_blocks(
                            raws, codec=codec, level=params.zlib_level,
                            parallel=params.parallel_entropy)
                else:
                    # "auto" on single-block payloads resolves per step,
                    # exactly as before; concrete ids pass through
                    # unchanged.
                    codec = entropy.resolve_codec(params.codec, raws,
                                                  params.zlib_level)
                    blks = entropy.compress_blocks(
                        raws, codec=codec, level=params.zlib_level,
                        parallel=params.parallel_entropy)
            sp_ent.set(codec=codec, blocks=len(blks))
        centers = round_centers(centers, curr.dtype)
        if centers.size > enc.marker:
            centers = centers[:enc.marker]
        ratio = entropy_ratio(blks, raw_sizes)
        bytes_in = int(np.asarray(raw_sizes).sum())
        bytes_out = sum(len(b) for b in blks)
        sp_fin.set(codec=codec, bytes_in=bytes_in, bytes_out=bytes_out)
    # "entropy_ratio" is the stage ratio whatever the codec; "zlib_ratio"
    # is kept as a deprecated alias (StepMeta warns once on read).
    full_meta = StepMeta({"entropy_ratio": ratio, "zlib_ratio": ratio,
                          "entropy_codec": codec})
    full_meta.update(meta)
    if telemetry.enabled():
        # Canonical per-step rollup: one fixed key set whatever the driver
        # (single-device vs sharded) or overlap mode, so series rollups
        # diff structurally (obs.report.STEP_TELEMETRY_KEYS).
        device_entropy = enc.entropy_coded is not None
        full_meta["telemetry"] = {
            "analyze_s": float(drv_tele.get("analyze_s", 0.0)),
            "encode_s": float(drv_tele.get("encode_s", 0.0)),
            "exceptions_s": sp_exc.duration,
            "entropy_s": (float(drv_tele.get("device_entropy_s", 0.0))
                          if device_entropy else sp_ent.duration),
            "finalize_s": sp_fin.duration,
            "bytes_in": bytes_in, "bytes_out": bytes_out,
            "entropy_ratio": ratio, "codec": codec,
            "device_entropy": device_entropy,
        }
    return CompressedStep(
        n=n, shape=tuple(curr.shape), dtype=str(curr.dtype),
        b_bits=enc.b_bits, error_bound=params.error_bound,
        strategy=params.strategy, reference=params.reference,
        domain_lo=float(domain_lo), bin_width=float(width),
        centers=centers, block_elems=enc.block_elems, codec=codec,
        block_codecs=block_codecs,
        index_blocks=blks, index_block_nbytes=raw_sizes,
        incomp_values=incomp_values, incomp_block_offsets=incomp_off,
        meta=full_meta)


def finalize_anchor(arr: np.ndarray, params: NumarckParams) -> CompressedStep:
    """Lossless anchor through the same entropy stage (codec-aware)."""
    arr = np.asarray(arr)
    flat = arr.reshape(-1)
    block_elems = max(1, params.block_bytes // flat.dtype.itemsize)
    with telemetry.span("finalize.anchor", n=arr.size) as sp:
        raws = [flat[s:e].tobytes() for s, e in block_slices(flat.size,
                                                             block_elems)]
        codec = entropy.resolve_codec(params.codec, raws, params.zlib_level)
        blks = entropy.compress_blocks(raws, codec=codec,
                                       level=params.zlib_level,
                                       parallel=params.parallel_entropy)
        sp.set(codec=codec)
    meta: dict = {"kind": "anchor"}
    if telemetry.enabled():
        bytes_in = arr.size * flat.dtype.itemsize
        bytes_out = sum(len(b) for b in blks)
        meta["telemetry"] = {
            "analyze_s": 0.0, "encode_s": 0.0, "exceptions_s": 0.0,
            "entropy_s": sp.duration, "finalize_s": sp.duration,
            "bytes_in": bytes_in, "bytes_out": bytes_out,
            "entropy_ratio": bytes_in / max(bytes_out, 1), "codec": codec,
            "device_entropy": False,
        }
    return CompressedStep(
        n=arr.size, shape=tuple(arr.shape), dtype=str(arr.dtype),
        b_bits=0, error_bound=params.error_bound, strategy=params.strategy,
        reference=params.reference, domain_lo=0.0, bin_width=0.0,
        centers=np.zeros(0), block_elems=block_elems, codec=codec,
        index_blocks=blks, meta=meta)


def reconstruct_from_indices(prev: np.ndarray, enc: EncodedIndices,
                             centers: np.ndarray, dtype,
                             incomp_values: Optional[np.ndarray] = None,
                             curr: Optional[np.ndarray] = None) -> np.ndarray:
    """Reconstruct R_i from the *pre-entropy* encode result.

    This is what lets the overlapped temporal stream advance: the
    REF_RECONSTRUCTED chain needs R_i before compressing step i+1, but not
    the deflated blobs -- so the entropy stage of step i can run in the
    background while the device encodes step i+1.  Bit-identical to
    ``decompress_step`` on the finalized blob AND to the device-resident
    chain: arithmetic runs in ``reconstruction_dtype(dtype)`` (the source
    precision), never silently promoting f32 chains through float64.
    """
    marker = enc.marker
    prev = np.asarray(prev)
    cdt = reconstruction_dtype(dtype)
    prev_flat = prev.reshape(-1).astype(cdt, copy=False)
    centers = np.asarray(centers, np.float64).astype(cdt)
    lut = np.concatenate([centers, np.zeros(marker + 1 - centers.size,
                                            cdt)])
    out = prev_flat * (1 + lut[enc.idx])
    mask = enc.idx == marker
    if mask.any():
        if incomp_values is None:
            assert curr is not None
            incomp_values = np.asarray(curr).reshape(-1)[mask]
        out[mask] = incomp_values.astype(cdt)
    return out.astype(dtype).reshape(prev.shape)


__all__ = ["StepMeta", "EncodedIndices", "DeviceEncoded", "block_slices",
           "topk_centers",
           "round_centers", "pack_blocks_host", "exception_offsets",
           "exception_table", "entropy_ratio", "finalize_step",
           "finalize_anchor", "reconstruct_from_indices",
           "reconstruction_dtype"]
