"""Batched serving engine: prefill + streaming decode with KV/SSM caches.

Serves any arch in the zoo.  Requests are padded into a fixed batch; the
engine jits one prefill and one decode executable per (batch, s_max) and
streams tokens.  This is the serve-side end-to-end driver (examples/
serve_lm.py uses it).

Session persistence: `snapshot_cache` / `load_cache` store a decode cache
(KV or SSM state) in an NCK container through the unified compression
pipeline's entropy stage (`core.entropy` codec registry, parallel host
finalize), so a long-lived session's prefix state can be evicted to disk
and resumed later without re-running prefill.

Sessions are held as `core.chain.SessionChain` handles: the decode cache,
resume token and position stay device-resident between requests and only
cross to host through the handle's explicit `.to_host()` at the
durable-write boundary (`save_session`).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import NumarckParams, make_anchor
from repro.core.chain import SessionChain
from repro.core.compress import decode_anchor, decode_anchor_device
from repro.core.container import NCKReader, NCKWriter
from repro.faults.errors import IntegrityError
from repro.models.model import Model
from repro.obs import telemetry


def _path_part(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey -> .name
    for attr in ("key", "idx", "name"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def _tree_keys(tree) -> List:
    flat = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = [_path_part(k) for k in path]
        if any("/" in p for p in parts):
            raise ValueError(
                f"cache key component contains '/': {parts}; rename the "
                "key or restore with load_cache(path, template=...)")
        flat.append(("/".join(parts), leaf))
    return flat


def snapshot_cache(cache: Any, path: str, codec: str = "zlib",
                   level: int = 6) -> Dict[str, int]:
    """Persist a decode-cache pytree losslessly (entropy-coded anchors)."""
    params = NumarckParams(codec=codec, zlib_level=level)
    w = NCKWriter()
    names = {}
    orig = comp = 0
    for i, (key, leaf) in enumerate(sorted(_tree_keys(cache))):
        arr = np.asarray(leaf)
        var = f"c{i:04d}"
        names[var] = key
        st = make_anchor(arr, params)
        orig += arr.nbytes
        comp += st.nbytes
        w.add_step(var, st)
    w.add_array("__names__",
                np.frombuffer(json.dumps(names).encode(), np.uint8))
    w.write(path)
    return {"orig_bytes": orig, "comp_bytes": comp}


def load_cache(path: str, template: Any = None,
               device: bool = False) -> Any:
    """Inverse of snapshot_cache; with `template`, leaves are reshaped and
    cast onto the template pytree (e.g. restoring device placement via a
    jitted identity afterwards).

    ``device=True`` decodes each anchor through the device route
    (`core.compress.decode_anchor_device`): blob bytes entropy-decode on
    the accelerator and the leaf materialises there directly -- no host
    reconstruction + re-upload round trip.  Bit-identical to the host
    path; leaves come back as jax Arrays instead of numpy."""
    r = NCKReader(path)
    names = json.loads(bytes(r.read_array("__names__")).decode())
    dec = decode_anchor_device if device else decode_anchor
    flat = {key: dec(r.read_step(var)) for var, key in names.items()}
    if template is None:
        root: Dict = {}
        for key, arr in flat.items():
            parts = key.split("/")
            d = root
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            d[parts[-1]] = arr
        return root
    keyed = _tree_keys(template)
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for key, leaf in keyed:
        arr = flat[key].reshape(np.shape(leaf))
        dtype = getattr(leaf, "dtype", None)
        leaves.append(arr.astype(dtype) if dtype is not None else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, model: Model, params, batch_size: int, s_max: int,
                 keep_session: bool = False):
        """`keep_session=True` retains each generate()'s final decode state
        (cache + next token + position) on the engine for
        save_session/resume (costs one cache of device memory between
        requests; off by default)."""
        self.model = model
        self.params = params
        self.B = batch_size
        self.s_max = s_max
        self.keep_session = keep_session
        # Engines are long-lived (one per serving process); constructor
        # traces happen once per instance, not per request.
        # repro-lint: disable=jit-cache-hygiene
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=s_max))
        # repro-lint: disable=jit-cache-hygiene
        self._decode = jax.jit(
            lambda p, c, tok, pos: model.decode(p, c, token=tok, pos=pos))
        self.stats = ServeStats()
        # Device-resident session handle (cache + next token + position);
        # host copies happen only through its .to_host() in save_session.
        self._session: Optional[SessionChain] = None
        # aval-only (shape/dtype) session template, recorded on the first
        # decode loop: lets load_session restore the exact traced avals on
        # any engine that has generated once, even with keep_session=False
        self._sess_template = None

    # Back-compat views of the session handle.
    @property
    def last_cache(self):
        """Decode cache of the last retained generate (device-resident)."""
        return self._session["cache"] if self._session is not None else None

    @property
    def last_tok(self):
        """Next (not yet emitted) token of the retained session."""
        return self._session["tok"] if self._session is not None else None

    @property
    def last_pos(self):
        """Absolute position of last_tok."""
        return self._session["pos"] if self._session is not None else None

    def save_session(self, path: str, codec: str = "zlib") -> Dict[str, int]:
        """Snapshot the last request batch's decode state to disk (cache +
        resume token/position, so the session restarts mid-stream).

        This is the durable-write boundary: the one place the
        device-resident session handle crosses to host (`.to_host()`)."""
        if self._session is None:
            raise RuntimeError(
                "no session cache retained: construct the Engine with "
                "keep_session=True and call generate() first")
        with telemetry.span("serve.save_session", path=path, codec=codec):
            return snapshot_cache(self._session.to_host(), path,
                                  codec=codec)

    def load_session(self, path: str):
        """Reload a snapshotted decode state and place it on device.

        Leaves decode straight onto the device (`load_cache(...,
        device=True)`: blob bytes entropy-decode on the accelerator, no
        host reconstruction + re-upload round trip); re-casting through
        the recorded session template and `jax.device_put` reproduces the
        exact avals the jitted decode executable was traced with, so
        `resume()` streams through the cached executable without a
        retrace (and without a per-step host->device transfer).  Requires
        one prior `generate()` on this engine (any keep_session setting)
        to have recorded the template.
        """
        names = json.loads(bytes(
            NCKReader(path).read_array("__names__")).decode())
        if not any(k == "pos" or k.split("/", 1)[0] == "cache"
                   for k in names.values()):
            raise ValueError(
                f"{path}: not an Engine session file (no cache/tok/pos "
                "record -- bare snapshot_cache() files predate the resume "
                "format; re-save with Engine.save_session)")
        if self._sess_template is None:
            raise RuntimeError(
                "load_session needs the session template: call generate() "
                "once on this engine first (any keep_session setting)")
        with telemetry.span("serve.load_session", path=path):
            try:
                sess = jax.device_put(load_cache(path,
                                                 template=self._sess_template,
                                                 device=True))
            except IntegrityError as e:
                # A flipped bit in a cold session must never resurrect as
                # wrong KV state; surface it with session context so the
                # caller can evict/refetch the snapshot.
                raise IntegrityError(
                    f"session snapshot {path} failed integrity "
                    f"verification and was not restored: {e}") from e
            self._session = SessionChain(sess)
        return self.last_cache

    def _decode_loop(self, cache, tok, pos, max_new: int, greedy: bool,
                     key, keep: bool) -> np.ndarray:
        """Shared streaming loop of generate/resume (same jitted callable)."""
        out = []
        t0 = time.perf_counter()
        with telemetry.span("serve.decode_loop", annotate=True,
                            max_new=max_new, batch=self.B):
            for i in range(max_new):
                out.append(np.asarray(tok)[:, 0])
                logits, cache = self._decode(self.params, cache, tok, pos)
                if greedy or key is None:
                    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                else:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(sub,
                                                 logits[:, -1])[:, None]
                tok = tok.astype(jnp.int32)
                pos = pos + 1
            jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.tokens_out += max_new * self.B
        if self._sess_template is None:
            self._sess_template = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                {"cache": cache, "tok": tok, "pos": pos})
        if keep:
            self._session = SessionChain({"cache": cache, "tok": tok,
                                          "pos": pos})
        return np.stack(out, axis=1)

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 greedy: bool = True, key=None) -> np.ndarray:
        """prompts (B, S0) int32 -> (B, max_new) int32 generated tokens."""
        assert prompts.shape[0] == self.B
        t0 = time.perf_counter()
        with telemetry.span("serve.prefill", annotate=True,
                            batch=self.B, s0=int(prompts.shape[1])):
            logits, cache, pos = self._prefill(
                self.params, {"tokens": jnp.asarray(prompts)})
            jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return self._decode_loop(cache, tok, pos, max_new, greedy, key,
                                 keep=self.keep_session)

    def resume(self, max_new: int = 16, greedy: bool = True,
               key=None) -> np.ndarray:
        """Continue a retained or load_session()-restored stream: no
        prefill, same jitted decode executable as generate().  Always
        advances the session state, so consecutive resume() calls stream
        onward (keep_session only governs whether generate() retains its
        cache between requests)."""
        if self._session is None:
            raise RuntimeError(
                "no session to resume: generate() with keep_session=True "
                "or load_session() first")
        return self._decode_loop(self._session["cache"],
                                 self._session["tok"],
                                 self._session["pos"], max_new, greedy, key,
                                 keep=True)


__all__ = ["Engine", "ServeStats", "snapshot_cache", "load_cache"]
