"""Batched serving engine: prefill + streaming decode with KV/SSM caches.

Serves any arch in the zoo.  Requests are padded into a fixed batch; the
engine jits one prefill and one decode executable per (batch, s_max) and
streams tokens.  This is the serve-side end-to-end driver (examples/
serve_lm.py uses it).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens_out: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens_out / self.decode_s if self.decode_s else 0.0


class Engine:
    def __init__(self, model: Model, params, batch_size: int, s_max: int):
        self.model = model
        self.params = params
        self.B = batch_size
        self.s_max = s_max
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, s_max=s_max))
        self._decode = jax.jit(
            lambda p, c, tok, pos: model.decode(p, c, token=tok, pos=pos))
        self.stats = ServeStats()

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 greedy: bool = True, key=None) -> np.ndarray:
        """prompts (B, S0) int32 -> (B, max_new) int32 generated tokens."""
        assert prompts.shape[0] == self.B
        t0 = time.perf_counter()
        logits, cache, pos = self._prefill(self.params,
                                           {"tokens": jnp.asarray(prompts)})
        jax.block_until_ready(logits)
        self.stats.prefill_s += time.perf_counter() - t0

        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for i in range(max_new):
            out.append(np.asarray(tok)[:, 0])
            logits, cache = self._decode(self.params, cache, tok, pos)
            if greedy or key is None:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            else:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits[:, -1])[:, None]
            tok = tok.astype(jnp.int32)
            pos = pos + 1
        jax.block_until_ready(tok)
        self.stats.decode_s += time.perf_counter() - t0
        self.stats.tokens_out += max_new * self.B
        return np.stack(out, axis=1)


__all__ = ["Engine", "ServeStats"]
