"""Near-zero-overhead pipeline telemetry: spans, counters, gauges, hists.

The paper's evaluation (and the stage-structured related work,
arXiv:1903.07761 / LCP arXiv:2411.00761) reports *per-stage* time
breakdowns of exactly our analyze/encode/entropy/write stages; this module
is the measurement substrate those numbers come from.  Design rules:

  * **Disabled is free.**  There is one process-global ``_active``
    registry slot; when it is ``None`` every primitive returns the shared
    no-op constant (``span``) or falls through a single attribute check
    (``counter``/``gauge``/``histo``).  No locks, no allocation, no
    timestamps on the disabled path -- instrumentation can stay in the hot
    paths permanently.
  * **Spans never change outputs.**  Every primitive is read-only with
    respect to pipeline state; blobs are byte-identical with telemetry
    enabled or disabled (asserted in tests/test_obs.py).
  * **Thread-aware.**  The span stack is thread-local (nesting depth is
    per thread) while the record list is shared under a lock, so spans
    from the entropy pool, the overlap workers and the main thread all
    land in one registry and export as separate Chrome-trace lanes
    (``obs.trace``).

Usage::

    from repro.obs import telemetry

    with telemetry.capture() as reg:
        with telemetry.span("encode", step=3) as sp:
            ...
            sp.set(bytes_out=n)
        telemetry.counter("entropy.bytes_in.zlib", total)
    report.rollup(reg)          # aggregates
    trace.write_chrome_trace(path, reg)   # chrome://tracing JSON

``span(..., annotate=True)`` additionally enters a
``jax.profiler.TraceAnnotation`` (registered lazily by ``obs.trace``) so
host spans line up with device kernels in a jax profiler capture.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["Registry", "SpanRecord", "span", "counter", "gauge", "histo",
           "capture", "enabled", "start", "stop", "active",
           "set_annotation_factory"]


class SpanRecord:
    """One finished span (immutable once recorded)."""

    __slots__ = ("name", "t0", "t1", "tid", "tname", "depth", "attrs",
                 "error")

    def __init__(self, name: str, t0: float, t1: float, tid: int,
                 tname: str, depth: int, attrs: Dict[str, Any],
                 error: Optional[str]):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.tid = tid
        self.tname = tname
        self.depth = depth
        self.attrs = attrs
        self.error = error

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"SpanRecord({self.name!r}, {self.duration * 1e3:.3f}ms, "
                f"thread={self.tname!r}, depth={self.depth})")


class Registry:
    """Holds every record of one capture window.

    Span records, counters, gauge sample series and histogram samples are
    appended under one lock (writers are the main thread plus pool/overlap
    workers); the span *stack* is thread-local so nesting depth is always
    per thread.
    """

    def __init__(self):
        self.t0 = time.perf_counter()
        self._lock = threading.Lock()
        self.spans: List[SpanRecord] = []
        self.counters: Dict[str, float] = {}
        # gauge name -> [(t_rel_seconds, value), ...] sample series
        self.gauges: Dict[str, List[Tuple[float, float]]] = {}
        self.hists: Dict[str, List[float]] = {}
        self._tls = threading.local()

    # ------------------------------------------------------------- writers
    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = []
            self._tls.stack = st
        return st

    def record_span(self, rec: SpanRecord):
        with self._lock:
            self.spans.append(rec)

    def counter_add(self, name: str, value: float):
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + value

    def gauge_set(self, name: str, value: float):
        t = time.perf_counter() - self.t0
        with self._lock:
            self.gauges.setdefault(name, []).append((t, float(value)))

    def hist_record(self, name: str, value: float):
        with self._lock:
            self.hists.setdefault(name, []).append(float(value))

    # ------------------------------------------------------------- readers
    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time copy of every record list (safe to iterate while
        workers keep appending)."""
        with self._lock:
            return {"spans": list(self.spans),
                    "counters": dict(self.counters),
                    "gauges": {k: list(v) for k, v in self.gauges.items()},
                    "hists": {k: list(v) for k, v in self.hists.items()}}

    def span_names(self) -> List[str]:
        with self._lock:
            return sorted({s.name for s in self.spans})


# ------------------------------------------------------------------ state

_active: Optional[Registry] = None
_annotation_factory: Optional[Callable[[str], Any]] = None


def set_annotation_factory(fn: Optional[Callable[[str], Any]]):
    """Register the device-annotation bridge (``obs.trace`` installs a
    ``jax.profiler.TraceAnnotation`` factory; ``None`` disables it).  The
    factory may return ``None`` (no annotation) or a context manager."""
    global _annotation_factory
    _annotation_factory = fn


def enabled() -> bool:
    return _active is not None


def active() -> Optional[Registry]:
    return _active


def start(registry: Optional[Registry] = None) -> Registry:
    """Enable telemetry into `registry` (a fresh one by default)."""
    global _active
    _active = registry if registry is not None else Registry()
    return _active


def stop() -> Optional[Registry]:
    """Disable telemetry; returns the registry that was collecting."""
    global _active
    reg, _active = _active, None
    return reg


@contextmanager
def capture(registry: Optional[Registry] = None):
    """Scoped enable: ``with telemetry.capture() as reg: ...``."""
    reg = start(registry)
    try:
        yield reg
    finally:
        if _active is reg:
            stop()


# ------------------------------------------------------------------ spans

class _NoopSpan:
    """The disabled-path constant: every method is a no-op, ``duration``
    is 0.0.  A single shared instance is returned by every ``span()`` call
    while telemetry is disabled -- no allocation, no timestamps."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, et, ev, tb):
        return False

    def set(self, **kw):
        return self

    @property
    def duration(self) -> float:
        return 0.0


NOOP_SPAN = _NoopSpan()


class Span:
    """A live span: context manager that records a SpanRecord on exit.

    ``set(**attrs)`` attaches attributes any time before exit (e.g. sizes
    known only at the end of the stage).  If the body raises, the record
    carries ``error`` and the exception propagates unchanged.
    """

    __slots__ = ("_reg", "name", "attrs", "t0", "t1", "_depth", "_ann")

    def __init__(self, reg: Registry, name: str, attrs: Dict[str, Any],
                 annotate: bool):
        self._reg = reg
        self.name = name
        self.attrs = attrs
        self.t0 = 0.0
        self.t1 = 0.0
        ann = _annotation_factory(name) if (annotate
                                            and _annotation_factory) else None
        self._ann = ann

    def set(self, **kw):
        self.attrs.update(kw)
        return self

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def __enter__(self):
        st = self._reg._stack()
        self._depth = len(st)
        st.append(self)
        if self._ann is not None:
            self._ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, et, ev, tb):
        self.t1 = time.perf_counter()
        if self._ann is not None:
            self._ann.__exit__(et, ev, tb)
        st = self._reg._stack()
        if st and st[-1] is self:
            st.pop()
        th = threading.current_thread()
        err = None if et is None else f"{et.__name__}: {ev}"
        self._reg.record_span(SpanRecord(
            self.name, self.t0, self.t1, th.ident or 0, th.name,
            self._depth, self.attrs, err))
        return False


def span(name: str, annotate: bool = False, **attrs):
    """Open a (nested) span.  Returns the shared no-op constant when
    telemetry is disabled -- safe to leave in hot paths."""
    reg = _active
    if reg is None:
        return NOOP_SPAN
    return Span(reg, name, attrs, annotate)


def counter(name: str, value: float = 1.0):
    reg = _active
    if reg is not None:
        reg.counter_add(name, value)


def gauge(name: str, value: float):
    reg = _active
    if reg is not None:
        reg.gauge_set(name, value)


def histo(name: str, value: float):
    reg = _active
    if reg is not None:
        reg.hist_record(name, value)
