"""Rollups of a telemetry capture: per-stage aggregates and per-series
summaries of the ``meta["telemetry"]`` records the pipeline emits.

Two consumers:

  * :func:`rollup` -- aggregate a whole capture window (every span name ->
    count/total/mean/max plus counters, last-value gauges and histogram
    summaries).  This is what ``docs/observability.md`` calls the
    "where did the time go" table.
  * :func:`series_rollup` -- aggregate the per-step ``meta["telemetry"]``
    dicts of a compressed series (each step carries its own stage
    timings; the series view sums the times and bytes and keeps the
    per-step entropy ratios).  Works on ``CompressedStep`` objects or on
    bare meta dicts.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.obs import telemetry

__all__ = ["rollup", "series_rollup", "STEP_TELEMETRY_KEYS",
           "READ_TELEMETRY_KEYS"]

# Canonical per-step telemetry keys (core.pipeline.finalize_step).  The
# set is identical across drivers (single-device vs sharded) and overlap
# modes so trajectory tooling can diff rollups structurally.
STEP_TELEMETRY_KEYS = ("analyze_s", "encode_s", "exceptions_s", "entropy_s",
                       "finalize_s", "bytes_in", "bytes_out",
                       "entropy_ratio", "codec", "device_entropy")

# Canonical per-read telemetry keys (``meta["telemetry_read"]``, written
# by ``core.compress._record_read``).  Mirrors the encode taxonomy on the
# decode side and -- like STEP_TELEMETRY_KEYS -- is identical across the
# single-device, sharded, and anchor read paths.
READ_TELEMETRY_KEYS = ("entropy_s", "dequant_s", "patch_s", "fetch_s",
                       "bytes_in", "bytes_out", "codec", "device_decode")


def rollup(reg: Optional[telemetry.Registry] = None) -> Dict[str, Any]:
    """Aggregate a capture: span-name totals, counters, gauges, hists."""
    reg = reg if reg is not None else telemetry.active()
    if reg is None:
        raise ValueError("no registry: pass one or run inside capture()")
    snap = reg.snapshot()
    spans: Dict[str, Dict[str, float]] = {}
    for rec in snap["spans"]:
        agg = spans.setdefault(rec.name, {"count": 0, "total_s": 0.0,
                                          "max_s": 0.0, "errors": 0})
        agg["count"] += 1
        agg["total_s"] += rec.duration
        agg["max_s"] = max(agg["max_s"], rec.duration)
        if rec.error is not None:
            agg["errors"] += 1
    for agg in spans.values():
        agg["mean_s"] = agg["total_s"] / max(agg["count"], 1)
    gauges = {name: {"last": samples[-1][1],
                     "min": min(v for _, v in samples),
                     "max": max(v for _, v in samples),
                     "samples": len(samples)}
              for name, samples in snap["gauges"].items() if samples}
    hists = {name: {"count": len(vs), "mean": sum(vs) / len(vs),
                    "min": min(vs), "max": max(vs)}
             for name, vs in snap["hists"].items() if vs}
    return {"spans": spans, "counters": dict(snap["counters"]),
            "gauges": gauges, "hists": hists}


def _step_tele(step) -> Optional[Dict[str, Any]]:
    meta = step if isinstance(step, dict) else getattr(step, "meta", None)
    if not meta:
        return None
    return meta.get("telemetry")


def series_rollup(steps: Iterable[Any]) -> Dict[str, Any]:
    """Aggregate the per-step ``meta["telemetry"]`` dicts of a series.

    Sums the stage seconds and byte counts over every step that carries a
    telemetry record (anchors included) and reports per-step entropy
    ratios; steps compressed with telemetry disabled are skipped (and
    counted in ``steps_without_telemetry``).
    """
    time_keys = ("analyze_s", "encode_s", "exceptions_s", "entropy_s",
                 "finalize_s")
    totals = {k: 0.0 for k in time_keys}
    bytes_in = bytes_out = 0
    ratios: List[float] = []
    codecs: Dict[str, int] = {}
    n_with = n_without = 0
    for step in steps:
        tele = _step_tele(step)
        if tele is None:
            n_without += 1
            continue
        n_with += 1
        for k in time_keys:
            totals[k] += float(tele.get(k, 0.0))
        bytes_in += int(tele.get("bytes_in", 0))
        bytes_out += int(tele.get("bytes_out", 0))
        if "entropy_ratio" in tele:
            ratios.append(float(tele["entropy_ratio"]))
        c = tele.get("codec")
        if c:
            codecs[c] = codecs.get(c, 0) + 1
    return {"steps": n_with, "steps_without_telemetry": n_without,
            "totals": totals, "bytes_in": bytes_in, "bytes_out": bytes_out,
            "entropy_ratio_mean": (sum(ratios) / len(ratios)) if ratios
            else None, "codecs": codecs}
