"""Chrome-trace (``chrome://tracing`` / Perfetto) export of a telemetry
capture, plus the jax device-annotation bridge.

``chrome_trace(reg)`` converts a :class:`~repro.obs.telemetry.Registry`
into the Trace Event Format dict Chrome/Perfetto load directly:

  * every span becomes a complete ("ph": "X") event on its own thread
    lane -- the entropy pool threads ("entropy_N"), the overlap/finalize
    workers ("finalize_N", "shard-finalize_N", "ckpt-save_N") and the
    main thread each render as a separate track, so "where did the time
    go" for one compressed step is visible at a glance;
  * gauge sample series become counter ("ph": "C") events (e.g. the
    FinalizeQueue depth over time);
  * counters and histogram summaries ride in ``otherData``.

Open a written file at chrome://tracing or https://ui.perfetto.dev.

Device bridging: importing this module registers a
``jax.profiler.TraceAnnotation`` factory with the telemetry layer, so
``span(..., annotate=True)`` host spans also appear inside a jax profiler
capture, lined up with the device kernels they launched.  The import is
lazy and failure-tolerant -- environments without jax still get host
spans.
"""
from __future__ import annotations

import json
from typing import Any, Dict, Optional

from repro.obs import telemetry

__all__ = ["chrome_trace", "write_chrome_trace", "device_annotation"]

_PID = 0                    # single-process trace; lanes are threads


def _jax_annotation(name: str):
    """Annotation factory: a TraceAnnotation when jax's profiler is
    importable, else None (span records host-side only)."""
    try:
        from jax.profiler import TraceAnnotation
    except Exception:  # pragma: no cover - jax is present in this repo
        return None
    return TraceAnnotation(name)


telemetry.set_annotation_factory(_jax_annotation)


def device_annotation(name: str):
    """Standalone device annotation (no host span): a context manager that
    is a no-op unless telemetry is enabled and jax is importable."""
    if not telemetry.enabled():
        return telemetry.NOOP_SPAN
    return _jax_annotation(name) or telemetry.NOOP_SPAN


def chrome_trace(reg: Optional[telemetry.Registry] = None) -> Dict[str, Any]:
    """Trace Event Format dict of a capture (the active one by default)."""
    reg = reg if reg is not None else telemetry.active()
    if reg is None:
        raise ValueError("no registry: pass one or run inside capture()")
    snap = reg.snapshot()
    events = []
    # Lane key is (os tid, thread name), not the tid alone: the OS reuses
    # idents, so a finalize worker that exits before an entropy pool
    # thread starts would otherwise be merged into the pool's lane.
    lanes: Dict[tuple, int] = {}
    for rec in snap["spans"]:
        tid = lanes.setdefault((rec.tid, rec.tname), len(lanes))
        args = {k: _jsonable(v) for k, v in rec.attrs.items()}
        if rec.error is not None:
            args["error"] = rec.error
        events.append({
            "name": rec.name, "cat": "host", "ph": "X",
            "ts": (rec.t0 - reg.t0) * 1e6, "dur": rec.duration * 1e6,
            "pid": _PID, "tid": tid, "args": args,
        })
    for (_, tname), tid in sorted(lanes.items(), key=lambda kv: kv[1]):
        events.append({"name": "thread_name", "ph": "M", "pid": _PID,
                       "tid": tid, "args": {"name": tname}})
    for name, samples in sorted(snap["gauges"].items()):
        for t, v in samples:
            events.append({"name": name, "ph": "C", "ts": t * 1e6,
                           "pid": _PID, "args": {"value": v}})
    hist_summary = {
        name: {"count": len(vs), "mean": sum(vs) / len(vs), "max": max(vs)}
        for name, vs in sorted(snap["hists"].items()) if vs}
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"counters": snap["counters"],
                          "histograms": hist_summary}}


def write_chrome_trace(path: str,
                       reg: Optional[telemetry.Registry] = None) -> str:
    """Write the Chrome-trace JSON for `reg` to `path`; returns `path`."""
    with open(path, "w") as f:
        json.dump(chrome_trace(reg), f)
    return path


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
