"""Pipeline observability: telemetry spans/counters/gauges, Chrome-trace
export, and per-step/per-series rollups.

Importing the package wires the pieces together (``trace`` registers the
jax TraceAnnotation bridge with ``telemetry``); all three submodules are
stdlib-only at import time, so ``repro.obs`` is safe to import from the
most import-light core modules.
"""
from repro.obs import telemetry
from repro.obs import trace
from repro.obs import report
from repro.obs.telemetry import (Registry, capture, counter, enabled, gauge,
                                 histo, span, start, stop)
from repro.obs.trace import chrome_trace, device_annotation, \
    write_chrome_trace
from repro.obs.report import rollup, series_rollup

__all__ = ["telemetry", "trace", "report", "Registry", "capture", "counter",
           "enabled", "gauge", "histo", "span", "start", "stop",
           "chrome_trace", "device_annotation", "write_chrome_trace",
           "rollup", "series_rollup"]
