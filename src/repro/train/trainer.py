"""Training loop: jitted train_step + host-side orchestration.

Features (DESIGN.md Sec. 6):
  * 2-D sharded params/optimizer (FSDP x TP) via distributed.sharding
  * optional NUMARCK gradient compression with error feedback
  * step-time watchdog (straggler mitigation surface)
  * checkpoint hooks (repro.checkpoint.manager) with NUMARCK temporal deltas
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.models.model import Model
from repro.train import gradcomp, optim


@dataclass
class TrainerConfig:
    opt: optim.AdamWConfig = field(default_factory=optim.AdamWConfig)
    grad_compression_bits: int = 0        # 0 = off
    log_every: int = 10
    watchdog_factor: float = 5.0          # step > factor * median -> flag
    checkpoint_every: int = 0             # steps; 0 = off


class TrainState:
    def __init__(self, params, opt_state, gc_state=None):
        self.params = params
        self.opt_state = opt_state
        self.gc_state = gc_state

    def tree(self):
        t = {"params": self.params, "opt_state": self.opt_state}
        if self.gc_state is not None:
            t["gc_state"] = self.gc_state
        return t


def make_train_step(model: Model, tcfg: TrainerConfig) -> Callable:
    """Pure (params, opt_state, gc_state, batch) -> (new..., metrics)."""

    def step(params, opt_state, gc_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        if tcfg.grad_compression_bits:
            grads, gc_state = gradcomp.compress_grads(
                grads, gc_state, b_bits=tcfg.grad_compression_bits)
        params, opt_state, om = optim.apply_updates(params, grads,
                                                    opt_state, tcfg.opt)
        metrics = dict(metrics, **om, loss=loss)
        return params, opt_state, gc_state, metrics

    return step


class Trainer:
    def __init__(self, model: Model, tcfg: TrainerConfig = TrainerConfig(),
                 checkpoint_manager=None):
        self.model = model
        self.tcfg = tcfg
        self.ckpt = checkpoint_manager
        # One Trainer per run: the step executable traces once per
        # instance.
        # repro-lint: disable=jit-cache-hygiene
        self._step_fn = jax.jit(make_train_step(model, tcfg))
        self._times: list = []
        self.straggler_events = 0

    def init_state(self, key) -> TrainState:
        params = self.model.init(key)
        opt_state = optim.init_state(params)
        gc_state = (gradcomp.init_state(params)
                    if self.tcfg.grad_compression_bits else None)
        return TrainState(params, opt_state, gc_state)

    def restore_or_init(self, key) -> tuple:
        """(state, start_step); restores from the checkpoint manager if a
        valid checkpoint exists (fault-tolerant restart path)."""
        if self.ckpt is not None:
            template = jax.eval_shape(
                lambda: TrainState(
                    self.model.init(jax.random.PRNGKey(0)),
                    optim.init_state(self.model.shape_params()),
                    gradcomp.init_state(self.model.shape_params())
                    if self.tcfg.grad_compression_bits else None).tree())
            restored = self.ckpt.restore_latest(template=template)
            if restored is not None:
                step, tree = restored
                state = TrainState(tree["params"], tree["opt_state"],
                                   tree.get("gc_state"))
                return state, step
        return self.init_state(key), 0

    def _watchdog(self, dt: float):
        """Step-time watchdog: deterministic data + even sharding means a
        slow step signals an infrastructure straggler.  On a real fleet this
        hooks the preemption/replacement API; here we count + log."""
        self._times.append(dt)
        hist = self._times[-50:]
        med = float(np.median(hist))
        if len(hist) >= 10 and dt > self.tcfg.watchdog_factor * med:
            self.straggler_events += 1
            return True
        return False

    def fit(self, state: TrainState, batches, start_step: int = 0,
            n_steps: Optional[int] = None, log: Callable = print):
        step = start_step
        history = []
        for batch in batches:
            if n_steps is not None and step >= n_steps:
                break
            t0 = time.perf_counter()
            (state.params, state.opt_state, state.gc_state,
             metrics) = self._step_fn(state.params, state.opt_state,
                                      state.gc_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = self._watchdog(dt)
            step += 1
            loss = float(metrics["loss"])
            history.append(loss)
            if step % self.tcfg.log_every == 0:
                log(f"step {step} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"dt {dt*1e3:.1f}ms" + (" [straggler]" if slow else ""))
            if (self.ckpt is not None and self.tcfg.checkpoint_every
                    and step % self.tcfg.checkpoint_every == 0):
                self.ckpt.save(step, state.tree())
        return state, step, history


__all__ = ["Trainer", "TrainerConfig", "TrainState", "make_train_step"]
