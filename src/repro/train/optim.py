"""AdamW with ZeRO-style sharded states (no external deps).

Optimizer state tensors (m, v) inherit the parameter's 2-D FSDP x TP
PartitionSpec, so the full Adam state is sharded over the whole mesh
(ZeRO-2/3 equivalent under GSPMD -- the gather happens inside the jitted
train step, never materializing replicated states).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_state(params) -> AdamState:
    # moments are always f32 masters, even for bf16-stored weights
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(zeros, params),
                     v=jax.tree.map(zeros, params))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def apply_updates(params, grads, state: AdamState, cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:                      # decoupled decay on matrices
            step_ = step_ + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamState(step, new_m, new_v), {
        "grad_norm": gn, "lr": lr}


__all__ = ["AdamWConfig", "AdamState", "init_state", "apply_updates",
           "schedule", "global_norm", "clip_by_global_norm"]
