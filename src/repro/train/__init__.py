"""Training substrate: optimizer, trainer loop, gradient compression."""
