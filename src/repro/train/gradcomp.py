"""NUMARCK-binning gradient compression with error feedback (beyond-paper).

The paper's top-k change-ratio codebook is reused as a *gradient* quantizer
for the cross-pod all-reduce: per tensor, gradients are binned into 2^B - 1
width-2E value bins chosen by histogram top-k (values, not ratios --
gradients have no temporal base), exceptions kept exact, and the residual
(quantization error) is accumulated locally and re-injected next step
(error feedback, a la 1-bit Adam / EF-SGD).

This is the "distributed-optimization trick" integration of the paper's
algorithm: the wire format shrinks from 32 bits to ~B bits per element for
the slow inter-pod hop while intra-pod reduction stays exact.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class GradCompState(NamedTuple):
    residual: jax.Array          # error-feedback accumulator (like grads)


@partial(jax.jit, static_argnames=("b_bits", "max_bins"))
def quantize_dequantize(g: jax.Array, b_bits: int = 6,
                        max_bins: int = 0):
    """Top-k value-binning round trip (what the wire would carry).

    Returns (g_hat, info) with g_hat the dequantized gradient; exceptions
    (out-of-top-k values) pass through exactly.

    `max_bins` defaults to 16 * 2^B: gradient values are roughly
    heavy-tailed-gaussian (NOT clustered like temporal change ratios), so
    the candidate grid must stay within a small multiple of the codebook
    for the top-k bins to cover most of the mass.  Constant tensors pass
    through exactly.
    """
    if not max_bins:
        max_bins = min(16 * (1 << b_bits), 1 << 16)
    flat = g.reshape(-1).astype(jnp.float32)
    lo = jnp.min(flat)
    hi = jnp.max(flat)
    width = jnp.maximum((hi - lo) / max_bins, 1e-20)
    ids = jnp.clip(((flat - lo) / width).astype(jnp.int32), 0, max_bins - 1)
    counts = jnp.zeros((max_bins,), jnp.int32).at[ids].add(1)
    k = (1 << b_bits) - 1
    _, top_ids = jax.lax.top_k(counts, k)
    lut = jnp.full((max_bins,), k, jnp.int32).at[top_ids].set(
        jnp.arange(k, dtype=jnp.int32))
    ranks = lut[ids]
    centers = lo + (top_ids.astype(jnp.float32) + 0.5) * width
    centers_pad = jnp.concatenate([centers, jnp.zeros((1,))])
    quant = centers_pad[ranks]
    compressible = (ranks < k) & (hi > lo)
    g_hat = jnp.where(compressible, quant, flat)
    alpha = jnp.mean((~compressible).astype(jnp.float32))
    return g_hat.reshape(g.shape).astype(g.dtype), {"alpha": alpha}


def init_state(grads_like) -> GradCompState:
    return GradCompState(residual=jax.tree.map(
        lambda g: jnp.zeros_like(g, jnp.float32), grads_like))


def compress_grads(grads, state: GradCompState, b_bits: int = 6,
                   max_bins: int = 0):
    """Error-feedback compression: g_hat = Q(g + r);  r' = g + r - g_hat."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        g_hat, _ = quantize_dequantize(corrected, b_bits=b_bits,
                                       max_bins=max_bins)
        return g_hat.astype(g.dtype), corrected - g_hat.astype(jnp.float32)

    flat = jax.tree.map(one, grads, state.residual)
    g_hat = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    resid = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda t: isinstance(t, tuple))
    return g_hat, GradCompState(residual=resid)


def wire_bits(g, b_bits: int, alpha: float) -> float:
    """Estimated wire size vs raw f32 (Eq. 6 adapted to gradients)."""
    n = g.size
    return (n * b_bits + alpha * n * 32) / (n * 32)


__all__ = ["GradCompState", "quantize_dequantize", "init_state",
           "compress_grads", "wire_bits"]
