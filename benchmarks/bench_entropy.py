"""Serial vs. parallel host entropy stage (core.entropy.compress_blocks).

Measures the finalize-stage speedup from the thread-pool dispatcher across
block sizes and codecs on a >= 64 MB synthetic index table -- the paper's
phase-6 ZLIB stage, finally parallel (cf. arXiv:1903.07761's threaded
entropy back-end).

Output (CSV via benchmarks.common.emit):
    entropy/<codec>/blk=<KB>KB/<mode>, us_per_call, MB/s + speedup
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import timeit, emit  # noqa: E402
from repro.core import entropy   # noqa: E402

TOTAL_BYTES = 64 << 20           # acceptance floor: >= 64 MB
BLOCK_BYTES = [256 << 10, 1 << 20, 4 << 20]
# lzma/bz2 are 10-40x slower than zlib; bench them on a slice so the whole
# run stays interactive, scaling MB/s accordingly.
CODEC_BYTES = {"zlib": TOTAL_BYTES, "raw": TOTAL_BYTES,
               "bz2": 16 << 20, "lzma": 8 << 20}


def synth_blocks(total: int, block: int) -> list:
    """Low-entropy synthetic packed index table: zipf-ish byte stream, the
    shape real B-bit rank tables have (rank 0 dominates)."""
    rng = np.random.default_rng(0)
    data = rng.zipf(1.6, total).astype(np.uint64) % 251
    raw = data.astype(np.uint8).tobytes()
    return [raw[s:s + block] for s in range(0, total, block)]


def synth_payloads(total: int) -> dict:
    """Payload families spanning the compressibility range the auto codec
    discriminates on: redundant (-> lzma), zipf index-like (-> zlib),
    random (-> raw)."""
    rng = np.random.default_rng(1)
    zipf = (rng.zipf(1.6, total).astype(np.uint64) % 251).astype(np.uint8)
    return {
        "redundant": np.zeros(total, np.uint8).tobytes(),
        "zipf-index": zipf.tobytes(),
        "random": rng.integers(0, 256, total).astype(np.uint8).tobytes(),
    }


def bench_auto_codec(rows: list, block: int = 1 << 20,
                     total: int = 8 << 20):
    """Auto pick vs every fixed codec: report the (ratio, time) gap between
    the adaptive choice and the best fixed codec per payload family."""
    for family, raw in synth_payloads(total).items():
        raws = [raw[s:s + block] for s in range(0, total, block)]
        pick = entropy.choose_codec(raws)
        results = {}
        for codec in ("zlib", "raw", "lzma"):
            t, out = timeit(entropy.compress_blocks, raws, codec=codec,
                            parallel=True, repeat=1)
            results[codec] = (t, sum(len(b) for b in out))
        best_ratio = min(results, key=lambda c: results[c][1])
        t_pick, sz_pick = results[pick]
        _, sz_best = results[best_ratio]
        gap = sz_pick / max(sz_best, 1)
        rows.append((f"entropy/auto/{family}", t_pick * 1e6,
                     f"pick={pick} best_fixed={best_ratio} "
                     f"size_vs_best={gap:.2f}x "
                     f"CR={total / max(sz_pick, 1):.1f}"))


def main():
    rows = []
    for codec in ("zlib", "raw", "bz2", "lzma"):
        total = CODEC_BYTES[codec]
        for block in BLOCK_BYTES:
            raws = synth_blocks(total, block)
            t_ser, out_s = timeit(entropy.compress_blocks, raws,
                                  codec=codec, parallel=False, repeat=2)
            t_par, out_p = timeit(entropy.compress_blocks, raws,
                                  codec=codec, parallel=True, repeat=2)
            assert out_s == out_p, "parallel output must be byte-identical"
            mb = total / (1 << 20)
            speedup = t_ser / max(t_par, 1e-9)
            tag = f"entropy/{codec}/blk={block >> 10}KB"
            rows.append((f"{tag}/serial", t_ser * 1e6,
                         f"{mb / t_ser:.0f}MB/s"))
            rows.append((f"{tag}/parallel", t_par * 1e6,
                         f"{mb / t_par:.0f}MB/s speedup={speedup:.2f}x"))
    bench_auto_codec(rows)
    emit(rows)


if __name__ == "__main__":
    main()
