"""Host + device entropy stage benchmarks.

Measures (a) the finalize-stage speedup from the thread-pool dispatcher
across block sizes and codecs on a >= 64 MB synthetic index table -- the
paper's phase-6 ZLIB stage, finally parallel (cf. arXiv:1903.07761's
threaded entropy back-end) -- (b) the device rANS codec (kernels.rans)
against the threaded-zlib finalize and raw store at 1/16/64 MB index
payloads, and (c) the decode mirror: the on-device rANS decoder vs the
host lane decoder vs zlib inflate on the same payloads (`--smoke` runs
only the device rows; `--json PATH` writes them as a BENCH_entropy.json
artifact for the CI perf trajectory).

Output (CSV via benchmarks.common.emit):
    entropy/<codec>/blk=<KB>KB/<mode>,   us_per_call, MB/s + speedup
    entropy/device/<MB>MB/<codec>,       us_per_call, MB/s + CR + speedup
    entropy/device_decode/<MB>MB/<mode>, us_per_call, MB/s + speedup
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

from common import timeit, emit, write_bench_json  # noqa: E402
from repro.core import entropy   # noqa: E402

# Device-codec payload sizes: the smoke sweep is a name-identical prefix
# of the full sweep, so check_regression can compare smoke CI rows
# against committed full-run artifacts by row-name intersection.
FULL_SIZES_MB = (1, 16, 64)
SMOKE_SIZES_MB = (1, 16)

TOTAL_BYTES = 64 << 20           # acceptance floor: >= 64 MB
BLOCK_BYTES = [256 << 10, 1 << 20, 4 << 20]
# lzma/bz2 are 10-40x slower than zlib; bench them on a slice so the whole
# run stays interactive, scaling MB/s accordingly.
CODEC_BYTES = {"zlib": TOTAL_BYTES, "raw": TOTAL_BYTES,
               "bz2": 16 << 20, "lzma": 8 << 20}


def synth_blocks(total: int, block: int) -> list:
    """Low-entropy synthetic packed index table: zipf-ish byte stream, the
    shape real B-bit rank tables have (rank 0 dominates)."""
    rng = np.random.default_rng(0)
    data = rng.zipf(1.6, total).astype(np.uint64) % 251
    raw = data.astype(np.uint8).tobytes()
    return [raw[s:s + block] for s in range(0, total, block)]


def synth_payloads(total: int) -> dict:
    """Payload families spanning the compressibility range the auto codec
    discriminates on: redundant (-> lzma), zipf index-like (-> zlib),
    random (-> raw)."""
    rng = np.random.default_rng(1)
    zipf = (rng.zipf(1.6, total).astype(np.uint64) % 251).astype(np.uint8)
    return {
        "redundant": np.zeros(total, np.uint8).tobytes(),
        "zipf-index": zipf.tobytes(),
        "random": rng.integers(0, 256, total).astype(np.uint8).tobytes(),
    }


def bench_auto_codec(rows: list, block: int = 1 << 20,
                     total: int = 8 << 20):
    """Auto pick vs every fixed codec: report the (ratio, time) gap between
    the adaptive choice and the best fixed codec per payload family."""
    for family, raw in synth_payloads(total).items():
        raws = [raw[s:s + block] for s in range(0, total, block)]
        pick = entropy.choose_codec(raws)
        results = {}
        for codec in ("zlib", "raw", "lzma"):
            t, out = timeit(entropy.compress_blocks, raws, codec=codec,
                            parallel=True, repeat=1)
            results[codec] = (t, sum(len(b) for b in out))
        best_ratio = min(results, key=lambda c: results[c][1])
        t_pick, sz_pick = results[pick]
        _, sz_best = results[best_ratio]
        gap = sz_pick / max(sz_best, 1)
        rows.append((f"entropy/auto/{family}", t_pick * 1e6,
                     f"pick={pick} best_fixed={best_ratio} "
                     f"size_vs_best={gap:.2f}x "
                     f"CR={total / max(sz_pick, 1):.1f}"))


def bench_device_codec(rows: list, sizes_mb=(1, 16, 64)):
    """Device rANS entropy stage vs the threaded-zlib finalize vs raw
    store on B=8 index payloads (blocks of 1 MB, the paper default).

    The device path starts from the on-device index table (its bit-pack
    rides inside the stage); the host codecs get the already-packed
    bytes, so the comparison is conservative in zlib's favor.
    """
    import jax.numpy as jnp
    from repro.kernels import rans

    b_bits = 8
    be = 1 << 20                  # 1 MB blocks at B=8
    pool = entropy._shared_pool()
    rng = np.random.default_rng(2)
    for mb in sizes_mb:
        n = mb << 20
        idx = (rng.zipf(1.6, n).astype(np.uint64) % 251).astype(np.int32)
        nblocks = -(-n // be)
        blk = min(be, n)
        idx_dev = jnp.asarray(idx)
        raw = idx.astype(np.uint8).tobytes()     # packed bytes at B=8
        raws = [raw[s:s + blk] for s in range(0, n, blk)]

        t_dev, blobs = timeit(rans.compress_blocks_device, idx_dev,
                              b_bits, nblocks, blk, pool=pool, repeat=2)
        t_zlib, out_z = timeit(entropy.compress_blocks, raws,
                               codec="zlib", parallel=True, repeat=2)
        t_raw, _ = timeit(entropy.compress_blocks, raws, codec="raw",
                          parallel=True, repeat=2)
        cr_dev = n / max(sum(len(b) for b in blobs), 1)
        cr_z = n / max(sum(len(b) for b in out_z), 1)
        tag = f"entropy/device/{mb}MB"
        rows.append((f"{tag}/rans_device", t_dev * 1e6,
                     f"{mb / t_dev:.0f}MB/s CR={cr_dev:.2f} "
                     f"speedup_vs_zlib={t_zlib / max(t_dev, 1e-9):.2f}x"))
        rows.append((f"{tag}/zlib_threaded", t_zlib * 1e6,
                     f"{mb / t_zlib:.0f}MB/s CR={cr_z:.2f}"))
        rows.append((f"{tag}/raw", t_raw * 1e6,
                     f"{mb / max(t_raw, 1e-9):.0f}MB/s CR=1.00"))


def bench_device_decode(rows: list, sizes_mb=(1, 16, 64)):
    """Decode mirror of bench_device_codec: the on-device rANS decoder
    (kernels.rans.decode_blocks_device, forward scan + unpack, one fetch)
    vs the host lane decoder (rans.decompress over the shared pool) vs
    threaded zlib inflate, on the same B=8 zipf index payloads.  The
    device row must hold within ~2x of the encode rows above -- decode is
    one table gather cheaper per symbol than encode, so a bigger gap
    means the lowering regressed.
    """
    import jax
    import jax.numpy as jnp
    from repro.kernels import rans

    b_bits = 8
    be = 1 << 20
    pool = entropy._shared_pool()
    rng = np.random.default_rng(2)
    for mb in sizes_mb:
        n = mb << 20
        idx = (rng.zipf(1.6, n).astype(np.uint64) % 251).astype(np.int32)
        nblocks = -(-n // be)
        blk = min(be, n)
        blobs = rans.compress_blocks_device(jnp.asarray(idx), b_bits,
                                            nblocks, blk, pool=pool)
        raw = idx.astype(np.uint8).tobytes()
        raws = [raw[s:s + blk] for s in range(0, n, blk)]
        zblobs = entropy.compress_blocks(raws, codec="zlib", parallel=True)

        def dev_decode():
            out = rans.decode_blocks_device(blobs, b_bits, blk, pool=pool)
            jax.block_until_ready(out)
            return out

        def host_decode():
            return list(pool.map(rans.decompress, blobs))

        t_dev, out_d = timeit(dev_decode, repeat=2)
        t_host, out_h = timeit(host_decode, repeat=2)
        t_z, _ = timeit(entropy.decompress_blocks, zblobs, codec="zlib",
                        parallel=True, repeat=2)
        got = np.asarray(out_d).reshape(-1)[:n]
        assert np.array_equal(got.astype(np.uint8),
                              idx.astype(np.uint8)), "device decode wrong"
        assert b"".join(out_h) == raw, "host decode wrong"
        tag = f"entropy/device_decode/{mb}MB"
        rows.append((f"{tag}/rans_device", t_dev * 1e6,
                     f"{mb / t_dev:.0f}MB/s "
                     f"speedup_vs_host={t_host / max(t_dev, 1e-9):.2f}x"))
        rows.append((f"{tag}/rans_host", t_host * 1e6,
                     f"{mb / t_host:.0f}MB/s"))
        rows.append((f"{tag}/zlib_inflate", t_z * 1e6,
                     f"{mb / max(t_z, 1e-9):.0f}MB/s"))


def run(smoke: bool = False, sizes_mb=None) -> list:
    """Benchmark rows (benchmarks/run.py entry point).  ``smoke`` runs
    only the device-codec comparison (the BENCH_entropy.json artifact)
    at the reduced SMOKE_SIZES_MB payload sweep."""
    if sizes_mb is None:
        sizes_mb = SMOKE_SIZES_MB if smoke else FULL_SIZES_MB
    rows: list = []
    if not smoke:
        for codec in ("zlib", "raw", "bz2", "lzma"):
            total = CODEC_BYTES[codec]
            for block in BLOCK_BYTES:
                raws = synth_blocks(total, block)
                t_ser, out_s = timeit(entropy.compress_blocks, raws,
                                      codec=codec, parallel=False,
                                      repeat=2)
                t_par, out_p = timeit(entropy.compress_blocks, raws,
                                      codec=codec, parallel=True, repeat=2)
                assert out_s == out_p, \
                    "parallel output must be byte-identical"
                mb = total / (1 << 20)
                speedup = t_ser / max(t_par, 1e-9)
                tag = f"entropy/{codec}/blk={block >> 10}KB"
                rows.append((f"{tag}/serial", t_ser * 1e6,
                             f"{mb / t_ser:.0f}MB/s"))
                rows.append((f"{tag}/parallel", t_par * 1e6,
                             f"{mb / t_par:.0f}MB/s speedup={speedup:.2f}x"))
        bench_auto_codec(rows)
    bench_device_codec(rows, sizes_mb=sizes_mb)
    bench_device_decode(rows, sizes_mb=sizes_mb)
    return rows


def write_json(rows: list, path: str, smoke: bool = False,
               sizes_mb=None):
    """BENCH_entropy.json in the shared schema (machine header + rows)."""
    if sizes_mb is None:
        sizes_mb = SMOKE_SIZES_MB if smoke else FULL_SIZES_MB
    write_bench_json(path, "entropy", rows,
                     config={"smoke": smoke,
                             "sizes_mb": list(sizes_mb),
                             "block_bytes": BLOCK_BYTES})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="device-codec rows only, reduced payload sweep")
    ap.add_argument("--json", default=None,
                    help="also write rows to this path (BENCH_entropy.json)")
    args = ap.parse_args()
    rows = run(smoke=args.smoke)
    emit(rows)
    if args.json:
        write_json(rows, args.json, smoke=args.smoke)


if __name__ == "__main__":
    main()
