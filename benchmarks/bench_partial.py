"""Paper Table 7: partial decompression time vs segment length.

Validates the paper's claim of a near-linear relationship (and the Sedov
caveat: a dataset with a single block decompresses the same regardless of
the requested fraction)."""
from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import NumarckParams, TemporalArchive, compress_series
from repro.data.temporal import generate_series


def run() -> list:
    rows: list[Row] = []
    # block sizes chosen so the scaled variables have ~50-100 blocks (the
    # paper's 59 GB variables at 1 MB blocks have ~60k); sedov keeps ONE
    # block to reproduce the paper's flat-curve caveat
    for name, scale, block_bytes in (("stir", 2, 1 << 13),
                                     ("asr", 2, 1 << 13),
                                     ("cmip", 2, 1 << 13),
                                     ("sedov", 1, 1 << 26)):  # 1 block
        series = list(generate_series(name, n_iterations=4, seed=3,
                                      scale=scale))
        p = NumarckParams(error_bound=1e-3, block_bytes=block_bytes)
        steps = compress_series(series, p)
        n = series[0].size
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "a.nck")
            TemporalArchive.write(path, "var", steps)
            ar = TemporalArchive(path)
            rng = np.random.default_rng(0)
            base = None
            for frac in (0.2, 0.4, 0.6, 0.8, 1.0):
                ln = max(1, int(n * frac))
                start = int(rng.integers(0, n - ln + 1))
                t, _ = timeit(ar.read_range, "var", 3, start, start + ln,
                              repeat=2)
                if base is None:
                    base = t / frac
                rows.append((f"table7_partial_{name}_{int(frac*100)}pct",
                             t * 1e6,
                             f"linearity={t/(base*frac):.2f}"))
    return rows
