"""Paper Table 2 + Figs. 3-8: parallel runtime, speedup, and phase
breakdown.

One CPU core cannot measure 12800-way speedup, so this bench does what the
paper's own analysis implies (Sec. V-A): measure each phase's single-core
throughput on Stir-like data, classify phases as perfectly-parallel
(change ratio, assign index, bits packing, ZLIB -- "no network
communication cost"), near-serial (top-k selection), or
collective-bound (MPI_Allreduce of the 2^16-bin histogram, modeled with
the v5e ICI latency/bandwidth), and derive the strong-scaling curve

    T(p) = T_parallel / p + T_topk + T_allreduce(p)

The derived speedups are validated against the paper's own shape: near-
linear until the binning collective dominates (Table 3: allreduce goes
5% -> 67.6% of the binning phase from 320 -> 1600 cores).

``run(real=True)`` adds MEASURED rows on top of the model: it launches
1/2/4 emulated jax.distributed processes (benchmarks/scaling_worker.py
via ``repro.launch.distributed.spawn_emulated``) and reports per-rank
CPU-seconds speedups for strong and weak scaling plus the per-phase
breakdown aggregated across ranks -- see docs/scaling.md for why
CPU-seconds (not wall) is the honest measure on the 1-core tracked
container.  These rows feed BENCH_scaling.json (`make bench-all`) and
the CI smoke gate."""
from __future__ import annotations

import json
import os
import zlib

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import NumarckParams
from repro.core import binning, packing, ratios
from repro.data.temporal import generate_series

# collective model: latency-bandwidth ring allreduce over p members
ALLREDUCE_LAT = 5e-6          # per hop
ICI_BW = 50e9


def allreduce_time(nbytes: float, p: int) -> float:
    if p == 1:
        return 0.0
    return 2 * (p - 1) * (ALLREDUCE_LAT + nbytes / p / ICI_BW)


def run_model() -> list:
    rows: list[Row] = []
    series = list(generate_series("stir", n_iterations=2, seed=5, scale=2))
    prev, curr = series[0].ravel(), series[1].ravel()
    n = curr.size
    p = NumarckParams(error_bound=1e-3)
    import jax.numpy as jnp
    import jax

    # ---- phase timings (per element; Figs. 5/6 phase breakdown) ---------
    prev_j, curr_j = jnp.asarray(prev, jnp.float32), jnp.asarray(
        curr, jnp.float32)

    f_ratio = jax.jit(lambda a, b: ratios.change_ratios(a, b)[0])
    t_ratio, _ = timeit(lambda: jax.block_until_ready(
        f_ratio(prev_j, curr_j)))

    r, valid = ratios.change_ratios(prev_j, curr_j)
    lo, hi = ratios.ratio_range(r, valid)
    dlo, w = ratios.histogram_domain(lo, hi, 1e-3, p.max_bins)
    ids, ok = ratios.candidate_bin_ids(r, valid, dlo, w, p.max_bins)
    f_hist = jax.jit(lambda i, o: binning.local_histogram(i, o, p.max_bins))
    t_hist, counts = timeit(lambda: jax.block_until_ready(
        f_hist(ids, ok)))

    f_sort = jax.jit(binning.sort_histogram)
    t_topk, (cd, idd) = timeit(lambda: jax.block_until_ready(
        f_sort(counts)))

    b_bits = 8
    k_eff = (1 << b_bits) - 1
    f_idx = jax.jit(lambda bi, dd: jnp.where(
        bi >= 0, jnp.where(binning.rank_lut(dd[:k_eff], k_eff,
                                            p.max_bins)[jnp.clip(bi, 0,
                                            p.max_bins - 1)] >= k_eff,
                           k_eff, binning.rank_lut(dd[:k_eff], k_eff,
                           p.max_bins)[jnp.clip(bi, 0, p.max_bins - 1)]),
        k_eff))
    t_idx, idx = timeit(lambda: jax.block_until_ready(f_idx(ids, idd)))

    idx_np = np.asarray(idx)
    t_pack, packed = timeit(packing.pack_indices_np, idx_np, b_bits)
    t_zlib, _ = timeit(zlib.compress, packed.tobytes(), 6)

    phases = {
        "change_ratio": t_ratio, "histogram": t_hist,
        "topk_selection": t_topk, "assign_index": t_idx,
        "bits_packing": t_pack, "zlib": t_zlib,
    }
    total = sum(phases.values())
    for name, t in phases.items():
        rows.append((f"fig5_6_phase_{name}", t * 1e6,
                     f"pct={t/total*100:.1f}% GBps={n*4/t/1e9:.2f}"))

    # ---- strong-scaling model (Table 2 / Figs 3-4) -----------------------
    t_parallel = total - t_topk
    hist_bytes = p.max_bins * 4
    # scale the measured variable up to Stir-2's 59 GB velx
    scale_up = 59e9 / (n * 4)
    for cores in (1, 320, 480, 640, 800, 960, 1120, 1280, 1440, 1600,
                  3200, 6400, 12800):
        t_p = (t_parallel * scale_up) / cores + t_topk \
            + allreduce_time(hist_bytes, cores)
        if cores == 1:
            t_serial = t_p
            continue
        speedup = t_serial / t_p
        rows.append((f"table2_stir2_model_p{cores}", t_p * 1e6,
                     f"T={t_p:.3f}s speedup={speedup:.0f} "
                     f"eff={speedup/cores*100:.0f}%"))

    # ---- Table 3 analogue: allreduce share of the binning phase ---------
    for cores in (320, 1600, 3200, 12800):
        t_bin = t_hist * scale_up / cores + t_topk + allreduce_time(
            hist_bytes, cores)
        ar = allreduce_time(hist_bytes, cores)
        rows.append((f"table3_allreduce_share_p{cores}", ar * 1e6,
                     f"share={ar/t_bin*100:.1f}% "
                     f"topk_share={t_topk/t_bin*100:.1f}%"))
    return rows


# --------------------------------------------------------- measured mode

# Paper's perfectly-parallel phases ("no network communication cost"):
# assign index + bits packing (encode), exception recovery, ZLIB.  The
# analyze phase carries the histogram allreduce and is collective-bound.
PAR_KEYS = ("encode_s", "exceptions_s", "entropy_s")
PHASE_KEYS = ("analyze_s", "encode_s", "exceptions_s", "entropy_s",
              "finalize_s")


def _launch(ranks: int, n: int, steps: int, *, preset: bool = True,
            timeout: float = 1800.0) -> list:
    """Spawn `ranks` emulated worker processes; return their RESULT
    records in rank order."""
    from repro.launch.distributed import check_spawned, spawn_emulated

    here = os.path.dirname(os.path.abspath(__file__))
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(here, "..", "src"))
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env["SCALING_N"] = str(n)
    env["SCALING_STEPS"] = str(steps)
    res = spawn_emulated(ranks, [os.path.join(here, "scaling_worker.py")],
                         base_env=env, preset=preset, timeout=timeout)
    check_spawned(res)
    recs = []
    for r in res:
        for line in r.stdout.splitlines():
            if line.startswith("RESULT "):
                recs.append(json.loads(line[len("RESULT "):]))
    if len(recs) != ranks:
        raise RuntimeError(f"expected {ranks} RESULT lines, got "
                           f"{len(recs)}")
    return recs


def _cpu_par(rec: dict) -> float:
    """The rank's CPU-seconds attributed to the perfectly-parallel
    phases: total process CPU scaled by the phases' wall share (uniform-
    contention attribution; docs/scaling.md)."""
    tot = sum(rec["phases"].values()) or 1.0
    par = sum(rec["phases"][k] for k in PAR_KEYS)
    return rec["cpu_s"] * par / tot


def run_real(smoke: bool = False) -> list:
    """Measured speedup-vs-ranks rows from emulated multi-process runs.

    Smoke keeps {1,2} ranks on a smaller payload; full runs {1,2,4}.
    Smoke row names are a subset of the full run's, so check_regression
    gates a CI smoke run against the committed full artifact."""
    rows: list[Row] = []
    n = 96_000 if smoke else 240_000
    steps = 2 if smoke else 3
    ranks = (1, 2) if smoke else (1, 2, 4)

    # Satellite: the runtime-env preset (tcmalloc preload + log quieting
    # + XLA host-device flag) before/after on the same 1-rank payload.
    rec_off = _launch(1, n, steps, preset=False)[0]
    rec_on = _launch(1, n, steps, preset=True)[0]
    from repro.launch.runtime_env import find_tcmalloc
    tc = "yes" if find_tcmalloc() else "absent"
    rows.append(("scaling/runtime_env/off", rec_off["cpu_s"] * 1e6,
                 f"wall={rec_off['wall_s']:.3f}s"))
    rows.append(("scaling/runtime_env/on", rec_on["cpu_s"] * 1e6,
                 f"wall={rec_on['wall_s']:.3f}s tcmalloc={tc} "
                 f"cpu_speedup="
                 f"{rec_off['cpu_s'] / rec_on['cpu_s']:.3f}x"))

    # Strong scaling: fixed global payload, more ranks.  The preset 1-rank
    # run above is exactly the p=1 configuration; reuse it as baseline.
    strong = {1: [rec_on]}
    for p in ranks[1:]:
        strong[p] = _launch(p, n, steps)
    base_cpu = strong[1][0]["cpu_s"]
    base_par = _cpu_par(strong[1][0])
    par_speedups = []
    for p in ranks:
        recs = strong[p]
        max_cpu = max(r["cpu_s"] for r in recs)
        max_par = max(_cpu_par(r) for r in recs)
        wall = max(r["wall_s"] for r in recs)
        spp = base_par / max_par
        par_speedups.append(spp)
        rows.append((f"scaling/real/strong/p{p}", max_cpu * 1e6,
                     f"cpu_speedup={base_cpu / max_cpu:.2f}x "
                     f"par_speedup={spp:.2f}x wall={wall:.3f}s"))
        # Per-phase breakdown aggregated across ranks: us = max across
        # ranks (the critical path), derived = fleet-total share.
        fleet_tot = sum(sum(r["phases"].values()) for r in recs) or 1.0
        for k in PHASE_KEYS:
            k_max = max(r["phases"][k] for r in recs)
            k_sum = sum(r["phases"][k] for r in recs)
            rows.append((f"scaling/real/p{p}/phase_{k[:-2]}", k_max * 1e6,
                         f"sum={k_sum:.4f}s "
                         f"pct={k_sum / fleet_tot * 100:.1f}%"))

    # Weak scaling: payload grows with the fleet, per-rank share constant.
    weak = {1: [rec_on]}
    for p in ranks[1:]:
        weak[p] = _launch(p, n * p, steps)
    for p in ranks:
        max_cpu = max(r["cpu_s"] for r in weak[p])
        rows.append((f"scaling/real/weak/p{p}", max_cpu * 1e6,
                     f"eff={base_cpu / max_cpu * 100:.0f}% "
                     f"wall={max(r['wall_s'] for r in weak[p]):.3f}s"))

    # Gate: speedup of the perfectly-parallel phases must grow with the
    # rank count.  A *_FAILED row name fails check_regression outright.
    ok = all(b > a for a, b in zip(par_speedups, par_speedups[1:]))
    rows.append(("scaling/real/monotonic" + ("" if ok else "_FAILED"),
                 0.0, "par_speedups=" + ",".join(
                     f"{s:.2f}" for s in par_speedups)))
    return rows


def run(real: bool = False, smoke: bool = False) -> list:
    """Analytical model rows, plus the measured multi-process rows when
    ``real`` (BENCH_scaling.json); smoke shrinks the measured sweep."""
    rows = run_model()
    if real:
        rows += run_real(smoke=smoke)
    return rows
