"""Paper Table 2 + Figs. 3-8: parallel runtime, speedup, and phase
breakdown.

One CPU core cannot measure 12800-way speedup, so this bench does what the
paper's own analysis implies (Sec. V-A): measure each phase's single-core
throughput on Stir-like data, classify phases as perfectly-parallel
(change ratio, assign index, bits packing, ZLIB -- "no network
communication cost"), near-serial (top-k selection), or
collective-bound (MPI_Allreduce of the 2^16-bin histogram, modeled with
the v5e ICI latency/bandwidth), and derive the strong-scaling curve

    T(p) = T_parallel / p + T_topk + T_allreduce(p)

The derived speedups are validated against the paper's own shape: near-
linear until the binning collective dominates (Table 3: allreduce goes
5% -> 67.6% of the binning phase from 320 -> 1600 cores)."""
from __future__ import annotations

import zlib

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import NumarckParams
from repro.core import binning, packing, ratios
from repro.data.temporal import generate_series

# collective model: latency-bandwidth ring allreduce over p members
ALLREDUCE_LAT = 5e-6          # per hop
ICI_BW = 50e9


def allreduce_time(nbytes: float, p: int) -> float:
    if p == 1:
        return 0.0
    return 2 * (p - 1) * (ALLREDUCE_LAT + nbytes / p / ICI_BW)


def run() -> list:
    rows: list[Row] = []
    series = list(generate_series("stir", n_iterations=2, seed=5, scale=2))
    prev, curr = series[0].ravel(), series[1].ravel()
    n = curr.size
    p = NumarckParams(error_bound=1e-3)
    import jax.numpy as jnp
    import jax

    # ---- phase timings (per element; Figs. 5/6 phase breakdown) ---------
    prev_j, curr_j = jnp.asarray(prev, jnp.float32), jnp.asarray(
        curr, jnp.float32)

    f_ratio = jax.jit(lambda a, b: ratios.change_ratios(a, b)[0])
    t_ratio, _ = timeit(lambda: jax.block_until_ready(
        f_ratio(prev_j, curr_j)))

    r, valid = ratios.change_ratios(prev_j, curr_j)
    lo, hi = ratios.ratio_range(r, valid)
    dlo, w = ratios.histogram_domain(lo, hi, 1e-3, p.max_bins)
    ids, ok = ratios.candidate_bin_ids(r, valid, dlo, w, p.max_bins)
    f_hist = jax.jit(lambda i, o: binning.local_histogram(i, o, p.max_bins))
    t_hist, counts = timeit(lambda: jax.block_until_ready(
        f_hist(ids, ok)))

    f_sort = jax.jit(binning.sort_histogram)
    t_topk, (cd, idd) = timeit(lambda: jax.block_until_ready(
        f_sort(counts)))

    b_bits = 8
    k_eff = (1 << b_bits) - 1
    f_idx = jax.jit(lambda bi, dd: jnp.where(
        bi >= 0, jnp.where(binning.rank_lut(dd[:k_eff], k_eff,
                                            p.max_bins)[jnp.clip(bi, 0,
                                            p.max_bins - 1)] >= k_eff,
                           k_eff, binning.rank_lut(dd[:k_eff], k_eff,
                           p.max_bins)[jnp.clip(bi, 0, p.max_bins - 1)]),
        k_eff))
    t_idx, idx = timeit(lambda: jax.block_until_ready(f_idx(ids, idd)))

    idx_np = np.asarray(idx)
    t_pack, packed = timeit(packing.pack_indices_np, idx_np, b_bits)
    t_zlib, _ = timeit(zlib.compress, packed.tobytes(), 6)

    phases = {
        "change_ratio": t_ratio, "histogram": t_hist,
        "topk_selection": t_topk, "assign_index": t_idx,
        "bits_packing": t_pack, "zlib": t_zlib,
    }
    total = sum(phases.values())
    for name, t in phases.items():
        rows.append((f"fig5_6_phase_{name}", t * 1e6,
                     f"pct={t/total*100:.1f}% GBps={n*4/t/1e9:.2f}"))

    # ---- strong-scaling model (Table 2 / Figs 3-4) -----------------------
    t_parallel = total - t_topk
    hist_bytes = p.max_bins * 4
    # scale the measured variable up to Stir-2's 59 GB velx
    scale_up = 59e9 / (n * 4)
    for cores in (1, 320, 480, 640, 800, 960, 1120, 1280, 1440, 1600,
                  3200, 6400, 12800):
        t_p = (t_parallel * scale_up) / cores + t_topk \
            + allreduce_time(hist_bytes, cores)
        if cores == 1:
            t_serial = t_p
            continue
        speedup = t_serial / t_p
        rows.append((f"table2_stir2_model_p{cores}", t_p * 1e6,
                     f"T={t_p:.3f}s speedup={speedup:.0f} "
                     f"eff={speedup/cores*100:.0f}%"))

    # ---- Table 3 analogue: allreduce share of the binning phase ---------
    for cores in (320, 1600, 3200, 12800):
        t_bin = t_hist * scale_up / cores + t_topk + allreduce_time(
            hist_bytes, cores)
        ar = allreduce_time(hist_bytes, cores)
        rows.append((f"table3_allreduce_share_p{cores}", ar * 1e6,
                     f"share={ar/t_bin*100:.1f}% "
                     f"topk_share={t_topk/t_bin*100:.1f}%"))
    return rows
