"""Paper Figs. 9-12 + Tables 4/5/6: compression ratios, incompressible
ratios, and compress/decompress times for NUMARCK vs ISABELA vs ZFP vs ZLIB
on the four dataset families (synthetic analogues, DESIGN.md data layer)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.baselines import isabela, zfp_like, zlib_lossless
from repro.core import (NumarckParams, compress_step, decompress_step,
                        mean_error_rate)
from repro.data.temporal import generate_series

E = 1e-3                       # paper: error threshold 0.1%
SCALE = {"sedov": 1, "stir": 2, "asr": 2, "cmip": 2}


def run(datasets=("sedov", "stir", "asr", "cmip")) -> list:
    rows: list[Row] = []
    for name in datasets:
        series = list(generate_series(name, n_iterations=3, seed=11,
                                      scale=SCALE[name]))
        prev, curr = series[1], series[2]
        nbytes = curr.nbytes

        # --- NUMARCK (top-k, auto-B) — figs 9-12 + tables 4/5/6 ---------
        p = NumarckParams(error_bound=E)
        t_c, step = timeit(compress_step, prev, curr, p, repeat=2)
        t_d, recon = timeit(decompress_step, step, prev, repeat=2)
        me = mean_error_rate(curr, recon)
        rows.append((f"fig9_12_cr_numarck_{name}", t_c * 1e6,
                     f"CR={step.compression_ratio():.2f} ME={me:.2e} "
                     f"B={step.b_bits}"))
        rows.append((f"table4_alpha_{name}", 0.0,
                     f"alpha={step.alpha*100:.2f}%"))
        rows.append((f"table5_compress_time_{name}", t_c * 1e6,
                     f"MBps={nbytes/t_c/1e6:.1f}"))
        rows.append((f"table6_decompress_time_{name}", t_d * 1e6,
                     f"MBps={nbytes/t_d/1e6:.1f}"))

        # --- ISABELA ----------------------------------------------------
        t_ci, blob_i = timeit(isabela.compress, curr, E, 1024, 32,
                              repeat=1)
        t_di, rec_i = timeit(isabela.decompress, blob_i, repeat=1)
        rows.append((f"fig9_12_cr_isabela_{name}", t_ci * 1e6,
                     f"CR={nbytes/blob_i.nbytes:.2f} "
                     f"ME={mean_error_rate(curr, rec_i):.2e}"))
        rows.append((f"table5_compress_time_isabela_{name}", t_ci * 1e6,
                     f"MBps={nbytes/t_ci/1e6:.1f}"))
        rows.append((f"table6_decompress_time_isabela_{name}",
                     t_di * 1e6, f"MBps={nbytes/t_di/1e6:.1f}"))

        # --- ZFP (abs tol = mean * E, the paper's convention) -----------
        tol = float(np.mean(np.abs(curr))) * E
        t_cz, blob_z = timeit(zfp_like.compress, curr, tol, repeat=1)
        t_dz, rec_z = timeit(zfp_like.decompress, blob_z, repeat=1)
        rows.append((f"fig9_12_cr_zfp_{name}", t_cz * 1e6,
                     f"CR={nbytes/blob_z.nbytes:.2f} "
                     f"ME={mean_error_rate(curr, rec_z):.2e}"))
        rows.append((f"table5_compress_time_zfp_{name}", t_cz * 1e6,
                     f"MBps={nbytes/t_cz/1e6:.1f}"))
        rows.append((f"table6_decompress_time_zfp_{name}", t_dz * 1e6,
                     f"MBps={nbytes/t_dz/1e6:.1f}"))

        # --- ZLIB lossless reference -------------------------------------
        t_zl, blob_l = timeit(zlib_lossless.compress, curr, repeat=1)
        rows.append((f"fig9_12_cr_zlib_{name}", t_zl * 1e6,
                     f"CR={nbytes/blob_l.nbytes:.2f} ME=0"))
    return rows
