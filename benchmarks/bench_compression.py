"""Paper Figs. 9-12 + Tables 4/5/6: compression ratios, incompressible
ratios, and compress/decompress times for NUMARCK vs ISABELA vs ZFP vs ZLIB
on the four dataset families (synthetic analogues, DESIGN.md data layer).

Also: the sharded overlapped-streaming wall-clock comparison (paper
Sec. IV-C compute/IO overlap at rank scale) -- run in a subprocess so the
2-device host-platform mesh doesn't leak into the caller's jax config.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks.common import Row, timeit
from repro.baselines import isabela, zfp_like, zlib_lossless
from repro.core import (NumarckParams, compress_step, decompress_step,
                        mean_error_rate)
from repro.data.temporal import generate_series

E = 1e-3                       # paper: error threshold 0.1%
SCALE = {"sedov": 1, "stir": 2, "asr": 2, "cmip": 2}


def run(datasets=("sedov", "stir", "asr", "cmip"),
        include_sharded: bool = True, include_chain: bool = True) -> list:
    """``include_sharded``/``include_chain`` gate the subprocess rides
    (2-device sharded stream, chain residency) so the smoke variant of
    `make bench-all` stays in-process; smoke rows remain a name-identical
    subset of the full run's rows."""
    rows: list[Row] = []
    for name in datasets:
        series = list(generate_series(name, n_iterations=3, seed=11,
                                      scale=SCALE[name]))
        prev, curr = series[1], series[2]
        nbytes = curr.nbytes

        # --- NUMARCK (top-k, auto-B) — figs 9-12 + tables 4/5/6 ---------
        p = NumarckParams(error_bound=E)
        t_c, step = timeit(compress_step, prev, curr, p, repeat=2)
        t_d, recon = timeit(decompress_step, step, prev, repeat=2)
        me = mean_error_rate(curr, recon)
        rows.append((f"fig9_12_cr_numarck_{name}", t_c * 1e6,
                     f"CR={step.compression_ratio():.2f} ME={me:.2e} "
                     f"B={step.b_bits}"))
        rows.append((f"table4_alpha_{name}", 0.0,
                     f"alpha={step.alpha*100:.2f}%"))
        rows.append((f"table5_compress_time_{name}", t_c * 1e6,
                     f"MBps={nbytes/t_c/1e6:.1f}"))
        rows.append((f"table6_decompress_time_{name}", t_d * 1e6,
                     f"MBps={nbytes/t_d/1e6:.1f}"))

        # --- ISABELA ----------------------------------------------------
        t_ci, blob_i = timeit(isabela.compress, curr, E, 1024, 32,
                              repeat=1)
        t_di, rec_i = timeit(isabela.decompress, blob_i, repeat=1)
        rows.append((f"fig9_12_cr_isabela_{name}", t_ci * 1e6,
                     f"CR={nbytes/blob_i.nbytes:.2f} "
                     f"ME={mean_error_rate(curr, rec_i):.2e}"))
        rows.append((f"table5_compress_time_isabela_{name}", t_ci * 1e6,
                     f"MBps={nbytes/t_ci/1e6:.1f}"))
        rows.append((f"table6_decompress_time_isabela_{name}",
                     t_di * 1e6, f"MBps={nbytes/t_di/1e6:.1f}"))

        # --- ZFP (abs tol = mean * E, the paper's convention) -----------
        tol = float(np.mean(np.abs(curr))) * E
        t_cz, blob_z = timeit(zfp_like.compress, curr, tol, repeat=1)
        t_dz, rec_z = timeit(zfp_like.decompress, blob_z, repeat=1)
        rows.append((f"fig9_12_cr_zfp_{name}", t_cz * 1e6,
                     f"CR={nbytes/blob_z.nbytes:.2f} "
                     f"ME={mean_error_rate(curr, rec_z):.2e}"))
        rows.append((f"table5_compress_time_zfp_{name}", t_cz * 1e6,
                     f"MBps={nbytes/t_cz/1e6:.1f}"))
        rows.append((f"table6_decompress_time_zfp_{name}", t_dz * 1e6,
                     f"MBps={nbytes/t_dz/1e6:.1f}"))

        # --- ZLIB lossless reference -------------------------------------
        t_zl, blob_l = timeit(zlib_lossless.compress, curr, repeat=1)
        rows.append((f"fig9_12_cr_zlib_{name}", t_zl * 1e6,
                     f"CR={nbytes/blob_l.nbytes:.2f} ME=0"))
    # --- robustness: NCK4 checksum-frame overhead (PR 10) ---------------
    # Unconditional so the smoke subset keeps the rows and bench-check
    # gates them against the committed artifact.
    rows.extend(run_checksum_overhead())
    if include_sharded:
        rows.extend(run_sharded_overlap())
    if include_chain:
        # host-chain vs device-chain residency (single-device and sharded,
        # overlap on/off) -- the ReferenceChain refactor, measured.
        from benchmarks import bench_chain
        rows.extend(bench_chain.run())
    return rows


def run_checksum_overhead() -> list:
    """Container write+read with the NCK4 checksum frame on vs off
    (``NCKWriter(checksums=...)``), same compressed payload both ways.
    The delta is the pure crc32 cost of the integrity layer
    (docs/robustness.md): one digest pass over the payload each way,
    clearly visible on raw container reads (no entropy decode here) and
    amortized to noise in decode-dominated workloads."""
    import tempfile

    from repro.core import compress_series
    from repro.core.container import NCKReader, NCKWriter

    rng = np.random.default_rng(23)
    n = 1 << 20                                   # 4 MB/step float32
    a = rng.normal(1.0, 0.5, n).astype(np.float32)
    b = (a * (1 + 0.01 * rng.standard_normal(n))).astype(np.float32)
    steps = compress_series([a, b], NumarckParams(error_bound=E))
    payload = float(sum(s.nbytes for s in steps))

    rows: list[Row] = []
    with tempfile.TemporaryDirectory() as d:
        for label, checksums in (("checksum_on", True),
                                 ("checksum_off", False)):
            path = os.path.join(d, f"{label}.nck")

            def write():
                w = NCKWriter(checksums=checksums)
                for i, s in enumerate(steps):
                    w.add_step(f"step{i:04d}", s)
                w.write(path)

            def read():
                r = NCKReader(path)
                return [r.read_step(nm) for nm in r.step_names()]

            t_w, _ = timeit(write, repeat=3)
            t_r, _ = timeit(read, repeat=3)
            rows.append((f"robustness/{label}", (t_w + t_r) * 1e6,
                         f"write_MBps={payload/t_w/1e6:.0f} "
                         f"read_MBps={payload/t_r/1e6:.0f}"))
    return rows


_OVERLAP_BENCH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import time
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams
    from repro.distributed.pipeline import ShardedCompressor

    rng = np.random.default_rng(5)
    # Sized so both modes (each warmed + timed) finish on the small
    # tracked machine; the row's point is the overlap speedup ratio.
    n = 500_000                       # 2 MB/step f32
    steps = 4
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    series = [base]
    for _ in range(steps - 1):
        series.append((series[-1]
                       * (1 + 0.01 * rng.standard_normal(n)))
                      .astype(np.float32))

    params = NumarckParams(error_bound=1e-3)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    def run(overlap):
        sc = ShardedCompressor(mesh, "data", params, use_pallas=False,
                               overlap=overlap)
        sc.compress_series(series)    # warm the jit caches + pools
        t0 = time.perf_counter()
        blobs = sc.compress_series(series)
        dt = time.perf_counter() - t0
        sc.close()
        return dt, blobs

    t_sync, b_sync = run(False)
    t_over, b_over = run(True)
    assert all(a.index_blocks == b.index_blocks
               for a, b in zip(b_sync, b_over))
    mb = n * 4 * steps / (1 << 20)
    print(f"RESULT sync_s={t_sync:.4f} overlap_s={t_over:.4f} "
          f"speedup={t_sync / max(t_over, 1e-9):.3f} mb={mb:.0f}")
""")


def run_sharded_overlap() -> list:
    """Sharded overlap=False vs overlap=True on a multi-step series under a
    host-platform 2-device mesh (byte-equality asserted in-process)."""
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run([sys.executable, "-c", _OVERLAP_BENCH], env=env,
                         capture_output=True, text=True, timeout=1200)
    rows: list[Row] = []
    for line in res.stdout.splitlines():
        if line.startswith("RESULT "):
            kv = dict(p.split("=") for p in line.split()[1:])
            rows.append(("sharded_stream/sync",
                         float(kv["sync_s"]) * 1e6,
                         f"MBps={float(kv['mb'])/float(kv['sync_s']):.0f}"))
            rows.append(("sharded_stream/overlap",
                         float(kv["overlap_s"]) * 1e6,
                         f"MBps={float(kv['mb'])/float(kv['overlap_s']):.0f}"
                         f" speedup={kv['speedup']}x"))
    if not rows:
        rows.append(("sharded_stream/overlap", 0.0,
                     f"FAILED rc={res.returncode}"))
    return rows
