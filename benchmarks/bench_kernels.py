"""Pallas kernel micro-benchmarks (interpret mode on CPU; the derived
column reports achieved GB/s against the v5e HBM roofline the BlockSpec
tiling was designed for)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, timeit
from repro.kernels import ref

HBM_BW = 819e9


def run() -> list:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    n = 2_000_000
    prev = rng.normal(1, 0.5, n).astype(np.float32)
    curr = (prev * (1 + 0.01 * rng.standard_normal(n))).astype(np.float32)

    # jnp oracle versions are the measurable path on CPU; the kernels
    # themselves are validated in interpret mode by tests/test_kernels.py
    f1 = jax.jit(lambda a, b: ref.change_ratio_bins_ref(
        a, b, -0.064, 0.002, max_bins=65536))
    t1, _ = timeit(lambda: jax.block_until_ready(f1(prev, curr)))
    bytes1 = n * 4 * 4
    rows.append(("kernel_change_ratio_2M", t1 * 1e6,
                 f"GBps={bytes1/t1/1e9:.2f} "
                 f"v5e_roofline_s={bytes1/HBM_BW:.2e}"))

    idx = rng.integers(0, 1 << 11, 32 * 65536).astype(np.int32)
    f2 = jax.jit(lambda i: ref.pack_bits_ref(i, b_bits=11))
    t2, _ = timeit(lambda: jax.block_until_ready(f2(idx)))
    bytes2 = idx.size * 4 + idx.size * 11 // 8
    rows.append(("kernel_bitpack_2M_b11", t2 * 1e6,
                 f"GBps={bytes2/t2/1e9:.2f} "
                 f"v5e_roofline_s={bytes2/HBM_BW:.2e}"))

    k = (1 << 11) - 1
    centers = rng.uniform(-0.1, 0.1, k).astype(np.float32)
    ids = rng.integers(0, k + 1, n).astype(np.int32)
    f3 = jax.jit(lambda i, p, c: ref.dequantize_ref(i, p, c, b_bits=11))
    t3, _ = timeit(lambda: jax.block_until_ready(f3(ids, prev, centers)))
    bytes3 = n * (4 + 4 + 4)
    rows.append(("kernel_dequant_2M_b11", t3 * 1e6,
                 f"GBps={bytes3/t3/1e9:.2f} "
                 f"v5e_roofline_s={bytes3/HBM_BW:.2e}"))

    f4 = jax.jit(lambda i: ref.histogram_ref(i, max_bins=65536))
    ids_h = rng.integers(-1, 65536, n).astype(np.int32)
    t4, _ = timeit(lambda: jax.block_until_ready(f4(ids_h)))
    rows.append(("kernel_histogram_2M_64k", t4 * 1e6,
                 f"GBps={n*4/t4/1e9:.2f} "
                 f"v5e_roofline_s={n*4/HBM_BW:.2e}"))
    return rows
