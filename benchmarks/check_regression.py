"""CI perf regression gate over the committed BENCH_*.json artifacts.

Compares a freshly produced BENCH JSON (``--current``) against the
tracked one in the repo (``--tracked``) by **row-name intersection** --
the smoke variants of `make bench-all` emit name-identical subsets of
the full runs, so a CI smoke run gates cleanly against committed full
artifacts.  Two kinds of checks:

  * timing: each row's ``us_per_call`` may grow by at most
    ``--tolerance`` (fractional; 0.5 = +50%).  Cross-machine timing is
    noisy, so CI passes a generous tolerance while local runs on the
    machine that produced the tracked file can use a tight one.
  * ratio: ``CR=<x>`` values parsed out of the ``derived`` text are
    machine-independent; they may drop by at most ``--ratio-tolerance``
    (fractional; 0.05 = -5%).  A compression-ratio regression fails even
    when timings are fine.

Rows named ``*_FAILED`` in the current file fail the gate outright;
rows that exist only in one file are reported but never fail (benches
grow over time).  Exit 0 = pass, 1 = regression/failure.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Tuple

_CR_RE = re.compile(r"\bCR=([0-9.]+)")


def load_rows(path: str) -> Tuple[Dict[str, dict], dict]:
    """{row name: row} plus the header (schema/machine/config)."""
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):          # pre-schema flat row list
        rows = data
        header = {"schema": 1}
    else:
        rows = data["rows"]
        header = {k: data.get(k) for k in ("schema", "bench", "machine",
                                           "config")}
    return {r["name"]: r for r in rows}, header


def parse_cr(derived: str):
    m = _CR_RE.search(derived or "")
    return float(m.group(1)) if m else None


def compare(tracked: Dict[str, dict], current: Dict[str, dict],
            tolerance: float, ratio_tolerance: float,
            min_us: float) -> List[str]:
    """Regression messages (empty = pass)."""
    problems: List[str] = []
    for name in sorted(current):
        if name.endswith("_FAILED"):
            problems.append(f"{name}: bench failed: "
                            f"{current[name].get('derived', '')}")
    common = sorted(set(tracked) & set(current))
    for name in common:
        t, c = tracked[name], current[name]
        t_us, c_us = float(t["us_per_call"]), float(c["us_per_call"])
        # Sub-threshold rows are noise-dominated (and 0.0 marks rows
        # that only report derived values); skip the timing check.
        if t_us >= min_us and c_us > t_us * (1.0 + tolerance):
            problems.append(
                f"{name}: {c_us:.0f}us vs tracked {t_us:.0f}us "
                f"(+{(c_us / t_us - 1) * 100:.0f}% > "
                f"+{tolerance * 100:.0f}% allowed)")
        t_cr, c_cr = parse_cr(t.get("derived")), parse_cr(c.get("derived"))
        if t_cr and c_cr and c_cr < t_cr * (1.0 - ratio_tolerance):
            problems.append(
                f"{name}: CR={c_cr:.2f} vs tracked CR={t_cr:.2f} "
                f"(-{(1 - c_cr / t_cr) * 100:.1f}% > "
                f"-{ratio_tolerance * 100:.0f}% allowed)")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Gate a BENCH JSON against the tracked artifact")
    ap.add_argument("--tracked", required=True,
                    help="committed BENCH_*.json (the baseline)")
    ap.add_argument("--current", required=True,
                    help="freshly produced BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.5,
                    help="allowed fractional us_per_call growth "
                         "(0.5 = +50%%; CI uses a larger value because "
                         "runners differ from the tracked machine)")
    ap.add_argument("--ratio-tolerance", type=float, default=0.05,
                    help="allowed fractional CR drop (machine-independent"
                         ", keep tight)")
    ap.add_argument("--min-us", type=float, default=100.0,
                    help="skip the timing check for tracked rows faster "
                         "than this (noise-dominated)")
    args = ap.parse_args()

    tracked, t_hdr = load_rows(args.tracked)
    current, _ = load_rows(args.current)
    common = set(tracked) & set(current)
    only_t = sorted(set(tracked) - common)
    only_c = sorted(set(current) - common)
    print(f"check_regression: {args.current} vs {args.tracked}: "
          f"{len(common)} comparable rows "
          f"(tolerance +{args.tolerance * 100:.0f}% timing, "
          f"-{args.ratio_tolerance * 100:.0f}% CR)")
    if t_hdr.get("machine"):
        m = t_hdr["machine"]
        print(f"  tracked machine: {m.get('platform')} "
              f"cpus={m.get('cpu_count')} jax={m.get('jax_version')}")
    for name in only_t:
        print(f"  note: only in tracked: {name}")
    for name in only_c:
        print(f"  note: only in current: {name}")
    if not common:
        print("FAIL: no comparable rows (did the bench fail to run?)")
        return 1
    problems = compare(tracked, current, args.tolerance,
                       args.ratio_tolerance, args.min_us)
    for p in problems:
        print(f"REGRESSION {p}")
    print("FAIL" if problems else "PASS")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
