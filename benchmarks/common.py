"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)


def timeit(fn: Callable, *args, repeat: int = 3, **kw):
    """(best seconds, result)."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
