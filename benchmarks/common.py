"""Shared helpers for the per-table benchmarks.

Besides the CSV row helpers, this is the home of the diffable BENCH JSON
schema (``write_bench_json``): every committed BENCH_*.json artifact has
the same shape --

    {"schema": 2, "bench": "...",
     "machine": {cpu_count, platform, python, jax_version, jax_x64,
                 backend, device_kind, device_count},
     "config": {...bench-specific knobs...},
     "rows": [{"name": ..., "us_per_call": ..., "derived": ...}, ...]}

-- so ``benchmarks/check_regression.py`` can compare runs by row name
and docs/observability.md can document one schema for all three files.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Callable, Dict, List, Optional, Tuple

Row = Tuple[str, float, str]     # (name, us_per_call, derived)

BENCH_SCHEMA_VERSION = 2


def timeit(fn: Callable, *args, repeat: int = 3, **kw):
    """(best seconds, result)."""
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def rate(mb: float, seconds: float) -> str:
    """MB/s as a derived-field string.  Three significant digits below
    10 MB/s: the old ``:.0f`` truncated slow sharded rows (< 0.5 MB/s on
    the 1-CPU tracked container) to a meaningless ``MBps=0``."""
    v = mb / seconds
    return f"{v:.0f}" if v >= 10 else f"{v:.3g}"


def machine_header() -> Dict:
    """Machine/config fingerprint stamped into every BENCH JSON, so a
    diff between two committed artifacts says whether the runs are even
    comparable before anyone reads a single timing row."""
    hdr: Dict = {
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
    }
    try:
        import jax
        hdr["jax_version"] = jax.__version__
        hdr["jax_x64"] = bool(jax.config.jax_enable_x64)
        devs = jax.devices()
        hdr["backend"] = jax.default_backend()
        hdr["device_kind"] = devs[0].device_kind if devs else None
        hdr["device_count"] = len(devs)
    except Exception as e:  # pragma: no cover - jax ships in this repo
        hdr["jax_version"] = f"unavailable: {type(e).__name__}"
    return hdr


def write_bench_json(path: str, bench: str, rows: List[Row],
                     config: Optional[Dict] = None) -> str:
    """Write one BENCH_*.json artifact in the stable diffable schema."""
    payload = {
        "schema": BENCH_SCHEMA_VERSION,
        "bench": bench,
        "machine": machine_header(),
        "config": config or {},
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
