"""Per-rank worker for the measured speedup-vs-ranks bench.

Launched N times by ``bench_scaling.run(real=True)`` through
``repro.launch.distributed.spawn_emulated`` (fleet coordinates arrive in
the ``REPRO_*`` environment).  Each rank joins the fleet, compresses the
same deterministic series through ``MultiProcessCompressor`` (warm run
first, measured run second), and prints one machine-readable line::

    RESULT {"rank":0,"num":2,"wall_s":...,"cpu_s":...,"phases":{...},...}

Measurement notes for the 1-CPU tracked container: with p ranks
oversubscribed on one core, wall-clock cannot improve, so the honest
per-rank cost is ``time.process_time()`` CPU-seconds -- each rank's
*work* shrinks as 1/p for the perfectly-parallel phases even though the
wall stays flat.  The per-phase wall times from ``meta["telemetry"]``
are reported for the breakdown; bench_scaling attributes the rank's CPU
seconds to phases proportionally to those wall shares (uniform-contention
assumption, documented in docs/scaling.md).

Knobs (environment, set by the parent):

  SCALING_N       elements per step (default 240000)
  SCALING_STEPS   steps in the series (default 3)
"""
from __future__ import annotations

import json
import os
import sys
import time

if __package__ in (None, ""):                      # standalone invocation
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))

PHASE_KEYS = ("analyze_s", "encode_s", "exceptions_s", "entropy_s",
              "finalize_s")


def _series(n: int, steps: int):
    import numpy as np
    rng = np.random.default_rng(7)
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    out = [base]
    for t in range(steps - 1):
        nxt = (out[-1] * (1 + 0.01 * rng.standard_normal(n))
               ).astype(np.float32)
        nxt[t::4001] *= 40.0          # keep the exception path exercised
        out.append(nxt)
    return out


def main() -> None:
    n = int(os.environ.get("SCALING_N", "240000"))
    steps = int(os.environ.get("SCALING_STEPS", "3"))

    from repro.launch import distributed as dist
    cfg = dist.initialize()
    mesh = dist.global_mesh()

    from repro.core import NumarckParams
    from repro.distributed.pipeline import MultiProcessCompressor
    from repro.obs import telemetry

    series = _series(n, steps)
    mp = MultiProcessCompressor(mesh, params=NumarckParams(
        error_bound=1e-3), use_pallas=False)
    mp.compress_series_fragments(series)          # warm the jit caches

    # Best-of-3 (lowest CPU-seconds): the measured runs are much
    # cheaper than the process startup they ride on, and the min is the
    # noise-robust statistic the monotonicity gate needs.  All ranks run
    # the same repeat count, so the fleet stays in collective lockstep.
    best = None
    for _ in range(3):
        with telemetry.capture():
            w0, c0 = time.perf_counter(), time.process_time()
            frags = mp.compress_series_fragments(series)
            wall = time.perf_counter() - w0
            cpu = time.process_time() - c0
        phases = {k: 0.0 for k in PHASE_KEYS}
        bytes_out = 0
        for f in frags:
            tele = f.meta.get("telemetry") or {}
            for k in PHASE_KEYS:
                phases[k] += float(tele.get(k, 0.0))
            bytes_out += int(tele.get("bytes_out", 0))
        rec = {"rank": cfg.process_id, "num": cfg.num_processes,
               "wall_s": wall, "cpu_s": cpu, "phases": phases,
               "n": n, "steps": steps, "bytes_out": bytes_out}
        if best is None or rec["cpu_s"] < best["cpu_s"]:
            best = rec
    mp.close()

    print("RESULT " + json.dumps(best, sort_keys=True))


if __name__ == "__main__":
    main()
