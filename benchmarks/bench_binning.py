"""Paper Table 8 + Figs. 13/14: binning strategies -- coverage vs the DP
oracle and runtime.  Top-k should cover ~the DP optimum at a fraction of
the runtime; equal < log < kmeans < topk <= DP (paper Sec. V-D)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.core import binning, dp_oracle, ratios
from repro.data.temporal import generate_series

import jax
import jax.numpy as jnp


def run() -> list:
    rows: list[Row] = []
    E = 1e-3
    cfgs = {"sedov": dict(B=8, scale=2), "asr": dict(B=10, scale=4)}
    for name, c in cfgs.items():
        series = list(generate_series(name, n_iterations=2, seed=9,
                                      scale=c["scale"]))
        prev, curr = series[0].ravel(), series[1].ravel()
        r, valid = ratios.change_ratios(jnp.asarray(prev, jnp.float32),
                                        jnp.asarray(curr, jnp.float32))
        rv = np.asarray(r)[np.asarray(valid)]
        # paper: points with |ratio| < E excluded from the DP comparison
        rv = rv[np.abs(rv) >= E]
        k = (1 << c["B"]) - 1
        max_bins = 1 << 16

        lo, hi = ratios.ratio_range(r, valid)
        dlo, w = ratios.histogram_domain(lo, hi, E, max_bins)
        ids, ok = ratios.candidate_bin_ids(r, valid, dlo, w, max_bins)

        # ---- DP oracle ---------------------------------------------------
        sub = rv if rv.size <= 200_000 else np.random.default_rng(0).choice(
            rv, 200_000, replace=False)
        t_dp, best = timeit(dp_oracle.dp_max_coverage, sub, 2 * E, k,
                            repeat=1)
        cov_dp = best / sub.size

        def coverage(centers):
            return dp_oracle.coverage_of_centers(sub, np.asarray(centers),
                                                 E) / sub.size

        # ---- top-k -------------------------------------------------------
        def topk_once():
            ids_s, ok_s = ratios.candidate_bin_ids(
                jnp.asarray(sub), jnp.ones(sub.size, bool), dlo, w,
                max_bins)
            counts = binning.local_histogram(ids_s, ok_s, max_bins)
            cd, idd = binning.sort_histogram(counts)
            cs, _ = binning.topk_centers(idd, k, dlo, w)
            return jax.block_until_ready(cs)

        t_topk, cs_topk = timeit(topk_once, repeat=2)
        cov_topk = coverage(cs_topk)

        # ---- equal width ---------------------------------------------------
        t_eq, cs_eq = timeit(lambda: jax.block_until_ready(
            binning.equal_width_centers(float(sub.min()), float(sub.max()),
                                        k)), repeat=2)
        cov_eq = coverage(cs_eq)

        # ---- log scale -----------------------------------------------------
        t_log, cs_log = timeit(lambda: jax.block_until_ready(
            binning.log_scale_centers(jnp.asarray(sub),
                                      jnp.ones(sub.size, bool), k)),
            repeat=2)
        cov_log = coverage(cs_log)

        # ---- k-means (histogram-weighted) -----------------------------------
        ids_s, ok_s = ratios.candidate_bin_ids(
            jnp.asarray(sub), jnp.ones(sub.size, bool), dlo, w, max_bins)
        counts = binning.local_histogram(ids_s, ok_s, max_bins)
        t_km, cs_km = timeit(lambda: jax.block_until_ready(
            binning.kmeans_centers(counts, dlo, w, min(k, 4096), 20)),
            repeat=1)
        cov_km = coverage(cs_km)

        for strat, t, cov in (("dp", t_dp, cov_dp),
                              ("topk", t_topk, cov_topk),
                              ("kmeans", t_km, cov_km),
                              ("log", t_log, cov_log),
                              ("equal", t_eq, cov_eq)):
            rows.append((f"table8_fig13_14_{name}_{strat}", t * 1e6,
                         f"coverage={cov*100:.1f}% vs_dp="
                         f"{cov/max(cov_dp,1e-9)*100:.1f}%"))
    return rows
