"""Host-resident vs device-resident temporal reference chain.

Measures what the ReferenceChain refactor buys (ISSUE 4): the
REF_RECONSTRUCTED chain advance of step i is on the critical path of step
i+1's encode, so keeping it on the accelerator (fused dequantize +
exception patch) instead of round-tripping through host
`reconstruct_from_indices` shortens the per-step serial section.

Rows (byte-equality of the two residencies is asserted in-process):

  chain/single/{host,device}                  TemporalCompressor, 8 steps
  chain/sharded/{host,device}_{sync,overlap}  ShardedCompressor, 2-device
                                              host mesh (subprocess)
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time

import numpy as np

if __package__ in (None, ""):                      # standalone invocation
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "src"))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import Row, rate  # noqa: E402

STEPS = 8
N = 1_500_000                    # 6 MB/step f32


def _series(n=N, steps=STEPS, seed=3):
    rng = np.random.default_rng(seed)
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    out = [base]
    for t in range(steps - 1):
        nxt = (out[-1] * (1 + 0.01 * rng.standard_normal(n))
               ).astype(np.float32)
        nxt[t::4001] *= 40.0      # keep the exception patch exercised
        out.append(nxt)
    return out


def run_single() -> list:
    from repro.core import NumarckParams, compress_series

    params = NumarckParams(error_bound=1e-3)
    series = _series()
    mb = N * 4 * STEPS / (1 << 20)
    rows: list[Row] = []
    blobs = {}
    times = {}
    for chain in ("host", "device"):
        compress_series(series, params, chain=chain)   # warm jit caches
        t0 = time.perf_counter()
        blobs[chain] = compress_series(series, params, chain=chain)
        times[chain] = time.perf_counter() - t0
    for a, b in zip(blobs["host"], blobs["device"]):
        assert a.index_blocks == b.index_blocks, "residency changed bytes!"
    for chain in ("host", "device"):
        dt = times[chain]
        extra = f" speedup={times['host'] / dt:.3f}x" if chain == "device" \
            else ""
        rows.append((f"chain/single/{chain}", dt * 1e6,
                     f"MBps={rate(mb, dt)}{extra}"))
    return rows


_SHARDED_BENCH = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import time
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams
    from repro.distributed.pipeline import ShardedCompressor

    rng = np.random.default_rng(5)
    # Sized so the 4-config sweep (2 residencies x 2 overlap modes, each
    # warmed + timed) finishes on the small tracked machine; the point of
    # the rows is the relative speedups, not the absolute payload.
    n = 250_000
    steps = 3
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    series = [base]
    for t in range(steps - 1):
        nxt = (series[-1] * (1 + 0.01 * rng.standard_normal(n))
               ).astype(np.float32)
        nxt[t::4001] *= 40.0
        series.append(nxt)

    params = NumarckParams(error_bound=1e-3)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    def run(chain, overlap):
        sc = ShardedCompressor(mesh, "data", params, use_pallas=False,
                               overlap=overlap, chain=chain)
        sc.compress_series(series)    # warm the jit caches + pools
        t0 = time.perf_counter()
        blobs = sc.compress_series(series)
        dt = time.perf_counter() - t0
        sc.close()
        return dt, blobs

    ref = None
    mb = n * 4 * steps / (1 << 20)
    for chain in ("host", "device"):
        for overlap in (False, True):
            dt, blobs = run(chain, overlap)
            if ref is None:
                ref = blobs
            assert all(a.index_blocks == b.index_blocks
                       for a, b in zip(ref, blobs)), (chain, overlap)
            mode = "overlap" if overlap else "sync"
            print(f"RESULT name={chain}_{mode} s={dt:.4f} mb={mb:.2f}")
""")


def run_sharded() -> list:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run([sys.executable, "-c", _SHARDED_BENCH], env=env,
                         capture_output=True, text=True, timeout=1800)
    rows: list[Row] = []
    base_s = None
    for line in res.stdout.splitlines():
        if not line.startswith("RESULT "):
            continue
        kv = dict(p.split("=") for p in line.split()[1:])
        s = float(kv["s"])
        if base_s is None:
            base_s = s                      # host_sync baseline
        rows.append((f"chain/sharded/{kv['name']}", s * 1e6,
                     f"MBps={rate(float(kv['mb']), s)} "
                     f"speedup={base_s / s:.3f}x"))
    if not rows:
        rows.append(("chain/sharded", 0.0, f"FAILED rc={res.returncode}"))
    return rows


def run(smoke: bool = False) -> list:
    """``smoke`` keeps only the in-process single-device rows (the
    sharded rows need a 2-device subprocess and dominate the wall-clock);
    smoke rows are a name-identical subset of the full run's."""
    return run_single() if smoke else run_single() + run_sharded()


if __name__ == "__main__":
    from benchmarks.common import emit
    print("name,us_per_call,derived")
    emit(run())
