# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness -- one bench per paper table/figure:

  bench_scaling      Table 2, Table 3, Figs. 3-8 (phases + scaling model)
  bench_compression  Figs. 9-12, Tables 4/5/6 (CR + times vs baselines)
  bench_partial      Table 7 (partial decompression linearity)
  bench_binning      Table 8, Figs. 13/14 (strategies vs DP oracle)
  bench_autob        Figs. 16/17, Table 9 (auto-B + ZLIB interaction)
  bench_kernels      kernel micro-bench (+ v5e roofline targets)

SS Roofline for the 40 (arch x shape) cells is a separate reader
(benchmarks/roofline.py) because it consumes launch/dryrun.py artifacts.
"""
from __future__ import annotations

import argparse
import importlib.util
import os
import sys

# Runnable as `python benchmarks/run.py` from the repo root: put the root
# (for `benchmarks.*`) and src (for `repro.*`) on the path -- but only
# when the packages aren't already importable (installed wheel, or
# PYTHONPATH=src), so an installed `repro` isn't shadowed by the tree.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if (importlib.util.find_spec("repro") is None
        or importlib.util.find_spec("benchmarks") is None):
    for _p in (_ROOT, os.path.join(_ROOT, "src")):
        if _p not in sys.path:
            sys.path.insert(0, _p)


def bench_all(out_dir: str, smoke: bool = False) -> int:
    """Write the committed perf-trajectory artifacts --
    BENCH_entropy.json, BENCH_chain.json, BENCH_compression.json,
    BENCH_scaling.json -- into `out_dir` in the stable schema of
    benchmarks.common.write_bench_json (machine/config header + named
    rows).

    ``smoke`` runs reduced, in-process variants whose rows are
    name-identical subsets of the full run's, so
    benchmarks/check_regression.py can gate a CI smoke run against the
    committed full artifacts.  Returns the number of failed benches.
    """
    from benchmarks import (bench_chain, bench_compression, bench_entropy,
                            bench_scaling)
    from benchmarks.common import emit, write_bench_json

    failed = 0
    plan = [
        ("entropy", "BENCH_entropy.json",
         lambda: bench_entropy.run(smoke=True,
                                   sizes_mb=(bench_entropy.SMOKE_SIZES_MB
                                             if smoke else
                                             bench_entropy.FULL_SIZES_MB)),
         {"smoke": smoke}),
        ("chain", "BENCH_chain.json",
         lambda: bench_chain.run(smoke=smoke), {"smoke": smoke}),
        ("compression", "BENCH_compression.json",
         lambda: bench_compression.run(
             datasets=("sedov",) if smoke
             else ("sedov", "stir", "asr", "cmip"),
             include_sharded=not smoke, include_chain=False),
         {"smoke": smoke, "note": "chain rows live in BENCH_chain.json"}),
        ("scaling", "BENCH_scaling.json",
         lambda: bench_scaling.run(real=True, smoke=smoke),
         {"smoke": smoke, "real": True,
          "note": "scaling/real/* rows are measured emulated multi-"
                  "process runs; the rest is the paper-scale model"}),
    ]
    for bench, fname, fn, config in plan:
        path = os.path.join(out_dir, fname)
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001 -- report, keep going
            print(f"{bench}_FAILED,0,{type(e).__name__}:{e}")
            import traceback
            traceback.print_exc(file=sys.stderr)
            failed += 1
            continue
        emit(rows)
        write_bench_json(path, bench, rows, config=config)
        print(f"# wrote {path} ({len(rows)} rows)")
    return failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: scaling,compression,partial,binning,"
                         "autob,kernels,chain,entropy")
    ap.add_argument("--entropy-json", default=None, metavar="PATH",
                    help="run the entropy smoke bench (device rANS vs "
                         "threaded zlib vs raw) and write the rows to "
                         "PATH (the BENCH_entropy.json CI artifact)")
    ap.add_argument("--bench-all", action="store_true",
                    help="write BENCH_entropy/chain/compression/scaling"
                         ".json into --out-dir (the committed perf "
                         "trajectory)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --bench-all: reduced in-process variants "
                         "(rows are a name subset of the full run)")
    ap.add_argument("--out-dir", default=_ROOT,
                    help="destination for the BENCH_*.json artifacts "
                         "(default: repo root)")
    args = ap.parse_args()

    if args.bench_all:
        print("name,us_per_call,derived")
        sys.exit(1 if bench_all(args.out_dir, smoke=args.smoke) else 0)

    from benchmarks import (bench_autob, bench_binning, bench_chain,
                            bench_compression, bench_entropy,
                            bench_kernels, bench_partial, bench_scaling)
    benches = {
        "compression": bench_compression.run,
        "scaling": bench_scaling.run,
        "partial": bench_partial.run,
        "binning": bench_binning.run,
        "autob": bench_autob.run,
        "kernels": bench_kernels.run,
        "chain": bench_chain.run,
        "entropy": bench_entropy.run,
    }
    # "chain" rows already ride along inside bench_compression, and the
    # full "entropy" sweep has its own make target; keep both out of the
    # default sweep so `make bench` stays bounded.
    wanted = (args.only.split(",") if args.only
              else [b for b in benches if b not in ("chain", "entropy")])
    print("name,us_per_call,derived")
    from benchmarks.common import emit
    if args.entropy_json:
        rows = bench_entropy.run(smoke=True)
        emit(rows)
        bench_entropy.write_json(rows, args.entropy_json, smoke=True)
        # The smoke rows just ran; don't re-run entropy via --only, and
        # skip the default sweep entirely when only the json was asked.
        wanted = ([w for w in wanted if w != "entropy"] if args.only
                  else [])
    for name in wanted:
        try:
            emit(benches[name]())
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            import traceback
            traceback.print_exc(file=sys.stderr)


if __name__ == '__main__':
    main()
