"""SS Roofline table builder: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits the per-(arch x shape x mesh) three-term
roofline with dominant bottleneck + usefulness ratio.

Run the dry-run first:
  PYTHONPATH=src python -m repro.launch.dryrun --out experiments/dryrun
then:
  PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dir_: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh="single"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "SKIP":
            rows.append((r["arch"], r["shape"], "SKIP", "-", "-", "-", "-",
                         "-", r.get("reason", "")[:40]))
            continue
        if r["status"] != "OK":
            rows.append((r["arch"], r["shape"], "FAIL", "-", "-", "-", "-",
                         "-", r.get("error", "")[:40]))
            continue
        t = r["roofline"]
        dom = r["dominant"].replace("_s", "")
        useful = r.get("useful_ratio")
        rows.append((
            r["arch"], r["shape"], "OK",
            f"{t['compute_s']:.2e}", f"{t['memory_s']:.2e}",
            f"{t['collective_s']:.2e}", dom,
            f"{useful:.3f}" if useful else "-",
            _fmt_b(r["memory"]["peak"]) if r.get("memory") else "-"))
    return rows


def _fmt_b(n):
    if n is None:
        return "?"
    for u in ("B", "KB", "MB", "GB"):
        if n < 1024:
            return f"{n:.1f}{u}"
        n /= 1024
    return f"{n:.1f}TB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir)
    if not recs:
        print(f"no dry-run records in {args.dir}; run launch/dryrun first")
        return
    hdr = ("arch", "shape", "status", "compute_s", "memory_s",
           "collective_s", "dominant", "useful", "peak/dev")
    rows = table(recs, args.mesh)
    widths = [max(len(str(x)) for x in [h] + [r[i] for r in rows])
              for i, h in enumerate(hdr)]
    print(" | ".join(h.ljust(w) for h, w in zip(hdr, widths)))
    print("-+-".join("-" * w for w in widths))
    for r in rows:
        print(" | ".join(str(x).ljust(w) for x, w in zip(r, widths)))


if __name__ == "__main__":
    main()
