"""Paper Figs. 16/17 + Table 9: auto-selection of the index length B.

Sweeps B by hand, records the actual compression ratio and the average
ZLIB ratio of the index table, and marks what auto-B picked.  Reproduces
the paper's finding: the Eq. 6 model ignores ZLIB, so on Sedov-like data
(80% sub-|E| ratios -> highly repetitive index tables, ZLIB ratio ~10) the
auto-picked B is smaller than the CR-optimal one, while on ASR-like data
(ZLIB ratio ~1.3) auto-B lands near the optimum."""
from __future__ import annotations

from benchmarks.common import Row, timeit
from repro.core import NumarckParams, compress_step
from repro.data.temporal import generate_series


def run() -> list:
    rows: list[Row] = []
    sweeps = {"asr": (dict(scale=2), [8, 10, 12, 13, 14, 15, 16]),
              "sedov": (dict(scale=1), [2, 3, 4, 6, 8, 10, 12])}
    for name, (kw, bs) in sweeps.items():
        series = list(generate_series(name, n_iterations=2, seed=21,
                                      scale=kw["scale"]))
        prev, curr = series[0], series[1]
        auto = compress_step(prev, curr, NumarckParams(error_bound=1e-3))
        b_auto = auto.b_bits
        best_b, best_cr = None, -1.0
        for b in bs:
            t, st = timeit(compress_step, prev, curr,
                           NumarckParams(error_bound=1e-3, b_bits=b),
                           repeat=1)
            cr = st.compression_ratio()
            if cr > best_cr:
                best_b, best_cr = b, cr
            rows.append((f"fig16_17_{name}_B{b}", t * 1e6,
                         f"CR={cr:.2f} zlib_ratio="
                         f"{st.meta['entropy_ratio']:.2f}"
                         + (" <-auto" if b == b_auto else "")))
        rows.append((f"fig16_17_{name}_summary", 0.0,
                     f"auto_B={b_auto} optimal_B={best_b} "
                     f"auto_CR={auto.compression_ratio():.2f} "
                     f"optimal_CR={best_cr:.2f}"))
    return rows
