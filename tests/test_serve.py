"""Serving engine + full-config sanity."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.model import build
from repro.serve.engine import Engine


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "mixtral-8x7b"])
def test_engine_generates(arch):
    model = build(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    B, S0, new = 2, 12, 5
    eng = Engine(model, params, B, S0 + new)
    prompts = np.random.default_rng(0).integers(
        0, model.cfg.vocab_size, (B, S0)).astype(np.int32)
    out = eng.generate(prompts, max_new=new)
    assert out.shape == (B, new)
    assert (out >= 0).all() and (out < model.cfg.vocab_size).all()
    assert eng.stats.tokens_out == B * new


def test_session_save_load_resume_no_retrace(tmp_path):
    """A restored session continues the stream exactly where it stopped,
    on device, through the already-traced decode executable."""
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    p = np.random.default_rng(2).integers(0, model.cfg.vocab_size,
                                          (1, 10)).astype(np.int32)
    full = Engine(model, params, 1, 32).generate(p, max_new=10)

    eng = Engine(model, params, 1, 32, keep_session=True)
    first = eng.generate(p, max_new=5)
    path = str(tmp_path / "sess.nck")
    stats = eng.save_session(path)
    assert stats["orig_bytes"] > 0

    eng2 = Engine(model, params, 1, 32, keep_session=True)
    eng2.generate(p, max_new=5)           # trace decode + define template
    n_traces = eng2._decode._cache_size()
    eng2.load_session(path)
    rest = eng2.resume(max_new=5)
    # greedy continuation == uninterrupted run (cache restore is lossless)
    np.testing.assert_array_equal(np.concatenate([first, rest], axis=1),
                                  full)
    # the restored leaves matched the traced avals: no re-trace happened
    assert eng2._decode._cache_size() == n_traces


def test_resume_advances_without_keep_session(tmp_path):
    """Consecutive resume() calls stream onward even on an engine built
    with keep_session=False (load_session establishes the session)."""
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    p = np.random.default_rng(3).integers(0, model.cfg.vocab_size,
                                          (1, 8)).astype(np.int32)
    full = Engine(model, params, 1, 24).generate(p, max_new=9)

    saver = Engine(model, params, 1, 24, keep_session=True)
    first = saver.generate(p, max_new=3)
    path = str(tmp_path / "s.nck")
    saver.save_session(path)

    eng = Engine(model, params, 1, 24)        # keep_session=False
    eng.generate(p, max_new=2)                # records the aval template
    eng.load_session(path)
    a = eng.resume(max_new=3)
    b = eng.resume(max_new=3)                 # must continue, not replay
    np.testing.assert_array_equal(
        np.concatenate([first, a, b], axis=1), full)


def test_load_session_rejects_bare_cache_snapshot(tmp_path):
    """Pre-resume-format files (bare snapshot_cache) fail loudly."""
    from repro.serve.engine import snapshot_cache
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, 1, 16)
    path = str(tmp_path / "old.nck")
    snapshot_cache({"layer0": np.zeros((2, 2), np.float32)}, path)
    with pytest.raises(ValueError, match="session file"):
        eng.load_session(path)


def test_resume_without_session_raises():
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, 1, 16)
    with pytest.raises(RuntimeError, match="no session"):
        eng.resume(max_new=2)


def test_engine_deterministic_greedy():
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, 1, 20)
    p = np.random.default_rng(1).integers(0, model.cfg.vocab_size,
                                          (1, 10)).astype(np.int32)
    a = eng.generate(p, max_new=6)
    b = eng.generate(p, max_new=6)
    np.testing.assert_array_equal(a, b)


# full-config parameter counts vs the published model sizes (rough)
EXPECTED_PARAMS = {
    "llama3.2-1b": (1.0e9, 1.7e9),
    "qwen1.5-110b": (95e9, 120e9),
    "deepseek-7b": (6e9, 8e9),
    "minicpm3-4b": (3.3e9, 5e9),
    "mixtral-8x7b": (42e9, 50e9),       # total (not active) params
    "phi3.5-moe-42b-a6.6b": (38e9, 45e9),
    "mamba2-780m": (0.65e9, 0.9e9),
    "hymba-1.5b": (1.1e9, 1.9e9),
    "paligemma-3b": (2.2e9, 3.5e9),     # backbone only (SigLIP stubbed)
    "musicgen-medium": (1.2e9, 2.0e9),  # SwiGLU (3 mats) vs published GELU
}


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = EXPECTED_PARAMS[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}," \
                          f"{hi/1e9}]B"


def test_moe_active_params_less_than_total():
    cfg = get_config("mixtral-8x7b")
    act = cfg.active_param_count()
    tot = cfg.param_count()
    assert act < tot * 0.45                 # top-2 of 8 experts
    assert 10e9 < act < 16e9                # ~13B active
