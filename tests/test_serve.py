"""Serving engine + full-config sanity."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models.model import build
from repro.serve.engine import Engine


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "mixtral-8x7b"])
def test_engine_generates(arch):
    model = build(arch, smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    B, S0, new = 2, 12, 5
    eng = Engine(model, params, B, S0 + new)
    prompts = np.random.default_rng(0).integers(
        0, model.cfg.vocab_size, (B, S0)).astype(np.int32)
    out = eng.generate(prompts, max_new=new)
    assert out.shape == (B, new)
    assert (out >= 0).all() and (out < model.cfg.vocab_size).all()
    assert eng.stats.tokens_out == B * new


def test_engine_deterministic_greedy():
    model = build("llama3.2-1b", smoke=True)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, 1, 20)
    p = np.random.default_rng(1).integers(0, model.cfg.vocab_size,
                                          (1, 10)).astype(np.int32)
    a = eng.generate(p, max_new=6)
    b = eng.generate(p, max_new=6)
    np.testing.assert_array_equal(a, b)


# full-config parameter counts vs the published model sizes (rough)
EXPECTED_PARAMS = {
    "llama3.2-1b": (1.0e9, 1.7e9),
    "qwen1.5-110b": (95e9, 120e9),
    "deepseek-7b": (6e9, 8e9),
    "minicpm3-4b": (3.3e9, 5e9),
    "mixtral-8x7b": (42e9, 50e9),       # total (not active) params
    "phi3.5-moe-42b-a6.6b": (38e9, 45e9),
    "mamba2-780m": (0.65e9, 0.9e9),
    "hymba-1.5b": (1.1e9, 1.9e9),
    "paligemma-3b": (2.2e9, 3.5e9),     # backbone only (SigLIP stubbed)
    "musicgen-medium": (1.2e9, 2.0e9),  # SwiGLU (3 mats) vs published GELU
}


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_param_counts(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = EXPECTED_PARAMS[arch]
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}," \
                          f"{hi/1e9}]B"


def test_moe_active_params_less_than_total():
    cfg = get_config("mixtral-8x7b")
    act = cfg.active_param_count()
    tot = cfg.param_count()
    assert act < tot * 0.45                 # top-2 of 8 experts
    assert 10e9 < act < 16e9                # ~13B active
