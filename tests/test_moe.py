"""MoE dispatch properties, incl. split-expert equivalence (SS Perf)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig


def _cfg(split=1, E=4, cf=8.0):
    # generous capacity so no tokens drop (equivalence needs drop-free)
    return ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                       vocab_size=64, n_experts=E, moe_top_k=2,
                       capacity_factor=cf, moe_ep_split=split,
                       dtype="float32")


def _split_weights(p, s):
    """Derive slot weights from unsplit expert weights (exact slicing)."""
    E, d, f = p["we_gate"].shape
    return {
        "router": p["router"],
        "we_gate": p["we_gate"].reshape(E, d, s, f // s).transpose(
            0, 2, 1, 3).reshape(E * s, d, f // s),
        "we_up": p["we_up"].reshape(E, d, s, f // s).transpose(
            0, 2, 1, 3).reshape(E * s, d, f // s),
        "we_down": p["we_down"].reshape(E, s, f // s, d).reshape(
            E * s, f // s, d),
    }


def test_split_expert_equivalence():
    """moe_ep_split is mathematically exact for SwiGLU (slot sums)."""
    cfg1, cfg2 = _cfg(split=1), _cfg(split=2)
    p1 = L.moe_init(jax.random.PRNGKey(0), cfg1)
    p2 = _split_weights(p1, 2)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32)) * 0.5
    y1, aux1 = L.moe_apply(p1, x, cfg=cfg1)
    y2, aux2 = L.moe_apply(p2, x, cfg=cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-6)


def test_moe_capacity_drop():
    """Tokens over capacity are dropped, not mis-routed."""
    cfg = _cfg(cf=0.25)          # tiny capacity forces drops
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    y, _ = L.moe_apply(p, x, cfg=cfg)
    assert np.isfinite(np.asarray(y)).all()
    # some outputs must be zero (dropped tokens pass nothing through)
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-6).any()


def test_moe_drop_priority_is_order_independent():
    """Capacity is granted by router weight, not sequence position: under
    overflow, permuting the tokens permutes the outputs (the same choices
    drop), where the old first-come cumsum dispatch coupled a token's
    fate to how many earlier tokens picked its expert."""
    cfg = _cfg(cf=0.25)          # tiny capacity forces drops
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32))
    y, _ = L.moe_apply(p, x, cfg=cfg)
    norms = np.linalg.norm(np.asarray(y)[0], axis=-1)
    assert (norms < 1e-6).any()              # drops genuinely happen
    perm = np.asarray(
        jax.random.permutation(jax.random.PRNGKey(2), 32))
    yp, _ = L.moe_apply(p, x[:, perm], cfg=cfg)
    np.testing.assert_allclose(np.asarray(yp), np.asarray(y)[:, perm],
                               rtol=2e-5, atol=2e-5)


def test_moe_router_gradient_flows():
    cfg = _cfg()
    p = L.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 32))

    def loss(pp):
        y, aux = L.moe_apply(pp, x, cfg=cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["we_gate"]).sum()) > 0
