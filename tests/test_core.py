"""Core NUMARCK behaviour: round trips, error bounds, strategies, auto-B."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import (NumarckParams, TemporalCompressor,
                        TemporalDecompressor, compress_series, compress_step,
                        decompress_series, decompress_step, make_anchor,
                        mean_error_rate)
from repro.core.compress import decode_anchor
from repro.core.types import REF_ORIGINAL

RNG = np.random.default_rng(42)


def temporal_series(shape=(64, 48), steps=5, vol=0.01, dtype=np.float32,
                    rng=RNG):
    base = rng.normal(1.0, 0.5, shape).astype(dtype)
    out = [base]
    for _ in range(steps - 1):
        change = 1 + vol * rng.standard_normal(shape)
        out.append((out[-1] * change).astype(dtype))
    return out


def test_anchor_roundtrip_exact():
    arr = RNG.normal(size=(37, 19)).astype(np.float32)
    step = make_anchor(arr, NumarckParams(block_bytes=256))
    assert step.is_anchor
    np.testing.assert_array_equal(decode_anchor(step), arr)


@pytest.mark.parametrize("strategy", ["topk", "equal", "log", "kmeans"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_series_roundtrip_me_bound(strategy, dtype):
    E = 1e-3
    series = temporal_series(dtype=dtype)
    p = NumarckParams(error_bound=E, strategy=strategy, max_bins=4096,
                      block_bytes=2048,
                      b_bits=None if strategy == "topk" else 8)
    recon = decompress_series(compress_series(series, p))
    for orig, rec in zip(series, recon):
        assert mean_error_rate(orig, rec) <= E * 1.05
        assert np.isfinite(rec).all()


def test_elementwise_bound_reconstructed_mode():
    """|R_i - D_i| <= E * |R_{i-1}| element-wise (strict in recon mode)."""
    E = 5e-3
    series = temporal_series(steps=8, vol=0.03)
    p = NumarckParams(error_bound=E, max_bins=8192, block_bytes=4096)
    comp = TemporalCompressor(p)
    dec = TemporalDecompressor()
    prev_recon = None
    for arr in series:
        step = comp.add(arr)
        recon = dec.add(step)
        if prev_recon is not None:
            bound = E * np.abs(prev_recon.astype(np.float64)) * (1 + 1e-5) \
                + 1e-12
            err = np.abs(recon.astype(np.float64) - arr.astype(np.float64))
            assert (err <= bound).all(), float((err - bound).max())
        prev_recon = recon


def test_original_mode_matches_paper_chain():
    """REF_ORIGINAL compresses vs original D_{i-1} (errors may compound)."""
    series = temporal_series(steps=6)
    p = NumarckParams(error_bound=1e-3, reference=REF_ORIGINAL,
                      max_bins=4096)
    steps = compress_series(series, p)
    recon = decompress_series(steps)
    for orig, rec in zip(series, recon):
        # compounding error: <= steps * E is a generous envelope
        assert mean_error_rate(orig, rec) <= len(series) * 1e-3


def test_incompressible_values_roundtrip_exact():
    prev = RNG.normal(1, 0.5, 4096).astype(np.float32)
    curr = prev.copy()
    curr[::7] *= 100.0              # big jumps -> incompressible
    prev[::13] = 0.0                # invalid ratios -> incompressible
    p = NumarckParams(error_bound=1e-4, max_bins=1024, block_bytes=512)
    step = compress_step(prev, curr, p)
    rec = decompress_step(step, prev)
    marker_positions = np.zeros(4096, bool)
    marker_positions[::7] = True
    marker_positions[::13] = True
    np.testing.assert_array_equal(rec[marker_positions],
                                  curr[marker_positions])


def test_zero_and_constant_data():
    prev = np.zeros(1000, np.float32)
    curr = np.zeros(1000, np.float32)
    p = NumarckParams(error_bound=1e-3, max_bins=1024)
    rec = decompress_step(compress_step(prev, curr, p), prev)
    np.testing.assert_array_equal(rec, curr)
    # constant nonzero: all ratios 0 -> single bin, tiny B.  The ratio sits
    # exactly E from the bin center, and reconstruction arithmetic runs in
    # the source precision (f32), so allow the suite's usual 1% slack on
    # the bound instead of zero slack at the exact boundary.
    prev = np.full(1000, 3.14, np.float32)
    step = compress_step(prev, prev, p)
    assert step.b_bits <= 2
    np.testing.assert_allclose(decompress_step(step, prev), prev,
                               rtol=1e-3 * 1.01)


def test_auto_b_minimizes_eq6():
    """Auto-selected B achieves the min of the Eq. 6 model (meta.est_sizes)."""
    series = temporal_series(steps=2, vol=0.02)
    p = NumarckParams(error_bound=1e-3, max_bins=8192, b_max=14)
    step = compress_step(series[0], series[1], p)
    est = np.asarray(step.meta["est_sizes"])
    assert step.meta["b_auto"] == int(np.argmin(est)) + 1
    assert step.b_bits == step.meta["b_auto"]


def test_compression_ratio_definition():
    series = temporal_series(steps=2)
    p = NumarckParams(error_bound=1e-3, max_bins=4096)
    step = compress_step(series[0], series[1], p)
    orig = series[1].size * series[1].itemsize
    assert abs(step.compression_ratio() - orig / step.nbytes) < 1e-9
    assert step.compression_ratio() > 1.5     # smooth data compresses


def test_forced_b_respected():
    series = temporal_series(steps=2)
    for b in (4, 10):
        p = NumarckParams(error_bound=1e-3, b_bits=b, max_bins=4096)
        step = compress_step(series[0], series[1], p)
        assert step.b_bits == b


def test_alpha_small_for_temporal_data():
    """Paper Table 4: temporal data has low incompressible ratios."""
    series = temporal_series(steps=3, vol=0.005)
    p = NumarckParams(error_bound=1e-3, max_bins=16384)
    steps = compress_series(series, p)
    assert steps[1].alpha < 0.05
    assert steps[2].alpha < 0.05


# ---------------------------------------------------------------------------
# Property-based tests
# ---------------------------------------------------------------------------

finite_f32 = st.floats(min_value=-1e6, max_value=1e6, width=32,
                       allow_nan=False, allow_infinity=False)


@settings(max_examples=25, deadline=None)
@given(st.lists(finite_f32, min_size=2, max_size=300),
       st.sampled_from([1e-2, 1e-3, 1e-4]))
def test_property_elementwise_bound(values, E):
    """For arbitrary prev/curr, every reconstructed element is within
    E * |prev| of the true value, or exactly equal (incompressible)."""
    curr = np.asarray(values, np.float32)
    prev = np.roll(curr, 1) * (1 + np.float32(E) / 3)
    p = NumarckParams(error_bound=E, max_bins=2048, block_bytes=256)
    step = compress_step(prev, curr, p)
    rec = decompress_step(step, prev)
    err = np.abs(rec.astype(np.float64) - curr.astype(np.float64))
    # slack: centers are stored in the data dtype (paper Fig. 2), so f32
    # rounding adds ~eps * (|prev| + |curr|) on top of the algorithmic bound
    bound = (E * np.abs(prev.astype(np.float64)) * (1 + 1e-5)
             + (np.abs(prev) + np.abs(curr)).astype(np.float64) * 1e-6
             + 1e-30)
    exact = rec == curr
    assert (exact | (err <= bound)).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=500))
def test_property_pack_unpack_roundtrip(b_bits, n):
    from repro.core import packing
    idx = RNG.integers(0, 1 << b_bits, n).astype(np.int32)
    packed = packing.pack_indices_np(idx, b_bits)
    assert packed.size == packing.packed_nbytes(n, b_bits)
    np.testing.assert_array_equal(
        packing.unpack_indices_np(packed, n, b_bits), idx)
    # jnp path agrees
    import jax.numpy as jnp
    packed_j = np.asarray(packing.pack_indices_jnp(jnp.asarray(idx), b_bits))
    np.testing.assert_array_equal(packed_j, packed)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=64).map(lambda k: k * 37))
def test_property_shapes_roundtrip(n):
    shape = (n // 37, 37)
    series = temporal_series(shape=shape, steps=3)
    p = NumarckParams(error_bound=1e-3, max_bins=1024, block_bytes=128)
    recon = decompress_series(compress_series(series, p))
    for orig, rec in zip(series, recon):
        assert rec.shape == orig.shape
        assert mean_error_rate(orig, rec) <= 1.05e-3
