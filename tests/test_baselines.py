"""Baseline compressors: round trips + error bounds + NUMARCK comparison."""
import numpy as np
import pytest

from repro.baselines import isabela, zfp_like, zlib_lossless
from repro.data.temporal import generate_series


@pytest.fixture(scope="module")
def field_pair():
    series = list(generate_series("asr", n_iterations=2, seed=3, scale=4))
    return series[0], series[1]


def test_zlib_roundtrip(field_pair):
    _, curr = field_pair
    blob = zlib_lossless.compress(curr)
    np.testing.assert_array_equal(zlib_lossless.decompress(blob), curr)


def test_isabela_error_bound(field_pair):
    _, curr = field_pair
    E = 1e-3
    blob = isabela.compress(curr, error_bound=E, window=256, n_knots=32)
    rec = isabela.decompress(blob)
    rel = np.abs(rec - curr) / np.maximum(np.abs(curr), 1e-30)
    assert np.max(rel) <= E * (1 + 1e-6), float(np.max(rel))
    assert blob.nbytes < curr.nbytes            # actually compresses


def test_zfp_error_bound(field_pair):
    _, curr = field_pair
    tol = float(np.mean(np.abs(curr))) * 1e-3   # paper's tol convention
    blob = zfp_like.compress(curr, tol)
    rec = zfp_like.decompress(blob)
    assert np.max(np.abs(rec - curr)) <= tol * 8, (
        float(np.max(np.abs(rec - curr))), tol)
    assert blob.nbytes < curr.nbytes


def test_numarck_beats_baselines_on_temporal_data(field_pair):
    """The paper's headline claim (Figs. 9-12) on synthetic temporal data."""
    from repro.core import NumarckParams, compress_step
    prev, curr = field_pair
    E = 1e-3
    st = compress_step(prev, curr, NumarckParams(error_bound=E))
    cr_numarck = st.compression_ratio()
    cr_isabela = curr.nbytes / isabela.compress(curr, E, 256, 32).nbytes
    tol = float(np.mean(np.abs(curr))) * E
    cr_zfp = curr.nbytes / zfp_like.compress(curr, tol).nbytes
    cr_zlib = curr.nbytes / zlib_lossless.compress(curr).nbytes
    assert cr_numarck > cr_isabela, (cr_numarck, cr_isabela)
    assert cr_numarck > cr_zlib, (cr_numarck, cr_zlib)
    # zfp is the stronger baseline; NUMARCK should still win on
    # temporally-coherent fields (the property it exploits)
    assert cr_numarck > cr_zfp, (cr_numarck, cr_zfp)
