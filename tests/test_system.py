"""End-to-end behaviour tests for the paper's system."""
import subprocess
import sys
import os

import numpy as np
import pytest

from repro.core import (NumarckParams, TemporalArchive, compress_series,
                        decompress_series, mean_error_rate)
from repro.data.temporal import generate_series


def test_end_to_end_simulation_workflow(tmp_path):
    """Paper Sec. V workflow: simulate -> compress -> archive -> partial
    decompress -> verify error bound, on two dataset families."""
    p = NumarckParams(error_bound=1e-3, block_bytes=1 << 14)
    for name in ("stir", "cmip"):
        series = list(generate_series(name, n_iterations=4, seed=1,
                                      scale=4))
        steps = compress_series(series, p)
        # CR > 1 on the delta steps (temporal coherence exploited)
        assert np.mean([s.compression_ratio() for s in steps[1:]]) > 1.5
        recon = decompress_series(steps)
        for orig, rec in zip(series, recon):
            assert mean_error_rate(orig, rec) <= 1.05e-3

        path = str(tmp_path / f"{name}.nck")
        TemporalArchive.write(path, name, steps)
        ar = TemporalArchive(path)
        n = series[0].size
        seg = ar.read_range(name, 3, n // 3, n // 3 + 777)
        np.testing.assert_array_equal(
            seg, recon[3].reshape(-1)[n // 3: n // 3 + 777])


def test_compression_ratio_beats_baselines_end_to_end():
    from repro.baselines import isabela, zfp_like
    series = list(generate_series("cmip", n_iterations=2, seed=2, scale=4))
    prev, curr = series
    from repro.core import compress_step
    st = compress_step(prev, curr, NumarckParams(error_bound=1e-3))
    cr_n = st.compression_ratio()
    cr_i = curr.nbytes / isabela.compress(curr, 1e-3).nbytes
    tol = float(np.mean(np.abs(curr))) * 1e-3
    cr_z = curr.nbytes / zfp_like.compress(curr, tol).nbytes
    assert cr_n > cr_i and cr_n > cr_z


@pytest.mark.slow
def test_quickstart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), "..", "examples",
                      "quickstart.py")],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "partial decompression" in res.stdout


@pytest.mark.slow
def test_train_restart_example_runs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run(
        [sys.executable,
         os.path.join(os.path.dirname(__file__), "..", "examples",
                      "train_restart.py"), "--steps", "60"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "restored step" in res.stdout
