"""Fixture: every suppression placement the framework supports."""
import numpy as np


def encode_device(x):
    a = np.asarray(x)   # repro-lint: disable=host-sync-in-device-path
    # repro-lint: disable=host-sync-in-device-path
    b = np.asarray(x)
    return a, b


# repro-lint: disable=host-sync-in-device-path
def decompress_step_device(x):
    # def-line (or line above def) suppression covers the whole body
    a = np.asarray(x)
    b = np.asarray(x)
    return a, b
