"""Fixture: jit-cache-hygiene violations and sanctioned shapes."""
import functools

import jax
from jax.experimental.shard_map import shard_map


@jax.jit
def module_level_ok(x):                       # sanctioned: module decorator
    return x + 1


@functools.partial(jax.jit, static_argnames=("n",))
def module_partial_ok(x, n):                  # sanctioned: partial decorator
    return x * n


_MODULE_FN = jax.jit(lambda x: x)             # sanctioned: module assignment


def _encode_shard(x):
    f = jax.jit(lambda y: y + 1)              # violation: per-call lambda
    return f(x)


def hot_loop(xs):
    out = []
    for x in xs:
        out.append(jax.jit(step)(x))          # violation: per-call jit
    return out


def step(x):
    return x


class Cached:
    def build(self, key, mesh, spec):
        fn = shard_map(step, mesh=mesh, in_specs=spec, out_specs=spec)
        self._fns[key] = jax.jit(fn)          # sanctioned: keyed two-step
        return self._fns[key]

    def build_direct(self, key):
        self._fns[key] = jax.jit(step)        # sanctioned: keyed store
        return self._fns[key]

    def __init__(self):
        self._fns = {}
        self._one = jax.jit(step)             # violation: unkeyed store
