"""Fixture: device-resident functions with forbidden host syncs."""
import jax
import numpy as np

from repro.obs import telemetry


def encode_device(x):
    a = np.asarray(x)                      # violation: np.asarray
    b = x.item()                           # violation: .item()
    jax.block_until_ready(x)               # violation: explicit sync
    c = float(a["b_auto"])                 # violation: scalar dict fetch
    d = float(1.5)                         # NOT a violation: plain scalar
    tele = telemetry.enabled()
    if tele:
        jax.block_until_ready(x)           # exempt: telemetry-gated
    return a, b, c, d


def _analyze_shard(x):
    return np.asarray(x)                   # violation: _*_shard pattern


def host_helper(x):
    return np.asarray(x)                   # NOT a violation: unregistered
