"""Fixture: direct rename publishes outside atomic_commit are flagged."""
import os


def atomic_commit(path, data):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)          # sanctioned: the one publish helper


def sloppy_publish(path, data):
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)          # BAD: no fsync before rename


def sloppy_rename(src, dst):
    os.rename(src, dst)            # BAD: same, via os.rename
