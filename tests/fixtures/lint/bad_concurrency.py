"""Fixture: concurrency-discipline violations."""
import threading

import jax

from repro.core.overlap import FinalizeQueue

_pool_lock = threading.Lock()
_shared_proc_pool = None


def blocking_under_lock(fut, x):
    with _pool_lock:
        r = fut.result()                      # violation: blocks under lock
        jax.block_until_ready(x)              # violation: jax sync under lock
    return r


def fine_under_lock(items):
    with _pool_lock:
        items.append(1)                       # fine: bounded critical section
    return items


def ungated_dispatch(fn, blob):
    pool = _shared_proc_pool                  # violation: no holds_gil check
    return pool.submit(fn, blob)


def gated_dispatch(codec, fn, blob):
    if codec.holds_gil:
        pool = _shared_proc_pool              # fine: behind holds_gil
        return pool.submit(fn, blob)
    return fn(blob)


def unlabeled_submit(overlap, fn, x):
    _q = FinalizeQueue(overlap)
    _q.submit(fn, x)                          # violation: no label=
    _q.submit(fn, x, label="step 3")          # fine
    return _q
