"""Fixture: dtype-hazard violations in device-reachable functions."""
import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def jitted_wide(x):
    a = x.astype(jnp.float64)                 # violation: attribute dtype
    b = jnp.zeros((4,), dtype="int64")        # violation: string dtype
    return a, b


@jax.jit
def jitted_guarded(x):
    if jax.config.jax_enable_x64:
        return x.astype(jnp.float64)          # exempt: x64-guarded
    return x


def host_staging(x):
    return np.asarray(x, np.float64)          # fine: host-side, unregistered
