"""Fixture: unbounded retry loops (retry-discipline violations)."""
import os
import time


def wait_for_file(path):
    # BAD: spins forever if the file never appears -- no attempt bound,
    # no deadline, no structured timeout.
    while not os.path.exists(path):
        time.sleep(0.05)


def poll_until_ready(is_ready):
    # BAD: constant-true test, sleep, and no break/return/raise.
    while True:
        if is_ready():
            pass
        time.sleep(1.0)


def bounded_ok(path, attempts=5):
    # OK: bounded attempts and a structured timeout on exhaustion.
    delay = 0.05
    for _ in range(attempts):
        if os.path.exists(path):
            return True
        time.sleep(delay)
        delay *= 2
    raise TimeoutError(f"{path} never appeared")


def exit_edge_ok(q):
    # OK: the loop sleeps but can break out.
    while True:
        if q.done():
            break
        time.sleep(0.1)
