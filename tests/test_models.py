"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, exact output shapes, no NaNs.  Also SSD chunked-vs-recurrent and
prefill-vs-decode consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs
from repro.models.model import build

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_loss(arch):
    model = build(arch, smoke=True)
    cfg = model.cfg
    params = model.init(KEY)
    B, S = 2, 32
    batch = model.sample_batch(jax.random.PRNGKey(1), B, S)
    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size), logits.shape
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    # one grad step works and is finite
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    flat = jax.tree.leaves(g)
    assert all(np.isfinite(np.asarray(x)).all() for x in flat)


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_prefill_decode(arch):
    model = build(arch, smoke=True)
    cfg = model.cfg
    params = model.init(KEY)
    B, S = 2, 16
    batch = model.sample_batch(jax.random.PRNGKey(2), B, S)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    s_max = S + 4
    logits, cache, pos = model.prefill(params, prompt, s_max=s_max)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # decode 3 tokens
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(3):
        if cfg.frontend == "frames":
            emb = jax.random.normal(jax.random.PRNGKey(3 + i),
                                    (B, 1, cfg.d_model), jnp.float32)
            logits, cache = model.decode(params, cache, pos=pos, embed=emb)
        else:
            logits, cache = model.decode(params, cache, token=tok, pos=pos)
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        pos = pos + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "hymba-1.5b", "mixtral-8x7b"])
def test_prefill_matches_forward_last_logits(arch):
    """Prefill's last-position logits == forward's last-position logits."""
    model = build(arch, smoke=True)
    params = model.init(KEY)
    B, S = 2, 12
    batch = model.sample_batch(jax.random.PRNGKey(4), B, S)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    full, _ = model.forward(params, prompt)
    pre, _, _ = model.prefill(params, prompt, s_max=S + 2)
    np.testing.assert_allclose(np.asarray(pre[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "minicpm3-4b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode reproduces forward logits step by step."""
    model = build(arch, smoke=True)
    params = model.init(KEY)
    B, S = 1, 10
    batch = model.sample_batch(jax.random.PRNGKey(5), B, S)
    tokens = batch["tokens"]
    full, _ = model.forward(params, {"tokens": tokens})
    k = 4   # prefill S-k, decode the rest teacher-forced
    pre_logits, cache, pos = model.prefill(
        params, {"tokens": tokens[:, : S - k]}, s_max=S)
    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full[:, S - k - 1]),
                               rtol=2e-3, atol=2e-3)
    for i in range(S - k, S):
        logits, cache = model.decode(params, cache,
                                     token=tokens[:, i: i + 1], pos=pos)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, i]),
                                   rtol=2e-3, atol=2e-3,
                                   err_msg=f"pos {i}")
        pos = pos + 1


def test_ssd_chunked_equals_recurrent():
    """Mamba2 SSD dual form == step-by-step recurrence."""
    from repro.models import ssm as S
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("mamba2-780m")
    p = S.ssd_init(jax.random.PRNGKey(7), cfg)
    B, T = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(8), (B, T, cfg.d_model),
                          jnp.float32) * 0.5
    y_chunk, h_final = S.ssd_apply(p, x, cfg=cfg)
    cache = S.ssd_empty_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(T):
        y_t, cache = S.ssd_decode(p, x[:, t: t + 1], cache, cfg=cfg)
        ys.append(y_t)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_final), np.asarray(cache["h"]),
                               rtol=2e-4, atol=2e-4)


def test_swa_masks_long_range():
    """SWA logits are independent of tokens beyond the window."""
    model = build("mixtral-8x7b", smoke=True)  # window 32 in smoke
    cfg = model.cfg
    params = model.init(KEY)
    S = 80    # > n_layers * window so token 0 is outside the last token's
              # receptive field
    t1 = jax.random.randint(jax.random.PRNGKey(9), (1, S), 0,
                            cfg.vocab_size)
    t2 = t1.at[:, 0].set((t1[:, 0] + 1) % cfg.vocab_size)
    l1, _ = model.forward(params, {"tokens": t1})
    l2, _ = model.forward(params, {"tokens": t2})
    # with n_layers=2 the receptive field is 2*window; check the last token
    assert 2 * cfg.sliding_window < S
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               rtol=1e-4, atol=1e-4)


def test_param_counts_match_estimate():
    for arch in ["llama3.2-1b", "mamba2-780m"]:
        model = build(arch, smoke=False)
        est = model.cfg.param_count()
        real = model.param_count()
        assert abs(est - real) / real < 0.05, (arch, est, real)


def test_prefix_lm_bidirectional_mask():
    """paligemma: patch positions attend bidirectionally; text is causal."""
    import jax.numpy as jnp
    from repro.models import layers as L

    q_pos = jnp.arange(10)
    kv_pos = jnp.arange(10)
    m = L.causal_mask(q_pos, kv_pos, prefix=4)
    m = np.asarray(m)
    # prefix block fully visible to everyone
    assert m[:, :4].all()
    # text remains causal among itself
    assert not m[5, 6] and m[6, 5]
    # prefix rows see future prefix but not future text
    assert m[0, 3] and not m[0, 7]


def test_paligemma_patches_influence_text_logits():
    model = build("paligemma-3b", smoke=True)
    params = model.init(KEY)
    B, S = 1, 16
    batch = model.sample_batch(jax.random.PRNGKey(11), B, S)
    l1, _ = model.forward(params, batch)
    batch2 = dict(batch)
    batch2["embeds"] = batch["embeds"] + 1.0
    l2, _ = model.forward(params, batch2)
    # changing the image changes text logits (cross-modal attention works)
    assert np.abs(np.asarray(l1) - np.asarray(l2)).max() > 1e-3
