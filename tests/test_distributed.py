"""Sharded pipeline == single-device pipeline, bit-exact.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main pytest process keeps seeing exactly 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams, compress_step
    from repro.distributed.pipeline import ShardedCompressor

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(7)
    n = 13_777          # odd size: exercises padding + straddling blocks
    prev = rng.normal(1.0, 0.6, n).astype(np.float32)
    prev[::101] = 0.0   # invalid ratios
    curr = (prev * (1 + 0.015 * rng.standard_normal(n))).astype(np.float32)
    curr[::503] *= 50.0  # outliers -> incompressible

    params = NumarckParams(error_bound=1e-3, block_bytes=512, max_bins=4096,
                           b_max=12)
    ref = compress_step(prev, curr, params)

    mesh = Mesh(np.array(jax.devices()), ("data",))
    for use_pallas in (False, True):
        sc = ShardedCompressor(mesh, "data", params, use_pallas=use_pallas)
        got = sc.compress(prev, curr)
        assert got.b_bits == ref.b_bits, (got.b_bits, ref.b_bits)
        assert got.block_elems == ref.block_elems
        assert np.array_equal(got.centers, ref.centers)
        assert len(got.index_blocks) == len(ref.index_blocks)
        for i, (a, b) in enumerate(zip(got.index_blocks, ref.index_blocks)):
            assert a == b, f"block {i} differs (use_pallas={use_pallas})"
        assert np.array_equal(got.incomp_values, ref.incomp_values)
        assert np.array_equal(got.incomp_block_offsets,
                              ref.incomp_block_offsets)
        # and the result decompresses to within the bound
        from repro.core import decompress_step, mean_error_rate
        rec = decompress_step(got, prev)
        me = mean_error_rate(curr, rec)
        assert me <= params.error_bound * 1.01, me

        # sharded decompression (dequant kernel) == host decompression
        from repro.distributed.pipeline import ShardedDecompressor
        sd = ShardedDecompressor(mesh, "data", use_pallas=use_pallas)
        rec2 = sd.decompress(got, prev)
        import numpy as _np
        _np.testing.assert_allclose(rec2, rec, rtol=2e-6, atol=1e-7)
    print("OK")
""")


@pytest.mark.slow
def test_sharded_equals_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


_OVERLAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams, compress_series
    from repro.distributed.pipeline import ShardedCompressor

    assert len(jax.devices()) == 2
    rng = np.random.default_rng(13)
    n = 37_111            # odd: padding + straddling blocks on both shards
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    series = [base]
    for _ in range(4):
        series.append((series[-1]
                       * (1 + 0.012 * rng.standard_normal(n)))
                      .astype(np.float32))
    series[2][::701] *= 40.0          # sprinkle incompressibles mid-stream

    params = NumarckParams(error_bound=1e-3, block_bytes=2048,
                           max_bins=4096, b_max=12)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    sc_sync = ShardedCompressor(mesh, "data", params, use_pallas=False,
                                overlap=False)
    blobs_sync = sc_sync.compress_series(series)
    sc_over = ShardedCompressor(mesh, "data", params, use_pallas=False,
                                overlap=True)
    blobs_over = sc_over.compress_series(series)
    sc_over.close()

    assert len(blobs_sync) == len(blobs_over) == len(series)
    for i, (a, b) in enumerate(zip(blobs_sync, blobs_over)):
        assert a.b_bits == b.b_bits and a.codec == b.codec, i
        assert a.index_blocks == b.index_blocks, f"step {i} blobs differ"
        assert np.array_equal(a.centers, b.centers), i
        if a.incomp_values is not None:
            assert np.array_equal(a.incomp_values, b.incomp_values), i
            assert np.array_equal(a.incomp_block_offsets,
                                  b.incomp_block_offsets), i

    # and the sharded temporal chain matches the single-device one
    ref = compress_series(series, params)
    for i, (a, b) in enumerate(zip(ref, blobs_sync)):
        assert a.index_blocks == b.index_blocks, f"step {i} != single-dev"

    # explicit pair API: overlap future vs immediate result, byte-equal
    f = sc_sync.compress_async(series[0], series[1])
    pair = ShardedCompressor(mesh, "data", params, use_pallas=False,
                             overlap=True)
    g = pair.compress_async(series[0], series[1])
    assert f.result().index_blocks == g.result().index_blocks
    pair.close()
    print("OK")
""")


@pytest.mark.slow
def test_sharded_overlap_byte_identical():
    """overlap=True double-buffering must not change a byte of any blob,
    and the sharded temporal chain must equal the single-device chain."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _OVERLAP_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


_RANS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams, compress_step, decompress_step
    from repro.core import compress_series, decompress_series
    from repro.kernels import rans
    rans.DEVICE_MIN_BYTES = 0        # force the device stage at test sizes
    from repro.distributed.pipeline import ShardedCompressor

    rng = np.random.default_rng(41)
    n = 50_111          # odd: padding + straddling blocks
    prev = rng.normal(1.0, 0.6, n).astype(np.float32)
    prev[::101] = 0.0
    curr = (prev * (1 + 0.015 * rng.standard_normal(n))).astype(np.float32)
    curr[::503] *= 50.0

    params = NumarckParams(error_bound=1e-3, block_bytes=2048,
                           max_bins=4096, b_max=12, codec="rans")
    ref = compress_step(prev, curr, params)
    assert ref.codec == "rans"
    mesh = Mesh(np.array(jax.devices()), ("data",))
    for use_pallas in (False, True):
        sc = ShardedCompressor(mesh, "data", params, use_pallas=use_pallas)
        got = sc.compress(prev, curr)
        assert got.index_blocks == ref.index_blocks, use_pallas
        assert np.array_equal(got.incomp_values, ref.incomp_values)
        assert np.array_equal(got.incomp_block_offsets,
                              ref.incomp_block_offsets)
        rec = decompress_step(got, prev)
        from repro.core import mean_error_rate
        assert mean_error_rate(curr, rec) <= params.error_bound * 1.01

    # overlapped sharded series with the device codec == sync == single
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    series = [base]
    for _ in range(3):
        series.append((series[-1] * (1 + 0.012 * rng.standard_normal(n)))
                      .astype(np.float32))
    sd_ref = compress_series(series, params)
    for overlap in (False, True):
        sc = ShardedCompressor(mesh, "data", params, use_pallas=False,
                               overlap=overlap)
        blobs = sc.compress_series(series)
        sc.close()
        for i, (a, b) in enumerate(zip(sd_ref, blobs)):
            assert a.index_blocks == b.index_blocks, (overlap, i)
    # device-codec archive decompresses bit-identically to the zlib chain
    rec_r = decompress_series(sd_ref)
    rec_z = decompress_series(compress_series(
        series, NumarckParams(error_bound=1e-3, block_bytes=2048,
                              max_bins=4096, b_max=12, codec="zlib")))
    for a, b in zip(rec_r, rec_z):
        np.testing.assert_array_equal(a, b)
    print("OK")
""")


@pytest.mark.slow
def test_sharded_rans_byte_identical():
    """The device entropy stage (shard_map rANS) must emit blobs
    byte-identical to the single-device driver and the host codec, in
    both lowering modes and under overlap."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _RANS_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


_CHAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams, compress_series, decompress_step
    import repro.core.pipeline as pipe
    from repro.distributed.pipeline import (ShardedCompressor,
                                            ShardedDecompressor)

    # Spy on the host chain-advance: the device-resident (default) chain
    # must never call it between steps (ISSUE 4 acceptance).
    calls = {"n": 0}
    orig = pipe.reconstruct_from_indices
    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)
    pipe.reconstruct_from_indices = spy

    rng = np.random.default_rng(17)
    n = 23_531           # odd: padding + straddling blocks on both shards
    base = rng.normal(1.0, 0.5, n).astype(np.float32)
    series = [base]
    for t in range(5):
        nxt = (series[-1] * (1 + 0.012 * rng.standard_normal(n))
               ).astype(np.float32)
        nxt[t::701] *= 40.0            # exceptions on every step
        series.append(nxt)

    params = NumarckParams(error_bound=1e-3, block_bytes=2048,
                           max_bins=4096, b_max=12)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    for use_pallas in (False, True):
        calls["n"] = 0
        blobs = {}
        for chain in ("device", "host"):
            for overlap in (False, True):
                sc = ShardedCompressor(mesh, "data", params,
                                       use_pallas=use_pallas,
                                       overlap=overlap, chain=chain)
                blobs[(chain, overlap)] = sc.compress_series(series)
                if chain == "device":
                    assert calls["n"] == 0, (
                        f"device chain hit host reconstruct_from_indices "
                        f"{calls['n']}x (use_pallas={use_pallas})")
                    state = sc.reference_state()
                sc.close()
        assert calls["n"] > 0          # host flavor does use it

        ref = blobs[("host", False)]
        for key, got in blobs.items():
            for i, (a, b) in enumerate(zip(ref, got)):
                assert a.index_blocks == b.index_blocks, (key, i)
                assert np.array_equal(a.centers, b.centers), (key, i)
                if a.incomp_values is not None:
                    assert np.array_equal(a.incomp_values,
                                          b.incomp_values), (key, i)

        # ... and byte-identical to the single-device chain (device too)
        for chain in ("host", "device"):
            sd_ref = compress_series(series, params, chain=chain)
            for i, (a, b) in enumerate(zip(sd_ref, ref)):
                assert a.index_blocks == b.index_blocks, (chain, i)

        # mesh-resident state == blob replay, bit-exact; the sharded
        # decompressor (device-side exception patch) matches too
        prev = series[0]
        sd = ShardedDecompressor(mesh, "data", use_pallas=use_pallas)
        for st in ref[1:]:
            r = decompress_step(st, prev)
            np.testing.assert_array_equal(sd.decompress(st, prev), r)
            prev = r
        np.testing.assert_array_equal(state, prev)
    print("OK")
""")


_DECODE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams, compress_series
    from repro.core import compress as comp
    from repro.kernels import rans
    from repro.distributed.pipeline import ShardedDecompressor

    rans.DEVICE_MIN_BYTES = 1       # force the device decode route

    # Spy: the mesh decode route must never touch the host lane decoder.
    orig_np = rans.decode_np
    calls = {"n": 0}
    def spy(*a, **k):
        calls["n"] += 1
        return orig_np(*a, **k)
    rans.decode_np = spy

    rng = np.random.default_rng(5)
    n = 8 * 65536                  # divisible blocks: uniform blob rows
    base = rng.normal(1.0, 0.1, n).astype(np.float32)
    series = [base]
    for t in range(3):
        nxt = (series[-1] * (1 + 5e-4 * rng.standard_normal(n))
               ).astype(np.float32)
        nxt[t::701] *= 40.0        # exceptions on every step
        series.append(nxt)

    mesh = Mesh(np.array(jax.devices()), ("data",))
    for symbol in (False, True):
        params = NumarckParams(error_bound=1e-3, codec="rans",
                               device_entropy=True, symbol_rans=symbol,
                               block_bytes=1 << 14)
        steps = compress_series(series, params)
        prev_h = prev_s = None
        dec = ShardedDecompressor(mesh)
        calls["n"] = 0
        mesh_steps = 0
        for st in steps:
            if st.is_anchor:
                prev_h = prev_s = comp.decode_anchor(st).reshape(st.shape)
                continue
            prev_h = comp.decompress_step(st, prev_h)
            prev_s = dec.decompress(st, np.asarray(prev_s))
            assert np.array_equal(np.asarray(prev_h).view(np.uint8),
                                  np.asarray(prev_s).view(np.uint8))
            rec = st.meta.get("telemetry_read")
        if all(rans.blob_version(b) in (1, 2)
               for st in steps[1:] for b in st.index_blocks):
            assert len(dec._rans_fns) > 0, "mesh decode stage never ran"
        assert calls["n"] == 0, (
            f"device decode route hit host decode_np {calls['n']}x "
            f"(symbol={symbol})")
    print("OK")
""")


@pytest.mark.slow
def test_sharded_decode_byte_identical():
    """Mesh rANS entropy decode (v1 and v2 blob rows) must reconstruct
    byte-identically to the single-device driver without ever calling the
    host lane decoder."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _DECODE_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout


@pytest.mark.slow
def test_sharded_device_chain_byte_identical():
    """The mesh-resident reference chain (default) must emit blobs
    byte-identical to the host chain in all overlap/lowering modes,
    without ever calling host reconstruct_from_indices between steps."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _CHAIN_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
