"""Multi-process tier: launch emulation, per-rank shard writers, NCKM
manifest commit/recovery, and 2-process byte-identity.

The fast tests exercise the container/launch layers in-process (hand-made
anchor fragments -- blocks compress independently, so a readable logical
file needs no compressor).  The slow tests spawn real
``jax.distributed``-initialized subprocess fleets through
``repro.launch.distributed.spawn_emulated`` -- the identical launch path
``make bench-all``'s scaling bench and a real multi-host run use.
"""
import json
import os
import struct
import subprocess
import sys
import textwrap
import zlib

import numpy as np
import pytest

from repro.core import container
from repro.core.container import (NCKReader, ShardNCKWriter, StepFragment,
                                  atomic_commit, rank_file_path,
                                  read_manifest, write_manifest)
from repro.launch import runtime_env as renv
from repro.launch.distributed import (ENV_COORDINATOR, ENV_NUM_PROCESSES,
                                      ENV_PROCESS_ID, rank_env,
                                      spawn_emulated)

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                    "src"))


# ------------------------------------------------------------ atomic commit

def test_atomic_commit_bytes_and_chunks(tmp_path):
    p = str(tmp_path / "out.bin")
    atomic_commit(p, b"hello")
    assert open(p, "rb").read() == b"hello"
    # chunked overwrite of an existing file, no tmp debris left behind
    atomic_commit(p, iter([b"a", b"bc", b""]))
    assert open(p, "rb").read() == b"abc"
    assert os.listdir(tmp_path) == ["out.bin"]


def test_atomic_commit_failure_leaves_target(tmp_path):
    p = str(tmp_path / "out.bin")
    atomic_commit(p, b"v1")

    def boom():
        yield b"partial"
        raise IOError("disk gone")

    with pytest.raises(IOError):
        atomic_commit(p, boom())
    assert open(p, "rb").read() == b"v1"


# ------------------------------------------------------- launch environment

def test_runtime_env_preset():
    base = {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    env = renv.runtime_env(base, host_device_count=4)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    assert "--xla_cpu_enable_fast_math=false" in env["XLA_FLAGS"]
    assert base == {"XLA_FLAGS": "--xla_cpu_enable_fast_math=false"}
    if renv.find_tcmalloc():
        assert "tcmalloc" in env["LD_PRELOAD"]
        assert env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] \
            == renv.TCMALLOC_REPORT_THRESHOLD


def test_merge_xla_flags_dedups_by_key():
    merged = renv.merge_xla_flags(
        "--xla_force_host_platform_device_count=2 --a=1",
        ["--xla_force_host_platform_device_count=8"])
    assert merged.split().count("--a=1") == 1
    assert "--xla_force_host_platform_device_count=8" in merged
    assert "--xla_force_host_platform_device_count=2" not in merged


def test_rank_env_coordinates():
    env = rank_env(1, 4, "localhost:1234", devices_per_process=2,
                   base={}, preset=True)
    assert env[ENV_COORDINATOR] == "localhost:1234"
    assert env[ENV_NUM_PROCESSES] == "4"
    assert env[ENV_PROCESS_ID] == "1"
    assert "--xla_force_host_platform_device_count=2" in env["XLA_FLAGS"]


def test_spawn_emulated_ranks_and_failure_reporting():
    code = ("import os,sys;"
            "print('rank', os.environ['REPRO_PROCESS_ID']);"
            "sys.exit(int(os.environ['REPRO_PROCESS_ID']))")
    res = spawn_emulated(2, ["-c", code], timeout=60)
    assert [r.returncode for r in res] == [0, 1]
    assert "rank 0" in res[0].stdout and "rank 1" in res[1].stdout


# ------------------------------------------------- manifest + shard writers

def _anchor_fragments(arr: np.ndarray, num_ranks: int):
    """Hand-made lossless anchor split across `num_ranks`, mirroring
    MultiProcessCompressor._anchor_fragment's block ownership."""
    from repro.core import pipeline as pipe
    flat = arr.reshape(-1)
    be = 8
    slices = pipe.block_slices(flat.size, be)
    nb = len(slices)
    info = dict(total_data_num=arr.size, shape=list(arr.shape),
                dtype=str(arr.dtype), bin_centers_number=0,
                elements_per_block=be, B=0, error_bound=1e-3,
                strategy="topk", reference="reconstructed", domain_lo=0.0,
                bin_width=0.0, is_anchor=True, n_blocks=nb, codec="zlib")
    frags = []
    for rank in range(num_ranks):
        lo = rank * nb // num_ranks
        hi = (rank + 1) * nb // num_ranks
        blks = [zlib.compress(flat[s:e].tobytes(), 6)
                for s, e in slices[lo:hi]]
        frags.append(StepFragment(is_anchor=True, block_start=lo,
                                  info=dict(info), index_blocks=blks))
    return frags


def _write_logical(path: str, arr: np.ndarray, num_ranks: int,
                   generation=None) -> str:
    frags = _anchor_fragments(arr, num_ranks)
    manifest = None
    for rank in range(num_ranks):
        w = ShardNCKWriter(path, rank, num_ranks, generation=generation)
        w.add_fragment("step0000", frags[rank])
        w.write()
        if rank == 0:
            rank0 = w
    manifest = rank0.commit_manifest(timeout=5.0)
    return manifest


def test_manifest_roundtrip_two_ranks(tmp_path):
    path = str(tmp_path / "series.nck")
    arr = np.arange(100, dtype=np.float32)
    _write_logical(path, arr, 2)
    assert sorted(os.listdir(tmp_path)) == [
        "series.nck", "series.nck.g0000.rank0", "series.nck.g0000.rank1"]
    r = NCKReader(path)
    assert r.step_names() == ["step0000"]
    step = r.read_step("step0000")
    assert step.is_anchor
    from repro.core.compress import decode_anchor
    np.testing.assert_array_equal(decode_anchor(step), arr)


def test_reader_rejects_missing_shard(tmp_path):
    path = str(tmp_path / "series.nck")
    _write_logical(path, np.arange(64, dtype=np.float32), 2)
    missing = rank_file_path(path, 0, 1)
    os.remove(missing)
    with pytest.raises(FileNotFoundError) as ei:
        NCKReader(path)
    assert os.path.basename(missing) in str(ei.value)
    assert "rank 1" in str(ei.value)


def test_reader_rejects_truncated_shard(tmp_path):
    path = str(tmp_path / "series.nck")
    _write_logical(path, np.arange(64, dtype=np.float32), 2)
    victim = rank_file_path(path, 0, 1)
    data = open(victim, "rb").read()
    with open(victim, "wb") as f:
        f.write(data[:-3])
    with pytest.raises(ValueError, match="bytes"):
        NCKReader(path)


def test_generation_bump_and_gc(tmp_path):
    path = str(tmp_path / "series.nck")
    arr = np.arange(80, dtype=np.float32)
    _write_logical(path, arr, 2)
    assert read_manifest(path)["generation"] == 0
    _write_logical(path, arr * 2, 2)          # next_generation() picks 1
    m = read_manifest(path)
    assert m["generation"] == 1
    # generation 0 is retained as the rollback target (manifest embeds it
    # under "previous"); its shard files survive GC
    assert m["previous"]["generation"] == 0
    assert sorted(os.listdir(tmp_path)) == [
        "series.nck",
        "series.nck.g0000.rank0", "series.nck.g0000.rank1",
        "series.nck.g0001.rank0", "series.nck.g0001.rank1"]
    _write_logical(path, arr * 3, 2)          # generation 2
    # now generation 0 is unreachable (previous == 1) and is GC'd
    assert sorted(os.listdir(tmp_path)) == [
        "series.nck",
        "series.nck.g0001.rank0", "series.nck.g0001.rank1",
        "series.nck.g0002.rank0", "series.nck.g0002.rank1"]
    step = NCKReader(path).read_step("step0000")
    from repro.core.compress import decode_anchor
    np.testing.assert_array_equal(decode_anchor(step), arr * 3)


def test_commit_timeout_preserves_previous_manifest(tmp_path):
    path = str(tmp_path / "series.nck")
    arr = np.arange(48, dtype=np.float32)
    _write_logical(path, arr, 2)              # generation 0, loadable
    # generation 1: rank 0 writes, rank 1 "crashed" (file never appears)
    frag = _anchor_fragments(arr, 2)[0]
    w = ShardNCKWriter(path, 0, 2)
    w.add_fragment("step0000", frag)
    w.write()
    with pytest.raises(TimeoutError, match="previous manifest"):
        w.commit_manifest(timeout=0.3)
    # the logical file still opens at generation 0
    r = NCKReader(path)
    assert read_manifest(path)["generation"] == 0
    from repro.core.compress import decode_anchor
    np.testing.assert_array_equal(
        decode_anchor(r.read_step("step0000")), arr)


def test_manifest_magic_rejects_corruption(tmp_path):
    path = str(tmp_path / "series.nck")
    _write_logical(path, np.arange(32, dtype=np.float32), 1)
    raw = open(path, "rb").read()
    assert raw[:4] == container._MANIFEST_MAGIC
    hlen = struct.unpack("<Q", raw[4:12])[0]
    assert json.loads(raw[12:12 + hlen])["schema"] == 2
    with open(path, "wb") as f:
        f.write(b"XXXX" + raw[4:])
    with pytest.raises(Exception):
        NCKReader(path)


# ---------------------------------------------------- multi-process (slow)

def _make_series_src(n=50_777, steps=4):
    return textwrap.dedent(f"""
        import numpy as np
        rng = np.random.default_rng(7)
        n = {n}
        base = rng.normal(1.0, 0.5, n).astype(np.float32)
        series = [base]
        for t in range({steps} - 1):
            nxt = (series[-1] * (1 + 0.01 * rng.standard_normal(n))
                   ).astype(np.float32)
            nxt[t::401] *= 40.0
            series.append(nxt)
    """)


_MP_WORKER = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.launch import distributed as dist
    cfg = dist.initialize()
    mesh = dist.global_mesh()
    import jax
    assert jax.process_count() == 2, jax.process_count()

    # Structural no-payload-gather proof: fetching a P(axis)-sharded
    # array whole from one process raises; only addressable shards (the
    # per-rank writer's entire input) are host-fetchable.
    from repro.distributed.pipeline import (MultiProcessCompressor,
                                            _put_sharded)
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharded = NamedSharding(mesh, P("data"))
    probe = _put_sharded(np.arange(8, dtype=np.float32), sharded)
    try:
        np.asarray(probe)
        raise SystemExit("cross-process fetch unexpectedly succeeded")
    except RuntimeError:
        pass

    from repro.core import NumarckParams
    {series_src}
    mp = MultiProcessCompressor(mesh, params=NumarckParams(
        error_bound=1e-3), use_pallas=False)
    if os.environ.get("CRASH_RANK", "") == str(cfg.process_id):
        mp.compress_series_fragments(series)   # collectives complete...
        mp.close()
        os._exit(3)                            # ...then die pre-publish
    out = mp.save_series(os.environ["OUT_PATH"], series,
                         manifest_timeout=float(
                             os.environ.get("MANIFEST_TIMEOUT", "60")))
    mp.close()
    print("WORKER_OK", out)
""")


def _spawn_workers(out_path, *, crash_rank=None, manifest_timeout=None,
                   timeout=240):
    env = dict(os.environ)
    env["OUT_PATH"] = out_path
    env["PYTHONPATH"] = _SRC
    if crash_rank is not None:
        env["CRASH_RANK"] = str(crash_rank)
    if manifest_timeout is not None:
        env["MANIFEST_TIMEOUT"] = str(manifest_timeout)
    script = _MP_WORKER.format(series_src=_make_series_src())
    return spawn_emulated(2, ["-c", script], base_env=env, timeout=timeout)


# Single-process reference over the SAME 2-device mesh (the block grid
# follows the shard layout, so an equal-device ShardedCompressor run is
# the byte-identity baseline; ShardedCompressor == single-device is
# covered by tests/test_distributed.py).
_SINGLE_REF = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro.core import NumarckParams
    from repro.core.container import NCKWriter
    from repro.distributed.pipeline import ShardedCompressor
    {series_src}
    sc = ShardedCompressor(Mesh(np.array(jax.devices()), ("data",)),
                           params=NumarckParams(error_bound=1e-3),
                           use_pallas=False)
    steps = sc.compress_series(series)
    sc.close()
    w = NCKWriter()
    for i, s in enumerate(steps):
        w.add_step(f"step{{i:04d}}", s)
    w.write(os.environ["REF_PATH"])
    print("REF_OK")
""")


@pytest.mark.slow
def test_two_process_byte_identity(tmp_path):
    """2-process save_series == single-process compress_series, byte for
    byte (blocks, centers, exceptions), with per-rank shard files plus a
    rank-0 manifest and zero cross-process payload fetches."""
    path = str(tmp_path / "series.nck")
    res = _spawn_workers(path)
    for rank, r in enumerate(res):
        assert r.returncode == 0, (
            f"rank {rank}:\n{r.stdout}\n{r.stderr}")
        assert "WORKER_OK" in r.stdout
    assert sorted(os.listdir(tmp_path)) == [
        "series.nck", "series.nck.g0000.rank0", "series.nck.g0000.rank1"]

    ref_path = str(tmp_path / "ref.nck")
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC
    env["REF_PATH"] = ref_path
    script = _SINGLE_REF.format(series_src=_make_series_src())
    ref = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert ref.returncode == 0, ref.stdout + ref.stderr

    got, want = NCKReader(path), NCKReader(ref_path)
    names = got.step_names()
    assert names == [f"step{i:04d}" for i in range(4)]
    for name in names:
        a, b = got.read_step(name), want.read_step(name)
        assert a.is_anchor == b.is_anchor
        assert len(a.index_blocks) == len(b.index_blocks)
        for j, (x, y) in enumerate(zip(a.index_blocks, b.index_blocks)):
            assert x == y, f"{name} block {j} differs"
        if not a.is_anchor:
            assert a.b_bits == b.b_bits and a.n == b.n
            np.testing.assert_array_equal(np.asarray(a.centers),
                                          np.asarray(b.centers))
            np.testing.assert_array_equal(a.incomp_values,
                                          b.incomp_values)
            np.testing.assert_array_equal(a.incomp_block_offsets,
                                          b.incomp_block_offsets)


@pytest.mark.slow
def test_crashed_rank_leaves_previous_manifest(tmp_path):
    """A rank dying after the collectives but before publishing its
    shard file must not corrupt the logical file: rank 0's manifest
    commit times out and the previous generation stays loadable."""
    path = str(tmp_path / "series.nck")
    res = _spawn_workers(path)                 # generation 0, both ranks
    assert [r.returncode for r in res] == [0, 0], [
        (r.returncode, r.stderr[-800:]) for r in res]
    before = NCKReader(path)
    baseline = {n: before.read_step(n).index_blocks
                for n in before.step_names()}

    res = _spawn_workers(path, crash_rank=1, manifest_timeout=3)
    assert res[1].returncode == 3              # the planted crash
    assert res[0].returncode != 0              # TimeoutError surfaced
    assert "TimeoutError" in res[0].stderr

    after = NCKReader(path)
    assert read_manifest(path)["generation"] == 0
    for n, blocks in baseline.items():
        assert after.read_step(n).index_blocks == blocks
