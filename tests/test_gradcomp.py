"""NUMARCK-binning gradient compression: quantizer properties + error
feedback behaviour (the beyond-paper distributed-optimization feature)."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.train import gradcomp

RNG = np.random.default_rng(0)


def test_quantizer_bounded_error():
    g = RNG.normal(0, 1e-2, 4096).astype(np.float32)
    g_hat, info = gradcomp.quantize_dequantize(jnp.asarray(g), b_bits=6)
    g_hat = np.asarray(g_hat)
    # in-top-k values land at a bin center: error <= half a bin width;
    # exceptions pass through exactly
    width = (g.max() - g.min()) / (16 * 64)
    err = np.abs(g_hat - g)
    assert err.max() <= width / 2 + 1e-12
    # pure gaussians are the hard case for uniform-grid top-k binning
    # (values don't cluster like temporal change ratios); alpha is high
    # but the bound holds and EF keeps training unbiased
    assert float(info["alpha"]) < 0.85


def test_quantizer_alpha_small_for_clustered_grads():
    """The regime the method targets: values concentrated in few levels
    (post-clipping / sparse gradients)."""
    g = np.concatenate([np.zeros(3000),
                        RNG.normal(1e-2, 1e-4, 1000),
                        RNG.normal(-1e-2, 1e-4, 1000)]).astype(np.float32)
    g_hat, info = gradcomp.quantize_dequantize(jnp.asarray(g), b_bits=4)
    assert float(info["alpha"]) < 0.05
    width = (g.max() - g.min()) / (16 * 16)
    assert np.abs(np.asarray(g_hat) - g).max() <= width / 2 + 1e-12


def test_quantizer_bounded_even_with_outliers():
    g = np.concatenate([RNG.normal(0, 1e-3, 1000),
                        np.array([5.0, -7.0])]).astype(np.float32)
    g_hat, _ = gradcomp.quantize_dequantize(jnp.asarray(g), b_bits=4)
    g_hat = np.asarray(g_hat)
    width = (g.max() - g.min()) / (16 * 16)
    # outliers either pass through exactly or land on their bin center
    assert np.abs(g_hat - g).max() <= width / 2 + 1e-12


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=2, max_value=8))
def test_error_feedback_residual_shrinks_bias(b_bits):
    """With EF the cumulative applied update tracks the true gradient."""
    g = RNG.normal(0, 1e-2, 512).astype(np.float32)
    state = gradcomp.init_state({"g": g})
    applied = np.zeros_like(g)
    steps = 30
    for _ in range(steps):
        g_hat, state = gradcomp.compress_grads({"g": g}, state,
                                               b_bits=b_bits)
        applied += np.asarray(g_hat["g"])
    bias = np.abs(applied / steps - g).mean() / (np.abs(g).mean() + 1e-12)
    assert bias < 0.12, bias


def test_wire_bits_estimate():
    g = np.zeros(1000, np.float32)
    frac = gradcomp.wire_bits(g, b_bits=6, alpha=0.02)
    assert 0.1 < frac < 0.3             # ~6.64/32


def test_zero_gradient_passthrough():
    g = np.zeros(256, np.float32)
    g_hat, _ = gradcomp.quantize_dequantize(jnp.asarray(g), b_bits=4)
    np.testing.assert_array_equal(np.asarray(g_hat), g)
