"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import bitpack, change_ratio, dequant, hist, ref

RNG = np.random.default_rng(1234)


def _temporal_pair(n, dtype, zero_frac=0.01, inf_frac=0.001):
    prev = RNG.normal(1.0, 0.7, n).astype(dtype)
    nz = RNG.random(n) < zero_frac
    prev[nz] = 0.0
    curr = (prev * (1 + 0.02 * RNG.standard_normal(n))).astype(dtype)
    bad = RNG.random(n) < inf_frac
    curr[bad] = np.inf
    return prev, curr


@pytest.mark.parametrize("n", [1, 100, 1024, 4097, 300_000])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_change_ratio_kernel(n, dtype):
    prev, curr = _temporal_pair(n, dtype)
    lo, w, m = -0.128, 0.002, 2048
    r_k, id_k = change_ratio.change_ratio_bins(
        jnp.asarray(prev, jnp.float32), jnp.asarray(curr, jnp.float32),
        lo, w, max_bins=m, interpret=True)
    r_r, id_r = ref.change_ratio_bins_ref(prev, curr, lo, w, max_bins=m)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(id_k), np.asarray(id_r))


@pytest.mark.parametrize("block_rows", [8, 256])
def test_change_ratio_kernel_block_shapes(block_rows):
    prev, curr = _temporal_pair(50_000, np.float32)
    r_k, id_k = change_ratio.change_ratio_bins(
        jnp.asarray(prev), jnp.asarray(curr), -0.064, 0.001, max_bins=1024,
        block_rows=block_rows, interpret=True)
    r_r, id_r = ref.change_ratio_bins_ref(prev, curr, -0.064, 0.001,
                                          max_bins=1024)
    np.testing.assert_allclose(np.asarray(r_k), np.asarray(r_r), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(id_k), np.asarray(id_r))


@pytest.mark.parametrize("b_bits", list(range(1, 17)) + [24])
def test_bitpack_kernel_all_widths(b_bits):
    n = 32 * 123
    idx = RNG.integers(0, 1 << b_bits, n).astype(np.int32)
    w_k = np.asarray(bitpack.pack_bits(jnp.asarray(idx), b_bits=b_bits,
                                       interpret=True))
    w_r = ref.pack_bits_ref(idx, b_bits=b_bits)
    np.testing.assert_array_equal(w_k, w_r)


@pytest.mark.parametrize("n_groups", [1, 7, 513, 4096])
def test_bitpack_kernel_sizes(n_groups):
    b = 11
    idx = RNG.integers(0, 1 << b, 32 * n_groups).astype(np.int32)
    w_k = np.asarray(bitpack.pack_bits(jnp.asarray(idx), b_bits=b,
                                       interpret=True))
    np.testing.assert_array_equal(w_k, ref.pack_bits_ref(idx, b_bits=b))


@pytest.mark.parametrize("b_bits", [2, 5, 8, 13])
@pytest.mark.parametrize("n", [17, 2048, 100_001])
def test_dequant_kernel(b_bits, n):
    k = (1 << b_bits) - 1
    centers = RNG.uniform(-0.1, 0.1, k).astype(np.float32)
    idx = RNG.integers(0, k + 1, n).astype(np.int32)
    prev = RNG.normal(1, 0.5, n).astype(np.float32)
    out_k = np.asarray(dequant.dequantize(
        jnp.asarray(idx), jnp.asarray(prev), jnp.asarray(centers),
        b_bits=b_bits, interpret=True))
    out_r = np.asarray(ref.dequantize_ref(idx, prev, centers, b_bits=b_bits))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("max_bins", [1024, 4096, 65536])
@pytest.mark.parametrize("n", [100, 65_537])
def test_hist_kernel(max_bins, n):
    ids = RNG.integers(-1, max_bins, n).astype(np.int32)
    h_k = np.asarray(hist.histogram(jnp.asarray(ids), max_bins=max_bins,
                                    interpret=True))
    h_r = np.asarray(ref.histogram_ref(ids, max_bins=max_bins))
    np.testing.assert_array_equal(h_k, h_r)
    assert h_k.sum() == (ids >= 0).sum()


def test_pack_matches_core_packing_bytes():
    """Kernel uint32 words viewed as bytes == core.packing byte stream."""
    from repro.core import packing
    b = 13
    idx = RNG.integers(0, 1 << b, 32 * 64).astype(np.int32)
    words = np.asarray(bitpack.pack_bits(jnp.asarray(idx), b_bits=b,
                                         interpret=True))
    byts = words.view("<u4").tobytes()
    expect = packing.pack_indices_np(idx, b).tobytes()
    assert byts[: len(expect)] == expect
