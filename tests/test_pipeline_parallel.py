"""GPipe pipeline == sequential stage composition (fwd and grad).

Subprocess with 4 host devices so the main process keeps 1 device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh
    from repro.distributed.pipeline_parallel import (pipeline_apply,
                                                     stack_stages)

    mesh = Mesh(np.array(jax.devices()).reshape(4), ("pipe",))
    P_, M, mb, d = 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), P_)
    stages = [{"w": jax.random.normal(k, (d, d)) * 0.3,
               "b": jnp.zeros((d,))} for k in ks]
    stacked = stack_stages(stages)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    # sequential reference
    ref = x
    for s in stages:
        ref = stage_fn(s, ref)

    out = pipeline_apply(mesh, "pipe", stage_fn, stacked, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)

    # gradients flow through the pipeline (reverse schedule via AD)
    def loss_pipe(params):
        return jnp.sum(pipeline_apply(mesh, "pipe", stage_fn, params,
                                      x) ** 2)
    def loss_seq(params_list):
        y = x
        for s in params_list:
            y = stage_fn(s, y)
        return jnp.sum(y ** 2)

    g_pipe = jax.grad(loss_pipe)(stacked)
    g_seq = stack_stages(jax.grad(loss_seq)(stages))
    for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    print("OK")
""")


@pytest.mark.slow
def test_pipeline_parallel_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
