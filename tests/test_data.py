"""Data layer: temporal field statistics + token pipeline determinism."""
import numpy as np
import pytest

from repro.data.temporal import SPECS, dataset_bytes, generate_series
from repro.data.tokens import TokenPipeline


@pytest.mark.parametrize("name", list(SPECS))
def test_series_temporal_coherence(name):
    """Consecutive iterations must have small change ratios (the property
    NUMARCK exploits) except for the intermittent jump fraction."""
    series = list(generate_series(name, n_iterations=3, seed=0, scale=4))
    spec = SPECS[name]
    assert series[0].dtype == np.dtype(spec.dtype)
    a, b = series[1], series[2]
    ratios = np.abs((b - a) / np.where(a == 0, 1, a))
    frac_small = float((ratios < 10 * spec.vol).mean())
    assert frac_small > 0.8, frac_small


def test_sedov_static_fraction():
    """Sedov-like data: most points change less than |E| (paper Sec. V-D:
    80% below the error bound -> high ZLIB ratios)."""
    series = list(generate_series("sedov", n_iterations=2, seed=1, scale=2))
    a, b = series
    ratios = np.abs((b - a) / np.where(a == 0, 1, a))
    assert (ratios < 1e-3).mean() > 0.6


def test_series_deterministic():
    s1 = list(generate_series("stir", 2, seed=5, scale=4))
    s2 = list(generate_series("stir", 2, seed=5, scale=4))
    np.testing.assert_array_equal(s1[1], s2[1])
    assert dataset_bytes("stir", 4) == s1[0].nbytes


def test_token_pipeline_shapes_and_range():
    pipe = TokenPipeline(1000, 33, 4, seed=0)
    b = pipe.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].max() < 1000


def test_token_pipeline_learnable_structure():
    """Markov structure: next-token entropy is far below uniform."""
    pipe = TokenPipeline(256, 257, 8, seed=0, n_states=16)
    b = pipe.batch(0)
    pairs = {}
    flat = b["tokens"]
    for row in range(flat.shape[0]):
        for t in range(flat.shape[1] - 1):
            key = flat[row, t]
            pairs.setdefault(key, []).append(flat[row, t + 1])
    # for frequent states, successor distribution is concentrated
    concentrated = 0
    checked = 0
    for k, succ in pairs.items():
        if len(succ) > 50:
            checked += 1
            _, counts = np.unique(succ, return_counts=True)
            if counts.max() / len(succ) > 0.2:
                concentrated += 1
    assert checked > 0 and concentrated / checked > 0.5
