"""Device entropy stage: block-parallel rANS kernel + per-block codecs.

Covers the PR's byte-exactness contract end to end: the NumPy coder
round-trips adversarial distributions (property tests), the jnp device
lowering emits byte-identical blobs to the host codec, both drivers route
through the same stage, per-block codec ids survive the NCK container and
partial reads, and the vectorized host packer matches the old loop.
"""
import dataclasses
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import (NCKReader, NCKWriter, NumarckParams, compress_series,
                        compress_step, decompress_series, decompress_step,
                        mean_error_rate)
from repro.core import entropy, packing
from repro.core import pipeline as pipe
from repro.core.compress import encode_device
from repro.core.partial import TemporalArchive, read_step_range
from repro.kernels import ops as kops
from repro.kernels import rans

RNG = np.random.default_rng(23)


def _payload(kind: str, n: int) -> np.ndarray:
    rng = np.random.default_rng(n + len(kind))
    if kind == "zipf":
        return (rng.zipf(1.6, n).astype(np.uint64) % 251).astype(np.uint8)
    if kind == "uniform":
        return rng.integers(0, 256, n).astype(np.uint8)
    if kind == "single":
        return np.full(n, 7, np.uint8)
    if kind == "marker":
        return np.full(n, 0xFF, np.uint8)
    if kind == "two":
        return rng.choice(np.array([3, 250], np.uint8), n)
    raise ValueError(kind)


# ------------------------------------------------------ property round-trip

@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["zipf", "uniform", "single", "marker", "two"]),
       st.integers(min_value=0, max_value=300_000))
def test_rans_round_trip_property(kind, n):
    raw = _payload(kind, n).tobytes()
    blob = rans.compress(raw)
    assert rans.decompress(blob) == raw


def test_rans_boundary_sizes():
    """Lane/stride rule boundaries and degenerate blocks round-trip."""
    for n in (0, 1, 2, 31, 32, 33, (8 << 10) - 1, 8 << 10,
              (64 << 10) - 1, 64 << 10, (256 << 10) - 1, 256 << 10,
              (512 << 10) + 17):
        for kind in ("zipf", "single", "uniform"):
            raw = _payload(kind, n).tobytes()
            assert rans.decompress(rans.compress(raw)) == raw, (kind, n)


def test_rans_raw_fallback_on_incompressible():
    raw = _payload("uniform", 200_000).tobytes()
    blob = rans.compress(raw)
    assert len(blob) == len(raw) + 5          # v0 store container
    assert rans.decompress(blob) == raw


def test_freq_table_invariants():
    for kind in ("zipf", "single", "marker", "uniform"):
        f = rans.freq_table(_payload(kind, 100_000))
        assert int(f.sum()) == rans.M
        assert (f >= 1).all()                  # sampling can't break encode
    assert int(rans.freq_table(np.zeros(0, np.uint8)).sum()) == rans.M


def test_corrupt_blob_rejected():
    raw = _payload("zipf", 10_000).tobytes()
    blob = bytearray(rans.compress(raw))
    with pytest.raises(ValueError):
        rans.decompress(bytes(blob[:40]))      # truncated
    blob[4] = 9                                # unknown version
    with pytest.raises(ValueError):
        rans.decompress(bytes(blob))


# ------------------------------------------- device lowering byte-identity

def test_device_encode_matches_host_codec():
    """kernels.rans device pack+scan == host rans.compress, per block."""
    b_bits, be, nblocks = 9, 4096, 5
    rng = np.random.default_rng(3)
    idx = rng.integers(0, (1 << b_bits) - 1, nblocks * be).astype(np.int32)
    idx[::37] = (1 << b_bits) - 1
    blobs = rans.compress_blocks_device(jnp.asarray(idx), b_bits, nblocks,
                                        be)
    nbytes = be * b_bits // 8
    for k in range(nblocks):
        raw = packing.pack_indices_np(
            idx[k * be:(k + 1) * be].astype(np.int64),
            b_bits).tobytes()[:nbytes]
        assert blobs[k] == rans.compress(raw), k
        assert rans.decompress(blobs[k]) == raw, k


@pytest.mark.parametrize("b_bits", [1, 5, 8, 12, 16])
def test_sampled_idx_bytes_matches_pack(b_bits):
    """The pre-pack byte sampler must reproduce the real packed stream."""
    be, nblocks = 1024, 3
    rng = np.random.default_rng(b_bits)
    idx = rng.integers(0, 1 << b_bits, nblocks * be).astype(np.int32)
    nbytes = be * b_bits // 8
    got = np.asarray(rans.sampled_idx_bytes(
        jnp.asarray(idx).reshape(nblocks, be), b_bits, 1))
    for k in range(nblocks):
        raw = packing.pack_indices_np(
            idx[k * be:(k + 1) * be].astype(np.int64),
            b_bits).tobytes()[:nbytes]
        np.testing.assert_array_equal(got[k], np.frombuffer(raw, np.uint8))


def test_sample_words_matches_byte_sample():
    rng = np.random.default_rng(11)
    words = rng.integers(0, 1 << 32, (4, 256), dtype=np.uint64
                         ).astype(np.uint32)
    raw = np.stack([np.frombuffer(w.astype("<u4").tobytes(), np.uint8)
                    for w in words])
    for stride in (1, 16):
        got = np.asarray(rans.sample_words(jnp.asarray(words), stride))
        np.testing.assert_array_equal(got, raw[:, ::stride])


# ------------------------------------------------ driver / finalize routes

def _series(shape, steps=3, vol=0.01, dtype=np.float32, seed=5):
    rng = np.random.default_rng(seed)
    base = rng.normal(1.0, 0.5, shape).astype(dtype)
    out = [base]
    for _ in range(steps - 1):
        out.append((out[-1] * (1 + vol * rng.standard_normal(shape)))
                   .astype(dtype))
    return out


def test_device_route_equals_host_route(monkeypatch):
    """Forcing the device entropy stage must not change a byte of any
    step (device-vs-host codec byte-compat)."""
    rng = np.random.default_rng(9)
    prev = rng.normal(1, 0.4, 150_000).astype(np.float32)
    curr = (prev * (1 + 0.01 * rng.standard_normal(prev.size))
            ).astype(np.float32)
    curr[::211] *= 30.0
    p = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=1 << 16)
    host = compress_step(prev, curr,
                         dataclasses.replace(p, device_entropy=False))
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    dev = compress_step(prev, curr, p)
    assert dev.index_blocks == host.index_blocks
    np.testing.assert_array_equal(dev.incomp_values, host.incomp_values)
    np.testing.assert_array_equal(dev.incomp_block_offsets,
                                  host.incomp_block_offsets)
    assert dev.codec == host.codec == "rans"


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_rans_series_round_trip_bit_exact(dtype, monkeypatch):
    """Compressed-with-rans series decompresses bit-identically to the
    zlib chain (the entropy stage is lossless whatever the codec)."""
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    series = _series((64, 210), steps=4, dtype=dtype)
    p_r = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=4096)
    p_z = NumarckParams(error_bound=1e-3, codec="zlib", block_bytes=4096)
    rec_r = decompress_series(compress_series(series, p_r))
    rec_z = decompress_series(compress_series(series, p_z))
    for a, b in zip(rec_r, rec_z):
        np.testing.assert_array_equal(a, b)
        assert a.dtype == dtype
    assert mean_error_rate(series[-1], rec_r[-1]) <= 1e-3 * 1.01


def test_rans_through_container_and_partial(monkeypatch, tmp_path):
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    series = _series((40_000,), steps=3)
    p = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=8192)
    steps = compress_series(series, p)
    path = os.path.join(tmp_path, "r.nck")
    TemporalArchive.write(path, "v", steps)
    arch = TemporalArchive(path)
    full = decompress_series(steps)
    for it in range(len(steps)):
        sl = arch.read_range("v", it, 1234, 9876)
        np.testing.assert_array_equal(sl, full[it].reshape(-1)[1234:9876])


# ------------------------------------------------------- per-block codecs

def _mixed_step():
    """A step whose blocks span the compressibility range (auto -> mixed
    per-block codecs)."""
    rng = np.random.default_rng(17)
    n = 1 << 19
    prev = rng.normal(1, 0.3, n).astype(np.float32)
    curr = prev.copy()
    curr[: n // 2] *= np.float32(1 + 1e-5)
    curr[n // 2:] *= (1 + 0.3 * rng.standard_normal(n // 2)
                      ).astype(np.float32)
    p = NumarckParams(error_bound=1e-3, codec="auto", block_bytes=1 << 14)
    return prev, curr, compress_step(prev, curr, p)


def test_auto_picks_per_block_codecs():
    prev, curr, st = _mixed_step()
    assert st.block_codecs is not None
    assert len(st.block_codecs) == st.n_blocks
    assert len(set(st.block_codecs)) > 1          # genuinely mixed
    assert st.codec in set(st.block_codecs)        # primary is concrete
    rec = decompress_step(st, prev)
    assert mean_error_rate(curr, rec) <= 1e-3 * 1.01


def test_per_block_codecs_survive_container_and_partial(tmp_path):
    prev, curr, st = _mixed_step()
    path = os.path.join(tmp_path, "m.nck")
    w = NCKWriter(checksums=False)
    w.add_step("v", st)
    w.write(path)
    with open(path, "rb") as f:
        assert f.read(4) == b"NCK2"        # per-block files bump version
    r = NCKReader(path)
    assert r.format_version == 2
    st2 = r.read_step("v")
    assert st2.block_codecs == st.block_codecs
    full = decompress_step(st2, prev)
    np.testing.assert_array_equal(full, decompress_step(st, prev))
    pf = np.asarray(prev).reshape(-1)
    sl = read_step_range(r, "v", 100_000, 300_000, pf[100_000:300_000])
    np.testing.assert_array_equal(sl, full.reshape(-1)[100_000:300_000])


def test_uniform_codec_files_stay_v1(tmp_path):
    """No per-block ids -> NCK1 magic: old readers keep loading them."""
    series = _series((96, 40))
    steps = compress_series(series, NumarckParams(error_bound=1e-3))
    path = os.path.join(tmp_path, "u.nck")
    w = NCKWriter(checksums=False)
    for i, s in enumerate(steps):
        w.add_step(f"v_it{i:05d}", s)
    w.write(path)
    with open(path, "rb") as f:
        assert f.read(4) == b"NCK1"
    assert NCKReader(path).format_version == 1


def test_old_reader_rejects_v2_magic(tmp_path):
    """An NCK1-era reader knows only the NCK1 magic; NCK2 files must fail
    its magic check (emulated here) instead of being mis-decoded."""
    prev, curr, st = _mixed_step()
    path = os.path.join(tmp_path, "m.nck")
    w = NCKWriter(checksums=False)
    w.add_step("v", st)
    w.write(path)
    with open(path, "rb") as f:
        magic = f.read(4)
    assert magic != b"NCK1"                    # the old reader's only check
    with pytest.raises(ValueError):            # unknown magics still reject
        path3 = os.path.join(tmp_path, "bad.nck")
        with open(path3, "wb") as f:
            f.write(b"NCK9" + b"\0" * 64)
        NCKReader(path3)


# ------------------------------------------- device decoder byte-identity

@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["zipf", "uniform", "single", "marker", "two"]),
       st.integers(min_value=1, max_value=6),
       st.sampled_from([4, 8, 12]))
def test_device_decode_matches_host_property(kind, nblocks, b_bits):
    """decode_blocks_device == the host decoder on adversarial payloads
    (mixed v0/v1 groups ride the same call)."""
    be = 4096
    rng = np.random.default_rng(nblocks * 31 + b_bits)
    if kind == "zipf":
        idx = (rng.zipf(1.6, nblocks * be).astype(np.uint64)
               % (1 << b_bits)).astype(np.int32)
    elif kind == "uniform":
        idx = rng.integers(0, 1 << b_bits, nblocks * be).astype(np.int32)
    elif kind == "single":
        idx = np.full(nblocks * be, min(3, (1 << b_bits) - 1), np.int32)
    elif kind == "marker":
        idx = np.full(nblocks * be, (1 << b_bits) - 1, np.int32)
    else:
        idx = rng.choice(np.array([0, (1 << b_bits) - 1], np.int32),
                         nblocks * be)
    blobs = rans.compress_blocks_device(jnp.asarray(idx), b_bits, nblocks,
                                        be)
    got = np.asarray(rans.decode_blocks_device(blobs, b_bits, be)
                     ).reshape(-1)
    for k, blob in enumerate(blobs):
        raw = rans.decompress(blob)
        want = packing.unpack_indices_np(np.frombuffer(raw, np.uint8),
                                         be, b_bits)
        np.testing.assert_array_equal(got[k * be:(k + 1) * be], want, k)
    np.testing.assert_array_equal(got, idx)


def test_device_decode_lane_boundaries():
    """Block sizes straddling every lanes_for threshold round-trip
    through the device decoder."""
    b_bits = 8
    rng = np.random.default_rng(41)
    for be in (32, 4096, 8 << 10, 64 << 10, 512 << 10):
        idx = (rng.zipf(1.6, 2 * be).astype(np.uint64) % 251
               ).astype(np.int32)
        blobs = rans.compress_blocks_device(jnp.asarray(idx), b_bits, 2,
                                            be)
        got = np.asarray(rans.decode_blocks_device(blobs, b_bits, be)
                         ).reshape(-1)
        np.testing.assert_array_equal(got, idx, be)


def test_device_decode_rejects_corrupt_blob():
    b_bits, be = 8, 8192
    rng = np.random.default_rng(43)
    idx = (rng.zipf(1.6, be).astype(np.uint64) % 251).astype(np.int32)
    blobs = rans.compress_blocks_device(jnp.asarray(idx), b_bits, 1, be)
    assert rans.blob_version(blobs[0]) == 1      # a real coded blob
    bad = bytearray(blobs[0])
    bad[-1] ^= 0xFF
    with pytest.raises(ValueError):
        rans.decode_blocks_device([bytes(bad)], b_bits, be)


def test_device_anchor_decode_matches_join():
    """decode_bytes_blocks_device: ragged anchor blobs -> one flat byte
    stream identical to joining the host-decoded pieces."""
    rng = np.random.default_rng(47)
    raws = [(rng.zipf(1.6, n).astype(np.uint64) % 251).astype(np.uint8)
            .tobytes() for n in (100_000, 70_001, 256)]
    raws.append(rng.integers(0, 256, 50_000).astype(np.uint8).tobytes())
    blobs = [rans.compress(r) for r in raws]
    flat = np.asarray(rans.decode_bytes_blocks_device(blobs))
    assert flat.tobytes() == b"".join(raws)


# ----------------------------------------------- symbol-level rANS (NCK3)

def test_symbol_blobs_match_host_oracle():
    """compress_blocks_device_symbols == host compress_symbols per block,
    and both decode back exactly (device and host decoders)."""
    b_bits, be, nblocks, k_eff = 9, 4096, 4, 300
    marker = (1 << b_bits) - 1
    rng = np.random.default_rng(53)
    idx = (rng.zipf(1.3, nblocks * be).astype(np.uint64) % k_eff
           ).astype(np.int32)
    idx[::41] = marker
    counts = np.bincount(np.minimum(idx, k_eff), minlength=k_eff + 1)
    blobs = rans.compress_blocks_device_symbols(
        jnp.asarray(idx), b_bits, k_eff, nblocks, be,
        counts[:k_eff].astype(np.int64))
    freq = rans.symbol_freq(counts[:k_eff].astype(np.int64), k_eff,
                            nblocks * be)
    for k in range(nblocks):
        want = rans.compress_symbols(idx[k * be:(k + 1) * be], b_bits,
                                     freq)
        assert blobs[k] == want, k
        # host decode returns packed bytes -> unpack must equal input
        raw = rans.decompress(blobs[k])
        np.testing.assert_array_equal(
            packing.unpack_indices_np(np.frombuffer(raw, np.uint8), be,
                                      b_bits),
            idx[k * be:(k + 1) * be])
    got = np.asarray(rans.decode_blocks_device(blobs, b_bits, be)
                     ).reshape(-1)
    np.testing.assert_array_equal(got, idx)


def test_symbol_rans_series_round_trip(monkeypatch):
    """symbol_rans=True end to end: bit-identical reconstruction vs the
    byte-level rans chain, and v2 blobs actually in the steps."""
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    series = _series((300_000,), steps=3)
    p_s = NumarckParams(error_bound=1e-3, codec="rans", symbol_rans=True,
                        block_bytes=1 << 14)
    p_b = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=1 << 14)
    steps_s = compress_series(series, p_s)
    assert any(rans.blob_version(b) == 2
               for st in steps_s if not st.is_anchor
               for b in st.index_blocks)
    rec_s = decompress_series(steps_s)
    rec_b = decompress_series(compress_series(series, p_b))
    for a, b in zip(rec_s, rec_b):
        np.testing.assert_array_equal(a, b)


def test_symbol_rans_container_magic_matrix(monkeypatch, tmp_path):
    """NCK1 (uniform codec) / NCK2 (per-block codecs) / NCK3 (symbol
    blobs) stamping, and NCK3 files round-trip through reader + partial
    reads."""
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    series = _series((200_000,), steps=3)
    steps = compress_series(
        series, NumarckParams(error_bound=1e-3, codec="rans",
                              symbol_rans=True, block_bytes=1 << 14))
    path = os.path.join(tmp_path, "s.nck")
    TemporalArchive.write(path, "v", steps, checksums=False)
    with open(path, "rb") as f:
        assert f.read(4) == b"NCK3"
    r = NCKReader(path)
    assert r.format_version == 3
    full = decompress_series(steps)
    arch = TemporalArchive(path)
    for it in range(len(steps)):
        got = arch.read_full("v", it)
        np.testing.assert_array_equal(got, full[it])
        sl = arch.read_range("v", it, 12_345, 99_876)
        np.testing.assert_array_equal(
            sl, full[it].reshape(-1)[12_345:99_876])
    # byte-level rans files never carry v2 blobs -> stay NCK1
    steps_b = compress_series(
        series, NumarckParams(error_bound=1e-3, codec="rans",
                              block_bytes=1 << 14))
    path_b = os.path.join(tmp_path, "b.nck")
    TemporalArchive.write(path_b, "v", steps_b, checksums=False)
    with open(path_b, "rb") as f:
        assert f.read(4) == b"NCK1"


# ------------------------------------------------- device decode routing

def test_decompress_device_route_bit_identical(monkeypatch):
    """Forcing the device decode route changes no byte of the output, and
    the host lane decoder is never called on it (spy)."""
    series = _series((400_000,), steps=3)
    p = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=1 << 16)
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    steps = compress_series(series, p)
    host_recs = decompress_series(steps)     # device route (forced)
    calls = {"n": 0}
    orig = rans.decode_np

    def spy(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(rans, "decode_np", spy)
    dev_recs = decompress_series(steps)
    assert calls["n"] == 0, "device route called host decode_np"
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 1 << 62)  # force host
    host_only = decompress_series(steps)
    assert calls["n"] > 0                    # host route does use it
    for a, b, c in zip(host_recs, dev_recs, host_only):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_read_telemetry_record_keys(monkeypatch):
    """Every decompressed step carries the canonical READ_TELEMETRY_KEYS
    record under an active capture, on both routes."""
    from repro.obs import report, telemetry
    series = _series((300_000,), steps=3)
    p = NumarckParams(error_bound=1e-3, codec="rans", block_bytes=1 << 16)
    for force_device in (True, False):
        monkeypatch.setattr(rans, "DEVICE_MIN_BYTES",
                            0 if force_device else 1 << 62)
        steps = compress_series(series, p)
        with telemetry.capture():
            decompress_series(steps)
        for st in steps:
            if st.is_anchor:
                continue
            rec = st.meta.get("telemetry_read")
            assert rec is not None
            assert tuple(rec) == report.READ_TELEMETRY_KEYS
            assert rec["device_decode"] is force_device


# -------------------------------------------------- satellite: exceptions

def test_exception_compact_matches_host_scan():
    rng = np.random.default_rng(29)
    for n, be in ((10_000, 512), (4096, 4096), (70_001, 2048)):
        b_bits = 8
        marker = (1 << b_bits) - 1
        idx = rng.integers(0, marker + 1, n).astype(np.int32)
        counts, pos = kops.exception_compact(jnp.asarray(idx), n, marker,
                                             be)
        mask = idx == marker
        np.testing.assert_array_equal(pos, np.flatnonzero(mask))
        ref_off = pipe.exception_offsets(mask, be)
        np.testing.assert_array_equal(
            np.concatenate([[0], np.cumsum(counts)])[:-1], ref_off)
    # no exceptions at all
    counts, pos = kops.exception_compact(jnp.zeros(100, jnp.int32), 100,
                                         255, 64)
    assert pos.size == 0 and counts.sum() == 0


def test_finalize_exception_fields_equal_host_path():
    rng = np.random.default_rng(31)
    prev = rng.normal(1, 0.4, 60_000).astype(np.float32)
    curr = (prev * (1 + 0.01 * rng.standard_normal(prev.size))
            ).astype(np.float32)
    curr[::97] *= 25.0
    p = NumarckParams(error_bound=1e-3, block_bytes=4096)
    dev = encode_device(prev, curr, p)
    assert dev.enc.exc_positions is not None
    a = pipe.finalize_step(curr, dev.enc, dev.centers, dev.domain_lo,
                           dev.width, p, dev.meta)
    stripped = dataclasses.replace(dev.enc, exc_positions=None,
                                   exc_block_counts=None)
    b = pipe.finalize_step(curr, stripped, dev.centers, dev.domain_lo,
                           dev.width, p, dev.meta)
    assert a.index_blocks == b.index_blocks
    np.testing.assert_array_equal(a.incomp_values, b.incomp_values)
    np.testing.assert_array_equal(a.incomp_block_offsets,
                                  b.incomp_block_offsets)


# ------------------------------------------- satellite: vectorized packer

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=20_000),
       st.sampled_from([1, 4, 8, 9, 12, 16]))
def test_pack_blocks_host_matches_per_block_loop(n, b_bits):
    rng = np.random.default_rng(n * 31 + b_bits)
    idx = rng.integers(0, 1 << b_bits, n).astype(np.int32)
    be = 32 * max(1, (n // 3) // 32)
    got = pipe.pack_blocks_host(idx, b_bits, be)
    # the pre-vectorization reference: marker-pad + pack one block at a time
    marker = (1 << b_bits) - 1
    want = []
    for s in range(0, n, be):
        chunk = idx[s:s + be]
        if chunk.size < be:
            chunk = np.concatenate(
                [chunk, np.full(be - chunk.size, marker, idx.dtype)])
        want.append(packing.pack_indices_np(chunk, b_bits).tobytes())
    assert got == want


# --------------------------------------------------- satellite: meta keys

def test_entropy_ratio_meta_key_and_alias():
    series = _series((96, 40))
    for codec in ("zlib", "raw", "rans"):
        st_ = compress_step(series[0], series[1],
                            NumarckParams(error_bound=1e-3, codec=codec,
                                          block_bytes=4096))
        assert st_.meta["entropy_codec"] == codec
        # The deprecated "zlib_ratio" alias still carries the same value
        # (read through dict to avoid tripping StepMeta's one-time
        # DeprecationWarning; the alias itself is tested in test_obs.py).
        assert (st_.meta["entropy_ratio"]
                == dict.__getitem__(st_.meta, "zlib_ratio"))
        if codec == "raw":
            assert abs(st_.meta["entropy_ratio"] - 1.0) < 1e-9
