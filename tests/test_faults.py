"""Fault-tolerance tier: corruption fuzz over every container format,
the fault-injection registry, and the self-healing manifest commit.

The fuzz oracle is the PR-10 integrity contract: a single-byte flip or a
truncation of a persisted artifact must surface as a *structured*
IntegrityError -- never a silent wrong decode, never a raw traceback
from json/struct/zlib internals.  For NCK4 the must-raise region is
everything the checksum frame covers ("crc32" whole-variable digests,
"block_crc32" per-block digests, the header crc): the magic/length/crc
prefix, the JSON header and its pad, and every variable payload.  Flips
in inter-section alignment pad are outside any digest and are allowed to
either raise or decode byte-identically -- what is forbidden, always, is
a *different* decode.  Legacy NCK1/2/3 files carry no payload digests,
so only their structural guarantees (prefix sanity, extent-vs-file-size)
are fuzzed.  NCKM manifests are covered end to end by the schema-2
trailer: every flip and every truncation must raise.
"""
import json
import os
import struct
import subprocess
import tempfile
import textwrap
import threading
import time
import zlib

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # hermetic CI image: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import NumarckParams, compress_series, decompress_series
from repro.core import container, entropy
from repro.core.compress import decode_anchor
from repro.core.container import (NCKReader, NCKWriter, ShardNCKWriter,
                                  atomic_commit, rank_file_path,
                                  read_manifest, verify_nck)
from repro.core.overlap import FinalizeQueue
from repro.core.partial import TemporalArchive
from repro.faults import (Backoff, CommitTimeoutError, CorruptBlockError,
                          CorruptShardError, InjectedFault, IntegrityError)
from repro.faults import inject
from repro.launch import distributed as dist
from repro.launch.distributed import spawn_emulated

from test_multiprocess import (_anchor_fragments, _make_series_src,
                               _write_logical)

_SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                    "src"))


@pytest.fixture(autouse=True)
def _no_fault_plan_leaks():
    """Every test leaves the process fault plan cleared."""
    yield
    inject.reset()


# ------------------------------------------------------------ fuzz corpus

_CASES = {}


def _steps():
    """Small real series (anchor + delta) with several index blocks."""
    if "steps" not in _CASES:
        rng = np.random.default_rng(11)
        n = 8192
        a = rng.normal(1.0, 0.5, n).astype(np.float32)
        b = (a * (1 + 0.01 * rng.standard_normal(n))).astype(np.float32)
        b[::701] *= 30.0                     # some incompressible outliers
        _CASES["steps"] = compress_series(
            [a, b], NumarckParams(error_bound=1e-3, block_bytes=1024))
    return _CASES["steps"]


def _write_steps(path, *, checksums=True, version=None):
    w = NCKWriter(checksums=checksums)
    for i, s in enumerate(_steps()):
        w.add_step(TemporalArchive.step_name("temp", i), s)
    if version is not None:
        w.bump_format(version)
    w.write(path)


def _read_all(path):
    r = NCKReader(path)
    return decompress_series([r.read_step(nm) for nm in r.step_names()])


def _case(version):
    """(raw_bytes, clean_decode) for one container version (4 = framed)."""
    key = f"v{version}"
    if key not in _CASES:
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "a.nck")
            if version == 4:
                _write_steps(p)
            else:
                _write_steps(p, checksums=False, version=version)
            raw = open(p, "rb").read()
            clean = _read_all(p)
        magic = {1: b"NCK1", 2: b"NCK2", 3: b"NCK3", 4: b"NCK4"}[version]
        assert raw[:4] == magic
        _CASES[key] = (raw, clean)
    return _CASES[key]


def _layout(raw):
    """(data_start, variables) parsed straight off the bytes."""
    version = {b"NCK1": 1, b"NCK2": 2, b"NCK3": 3, b"NCK4": 4}[bytes(raw[:4])]
    prefix = 16 if version >= 4 else 12
    (hlen,) = struct.unpack("<Q", raw[4:12])
    data_start = prefix + hlen + (-(prefix + hlen)) % 64
    header = json.loads(raw[prefix:prefix + hlen])
    return data_start, header["variables"]


def _structural_end(raw):
    data_start, variables = _layout(raw)
    return data_start + max(int(v["offset"]) + int(v["nbytes"])
                            for v in variables.values())


def _in_covered_region(raw, pos):
    """Is byte `pos` under a digest in an NCK4 file (prefix + header +
    header pad + any variable payload)?"""
    data_start, variables = _layout(raw)
    if pos < data_start:
        return True
    return any(data_start + int(v["offset"]) <= pos
               < data_start + int(v["offset"]) + int(v["nbytes"])
               for v in variables.values())


def _expect_structured(mutated, clean, must_raise):
    """The fuzz oracle: mutated bytes either raise IntegrityError on the
    full read path or decode byte-identically to the clean file."""
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "m.nck")
        with open(p, "wb") as f:
            f.write(mutated)
        try:
            verify_nck(p)
            out = _read_all(p)
        except IntegrityError:
            return
        assert not must_raise, \
            "digest-covered corruption was read back without an error"
        for got, want in zip(out, clean):
            np.testing.assert_array_equal(got, want)


def _flip_var_payload(path, var, where=0.5):
    """Flip one bit inside variable `var`'s payload; returns the offset."""
    raw = bytearray(open(path, "rb").read())
    data_start, variables = _layout(raw)
    v = variables[var]
    off = data_start + int(v["offset"]) + min(int(v["nbytes"] * where),
                                              int(v["nbytes"]) - 1)
    raw[off] ^= 0x01
    with open(path, "wb") as f:
        f.write(bytes(raw))
    return off


# -------------------------------------------------- checksum frame basics

def test_writer_stamps_checksum_frame(tmp_path):
    p = str(tmp_path / "a.nck")
    _write_steps(p)
    assert open(p, "rb").read(4) == b"NCK4"
    verify_nck(p)
    r = NCKReader(p)
    anchor = r.variables["temp_it00000_anchor"]
    assert "crc32" in anchor and "block_crc32" in anchor
    assert len(anchor["block_crc32"]) \
        == r.attrs("temp_it00000_anchor_info")["n_blocks"]
    delta = r.variables["temp_it00001_index_table"]
    assert "crc32" in delta and "block_crc32" in delta
    # unblocked variables get the whole-payload digest only
    centers = r.variables["temp_it00001_bin_centers"]
    assert "crc32" in centers and "block_crc32" not in centers


def test_checksums_off_restores_legacy_magic(tmp_path):
    p = str(tmp_path / "a.nck")
    _write_steps(p, checksums=False)
    assert open(p, "rb").read(4) == b"NCK1"
    r = NCKReader(p)
    assert "crc32" not in r.variables["temp_it00000_anchor"]
    for got, want in zip(_read_all(p), decompress_series(_steps())):
        np.testing.assert_array_equal(got, want)


def test_error_taxonomy():
    e = CorruptBlockError("/f.nck", "temp_anchor", 3, 0x11, 0x22)
    assert isinstance(e, ValueError) and e.block == 3
    assert "block 3" in str(e) and "0x00000011" in str(e)
    s = CorruptShardError("/m.nck", "m.g0001.rank1", 1, "torn")
    assert isinstance(s, IntegrityError)
    assert "rank 1" in str(s) and "torn" in str(s)
    c = CommitTimeoutError("deadline", {"missing_ranks": [2],
                                        "quarantined": ["x.quarantine"]})
    assert isinstance(c, TimeoutError)
    assert c.missing_ranks == [2] and c.quarantined == ["x.quarantine"]
    i = InjectedFault("rank_crash", "step=3")
    assert isinstance(i, RuntimeError) and "rank_crash" in str(i)


# ------------------------------------------------------- corruption fuzz

@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=1 << 30),
       st.integers(min_value=0, max_value=7))
def test_nck4_bit_flips_never_decode_silently(pos_seed, bit):
    raw, clean = _case(4)
    pos = pos_seed % len(raw)
    mutated = bytearray(raw)
    mutated[pos] ^= 1 << bit
    _expect_structured(bytes(mutated), clean,
                       must_raise=_in_covered_region(raw, pos))


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=1 << 30))
def test_nck4_truncations_never_decode_silently(cut_seed):
    raw, clean = _case(4)
    cut = cut_seed % len(raw)
    _expect_structured(raw[:cut], clean,
                       must_raise=cut < _structural_end(raw))


def test_nck4_targeted_flip_sweep():
    """Deterministic complement to the fuzz: one flip in every region of
    the layout (magic, length, header crc, header JSON, header pad, and
    the first/middle/last byte of every variable payload)."""
    raw, clean = _case(4)
    data_start, variables = _layout(raw)
    positions = [0, 3, 5, 13, 20, data_start - 1]
    for v in variables.values():
        o, n = data_start + int(v["offset"]), int(v["nbytes"])
        if n:
            positions += [o, o + n // 2, o + n - 1]
    for pos in positions:
        mutated = bytearray(raw)
        mutated[pos] ^= 0x01
        _expect_structured(bytes(mutated), clean,
                           must_raise=_in_covered_region(raw, pos))


@pytest.mark.parametrize("version", [1, 2, 3])
def test_legacy_prefix_flips_and_truncations(version):
    raw, clean = _case(version)
    # clean file still loads on the current reader (back-compat matrix)
    _expect_structured(raw, clean, must_raise=False)
    # prefix flips: structured error or an identical decode, never junk
    for pos in range(12):
        for bit in (0, 3, 7):
            mutated = bytearray(raw)
            mutated[pos] ^= 1 << bit
            _expect_structured(bytes(mutated), clean, must_raise=False)
    # truncating below the structural extent must always raise
    end = _structural_end(raw)
    for cut in (3, 11, 12, len(raw) // 3, len(raw) // 2, end - 1):
        _expect_structured(raw[:cut], clean, must_raise=cut < end)


def test_manifest_every_flip_and_truncation_raises(tmp_path):
    """The schema-2 trailer covers the whole NCKM byte string: exhaustive
    single-bit flips at every offset, and every truncation length, must
    raise a structured error through NCKReader."""
    path = str(tmp_path / "series.nck")
    _write_logical(path, np.arange(200, dtype=np.float32), 2)
    raw = open(path, "rb").read()
    mpath = str(tmp_path / "mut.nck")     # same dir: rank files resolve
    for pos in range(len(raw)):
        mutated = bytearray(raw)
        mutated[pos] ^= 0x01
        with open(mpath, "wb") as f:
            f.write(bytes(mutated))
        with pytest.raises(IntegrityError):
            NCKReader(mpath)
    for cut in range(len(raw)):
        with open(mpath, "wb") as f:
            f.write(raw[:cut])
        with pytest.raises(IntegrityError):
            NCKReader(mpath)


# ----------------------------------------------------- partial-read path

def test_partial_read_verifies_only_touched_blocks(tmp_path):
    p = str(tmp_path / "a.nck")
    _write_steps(p)
    info = NCKReader(p).attrs("temp_it00001_info")
    eb, n = info["elements_per_block"], info["total_data_num"]
    assert n > 2 * eb, "fuzz corpus must span multiple index blocks"
    clean_tail = TemporalArchive(p).read_range("temp", 1, n - 4, n)
    _flip_var_payload(p, "temp_it00001_index_table", where=0.0)
    arch = TemporalArchive(p)
    with pytest.raises(CorruptBlockError) as ei:
        arch.read_range("temp", 1, 0, min(eb, 64))
    assert ei.value.block == 0
    assert "block 0" in str(ei.value)
    # a range over the undamaged last block still reads (and matches)
    np.testing.assert_array_equal(
        TemporalArchive(p).read_range("temp", 1, n - 4, n), clean_tail)


def test_anchor_partial_read_detects_flip(tmp_path):
    p = str(tmp_path / "a.nck")
    _write_steps(p)
    _flip_var_payload(p, "temp_it00000_anchor", where=0.0)
    with pytest.raises(CorruptBlockError):
        TemporalArchive(p).read_range("temp", 0, 0, 32)


# ------------------------------------------------------ sharded read path

def test_bitflipped_shard_raises_corrupt_shard_error(tmp_path):
    path = str(tmp_path / "s.nck")
    _write_logical(path, np.arange(256, dtype=np.float32), 2)
    victim = rank_file_path(path, 0, 1)
    raw = bytearray(open(victim, "rb").read())
    raw[len(raw) // 2] ^= 0x01            # whole-file crc covers pad too
    with open(victim, "wb") as f:
        f.write(bytes(raw))
    with pytest.raises(CorruptShardError) as ei:
        NCKReader(path)
    assert ei.value.rank == 1
    assert os.path.basename(victim) in str(ei.value)


def test_reader_falls_back_to_previous_generation(tmp_path):
    path = str(tmp_path / "s.nck")
    arr = np.arange(128, dtype=np.float32)
    _write_logical(path, arr, 2)                    # generation 0
    _write_logical(path, arr * 2, 2)                # generation 1
    victim = rank_file_path(path, 1, 1)
    raw = bytearray(open(victim, "rb").read())
    raw[len(raw) // 2] ^= 0x01
    with open(victim, "wb") as f:
        f.write(bytes(raw))
    r = NCKReader(path)
    assert r.recovered_generation == 0
    assert isinstance(r.fallback_cause, CorruptShardError)
    np.testing.assert_array_equal(decode_anchor(r.read_step("step0000")),
                                  arr)
    os.remove(victim)                               # deletion: same path
    r2 = NCKReader(path)
    assert r2.recovered_generation == 0
    assert isinstance(r2.fallback_cause, FileNotFoundError)


def test_no_previous_generation_reraises(tmp_path):
    path = str(tmp_path / "s.nck")
    _write_logical(path, np.arange(64, dtype=np.float32), 2)
    os.remove(rank_file_path(path, 0, 1))
    with pytest.raises(FileNotFoundError):
        NCKReader(path)


# -------------------------------------------- self-healing manifest commit

def test_commit_timeout_quarantines_and_reports(tmp_path):
    path = str(tmp_path / "s.nck")
    arr = np.arange(96, dtype=np.float32)
    _write_logical(path, arr, 2)                    # generation 0 durable
    manifest_raw = open(path, "rb").read()
    frags = _anchor_fragments(arr, 2)
    writers = []
    for rank in range(2):
        w = ShardNCKWriter(path, rank, 2)
        w.add_fragment("step0000", frags[rank])
        w.write()
        writers.append(w)
    victim = writers[1].rank_path
    _flip_var_payload(victim, "step0000_frag_index_table")
    with pytest.raises(CommitTimeoutError, match="previous manifest") as ei:
        writers[0].commit_manifest(timeout=0.6)
    e = ei.value
    assert e.missing_ranks == [1]
    assert e.report["rolled_back_to"] == 0
    assert e.report["generation"] == 1
    assert e.quarantined == [os.path.basename(victim) + ".quarantine"]
    assert os.path.exists(victim + ".quarantine")
    assert not os.path.exists(victim)
    assert "crc32" in e.report["quarantine_detail"][0]["error"]
    # the previous manifest is byte-identical and still decodes
    assert open(path, "rb").read() == manifest_raw
    np.testing.assert_array_equal(
        decode_anchor(NCKReader(path).read_step("step0000")), arr)


def test_commit_converges_when_good_shard_republished(tmp_path):
    path = str(tmp_path / "s.nck")
    arr = np.arange(96, dtype=np.float32)
    _write_logical(path, arr, 2)                    # generation 0
    frags = _anchor_fragments(arr * 2, 2)
    w0 = ShardNCKWriter(path, 0, 2)
    w0.add_fragment("step0000", frags[0])
    w0.write()
    w1 = ShardNCKWriter(path, 1, 2)
    w1.add_fragment("step0000", frags[1])
    w1.write()
    _flip_var_payload(w1.rank_path, "step0000_frag_index_table")

    def heal():
        time.sleep(0.4)                 # after the first quarantine pass
        w = ShardNCKWriter(path, 1, 2)
        w.add_fragment("step0000", frags[1])
        w.write()

    t = threading.Thread(target=heal)
    t.start()
    try:
        out = w0.commit_manifest(timeout=30.0)
    finally:
        t.join()
    assert out == path
    m = read_manifest(path)
    assert m["generation"] == 1 and m["previous"]["generation"] == 0
    assert any(".quarantine" in f for f in os.listdir(tmp_path))
    np.testing.assert_array_equal(
        decode_anchor(NCKReader(path).read_step("step0000")), arr * 2)


# --------------------------------------------------- fault-injection plan

def test_fault_spec_parsing_and_rank_matching(monkeypatch):
    plan = inject.FaultPlan("straggler@1=0.5*3, torn_shard=64")
    assert [e.site for e in plan.entries] == ["straggler", "torn_shard"]
    assert plan.entries[0].rank == 1
    assert plan.entries[0].value == 0.5
    assert plan.entries[0].remaining == 3
    with pytest.raises(ValueError, match="unknown fault site"):
        inject.FaultPlan("disk_melt")
    assert inject.configure("") is None and not inject.enabled()

    inject.configure("rank_crash@1")
    monkeypatch.setenv("REPRO_PROCESS_ID", "0")
    inject.fire("rank_crash")                     # other rank: no-op
    monkeypatch.setenv("REPRO_PROCESS_ID", "1")
    with pytest.raises(InjectedFault, match="rank_crash"):
        inject.fire("rank_crash", step=3)
    assert inject.plan().fired[0]["site"] == "rank_crash"
    inject.fire("rank_crash")                     # count=1: exhausted


def test_disabled_plan_is_noop(tmp_path):
    inject.reset()
    inject.fire("rank_crash")
    p = str(tmp_path / "x.g0000.rank0")
    atomic_commit(p, b"A" * 16)
    assert open(p, "rb").read() == b"A" * 16


def test_straggler_sleeps():
    inject.configure("straggler=0.15")
    t0 = time.monotonic()
    inject.fire("straggler")
    assert time.monotonic() - t0 >= 0.14
    inject.fire("straggler")                      # exhausted: instant


def test_fsync_and_rename_injection_preserve_target(tmp_path):
    p = str(tmp_path / "out.bin")
    atomic_commit(p, b"v1")
    for site in ("fsync_fail", "rename_fail"):
        inject.configure(site)
        with pytest.raises(OSError, match=f"injected {site}"):
            atomic_commit(p, b"v2")
        assert open(p, "rb").read() == b"v1"
    inject.reset()
    atomic_commit(p, b"v2")
    assert open(p, "rb").read() == b"v2"


def test_shard_mangling_only_touches_rank_files(tmp_path):
    inject.configure("torn_shard=5")
    mpath = str(tmp_path / "series.nck")          # manifests never mangled
    atomic_commit(mpath, b"A" * 32)
    assert os.path.getsize(mpath) == 32
    spath = str(tmp_path / "series.nck.g0000.rank1")
    atomic_commit(spath, b"B" * 32)
    assert os.path.getsize(spath) == 27
    inject.configure("bitflip_shard=3")
    atomic_commit(spath, b"C" * 8)
    raw = open(spath, "rb").read()
    assert raw[3] == ord("C") ^ 0x01 and raw[:3] == b"CCC"


def test_injected_torn_shard_is_caught_by_verification(tmp_path):
    """End to end: a torn publish that rode the atomic rename is exactly
    what verify_nck + the manifest scan must catch."""
    path = str(tmp_path / "s.nck")
    arr = np.arange(64, dtype=np.float32)
    frags = _anchor_fragments(arr, 1)
    inject.configure("torn_shard=16")
    w = ShardNCKWriter(path, 0, 1)
    w.add_fragment("step0000", frags[0])
    w.write()
    with pytest.raises(IntegrityError):
        verify_nck(w.rank_path)
    with pytest.raises(CommitTimeoutError) as ei:
        w.commit_manifest(timeout=0.5)
    assert ei.value.report["rolled_back_to"] is None
    assert len(ei.value.quarantined) == 1


def test_entropy_worker_death_site_and_structured_decode_errors():
    inject.configure("entropy_worker_death")
    with pytest.raises(InjectedFault, match="entropy_worker_death"):
        entropy._compress_batch("zlib", [b"x" * 32], 6)
    blob = entropy._compress_batch("zlib", [b"x" * 32], 6)[0]  # exhausted
    assert entropy.decompress_block(blob, "zlib") == b"x" * 32
    bad = bytearray(blob)
    bad[len(bad) // 2] ^= 0xFF
    with pytest.raises(IntegrityError, match="entropy decode failed"):
        entropy.decompress_block(bytes(bad), "zlib")


# ------------------------------------------------- wedged-worker timeout

def test_finalize_queue_times_out_and_retires_wedged_worker():
    q = FinalizeQueue(overlap=True, name="enc", timeout=0.3)
    gate = threading.Event()
    q.submit(gate.wait, label="finalize step 7")
    try:
        with pytest.raises(TimeoutError,
                           match=r"label=finalize step 7.*retired"):
            q.flush()
    finally:
        gate.set()                    # release the abandoned thread
    # the queue is usable again on a fresh worker
    assert q.submit(lambda: 42, label="next").result(timeout=10) == 42
    q.flush()


def test_finalize_queue_default_timeout_unchanged():
    q = FinalizeQueue(overlap=True, name="enc")
    f = q.submit(lambda: "ok")
    q.flush()
    assert f.result() == "ok"


# ---------------------------------------------------- spawn bind-race fix

def _proc(rc, stderr=""):
    return subprocess.CompletedProcess([], rc, "", stderr)


def test_coordinator_bind_failure_detection():
    assert dist._coordinator_bind_failed(
        [_proc(0), _proc(1, "E0809 ... Address already in use")])
    assert dist._coordinator_bind_failed([_proc(1, "EADDRINUSE: nope")])
    assert not dist._coordinator_bind_failed([_proc(0), _proc(0)])
    assert not dist._coordinator_bind_failed(
        [_proc(3, "Traceback ... InjectedFault: rank_crash")])
    # a *succeeding* rank mentioning the marker does not count
    assert not dist._coordinator_bind_failed(
        [_proc(0, "address already in use")])


def test_spawn_emulated_retries_fresh_port_on_bind_race(monkeypatch):
    calls = []

    def fake_spawn_once(n, argv, coordinator, dpp, base_env, preset,
                        timeout):
        calls.append(coordinator)
        if len(calls) == 1:
            return [_proc(1, "failed to bind to coordinator address")]
        return [_proc(0)]

    monkeypatch.setattr(dist, "_spawn_once", fake_spawn_once)
    res = spawn_emulated(1, ["-c", "pass"], timeout=5)
    assert [r.returncode for r in res] == [0]
    assert len(calls) == 2 and calls[0] != calls[1]


def test_spawn_emulated_bind_retry_is_bounded(monkeypatch):
    calls = []

    def always_bind_fail(n, argv, coordinator, dpp, base_env, preset,
                         timeout):
        calls.append(coordinator)
        return [_proc(1, "Address already in use")]

    monkeypatch.setattr(dist, "_spawn_once", always_bind_fail)
    res = spawn_emulated(1, ["-c", "pass"], timeout=5, bind_attempts=3)
    assert len(calls) == 3                        # bounded, then reported
    assert res[0].returncode == 1


def test_spawn_emulated_does_not_retry_worker_crashes(monkeypatch):
    calls = []

    def crash(n, argv, coordinator, dpp, base_env, preset, timeout):
        calls.append(coordinator)
        return [_proc(3, "Traceback: ValueError: boom")]

    monkeypatch.setattr(dist, "_spawn_once", crash)
    res = spawn_emulated(1, ["-c", "pass"], timeout=5)
    assert len(calls) == 1 and res[0].returncode == 3


# ------------------------------------------------ restore walks back past

def test_checkpoint_restore_walks_back_and_reports(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    tree1 = {"w": np.arange(64, dtype=np.float32)}
    tree2 = {"w": np.arange(64, dtype=np.float32) * 2}
    mgr.save(1, tree1)
    mgr.save(2, tree2)
    mgr.wait()
    victim = mgr._step_path(2)
    raw = open(victim, "rb").read()
    _, variables = _layout(raw)
    var = max(variables, key=lambda v: variables[v]["nbytes"])
    _flip_var_payload(victim, var)
    mgr2 = CheckpointManager(str(tmp_path))
    step, tree = mgr2.restore_latest()
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), tree1["w"])
    assert [r["step"] for r in mgr2.last_restore_report] == [2]
    assert "Error" in mgr2.last_restore_report[0]["error"]


def test_serve_snapshot_corruption_refuses_restore(tmp_path):
    from repro.serve.engine import load_cache, snapshot_cache
    cache = {"layer0": {"k": np.arange(96, dtype=np.float32)}}
    p = str(tmp_path / "cache.nck")
    snapshot_cache(cache, p)
    back = load_cache(p)
    np.testing.assert_array_equal(back["layer0"]["k"],
                                  cache["layer0"]["k"])
    _flip_var_payload(p, "c0000_anchor", where=0.0)
    with pytest.raises(IntegrityError):
        load_cache(p)


# ----------------------------------------------------------- backoff unit

def test_backoff_delays_bounded_and_capped():
    ds = list(Backoff(attempts=6, base=0.05, factor=2.0, cap=0.4,
                      jitter=0.0).delays())
    assert len(ds) == 6
    assert ds[0] == pytest.approx(0.05) and ds[1] == pytest.approx(0.1)
    assert max(ds) <= 0.4 and ds[-1] == pytest.approx(0.4)
    j1 = list(Backoff(attempts=4, jitter=0.25, seed=7).delays())
    j2 = list(Backoff(attempts=4, jitter=0.25, seed=7).delays())
    assert j1 == j2                               # reproducible schedule
    for base, d in zip(Backoff(attempts=4, jitter=0.0).delays(), j1):
        assert base <= d <= base * 1.25


def test_backoff_sleep_until_respects_deadline():
    deadline = time.monotonic() + 0.12
    n = 0
    for d in Backoff(base=0.02, jitter=0.0).repolling() \
            .sleep_until(deadline):
        assert d <= deadline - time.monotonic() + 1e-3
        time.sleep(d)
        n += 1
        assert n < 100                            # deadline bounds the loop
    assert time.monotonic() >= deadline - 0.03
    assert n >= 2                                 # still polled repeatedly


# -------------------------------------------------- injected fleet (slow)

_FAULT_WORKER = textwrap.dedent("""
    import os, sys
    import numpy as np
    from repro.launch import distributed as dist
    cfg = dist.initialize()
    mesh = dist.global_mesh()
    from repro.core import NumarckParams
    from repro.distributed.pipeline import MultiProcessCompressor
    from repro.faults import CommitTimeoutError
    from repro.faults import inject
    {series_src}
    mp = MultiProcessCompressor(mesh, params=NumarckParams(
        error_bound=1e-3), use_pallas=False)
    out_path = os.environ["OUT_PATH"]
    # generation 0: rank 1 straggles mid-encode; the bounded commit poll
    # absorbs it and the fleet converges
    if cfg.process_id == 1:
        inject.configure("straggler=0.8")
    mp.save_series(out_path, series, manifest_timeout=60)
    print("GEN0_OK")
    # generation 1: rank 1 publishes a torn shard -- rank 0 quarantines
    # it, times out, and generation 0 stays durable
    if cfg.process_id == 1:
        inject.configure("torn_shard=1000000")
    try:
        mp.save_series(out_path, [s * 2 for s in series],
                       manifest_timeout=4)
        if cfg.process_id == 0:
            raise SystemExit("torn-shard commit unexpectedly succeeded")
        print("GEN1_SHARD_PUBLISHED")
    except CommitTimeoutError as e:
        assert cfg.process_id == 0, e
        assert e.report["missing_ranks"] == [1], e.report
        assert e.report["rolled_back_to"] == 0, e.report
        assert len(e.report["quarantined"]) == 1, e.report
        print("ROLLBACK_OK", e.report["quarantined"][0])
        print("ERR:", type(e).__name__, e, file=sys.stderr)
    mp.close()
    print("WORKER_DONE")
""")


@pytest.mark.slow
def test_fleet_straggler_converges_and_torn_shard_rolls_back(tmp_path):
    path = str(tmp_path / "series.nck")
    env = dict(os.environ)
    env["OUT_PATH"] = path
    env["PYTHONPATH"] = _SRC
    script = _FAULT_WORKER.format(
        series_src=_make_series_src(n=20_011, steps=2))
    res = spawn_emulated(2, ["-c", script], base_env=env, timeout=300)
    for rank, r in enumerate(res):
        assert r.returncode == 0, f"rank {rank}:\n{r.stdout}\n{r.stderr}"
        assert "GEN0_OK" in r.stdout
        assert "WORKER_DONE" in r.stdout
    assert "ROLLBACK_OK" in res[0].stdout
    assert "TimeoutError" in res[0].stderr        # structured, in the log
    assert "GEN1_SHARD_PUBLISHED" in res[1].stdout
    m = read_manifest(path)
    assert m["generation"] == 0                   # gen 1 never committed
    quar = [f for f in os.listdir(tmp_path) if ".quarantine" in f]
    assert len(quar) == 1 and ".g0001.rank1" in quar[0]
    # generation 0 still decodes to the worker's deterministic series
    ns = {}
    exec(_make_series_src(n=20_011, steps=2), ns)  # noqa: S102 -- test data
    r = NCKReader(path)
    step0 = r.read_step(r.step_names()[0])
    assert step0.is_anchor
    np.testing.assert_array_equal(decode_anchor(step0), ns["series"][0])
