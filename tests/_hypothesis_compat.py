"""Deterministic fallback for `hypothesis` when it is not installed.

The container image has no `hypothesis`; rather than losing every test in
a module to a collection error, test files import through:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

The shim replays each property over a bounded set of examples drawn from
a per-test seeded RNG, with boundary values (min/max/zero, min/max sizes)
issued first.  No shrinking, no database -- just deterministic coverage so
the suite keeps its signal.  Installing the real hypothesis
(requirements-dev.txt) upgrades these tests in place.
"""
from __future__ import annotations

import zlib as _zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    """A strategy is a function (rng, example_index) -> value."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng, i):
        return self._draw(rng, i)

    def map(self, f):
        return _Strategy(lambda rng, i: f(self._draw(rng, i)))


class _St:
    @staticmethod
    def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
               allow_infinity=False, width=64):
        cast = np.float32 if width == 32 else np.float64
        bounds = [cast(min_value), cast(max_value), cast(0.0)]

        def draw(rng, i):
            if i < len(bounds):
                v = bounds[i]
            else:
                v = cast(rng.uniform(min_value, max_value))
            return float(np.clip(v, min_value, max_value))
        return _Strategy(draw)

    @staticmethod
    def integers(min_value=0, max_value=1 << 30):
        bounds = [min_value, max_value]

        def draw(rng, i):
            if i < len(bounds):
                return bounds[i]
            return int(rng.integers(min_value, max_value + 1))
        return _Strategy(draw)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)

        def draw(rng, i):
            if i < len(seq):
                return seq[i]
            return seq[int(rng.integers(len(seq)))]
        return _Strategy(draw)

    @staticmethod
    def lists(elem, min_size=0, max_size=10):
        def draw(rng, i):
            if i == 0:
                size = min_size
            elif i == 1:
                size = max_size
            else:
                size = int(rng.integers(min_size, max_size + 1))
            return [elem.example(rng, int(rng.integers(1 << 16)))
                    for _ in range(size)]
        return _Strategy(draw)


st = _St()


def settings(max_examples=None, deadline=None, **_ignored):
    def deco(fn):
        if max_examples is not None:
            fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        # NOTE: deliberately no functools.wraps -- pytest would follow
        # __wrapped__ to the inner signature and demand fixtures for the
        # property arguments.  The wrapper must look zero-argument.
        def wrapper():
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES),
                    _DEFAULT_MAX_EXAMPLES)
            seed = _zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(n):
                fn(*(s.example(rng, i) for s in strategies))
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


__all__ = ["given", "settings", "st"]
