"""NCK container format: round trips, multi-variable files, offsets."""
import numpy as np
import pytest

from repro.core import (NCKReader, NCKWriter, NumarckParams, compress_step,
                        decompress_step, make_anchor)
from repro.core.compress import decode_anchor
from repro.core.types import NumarckParams as NP


def test_raw_array_roundtrip(tmp_path):
    w = NCKWriter()
    a = np.random.default_rng(0).normal(size=(17, 5)).astype(np.float32)
    b = np.arange(100, dtype=np.int64)
    w.add_array("a", a, attrs={"unit": "m/s"})
    w.add_array("b", b)
    w.add_bytes("blob", b"hello world")
    path = str(tmp_path / "t.nck")
    w.write(path)
    r = NCKReader(path)
    np.testing.assert_array_equal(r.read_array("a"), a)
    np.testing.assert_array_equal(r.read_array("b"), b)
    assert r.read("blob") == b"hello world"
    assert r.attrs("a")["unit"] == "m/s"


def test_compressed_step_roundtrip_and_offsets(tmp_path):
    rng = np.random.default_rng(1)
    prev = rng.normal(1, 0.4, 9001).astype(np.float32)
    curr = (prev * (1 + 0.01 * rng.standard_normal(9001))).astype(
        np.float32)
    p = NumarckParams(error_bound=1e-3, block_bytes=512)
    st = compress_step(prev, curr, p)
    w = NCKWriter()
    w.add_step("UU", st)
    path = str(tmp_path / "s.nck")
    w.write(path)
    r = NCKReader(path)
    # paper Fig. 2 variable set exists
    for suffix in ("info", "bin_centers", "index_table_offset",
                   "incompressible_table_offset", "index_table",
                   "incompressible_table"):
        assert f"UU_{suffix}" in r.variables, suffix
    st2 = r.read_step("UU")
    np.testing.assert_array_equal(decompress_step(st2, prev),
                                  decompress_step(st, prev))
    info = r.attrs("UU_info")
    assert info["total_data_num"] == 9001
    assert info["B"] == st.b_bits
    # byte offsets partition the index table exactly
    offs = r.read_array("UU_index_table_offset")
    assert offs[0] == 0 and offs[-1] == len(r.read("UU_index_table"))
    assert (np.diff(offs) > 0).all()


def test_anchor_roundtrip_via_container(tmp_path):
    arr = np.random.default_rng(2).normal(size=(40, 11)).astype(np.float64)
    st = make_anchor(arr, NumarckParams(block_bytes=1024))
    w = NCKWriter()
    w.add_step("X", st)
    path = str(tmp_path / "a.nck")
    w.write(path)
    st2 = NCKReader(path).read_step("X")
    np.testing.assert_array_equal(decode_anchor(st2), arr)


def test_multiple_variables_per_file(tmp_path):
    """Paper: 'NUMARCK allows multiple compressed variables stored in one
    netCDF file'."""
    rng = np.random.default_rng(3)
    w = NCKWriter()
    originals = {}
    prevs = {}
    for name in ("UU", "VV", "dens"):
        prev = rng.normal(1, 0.3, 4096).astype(np.float32)
        curr = (prev * (1 + 0.005 * rng.standard_normal(4096))).astype(
            np.float32)
        st = compress_step(prev, curr, NumarckParams(error_bound=1e-3,
                                                     block_bytes=512))
        w.add_step(name, st)
        originals[name], prevs[name] = curr, prev
    path = str(tmp_path / "multi.nck")
    w.write(path)
    r = NCKReader(path)
    assert set(r.step_names()) == {"UU", "VV", "dens"}
    for name in r.step_names():
        rec = decompress_step(r.read_step(name), prevs[name])
        me = np.mean(np.abs((rec - originals[name])
                            / np.maximum(np.abs(originals[name]), 1e-30)))
        assert me <= 1.01e-3


def test_params_json_roundtrip():
    p = NP(error_bound=5e-4, b_bits=9, strategy="log", block_bytes=4096)
    assert NP.from_json(p.to_json()) == p


def test_duplicate_variable_rejected():
    w = NCKWriter()
    w.add_array("x", np.zeros(3))
    with pytest.raises(ValueError):
        w.add_array("x", np.zeros(3))
