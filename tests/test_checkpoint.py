"""Checkpoint manager: anchor+delta round trip, fault tolerance, retention,
async save, elastic template restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import NumarckParams


def _fake_state(key, scale=1.0):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "params": {
            "w1": jax.random.normal(k1, (64, 128)) * scale,
            "norm": {"scale": jnp.ones((128,))},
        },
        "opt": {
            "m": jax.random.normal(k2, (64, 128)) * 0.01 * scale,
            "step": jnp.int32(7),
        },
        "big": jax.random.normal(k3, (100, 101)) * scale,
    }


def _evolve(state, rng):
    """Small multiplicative drift -- mimics optimizer steps."""
    def f(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x * (1 + 0.01 * rng.standard_normal(x.shape)
                        ).astype(x.dtype)
        return x
    return jax.tree.map(f, state)


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), anchor_every=3, keep=10,
                            params=NumarckParams(error_bound=1e-3,
                                                 block_bytes=4096))
    rng = np.random.default_rng(0)
    state = _fake_state(jax.random.PRNGKey(0))
    saved = []
    for step in range(6):
        stats = mgr.save(step, state)
        assert stats["comp_bytes"] > 0
        saved.append(jax.tree.map(np.asarray, state))
        state = _evolve(state, rng)

    step, tree = mgr.restore_latest()
    assert step == 5
    ref = saved[-1]
    for key in ("w1",):
        got = tree["params"][key]
        want = ref["params"][key]
        rel = np.abs(got - want) / (np.abs(want) + 1e-12)
        assert np.median(rel) <= 2e-3          # lossy within bound
    # exempt tensors are exact
    np.testing.assert_array_equal(tree["params"]["norm"]["scale"],
                                  ref["params"]["norm"]["scale"])
    np.testing.assert_array_equal(tree["opt"]["step"], ref["opt"]["step"])


def test_save_restore_with_device_rans_codec(tmp_path, monkeypatch):
    """The checkpoint driver rides encode_device/finalize, so
    params.codec="rans" routes its deltas through the device entropy
    stage; files round-trip bit-identically to the zlib-coded manager."""
    from repro.kernels import rans
    monkeypatch.setattr(rans, "DEVICE_MIN_BYTES", 0)
    trees = {}
    for codec in ("zlib", "rans"):
        d = os.path.join(str(tmp_path), codec)
        mgr = CheckpointManager(d, anchor_every=3, keep=10,
                                params=NumarckParams(error_bound=1e-3,
                                                     block_bytes=4096,
                                                     codec=codec))
        rng = np.random.default_rng(4)
        state = _fake_state(jax.random.PRNGKey(4))
        for step in range(5):
            mgr.save(step, state)
            state = _evolve(state, rng)
        step, tree = mgr.restore_latest()
        assert step == 4
        trees[codec] = tree
    # entropy codecs are lossless: restored trees are bit-identical
    a = jax.tree.leaves(trees["zlib"])
    b = jax.tree.leaves(trees["rans"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_restore_with_template_preserves_structure(tmp_path):
    mgr = CheckpointManager(str(tmp_path), anchor_every=2)
    state = _fake_state(jax.random.PRNGKey(1))
    mgr.save(0, state)
    step, tree = mgr.restore_latest(template=state)
    assert step == 0
    assert jax.tree_util.tree_structure(tree) == \
        jax.tree_util.tree_structure(state)
    assert tree["big"].dtype == np.asarray(state["big"]).dtype


def test_corruption_fallback(tmp_path):
    mgr = CheckpointManager(str(tmp_path), anchor_every=1, keep=10)
    rng = np.random.default_rng(2)
    state = _fake_state(jax.random.PRNGKey(2))
    for step in range(3):
        mgr.save(step, state)
        state = _evolve(state, rng)
    # corrupt the newest checkpoint file
    newest = os.path.join(str(tmp_path), "step_00000002.nck")
    with open(newest, "r+b") as f:
        f.seek(0)
        f.write(b"garbage!")
    mgr2 = CheckpointManager(str(tmp_path))
    step, tree = mgr2.restore_latest()
    assert step == 1                      # walked back past the corruption


def test_delta_compression_beats_lossless(tmp_path):
    """Temporal deltas should compress better than repeated anchors."""
    p = NumarckParams(error_bound=1e-3, block_bytes=8192)
    mgr = CheckpointManager(str(tmp_path), anchor_every=100, keep=100,
                            params=p)
    rng = np.random.default_rng(3)
    state = {"w": jax.random.normal(jax.random.PRNGKey(3), (256, 256))}
    s0 = mgr.save(0, state)
    state = _evolve(state, rng)
    s1 = mgr.save(1, state)
    assert s1["comp_bytes"] < s0["comp_bytes"] * 0.6, (
        s0["comp_bytes"], s1["comp_bytes"])


def test_retention_keeps_chain(tmp_path):
    mgr = CheckpointManager(str(tmp_path), anchor_every=3, keep=2)
    rng = np.random.default_rng(4)
    state = _fake_state(jax.random.PRNGKey(4))
    for step in range(8):
        mgr.save(step, state)
        state = _evolve(state, rng)
    with open(os.path.join(str(tmp_path), "MANIFEST.json")) as f:
        m = json.load(f)
    # newest two steps restorable => all files from their anchor onward exist
    step, tree = CheckpointManager(str(tmp_path)).restore_latest()
    assert step == 7
    assert all(os.path.exists(os.path.join(str(tmp_path),
                                           f"step_{s:08d}.nck"))
               for s in m["steps"])


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = _fake_state(jax.random.PRNGKey(5))
    fut = mgr.save(0, state)
    stats = fut.result()                  # async saves return a Future
    assert stats["comp_bytes"] > 0
    mgr.wait()
    step, _ = mgr.restore_latest()
    assert step == 0


def test_async_save_double_buffered(tmp_path):
    """Several overlapping async saves land in order and all restore."""
    mgr = CheckpointManager(str(tmp_path), async_save=True, anchor_every=2,
                            keep=10)
    rng = np.random.default_rng(6)
    state = _fake_state(jax.random.PRNGKey(6))
    futs = []
    for step in range(5):
        futs.append(mgr.save(step, state))     # never more than 2 in flight
        state = _evolve(state, rng)
    mgr.wait()
    assert all(f.done() for f in futs)
    anchors = [f.result()["anchor"] for f in futs]
    assert anchors == [True, False, True, False, True]  # cadence preserved
    step, _ = mgr.restore_latest()
    assert step == 4
    with open(os.path.join(str(tmp_path), "MANIFEST.json")) as f:
        m = json.load(f)
    assert m["steps"] == [0, 1, 2, 3, 4]


def test_async_save_mutation_after_submit_is_safe(tmp_path):
    """The caller may mutate numpy state right after save() returns."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    arr = np.random.default_rng(7).normal(size=(64, 64)).astype(np.float32)
    want = arr.copy()
    mgr.save(0, {"w": arr})
    arr[:] = -1.0                         # simulate the next optimizer step
    mgr.wait()
    _, tree = mgr.restore_latest()
    np.testing.assert_array_equal(tree["w"], want)


def test_crashed_save_never_committed_to_manifest(tmp_path, monkeypatch):
    """A save that dies mid-write must leave the manifest untouched: the
    manifest is only updated after the .nck rename, so a crash can never
    publish a half-written step."""
    from repro.core import container

    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = _fake_state(jax.random.PRNGKey(8))
    mgr.save(0, state)
    mgr.wait()

    real_write = container.NCKWriter.write

    def dying_write(self, path):
        # leave a torn file at the final path, as a kill -9 mid-write would
        with open(path, "wb") as f:
            f.write(b"NCK1\x00torn")
        raise RuntimeError("simulated crash during checkpoint write")

    monkeypatch.setattr(container.NCKWriter, "write", dying_write)
    fut = mgr.save(1, state)
    with pytest.raises(RuntimeError, match="simulated crash"):
        fut.result()
    monkeypatch.setattr(container.NCKWriter, "write", real_write)

    with open(os.path.join(str(tmp_path), "MANIFEST.json")) as f:
        m = json.load(f)
    assert m["steps"] == [0]              # step 1 never committed
    mgr2 = CheckpointManager(str(tmp_path))
    step, _ = mgr2.restore_latest()
    assert step == 0                      # torn file is invisible to restore

    # the delta chain survives the failed save: the manager's in-memory
    # reference state only commits after a durable write, so the NEXT save
    # encodes against the last persisted step, not the ghost step 1
    rng = np.random.default_rng(9)
    state2 = _evolve(state, rng)
    # the queue surfaces the failed background save once more on the next
    # interaction (fail-loudly for callers that ignored the Future) ...
    with pytest.raises(RuntimeError, match="simulated crash"):
        mgr.save(1, state2)
    # ... then the retry goes through
    mgr.save(1, state2).result()
    step, tree = mgr.restore_latest()
    assert step == 1
    want = np.asarray(state2["params"]["w1"])
    got = np.asarray(tree["params"]["w1"])
    rel = np.abs(got - want) / (np.abs(want) + 1e-12)
    assert np.median(rel) <= 2e-3         # chained off step 0, within bound
