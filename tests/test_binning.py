"""Binning strategies + DP oracle properties."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:             # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core import binning, dp_oracle, ratios

RNG = np.random.default_rng(0)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(min_value=-1, max_value=1, allow_nan=False,
                          width=32), min_size=1, max_size=9),
       st.integers(min_value=1, max_value=3),
       st.sampled_from([0.05, 0.2, 0.5]))
def test_dp_matches_brute_force(values, k, width):
    vals = np.asarray(values)
    assert dp_oracle.dp_max_coverage(vals, width, k) == \
        dp_oracle.brute_force_max_coverage(vals, width, k)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_dp_is_an_upper_bound_for_topk(seed):
    """No strategy covers more than the DP optimum (paper's proof claim)."""
    rng = np.random.default_rng(seed)
    vals = np.concatenate([rng.normal(0, 0.01, 300),
                           rng.normal(0.05, 0.005, 100)])
    E = 1e-3
    k = 15
    best = dp_oracle.dp_max_coverage(vals, 2 * E, k)

    max_bins = 4096
    v = jnp.asarray(vals, jnp.float32)
    ok = jnp.ones(vals.size, bool)
    lo, hi = float(vals.min()), float(vals.max())
    dlo, w = ratios.histogram_domain(jnp.float32(lo), jnp.float32(hi), E,
                                     max_bins)
    ids, okb = ratios.candidate_bin_ids(v, ok, dlo, w, max_bins)
    counts = binning.local_histogram(ids, okb, max_bins)
    cd, idd = binning.sort_histogram(counts)
    covered_topk = int(np.asarray(cd)[:k].sum())
    assert covered_topk <= best
    # and top-k with aligned bins is near-optimal (paper Figs. 13/14)
    assert covered_topk >= 0.8 * best


def test_dp_select_bins_consistent():
    vals = RNG.normal(0, 0.02, 500)
    cov, starts = dp_oracle.dp_select_bins(vals, 0.002, 10)
    assert cov == dp_oracle.dp_max_coverage(vals, 0.002, 10)
    assert len(starts) <= 10
    # windows anchored at the returned starts actually cover `cov` points
    total = 0
    sv = np.sort(vals)
    for s in starts:
        total += int(((sv >= s) & (sv <= s + 0.002)).sum())
    assert total == cov


def test_strategy_quality_ordering():
    """equal <= log <= topk coverage on clustered ratios (paper Sec. V-D)."""
    rng = np.random.default_rng(1)
    vals = np.concatenate([rng.normal(0.0, 5e-4, 5000),
                           rng.normal(0.08, 1e-3, 2000),
                           rng.uniform(-2, 2, 300)])
    E, k, max_bins = 1e-3, 63, 8192
    v = jnp.asarray(vals, jnp.float32)
    ok = jnp.ones(vals.size, bool)
    dlo, w = ratios.histogram_domain(jnp.float32(vals.min()),
                                     jnp.float32(vals.max()), E, max_bins)
    ids, okb = ratios.candidate_bin_ids(v, ok, dlo, w, max_bins)
    counts = binning.local_histogram(ids, okb, max_bins)
    cd, idd = binning.sort_histogram(counts)
    cs_topk, _ = binning.topk_centers(idd, k, dlo, w)
    def cov(cs):
        return dp_oracle.coverage_of_centers(vals, np.asarray(cs), E)
    cov_topk = cov(cs_topk)
    cov_equal = cov(binning.equal_width_centers(float(vals.min()),
                                                float(vals.max()), k))
    cov_log = cov(binning.log_scale_centers(v, ok, k))
    assert cov_topk >= cov_log >= cov_equal
    assert cov_topk >= 0.9 * vals.size * 0.95  # most points in clusters


def test_kmeans_centers_weighted():
    """k-means centers concentrate where the histogram mass is."""
    counts = jnp.zeros(1024, jnp.int32).at[100:110].set(1000).at[900].set(5)
    cs = binning.kmeans_centers(counts, jnp.float32(0.0), jnp.float32(1.0),
                                8, 20)
    c = np.asarray(cs)
    assert ((c > 99) & (c < 111)).sum() >= 6
