"""Entropy stage: codec registry, parallel finalize, codec persistence."""
import os

import numpy as np
import pytest

from repro.core import (NCKReader, NCKWriter, NumarckParams, codec_names,
                        compress_series, compress_step, decompress_series,
                        decompress_step, get_codec, make_anchor,
                        mean_error_rate)
from repro.core import entropy
from repro.core.compress import decode_anchor

RNG = np.random.default_rng(11)
CODECS = ["zlib", "raw", "lzma", "bz2", "rans"]


def _series(shape=(96, 40), steps=4, vol=0.01, dtype=np.float32):
    base = RNG.normal(1.0, 0.5, shape).astype(dtype)
    out = [base]
    for _ in range(steps - 1):
        out.append((out[-1] * (1 + vol * RNG.standard_normal(shape)))
                   .astype(dtype))
    return out


def test_registry_contents():
    assert set(CODECS) <= set(codec_names())
    for name in CODECS:
        c = get_codec(name)
        blob = c.compress(b"hello entropy stage" * 100, 6)
        assert c.decompress(blob) == b"hello entropy stage" * 100
    with pytest.raises(ValueError):
        get_codec("snappy")


def test_unknown_codec_rejected_by_params():
    with pytest.raises(ValueError):
        NumarckParams(codec="nope")


@pytest.mark.parametrize("codec", CODECS)
def test_round_trip_every_codec(codec):
    series = _series()
    p = NumarckParams(error_bound=1e-3, codec=codec)
    steps = compress_series(series, p)
    assert all(s.codec == codec for s in steps)
    recon = decompress_series(steps)
    for orig, rec in zip(series, recon):
        assert mean_error_rate(orig, rec) <= 1e-3 * 1.01


def test_parallel_finalize_byte_identical():
    """Thread-pool dispatch must not change a single byte of any blob."""
    raws = [RNG.integers(0, 50, 1 << 16).astype(np.uint8).tobytes()
            for _ in range(64)]
    for codec in ("zlib", "raw", "bz2"):
        serial = entropy.compress_blocks(raws, codec=codec, parallel=False)
        parallel = entropy.compress_blocks(raws, codec=codec, parallel=True)
        assert serial == parallel
        for raw, blob in zip(raws, serial):
            assert entropy.decompress_block(blob, codec) == raw


def test_parallel_step_equals_serial_step():
    series = _series(shape=(512, 130))
    prev, curr = series[0], series[1]
    a = compress_step(prev, curr, NumarckParams(parallel_entropy=False,
                                                block_bytes=2048))
    b = compress_step(prev, curr, NumarckParams(parallel_entropy=True,
                                                block_bytes=2048))
    assert a.index_blocks == b.index_blocks
    np.testing.assert_array_equal(a.centers, b.centers)
    np.testing.assert_array_equal(a.incomp_values, b.incomp_values)


@pytest.mark.parametrize("codec", CODECS)
def test_container_round_trips_codec(tmp_path, codec):
    series = _series()
    p = NumarckParams(error_bound=1e-3, codec=codec, block_bytes=4096)
    steps = compress_series(series, p)
    path = os.path.join(tmp_path, f"{codec}.nck")
    w = NCKWriter()
    for i, st in enumerate(steps):
        w.add_step(f"v_it{i:05d}", st)
    w.write(path)

    r = NCKReader(path)
    prev = None
    for i, orig_step in enumerate(steps):
        st = r.read_step(f"v_it{i:05d}")
        assert st.codec == codec
        rec_file = decompress_step(st, prev)
        rec_mem = decompress_step(orig_step, prev)
        np.testing.assert_array_equal(rec_file, rec_mem)  # bit-exact
        prev = rec_file


def test_legacy_header_defaults_to_zlib(tmp_path):
    """Files written before the codec field existed must load as zlib."""
    series = _series(steps=2)
    steps = compress_series(series, NumarckParams())
    path = os.path.join(tmp_path, "legacy.nck")
    w = NCKWriter()
    w.add_step("v", steps[1])
    # simulate a pre-codec writer by stripping the attribute
    del w._vars["v_info"]["attributes"]["codec"]
    w.write(path)
    st = NCKReader(path).read_step("v")
    assert st.codec == "zlib"
    np.testing.assert_array_equal(decompress_step(st, series[0]),
                                  decompress_step(steps[1], series[0]))


def test_overlapped_series_identical_to_serial():
    series = _series(steps=6)
    p = NumarckParams(error_bound=1e-3)
    serial = compress_series(series, p, overlap=False)
    overlapped = compress_series(series, p, overlap=True)
    assert len(serial) == len(overlapped)
    for a, b in zip(serial, overlapped):
        assert a.index_blocks == b.index_blocks
        assert a.b_bits == b.b_bits
        np.testing.assert_array_equal(a.centers, b.centers)
        np.testing.assert_array_equal(a.incomp_values, b.incomp_values)
        np.testing.assert_array_equal(a.incomp_block_offsets,
                                      b.incomp_block_offsets)


@pytest.mark.parametrize("codec", ["zlib", "raw"])
def test_tiny_and_empty_arrays(codec):
    p = NumarckParams(error_bound=1e-3, codec=codec)
    # single-element series round-trips through anchor + delta
    tiny = [np.array([1.25], np.float32), np.array([1.27], np.float32)]
    rec = decompress_series(compress_series(tiny, p))
    assert abs(rec[1][0] - 1.27) <= 1.27 * 1e-3 * 1.01
    # empty anchor survives the entropy stage
    empty = np.zeros((0,), np.float32)
    st = make_anchor(empty, p)
    assert st.codec == codec
    assert decode_anchor(st).size == 0


def test_auto_codec_policy():
    """choose_codec picks from the measured prefix compressibility."""
    rnd = np.random.default_rng(3).integers(0, 256, 1 << 16)
    assert entropy.choose_codec([rnd.astype(np.uint8).tobytes()]) == "raw"
    assert entropy.choose_codec([b"\x00" * (1 << 16)]) == "lzma"
    # mid-entropy payload stays on the fast default
    mid = np.random.default_rng(4).integers(0, 17, 1 << 16)
    assert entropy.choose_codec([mid.astype(np.uint8).tobytes()]) == "zlib"
    # empty payload: never crash, fall back to the default codec
    assert entropy.choose_codec([]) == entropy.DEFAULT_CODEC
    assert entropy.choose_codec([b""]) == entropy.DEFAULT_CODEC


def test_auto_codec_round_trip_through_container(tmp_path):
    """codec="auto" resolves per step; the NCK container persists the
    concrete pick and readers decompress without ever seeing "auto"."""
    series = _series(steps=4)
    p = NumarckParams(error_bound=1e-3, codec="auto", block_bytes=4096)
    steps = compress_series(series, p)
    assert all(s.codec != "auto" for s in steps)
    assert all(s.codec in codec_names() for s in steps)

    path = os.path.join(tmp_path, "auto.nck")
    w = NCKWriter()
    for i, st in enumerate(steps):
        w.add_step(f"v_it{i:05d}", st)
    w.write(path)
    r = NCKReader(path)
    prev = None
    for i, orig in enumerate(steps):
        st = r.read_step(f"v_it{i:05d}")
        assert st.codec == orig.codec
        prev = decompress_step(st, prev)
    assert mean_error_rate(series[-1], prev) <= 1e-3 * 1.01

    # an incompressible series resolves to raw on the anchor
    noise = np.frombuffer(np.random.default_rng(9).integers(
        0, 256, 1 << 16).astype(np.uint8).tobytes(), np.uint8)
    st = make_anchor(noise, NumarckParams(codec="auto"))
    assert st.codec == "raw"
    np.testing.assert_array_equal(decode_anchor(st), noise)


def test_auto_codec_accepted_by_params_but_never_persisted():
    p = NumarckParams(codec="auto")
    assert p.codec == "auto"              # parameter keeps the pseudo-id
    with pytest.raises(ValueError):
        entropy.get_codec("auto")         # registry never resolves it


class _GilBoundCodec(entropy.Codec):
    """Pure-python codec (holds the GIL): exercises the process-pool
    dispatch path.  Module level so forked workers can unpickle tasks."""

    name = "_test_gil_xor"
    holds_gil = True

    def compress(self, raw: bytes, level: int) -> bytes:
        return bytes(b ^ 0xA5 for b in raw)

    def decompress(self, blob: bytes) -> bytes:
        return bytes(b ^ 0xA5 for b in blob)


def test_gil_holding_codec_process_pool_dispatch():
    """GIL-holding codecs go through the forked process pool (or its
    serial fallback) and stay byte-identical to the serial loop."""
    entropy.register_codec(_GilBoundCodec())
    raws = [np.random.default_rng(i).integers(0, 256, 1 << 19)
            .astype(np.uint8).tobytes() for i in range(8)]
    serial = entropy.compress_blocks(raws, codec="_test_gil_xor",
                                     parallel=False)
    parallel = entropy.compress_blocks(raws, codec="_test_gil_xor",
                                       parallel=True)
    assert serial == parallel
    for raw, blob in zip(raws, serial):
        assert entropy.decompress_block(blob, "_test_gil_xor") == raw


def test_serve_cache_snapshot_round_trip(tmp_path):
    from repro.serve.engine import load_cache, snapshot_cache
    cache = {"layer0": {"k": RNG.normal(size=(2, 8, 4)).astype(np.float32),
                        "v": RNG.normal(size=(2, 8, 4)).astype(np.float32)},
             "pos": np.arange(8, dtype=np.int32)}
    path = os.path.join(tmp_path, "session.nck")
    stats = snapshot_cache(cache, path, codec="zlib")
    assert stats["orig_bytes"] > 0
    out = load_cache(path)
    np.testing.assert_array_equal(out["layer0"]["k"], cache["layer0"]["k"])
    np.testing.assert_array_equal(out["layer0"]["v"], cache["layer0"]["v"])
    np.testing.assert_array_equal(out["pos"], cache["pos"])
    # template-shaped restore
    out2 = load_cache(path, template=cache)
    np.testing.assert_array_equal(out2["pos"], cache["pos"])
