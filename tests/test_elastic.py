"""Elastic restore: checkpoint -> different mesh, values preserved."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from jax.sharding import Mesh
    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.elastic import restore_elastic, reshard_tree
    from repro.configs import get_smoke_config
    from repro.models.model import Model
    from repro.core import NumarckParams

    cfg = get_smoke_config("llama3.2-1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, params=NumarckParams(error_bound=1e-4))
        mgr.save(0, {"params": params})

        # "new fleet": 4x2 mesh (as if we lost half the chips)
        mesh = Mesh(np.array(jax.devices()).reshape(4, 2),
                    ("data", "model"))
        template = {"params": jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))}
        out = restore_elastic(CheckpointManager(d), template,
                              cfg, mesh)
        assert out is not None
        step, tree = out
        assert step == 0
        # values round-trip (anchor step 0 is lossless)
        ref = jax.tree.leaves(params)
        got = jax.tree.leaves(tree["params"])
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # and every leaf is actually addressable on the new mesh
        for leaf in got:
            assert len(leaf.sharding.device_set) >= 1
    print("OK")
""")


@pytest.mark.slow
def test_elastic_restore_new_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
