"""Sharding rules: param/cache PartitionSpecs in a 4x2 test mesh."""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.distributed import sharding as shd
    from repro.configs import get_smoke_config
    from repro.models.model import Model
    import dataclasses

    mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))

    # ---- dense arch: TP on heads/ffn, FSDP on d_model ------------------
    cfg = get_smoke_config("llama3.2-1b")
    # smoke: d=64, H=4, K=2, hd=16, ff=128, vocab=256
    m = Model(cfg)
    specs = shd.param_specs(m.shape_params(), cfg, mesh)
    assert specs["embed"] == P("model", "data"), specs["embed"]
    lay = specs["layers"]
    assert lay["attn"]["wq"] == P(None, "data", "model", None)
    assert lay["attn"]["wk"] == P(None, "data", "model", None)
    assert lay["attn"]["wo"] == P(None, "model", None, "data")
    assert lay["mlp"]["w_gate"] == P(None, "data", "model")
    assert lay["mlp"]["w_down"] == P(None, "model", "data")

    # ---- MoE with ep split: expert slots sharded over model -------------
    cfg_m = dataclasses.replace(get_smoke_config("mixtral-8x7b"),
                                n_experts=4, moe_ep_split=1)
    # slots = 4 >= ... not ep (needs >= 16) -> TP fallback inside expert
    m2 = Model(cfg_m)
    sp2 = shd.param_specs(m2.shape_params(), cfg_m, mesh)
    assert sp2["layers"]["mlp"]["we_gate"] == P(None, None, "data", "model")
    cfg_m2 = dataclasses.replace(cfg_m, n_experts=16, moe_top_k=2)
    m3 = Model(cfg_m2)
    sp3 = shd.param_specs(m3.shape_params(), cfg_m2, mesh)
    assert sp3["layers"]["mlp"]["we_gate"] == P(None, "model", "data", None)

    # ---- cache specs: kv-head fallback to head_dim -----------------------
    cache = {
        "k": jax.ShapeDtypeStruct((8, 64, 3, 16), jnp.bfloat16),  # K=3 !%2
        "v": jax.ShapeDtypeStruct((8, 64, 3, 16), jnp.bfloat16),
        "pos_map": jax.ShapeDtypeStruct((64,), jnp.int32),
    }
    cs = shd.cache_specs(cache, mesh)
    assert cs["k"] == P("data", None, None, "model"), cs["k"]   # hd fallback
    cache2 = {"k": jax.ShapeDtypeStruct((8, 64, 4, 16), jnp.bfloat16)}
    cs2 = shd.cache_specs(cache2, mesh)
    assert cs2["k"] == P("data", None, "model", None)           # K divides

    # batch=1 -> replicated
    cache3 = {"k": jax.ShapeDtypeStruct((1, 64, 4, 16), jnp.bfloat16)}
    assert shd.cache_specs(cache3, mesh)["k"] == P(None, None, "model",
                                                   None)
    print("OK")
""")


@pytest.mark.slow
def test_sharding_rules():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "OK" in res.stdout
