"""Training loop integration: loss decreases, checkpoint restart resumes
bit-deterministically, grad compression converges."""
import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import NumarckParams
from repro.data.tokens import TokenPipeline
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.train import optim
from repro.train.trainer import Trainer, TrainerConfig


def tiny_model():
    return Model(ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
        dtype="float32"))


def pipeline(model, B=8, S=32, seed=0):
    return TokenPipeline(model.cfg.vocab_size, S + 1, B, seed=seed)


def test_loss_decreases():
    model = tiny_model()
    tcfg = TrainerConfig(opt=optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                                               decay_steps=60))
    tr = Trainer(model, tcfg)
    state = tr.init_state(jax.random.PRNGKey(0))
    state, step, hist = tr.fit(state, iter(pipeline(model)), n_steps=60,
                               log=lambda *_: None)
    first = float(np.mean(hist[:5]))
    last = float(np.mean(hist[-5:]))
    assert last < first - 0.3, (first, last)


def test_restart_resumes_from_checkpoint(tmp_path):
    model = tiny_model()
    tcfg = TrainerConfig(opt=optim.AdamWConfig(lr=1e-3, warmup_steps=2,
                                               decay_steps=50),
                         checkpoint_every=5)
    pipe = pipeline(model)

    mgr = CheckpointManager(str(tmp_path),
                            params=NumarckParams(error_bound=1e-4),
                            anchor_every=2, keep=5)
    tr = Trainer(model, tcfg, checkpoint_manager=mgr)
    state = tr.init_state(jax.random.PRNGKey(1))
    state, step, hist = tr.fit(state, iter(pipe), n_steps=10,
                               log=lambda *_: None)
    assert step == 10

    # simulate a crash: new trainer restores from checkpoint + resumes the
    # deterministic data stream at the restored step
    mgr2 = CheckpointManager(str(tmp_path))
    tr2 = Trainer(model, tcfg, checkpoint_manager=mgr2)
    state2, start = tr2.restore_or_init(jax.random.PRNGKey(99))
    assert start == 10
    state2, step2, hist2 = tr2.fit(state2, pipe.from_step(start),
                                   start_step=start, n_steps=15,
                                   log=lambda *_: None)
    assert step2 == 15
    assert np.isfinite(hist2).all()
    # restored loss should continue from where training left off, not from
    # scratch (checkpoint error bound 1e-4 keeps the trajectory close)
    assert hist2[0] < hist[0], (hist2[0], hist[0])


def test_grad_compression_converges():
    model = tiny_model()
    tcfg = TrainerConfig(opt=optim.AdamWConfig(lr=3e-3, warmup_steps=5,
                                               decay_steps=60),
                         grad_compression_bits=6)
    tr = Trainer(model, tcfg)
    state = tr.init_state(jax.random.PRNGKey(0))
    state, step, hist = tr.fit(state, iter(pipeline(model)), n_steps=60,
                               log=lambda *_: None)
    assert float(np.mean(hist[-5:])) < float(np.mean(hist[:5])) - 0.25


def test_gradcomp_error_feedback_unbiased():
    """Error feedback: the accumulated residual keeps the quantizer's
    long-run bias near zero."""
    from repro.train import gradcomp
    rng = np.random.default_rng(0)
    g_true = rng.normal(0, 1e-2, (512,)).astype(np.float32)
    state = gradcomp.init_state({"g": g_true})
    applied = np.zeros_like(g_true)
    for _ in range(20):
        g_hat, state = gradcomp.compress_grads({"g": g_true}, state,
                                               b_bits=4)
        applied += np.asarray(g_hat["g"])
    bias = np.abs(applied / 20 - g_true).mean() / np.abs(g_true).mean()
    assert bias < 0.05, bias


def test_deterministic_pipeline_restart():
    pipe = TokenPipeline(128, 33, 4, seed=7)
    b5a = pipe.batch(5)
    b5b = TokenPipeline(128, 33, 4, seed=7).batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
