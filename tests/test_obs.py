"""Telemetry layer (src/repro/obs): span semantics, disabled-path cost,
Chrome-trace export, per-step rollup stability and the overlap/queue
metrics -- plus the invariant the whole subsystem hangs on: telemetry
NEVER changes pipeline outputs (blobs byte-identical on vs off)."""
import json
import os
import sys
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import (NumarckParams, compress_series,
                        decompress_series)
from repro.core import entropy
from repro.core.overlap import FinalizeQueue, _attach_context
from repro.core.pipeline import StepMeta
from repro.obs import report, telemetry, trace
from repro.obs.report import STEP_TELEMETRY_KEYS

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

P = NumarckParams(error_bound=1e-3, max_bins=1024, block_bytes=512)


@pytest.fixture(autouse=True)
def _telemetry_off():
    """Tests must never leak an enabled registry into each other."""
    telemetry.stop()
    yield
    telemetry.stop()


def _series(n_steps=4, n=4096, seed=0):
    rng = np.random.default_rng(seed)
    out = [rng.normal(size=n).astype(np.float32)]
    for _ in range(n_steps - 1):
        out.append(out[-1]
                   + rng.normal(scale=1e-4, size=n).astype(np.float32))
    return out


def _blob_sig(steps):
    """Everything that lands in the NCK container, as comparable bytes."""
    return [(s.b_bits, s.codec, tuple(s.block_codecs or ()),
             tuple(s.index_blocks),
             b"" if s.incomp_values is None else s.incomp_values.tobytes())
            for s in steps]


# ---------------------------------------------------------------- spans

def test_span_nesting_depth_and_attrs():
    with telemetry.capture() as reg:
        with telemetry.span("a", step=1) as sa:
            with telemetry.span("b"):
                with telemetry.span("c") as sc:
                    sc.set(late=42)
            sa.set(bytes_out=7)
    recs = {r.name: r for r in reg.spans}
    assert [recs[n].depth for n in "abc"] == [0, 1, 2]
    # children close before (and inside) their parent
    assert recs["a"].t0 <= recs["b"].t0 <= recs["c"].t0
    assert recs["c"].t1 <= recs["b"].t1 <= recs["a"].t1
    # late-set attributes are recorded
    assert recs["a"].attrs == {"step": 1, "bytes_out": 7}
    assert recs["c"].attrs == {"late": 42}
    assert all(r.duration >= 0.0 for r in reg.spans)


def test_span_stack_is_thread_local():
    """Nesting depth is per thread: a worker span opened while the main
    thread holds a span open starts at depth 0 on its own lane."""
    with telemetry.capture() as reg:
        def worker():
            with telemetry.span("w.outer"):
                with telemetry.span("w.inner"):
                    pass
        with telemetry.span("main.outer"):
            t = threading.Thread(target=worker, name="obs-worker")
            t.start()
            t.join()
    recs = {r.name: r for r in reg.spans}
    assert recs["w.outer"].depth == 0
    assert recs["w.inner"].depth == 1
    assert recs["main.outer"].depth == 0
    assert recs["w.outer"].tid != recs["main.outer"].tid
    assert recs["w.inner"].tname == "obs-worker"


def test_span_error_recorded_and_propagates():
    with telemetry.capture() as reg:
        with pytest.raises(ValueError, match="boom"):
            with telemetry.span("failing"):
                raise ValueError("boom")
        # the stack unwound: a follow-up span is back at depth 0
        with telemetry.span("after"):
            pass
    recs = {r.name: r for r in reg.spans}
    assert recs["failing"].error == "ValueError: boom"
    assert recs["after"].depth == 0
    assert report.rollup(reg)["spans"]["failing"]["errors"] == 1


def test_capture_scoping():
    assert not telemetry.enabled()
    with telemetry.capture() as reg:
        assert telemetry.enabled() and telemetry.active() is reg
    assert not telemetry.enabled()
    assert telemetry.stop() is None


# ------------------------------------------------------- disabled path

def test_disabled_returns_shared_noop():
    assert not telemetry.enabled()
    assert telemetry.span("x") is telemetry.NOOP_SPAN
    assert telemetry.span("y", annotate=True, k=1) is telemetry.NOOP_SPAN
    assert telemetry.NOOP_SPAN.set(a=1) is telemetry.NOOP_SPAN
    assert telemetry.NOOP_SPAN.duration == 0.0
    # counters/gauges/hists fall through without touching a registry
    telemetry.counter("n"), telemetry.gauge("g", 1.0), telemetry.histo("h", 1.0)


def test_disabled_overhead_is_negligible():
    """The instrumentation left in the hot paths must cost ~nothing while
    disabled: per-callsite cost far under a percent of one small step."""
    assert not telemetry.enabled()
    N = 20_000

    def loop():
        t0 = time.perf_counter()
        for _ in range(N):
            with telemetry.span("hot"):
                pass
            telemetry.counter("hot.n")
            telemetry.gauge("hot.g", 1.0)
        return (time.perf_counter() - t0) / (3 * N)

    per_call = min(loop() for _ in range(3))         # best-of-3 vs noise
    series = _series()
    compress_series(series, P)                       # warm the jit caches
    t0 = time.perf_counter()
    steps = compress_series(series, P)
    step_s = (time.perf_counter() - t0) / len(series)
    assert steps[-1].meta.get("telemetry") is None   # really disabled
    # ~a dozen callsites per step; assert 100x that against 5% of a step
    assert 100 * per_call < 0.05 * step_s, (
        f"disabled telemetry too hot: {per_call * 1e9:.0f}ns/call vs "
        f"{step_s * 1e3:.2f}ms/step")


# ------------------------------------------- outputs must never change

def test_blobs_byte_identical_telemetry_on_off():
    series = _series()
    base = compress_series(series, P)
    with telemetry.capture():
        on = compress_series(series, P)
        on_overlap = compress_series(series, P, overlap=True)
    assert _blob_sig(on) == _blob_sig(base)
    assert _blob_sig(on_overlap) == _blob_sig(base)
    # and the instrumented steps reconstruct to exactly the same arrays
    for a, b in zip(decompress_series(on), decompress_series(base)):
        assert np.array_equal(a, b)


# ------------------------------------------------- per-step rollup

def test_step_telemetry_canonical_keys_across_overlap_modes():
    series = _series()
    with telemetry.capture():
        serial = compress_series(series, P, overlap=False)
        overlap = compress_series(series, P, overlap=True)
    for steps in (serial, overlap):
        for st in steps:
            tele = st.meta["telemetry"]
            assert tuple(tele) == STEP_TELEMETRY_KEYS
            assert tele["bytes_in"] > 0 and tele["bytes_out"] > 0
            assert tele["finalize_s"] >= 0.0
    # anchors carry the same key set as delta steps
    assert serial[0].is_anchor and not serial[1].is_anchor
    # the non-timing fields are deterministic across modes
    for a, b in zip(serial, overlap):
        ta, tb = a.meta["telemetry"], b.meta["telemetry"]
        for k in ("bytes_in", "bytes_out", "entropy_ratio", "codec",
                  "device_entropy"):
            assert ta[k] == tb[k]


def test_sharded_driver_same_telemetry_shape_and_blobs():
    """Single-device vs sharded (1-shard mesh in-process): identical
    canonical telemetry keys, byte-identical blobs, on or off."""
    import jax
    from jax.sharding import Mesh
    from repro.distributed.pipeline import ShardedCompressor

    series = _series(n_steps=3)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    base = compress_series(series, P)
    sc = ShardedCompressor(mesh, "data", P, use_pallas=False)
    off = sc.compress_series(series)
    assert _blob_sig(off) == _blob_sig(base)
    with telemetry.capture():
        on = sc.compress_series(series)
        single = compress_series(series, P)
    assert _blob_sig(on) == _blob_sig(base)
    for st_s, st_d in zip(single, on):
        ts, td = st_s.meta["telemetry"], st_d.meta["telemetry"]
        assert tuple(ts) == tuple(td) == STEP_TELEMETRY_KEYS
    roll_s = report.series_rollup(single)
    roll_d = report.series_rollup(on)
    for k in ("steps", "bytes_in", "bytes_out", "codecs"):
        assert roll_s[k] == roll_d[k]
    sc.close()


def test_series_rollup():
    series = _series()
    with telemetry.capture():
        steps = compress_series(series, P)
    roll = report.series_rollup(steps)
    assert roll["steps"] == len(series)
    assert roll["steps_without_telemetry"] == 0
    # bytes_in is entropy-stage input (anchor raw bytes + packed index
    # bytes per delta step), so it sits between one step's raw size and
    # the whole series' raw size for this well-binned series
    raw = sum(a.nbytes for a in series)
    assert series[0].nbytes <= roll["bytes_in"] <= raw
    assert 0 < roll["bytes_out"] < roll["bytes_in"]
    assert roll["entropy_ratio_mean"] > 1.0
    assert sum(roll["codecs"].values()) == len(series)
    assert all(v >= 0.0 for v in roll["totals"].values())
    # steps compressed with telemetry off are counted, not invented
    plain = compress_series(series, P)
    roll2 = report.series_rollup(plain)
    assert roll2["steps"] == 0
    assert roll2["steps_without_telemetry"] == len(series)


def test_rollup_aggregates():
    series = _series()
    with telemetry.capture() as reg:
        compress_series(series, P)
    roll = report.rollup(reg)
    for name in ("finalize", "finalize.entropy", "finalize.anchor",
                 "encode.analyze", "encode.index", "entropy.compress"):
        assert name in roll["spans"], sorted(roll["spans"])
    fin = roll["spans"]["finalize"]
    assert fin["count"] == len(series) - 1          # anchor has its own span
    assert fin["total_s"] >= fin["max_s"] >= fin["mean_s"] >= 0.0
    assert any(k.startswith("entropy.bytes_in.") for k in roll["counters"])


# -------------------------------------------------------- chrome trace

def test_chrome_trace_json_valid_with_pool_lanes(tmp_path):
    rng = np.random.default_rng(1)
    with telemetry.capture() as reg:
        compress_series(_series(), P, overlap=True)
        # drive the shared entropy pool directly: > _MIN_PARALLEL_BYTES
        raws = [rng.integers(0, 8, 1 << 19, dtype=np.uint8).tobytes()
                for _ in range(8)]
        entropy.compress_blocks(raws, codec="zlib", parallel=True)
    path = trace.write_chrome_trace(str(tmp_path / "trace.json"), reg)
    with open(path) as f:
        doc = json.load(f)                           # valid JSON
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    assert xs, "no span events"
    for e in xs:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
        assert e["ts"] >= 0.0 and e["dur"] >= 0.0
        json.dumps(e["args"])                        # attrs all jsonable
    lanes = {e["args"]["name"] for e in events if e["ph"] == "M"}
    assert any(n.startswith("finalize") for n in lanes), lanes
    assert any(n.startswith("entropy") for n in lanes), lanes
    assert any(n.startswith("MainThread") for n in lanes), lanes
    # the FinalizeQueue depth gauge exports as counter events
    counters = {e["name"] for e in events if e["ph"] == "C"}
    assert "finalize.depth" in counters
    assert doc["otherData"]["counters"]


# ------------------------------------------------ overlap queue metrics

def test_finalize_queue_metrics():
    with telemetry.capture() as reg:
        q = FinalizeQueue(True, name="qq", max_in_flight=1)
        for _ in range(3):
            q.submit(time.sleep, 0.02, label="napping")
        q.close()
    roll = report.rollup(reg)
    assert roll["hists"]["qq.queue_wait_s"]["count"] == 3
    assert roll["gauges"]["qq.depth"]["max"] == 1.0
    assert roll["counters"]["qq.stall_s"] > 0.0      # bound forced a stall
    assert roll["spans"]["qq.task"]["count"] == 3
    assert roll["spans"]["qq.flush"]["count"] >= 1


@pytest.mark.parametrize("overlap", [False, True])
def test_finalize_queue_exception_context(overlap):
    def explode(i):
        raise ValueError(f"bad step data {i}")

    q = FinalizeQueue(overlap, name="shard-finalize")
    with telemetry.capture() as reg:
        f = q.submit(explode, 7, label="finalize step 7")
        # original message stays a prefix: match= keeps working
        with pytest.raises(ValueError, match="^bad step data 7") as ei:
            if overlap:
                q.flush()
            else:
                f.result()
        q.close()
    # the worker/stage/step context rides in the message ...
    assert "[shard-finalize worker: finalize step 7]" in str(ei.value)
    assert ei.value.args[0].startswith("bad step data 7")
    # ... and the failure is recorded on the task span
    assert report.rollup(reg)["spans"]["shard-finalize.task"]["errors"] == 1


def test_exception_context_attached_once():
    e = ValueError("boom")
    _attach_context(e, "finalize", "finalize step 2")
    _attach_context(e, "finalize", "finalize step 2")   # resurfaced future
    assert str(e).count("[finalize worker: finalize step 2]") == 1


# ------------------------------------------------ zlib_ratio deprecation

def test_zlib_ratio_alias_warns_once():
    series = _series(n_steps=2)
    steps = compress_series(series, P)
    meta = steps[1].meta
    assert isinstance(meta, StepMeta)
    StepMeta._warned = False                 # order-independence
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert meta["zlib_ratio"] == meta["entropy_ratio"]
        assert meta.get("zlib_ratio") == meta["entropy_ratio"]
        steps[1].meta.get("zlib_ratio")      # and again via another read
    deps = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(deps) == 1                    # once per process, not per read
    assert "entropy_ratio" in str(deps[0].message)
    # non-alias reads never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        meta["entropy_ratio"], meta.get("entropy_codec")


# ------------------------------------------------ perf regression gate

def test_check_regression_compare():
    if _ROOT not in sys.path:
        sys.path.insert(0, _ROOT)
    from benchmarks.check_regression import compare, parse_cr

    def row(name, us, derived=""):
        return {"name": name, "us_per_call": us, "derived": derived}

    tracked = {"enc": row("enc", 1000.0, "CR=4.00"),
               "tiny": row("tiny", 20.0)}
    # identical -> pass
    assert compare(tracked, dict(tracked), 0.5, 0.05, 100.0) == []
    # +40% under a +50% tolerance -> pass; +120% -> fail
    assert compare(tracked, {"enc": row("enc", 1400.0, "CR=4.00")},
                   0.5, 0.05, 100.0) == []
    probs = compare(tracked, {"enc": row("enc", 2200.0, "CR=4.00")},
                    0.5, 0.05, 100.0)
    assert len(probs) == 1 and "enc" in probs[0]
    # sub-min_us rows are noise: never timing-gated
    assert compare(tracked, {"tiny": row("tiny", 900.0)},
                   0.5, 0.05, 100.0) == []
    # CR regressions fail even when timing is fine
    probs = compare(tracked, {"enc": row("enc", 1000.0, "CR=3.00")},
                    0.5, 0.05, 100.0)
    assert len(probs) == 1 and "CR=3.00" in probs[0]
    # a bench that failed to run fails the gate outright
    probs = compare(tracked, {"x_FAILED": row("x_FAILED", 0.0, "boom")},
                    0.5, 0.05, 100.0)
    assert len(probs) == 1 and "failed" in probs[0]
    assert parse_cr("CR=2.50 n=3") == 2.5 and parse_cr("") is None
