"""Analytical cost model vs fully-unrolled HLO FLOPs (exact on small
configs -- validates the roofline numbers in EXPERIMENTS.md)."""
import jax
import pytest

from repro.launch import cost_model
from repro.models.config import ModelConfig
from repro.models.model import Model
from repro.models.unroll import full_unroll
from repro.train import optim


def _small(family="dense", **kw):
    base = dict(
        name="probe", family=family, n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def _train_flops_hlo(cfg, B, S):
    model = Model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(optim.init_state, params)
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jax.numpy.int32),
             "labels": jax.ShapeDtypeStruct((B, S), jax.numpy.int32)}
    ocfg = optim.AdamWConfig()

    def step(p, o, b):
        (loss, _), g = jax.value_and_grad(lambda pp: model.loss(pp, b),
                                          has_aux=True)(p)
        p, o, _ = optim.apply_updates(p, g, o, ocfg)
        return p, o, loss

    with full_unroll():
        compiled = jax.jit(step).lower(params, opt, batch).compile()
    # hlo_flops normalizes the dict-vs-list-of-dicts cost_analysis()
    # return across jax versions (0.4.3x returns a per-platform list)
    return cost_model.hlo_flops(compiled)


def _analytic_train_flops(cfg, B, S):
    # mirror flops_cell but with explicit shapes (not the assigned table)
    import repro.models.config as mc
    saved = dict(mc.SHAPES)
    mc.SHAPES["__probe__"] = dict(kind="train", seq_len=S, global_batch=B)
    try:
        return cost_model.flops_cell(cfg, "__probe__")
    finally:
        mc.SHAPES.clear()
        mc.SHAPES.update(saved)


@pytest.mark.slow
@pytest.mark.parametrize("family,kw", [
    ("dense", {}),
    ("dense", dict(attn_kind="mla", q_lora_rank=32, kv_lora_rank=16,
                   qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
                   n_kv_heads=4)),
    ("moe", dict(n_experts=4, moe_top_k=2)),
    ("ssm", dict(n_heads=0, n_kv_heads=0, d_ff=0, ssm_state=16,
                 ssm_head_dim=16, ssm_chunk=16, attn_kind="none")),
])
def test_analytic_flops_match_unrolled_hlo(family, kw):
    cfg = _small(family=family, **kw)
    B, S = 2, 64
    hlo = _train_flops_hlo(cfg, B, S)
    ana = _analytic_train_flops(cfg, B, S)
    # Adam elementwise ops + norms/softmax are excluded from the analytic
    # model, so allow a modest envelope.  The while-loop bug this guards
    # against is a ~n_layers-fold (2x+) discrepancy.
    assert 0.65 <= ana / hlo <= 1.45, (family, ana, hlo, ana / hlo)


def test_hlo_cost_normalizes_across_jax_versions():
    class FakeCompiled:
        def __init__(self, ret):
            self._ret = ret

        def cost_analysis(self):
            return self._ret

    assert cost_model.hlo_flops(FakeCompiled({"flops": 5.0})) == 5.0
    assert cost_model.hlo_flops(FakeCompiled([{"flops": 7.0}])) == 7.0
    assert cost_model.hlo_flops(FakeCompiled(None)) == 0.0
    assert cost_model.hlo_flops(FakeCompiled([])) == 0.0


def test_flops_scale_linearly_with_layers():
    cfg2 = _small(n_layers=2)
    cfg8 = _small(n_layers=8)
    import repro.models.config as mc
    mc.SHAPES["__p2__"] = dict(kind="train", seq_len=64, global_batch=2)
    try:
        f2 = cost_model.flops_cell(cfg2, "__p2__")
        f8 = cost_model.flops_cell(cfg8, "__p2__")
    finally:
        del mc.SHAPES["__p2__"]
    per_layer = (f8 - f2) / 6
    assert per_layer > 0
    # logits epilogue is the constant part
    assert abs((f2 - 2 * per_layer)
               - (f8 - 8 * per_layer)) / f2 < 1e-6


def test_assigned_cells_have_sane_magnitudes():
    from repro.configs import get_config
    cfg = get_config("llama3.2-1b")
    f = cost_model.flops_cell(cfg, "train_4k")
    # ~3 * 2 * N * D * (impl factor ~2 for full-block attention)
    n, d_tokens = cfg.param_count(), 256 * 4096
    assert 0.8 * 6 * n * d_tokens < f < 6 * 6 * n * d_tokens
